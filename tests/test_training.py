"""Optimizers, gradient compression, fault-tolerant loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.util import tree_bytes
from repro.training.grad_compress import compress_grads, init_state
from repro.training.optimizer import adafactor, adamw


def _quadratic_params():
    return {"w": jnp.asarray(np.random.default_rng(0).normal(size=(16, 8)),
                             jnp.float32),
            "b": jnp.zeros((8,), jnp.float32)}


def _loss(params):
    return jnp.sum((params["w"] - 3.0) ** 2) + jnp.sum((params["b"] + 1) ** 2)


@pytest.mark.parametrize("make_opt", [lambda: adamw(lr=0.05),
                                      lambda: adafactor(lr=0.2)])
def test_optimizer_converges(make_opt):
    opt = make_opt()
    params = _quadratic_params()
    state = opt.init(params)
    first = float(_loss(params))
    for _ in range(300):
        grads = jax.grad(_loss)(params)
        params, state = opt.update(grads, state, params)
    final = float(_loss(params))
    # weight decay shifts the optimum slightly off 0 loss
    assert final < max(0.5, 0.01 * first), (first, final)


def test_adafactor_state_is_factored():
    params = {"big": jnp.zeros((256, 128))}
    af = adafactor().init(params)
    aw = adamw().init(params)
    assert tree_bytes(af) < tree_bytes(aw) / 20


@pytest.mark.parametrize("method,frac,steps,min_ratio,max_loss",
                         [("int8", 0.0, 400, 3.5, 0.5),
                          ("topk", 0.15, 600, 3.0, 2.0)])
def test_grad_compression_converges(method, frac, steps, min_ratio, max_loss):
    """Error feedback: compressed training still approaches the optimum
    (sparse top-k converges slower -- EF trades per-step progress for wire
    bytes), and the wire format is genuinely smaller."""
    opt = adamw(lr=0.05)
    params = _quadratic_params()
    state = opt.init(params)
    comp = init_state(params)
    first = float(_loss(params))
    ratio = None
    for _ in range(steps):
        grads = jax.grad(_loss)(params)
        grads, comp, wire, dense = compress_grads(grads, comp, method, frac)
        ratio = dense / wire
        params, state = opt.update(grads, state, params)
    final = float(_loss(params))
    assert final < max_loss and final < 0.01 * first, (method, first, final)
    assert ratio >= min_ratio


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import store
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "nested": {"b": jnp.ones((3, 4), jnp.bfloat16)},
            "tup": (jnp.zeros(2), jnp.ones(3))}
    store.save(tmp_path, 7, tree, extra={"note": "hi"})
    latest = store.latest_complete(tmp_path)
    assert latest is not None and latest.name == "step_00000007"
    like = jax.eval_shape(lambda: tree)
    back = store.load(latest, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    from repro.checkpoint import store
    tree = {"a": jnp.arange(4, dtype=jnp.float32)}
    d = store.save(tmp_path, 1, tree)
    npy = next(d.glob("*.npy"))
    arr = np.load(npy)
    arr[0] += 1
    np.save(npy, arr)
    with pytest.raises(IOError, match="checksum"):
        store.load(d, jax.eval_shape(lambda: tree))


def test_incomplete_checkpoint_skipped(tmp_path):
    from repro.checkpoint import store
    tree = {"a": jnp.arange(4, dtype=jnp.float32)}
    store.save(tmp_path, 1, tree)
    d2 = store.save(tmp_path, 2, tree)
    (d2 / "COMMIT").unlink()                   # simulate preemption mid-write
    latest = store.latest_complete(tmp_path)
    assert latest.name == "step_00000001"


def test_loop_failure_and_resume(tmp_path):
    """Kill training mid-run; resume continues from the checkpoint with a
    sane loss trajectory (the checkpoint/restart contract)."""
    import dataclasses

    from repro.config.base import get_arch
    from repro.training.loop import LoopConfig, train

    cfg = get_arch("qwen1.5-0.5b").smoke_config
    rng = np.random.default_rng(0)

    def data():
        while True:
            yield {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=(2, 16)), jnp.int32)}

    lc = LoopConfig(total_steps=12, checkpoint_every=4,
                    checkpoint_dir=str(tmp_path), lr=1e-3)
    with pytest.raises(RuntimeError, match="injected failure"):
        train(cfg, data(), lc, fail_at_step=6)
    # resumed run starts from step 4's checkpoint
    st = train(cfg, data(), lc)
    assert st.step == 12
    losses = [m["loss"] for m in st.metrics_history]
    assert all(np.isfinite(losses))
    from repro.checkpoint import store
    assert store.latest_complete(tmp_path).name == "step_00000012"
