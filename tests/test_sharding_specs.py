"""Sharding policies: every (arch x shape) cell's specs must divide shapes
exactly (the dry-run's precondition) -- checked WITHOUT 512 devices by
validating divisibility against the mesh shape directly."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config.base import get_arch, list_archs
from repro.distributed import sharding as shd
from repro.models import api as mapi


class FakeMesh:
    """Shape-only stand-in (sharding rules never touch devices)."""

    def __init__(self, multi_pod: bool):
        self.shape = ({"pod": 2, "data": 16, "model": 16} if multi_pod
                      else {"data": 16, "model": 16})
        self.axis_names = tuple(self.shape)


def _check(spec_tree, shape_tree, mesh):
    specs = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    shapes = jax.tree.leaves(shape_tree)
    assert len(specs) == len(shapes)
    for spec, leaf in zip(specs, shapes):
        assert len(spec) <= len(leaf.shape), (spec, leaf.shape)
        for dim, axes in zip(leaf.shape, tuple(spec)):
            if axes is None:
                continue
            group = axes if isinstance(axes, tuple) else (axes,)
            size = int(np.prod([mesh.shape[a] for a in group]))
            assert dim % size == 0, (spec, leaf.shape)


@pytest.mark.parametrize("multi_pod", [False, True])
@pytest.mark.parametrize("arch_id", list_archs())
def test_all_cells_specs_divide(arch_id, multi_pod):
    mesh = FakeMesh(multi_pod)
    arch = get_arch(arch_id)
    for shape in arch.shapes:
        if shape.skip_reason:
            continue
        cfg = mapi.resolve_config(arch.config, shape)
        params_spec = mapi.abstract_params(cfg)
        p = shd.param_specs(cfg, params_spec, mesh)
        _check(p, params_spec, mesh)
        specs = mapi.input_specs(cfg, shape)
        b = shd.batch_specs(cfg, shape, specs, mesh)
        _check(b, specs, mesh)


def test_opt_specs_mirror_params():
    mesh = FakeMesh(False)
    arch = get_arch("kimi-k2-1t-a32b")
    cfg = arch.config
    params_spec = mapi.abstract_params(cfg)
    pspecs = shd.param_specs(cfg, params_spec, mesh)
    opt_spec = mapi.abstract_opt_state(cfg, params_spec)
    ospecs = shd.opt_specs(pspecs, opt_spec)
    # adafactor vr/vc exist and have reduced rank
    flat = jax.tree_util.tree_flatten_with_path(
        ospecs, is_leaf=lambda x: isinstance(x, P))[0]
    keys = {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat}
    assert any("vr" in k for k in keys)
    assert any("count" in k for k in keys)
