import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bitset


@given(st.integers(1, 300), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random(n) < 0.4
    bits = bitset.pack(jnp.asarray(mask))
    assert bits.dtype == jnp.uint32
    back = np.asarray(bitset.unpack(bits, n))
    np.testing.assert_array_equal(back, mask)
    assert int(bitset.count(bits)) == int(mask.sum())


def test_test_bits_with_padding():
    mask = np.zeros(70, bool)
    mask[[0, 31, 32, 63, 64, 69]] = True
    bits = bitset.pack(jnp.asarray(mask))
    ids = jnp.asarray([0, 1, 31, 32, 63, 64, 69, -1, -5], jnp.int32)
    got = np.asarray(bitset.test(bits, ids))
    np.testing.assert_array_equal(
        got, [True, False, True, True, True, True, True, False, False])


@given(st.integers(1, 3), st.integers(1, 300), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_pack_np_bitwise_matches_pack(ndim, n, seed):
    """Host-side pack is bit-identical to the jnp pack over any leading
    dims (the serving tier relies on this; the deterministic must-run
    copy lives in tests/test_overlap.py)."""
    rng = np.random.default_rng(seed)
    shape = (2,) * (ndim - 1) + (n,)
    mask = rng.random(shape) < 0.4
    np.testing.assert_array_equal(
        bitset.pack_np(mask), np.asarray(bitset.pack(jnp.asarray(mask))))


@given(st.integers(10, 200), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_set_bits_matches_numpy(n, seed):
    rng = np.random.default_rng(seed)
    base = rng.random(n) < 0.3
    new_ids = rng.choice(n, size=min(10, n), replace=False)
    bits = bitset.pack(jnp.asarray(base))
    # pad with -1s; duplicates with already-set are allowed (no-op)
    ids = jnp.asarray(list(new_ids) + [-1, -1], jnp.int32)
    out = bitset.set_bits(bits, ids)
    expect = base.copy()
    expect[new_ids] = True
    np.testing.assert_array_equal(np.asarray(bitset.unpack(out, n)), expect)


def test_set_bits_duplicate_ids_are_safe():
    """Regression: duplicate ids must OR into the same bit, not carry
    into neighboring bits (additive scatter corrupted the word)."""
    n = 70
    bits = bitset.pack(jnp.zeros(n, bool))
    ids = jnp.asarray([5, 5, 5, 5, 37, 37, 69, -1, -1], jnp.int32)
    out = np.asarray(bitset.unpack(bitset.set_bits(bits, ids), n))
    expect = np.zeros(n, bool)
    expect[[5, 37, 69]] = True
    np.testing.assert_array_equal(out, expect)


def test_set_bits_duplicates_against_preset_bits():
    """Duplicates of an already-set bit stay a no-op."""
    n = 40
    base = np.zeros(n, bool)
    base[7] = True
    bits = bitset.pack(jnp.asarray(base))
    out = bitset.set_bits(bits, jnp.asarray([7, 7, 8, 8], jnp.int32))
    expect = base.copy()
    expect[8] = True
    np.testing.assert_array_equal(np.asarray(bitset.unpack(out, n)), expect)
    assert int(bitset.count(out)) == 2


@given(st.integers(10, 120), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_set_bits_with_duplicates_matches_numpy(n, seed):
    rng = np.random.default_rng(seed)
    base = rng.random(n) < 0.3
    ids = rng.integers(-2, n, size=25)          # duplicates very likely
    bits = bitset.set_bits(bitset.pack(jnp.asarray(base)),
                           jnp.asarray(ids, jnp.int32))
    expect = base.copy()
    expect[ids[ids >= 0]] = True
    np.testing.assert_array_equal(np.asarray(bitset.unpack(bits, n)), expect)


def _tiny_index(n=70, d=4):
    from repro.core.graph import empty_graph
    from repro.core.navix import NavixConfig, NavixIndex
    graph = empty_graph(n, d, m_l=4, m_u=2, n_upper=4,
                        vectors=jnp.zeros((n, d), jnp.float32))
    return NavixIndex.from_graph(graph, NavixConfig())


def test_pack_semimask_validates_prepacked_width():
    idx = _tiny_index(n=70)                      # needs ceil(70/32) = 3 words
    good = bitset.pack(jnp.zeros(70, bool))
    assert idx.pack_semimask(good).shape == (3,)
    stale = jnp.zeros(5, jnp.uint32)             # packed for a bigger index
    with pytest.raises(ValueError, match="3"):
        idx.pack_semimask(stale)
    with pytest.raises(ValueError, match="words"):
        idx.pack_semimask(jnp.zeros(2, jnp.uint32))


def test_pack_semimask_validates_bool_length():
    idx = _tiny_index(n=70)
    with pytest.raises(ValueError, match="70"):
        idx.pack_semimask(np.zeros(64, bool))


def test_count_members_sigma_l():
    """The adaptive-local sigma_l numerator: membership counting only."""
    mask = np.zeros(100, bool)
    mask[:50] = True
    bits = bitset.pack(jnp.asarray(mask))
    nbrs = jnp.asarray([1, 2, 60, 70, -1, -1], jnp.int32)
    assert int(bitset.count_members(bits, nbrs)) == 2


def test_full_mask_tail_bits():
    for n in (1, 31, 32, 33, 64, 100):
        bits = bitset.full_mask(n)
        assert int(bitset.count(bits)) == n


# -- shard-aware [S, B, W] primitives (sharded mixed-plan batching) ----------


@given(st.integers(1, 4), st.integers(1, 3), st.integers(1, 90),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_shard_lane_roundtrip(s, b, n, seed):
    """pack/unpack already map over leading dims: a [S, B, n] mask stack
    round-trips through [S, B, W] and count_batch counts per (s, b)."""
    rng = np.random.default_rng(seed)
    mask = rng.random((s, b, n)) < 0.4
    bits = bitset.pack(jnp.asarray(mask))
    assert bits.shape == (s, b, bitset.n_words(n))
    np.testing.assert_array_equal(
        np.asarray(bitset.unpack(bits, n)), mask)
    np.testing.assert_array_equal(
        np.asarray(bitset.count_batch(bits)), mask.sum(axis=-1))


@given(st.integers(1, 4), st.integers(1, 3), st.integers(8, 90),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_count_members_batch_shard_lanes_match_numpy(s, b, n, seed):
    """count_members_batch over any leading dims: each (shard, lane)
    counts membership against its OWN bitset; ids < 0 never count."""
    rng = np.random.default_rng(seed)
    mask = rng.random((s, b, n)) < 0.5
    ids = rng.integers(-2, n, size=(s, b, 7)).astype(np.int32)
    got = np.asarray(bitset.count_members_batch(
        bitset.pack(jnp.asarray(mask)), jnp.asarray(ids)))
    expect = np.zeros((s, b), np.int64)
    for i in range(s):
        for j in range(b):
            sel = ids[i, j][ids[i, j] >= 0]
            expect[i, j] = mask[i, j][sel].sum()
    np.testing.assert_array_equal(got, expect)


# NOTE: the deterministic shard-aware tests (count_members_batch vmap
# oracle, broadcast_shard_lanes) live in tests/test_distributed_batch.py
# -- this module's top-level hypothesis importorskip would skip them in
# hypothesis-less environments, and the oracle check must always run.
