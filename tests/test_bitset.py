import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bitset


@given(st.integers(1, 300), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random(n) < 0.4
    bits = bitset.pack(jnp.asarray(mask))
    assert bits.dtype == jnp.uint32
    back = np.asarray(bitset.unpack(bits, n))
    np.testing.assert_array_equal(back, mask)
    assert int(bitset.count(bits)) == int(mask.sum())


def test_test_bits_with_padding():
    mask = np.zeros(70, bool)
    mask[[0, 31, 32, 63, 64, 69]] = True
    bits = bitset.pack(jnp.asarray(mask))
    ids = jnp.asarray([0, 1, 31, 32, 63, 64, 69, -1, -5], jnp.int32)
    got = np.asarray(bitset.test(bits, ids))
    np.testing.assert_array_equal(
        got, [True, False, True, True, True, True, True, False, False])


@given(st.integers(10, 200), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_set_bits_matches_numpy(n, seed):
    rng = np.random.default_rng(seed)
    base = rng.random(n) < 0.3
    new_ids = rng.choice(n, size=min(10, n), replace=False)
    bits = bitset.pack(jnp.asarray(base))
    # pad with -1s; duplicates with already-set are allowed (no-op)
    ids = jnp.asarray(list(new_ids) + [-1, -1], jnp.int32)
    out = bitset.set_bits(bits, ids)
    expect = base.copy()
    expect[new_ids] = True
    np.testing.assert_array_equal(np.asarray(bitset.unpack(out, n)), expect)


def test_count_members_sigma_l():
    """The adaptive-local sigma_l numerator: membership counting only."""
    mask = np.zeros(100, bool)
    mask[:50] = True
    bits = bitset.pack(jnp.asarray(mask))
    nbrs = jnp.asarray([1, 2, 60, 70, -1, -1], jnp.int32)
    assert int(bitset.count_members(bits, nbrs)) == 2


def test_full_mask_tail_bits():
    for n in (1, 31, 32, 33, 64, 100):
        bits = bitset.full_mask(n)
        assert int(bitset.count(bits)) == n
