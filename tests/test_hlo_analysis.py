"""The trip-count-aware HLO cost analyzer (foundation of the roofline)."""

import jax
import jax.numpy as jnp
from jax import lax

from repro.launch import hlo_analysis as ha


def _flops_of(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return ha.analyze_text(txt), txt


def test_scan_flops_scaled_by_trip_count():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return lax.scan(body, x, ws)[0]

    x = jnp.zeros((64, 128))
    ws = jnp.zeros((6, 128, 128))
    cost, _ = _flops_of(scanned, x, ws)
    expect = 2 * 6 * 64 * 128 * 128
    assert abs(cost.flops - expect) / expect < 0.05, (cost.flops, expect)
    assert cost.max_trip == 6


def test_nested_scan_multiplies():
    def inner(x, w):
        return x @ w, None

    def outer(x, ws):
        def step(x, _):
            return lax.scan(inner, x, ws)[0], None
        return lax.scan(step, x, None, length=3)[0]

    x = jnp.zeros((32, 64))
    ws = jnp.zeros((4, 64, 64))
    cost, _ = _flops_of(outer, x, ws)
    expect = 2 * 3 * 4 * 32 * 64 * 64
    assert abs(cost.flops - expect) / expect < 0.05


def test_unrolled_matches_scan():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(ws.shape[0]):
            x, _ = body(x, ws[i])
        return x

    x = jnp.zeros((64, 128))
    ws = jnp.zeros((5, 128, 128))
    c1, _ = _flops_of(scanned, x, ws)
    c2, _ = _flops_of(unrolled, x, ws)
    assert abs(c1.flops - c2.flops) / c2.flops < 0.05


def test_collective_parsing_from_text():
    txt = """
ENTRY %main.1 (p0: f32[16]) -> f32[16] {
  %p0 = f32[16]{0} parameter(0)
  %ar = f32[16]{0} all-reduce(%p0), replica_groups={}, to_apply=%add.1
  %ag = f32[64]{0} all-gather(%ar), dimensions={0}
  ROOT %out = f32[16]{0} slice(%ag), slice={[0:16]}
}
"""
    cost = ha.analyze_text(txt)
    assert cost.coll_breakdown["all-reduce"] == 16 * 4
    assert cost.coll_breakdown["all-gather"] == 64 * 4


def test_shape_bytes_tuple_and_comments():
    s = "(s32[], f32[64,64]{1,0}, /*index=5*/bf16[8,16]{1,0})"
    assert ha._shape_bytes(s) == 4 + 64 * 64 * 4 + 8 * 16 * 2


def test_instr_parser_handles_index_comments():
    line = ("  %while.8 = (s32[], f32[64,64]{1,0}, /*index=5*/f32[8]{0}) "
            "while(%tuple.5), condition=%c, body=%b, "
            'backend_config={"known_trip_count":{"n":"24"}}')
    name, shape, op, operands = ha._parse_instr(line)
    assert name == "while.8" and op == "while"
    assert ha._trip_count(line) == 24
    assert ("b", 24) in ha._called(line)
