"""Tests for the unified NavixDB API: plan algebra, builder, program
cache, projection, and the declarative serving path."""

import numpy as np
import pytest

from repro.api import NavixDB, Q
from repro.core.navix import NavixConfig
from repro.data.synthetic import make_queries, make_wiki_like
from repro.query.operators import (Filter, HopJoin, KnnSearch, Limit,
                                   NodeScan, Project, evaluate,
                                   split_pipeline)
from repro.serving.engine import SearchEngine


@pytest.fixture(scope="module")
def wikidb():
    data = make_wiki_like(n_person=100, n_resource=260, d=24, seed=2)
    db = NavixDB(data.store)
    idx, stats = db.create_index(
        "chunk_emb", "Chunk", column="embedding", vectors=data.embeddings,
        config=NavixConfig(m_u=8, ef_construction=48, metric="cos"))
    assert stats.n == data.n_chunks
    return db, idx, data


# -- plan algebra ----------------------------------------------------------


def test_builder_equals_hand_built_plan():
    built = (Q.match("Person")
              .where("birth_date", "range", lo=0, hi=100)
              .hop("PersonChunk", "fwd")
              .knn(k=7, efs=30)
              .project("cID")
              .limit(5)
              .plan())
    hand = Limit(
        Project(
            KnnSearch(
                child=HopJoin(
                    Filter(NodeScan("Person"), "birth_date", "range",
                           lo=0, hi=100),
                    "PersonChunk", "fwd"),
                k=7, efs=30, heuristic="adaptive_local"),
            ("cID",)),
        5)
    assert built == hand
    assert hash(built) == hash(hand)      # plans are group/cache keys


def test_split_pipeline():
    sel = Filter(NodeScan("Chunk"), "cID", "<", value=10)
    plan = Limit(Project(KnnSearch(child=sel, k=5), ("cID", "year")), 3)
    parts = split_pipeline(plan)
    assert parts.selection == sel
    assert parts.knn.k == 5
    assert parts.projections == ("cID", "year")
    assert parts.limit == 3
    # selection-only plans split too
    parts2 = split_pipeline(Project(sel, ("cID",)))
    assert parts2.knn is None and parts2.selection == sel


def test_evaluate_rejects_row_plans():
    with pytest.raises(TypeError, match="NavixDB"):
        evaluate(KnnSearch(child=NodeScan("Chunk")), None)


# -- end-to-end execution ---------------------------------------------------


def test_knn_plan_recall_vs_oracle(wikidb):
    db, idx, data = wikidb
    queries = make_queries(data, 8, "uncorrelated", seed=9)
    sel = Filter(NodeScan("Chunk"), "cID", "<", value=data.n_chunks // 2)
    rs = db.execute(KnnSearch(child=sel, k=10, efs=80), query=queries)
    assert rs.ids.shape == (8, 10)
    mask = db.prefilter(sel).mask
    # no leakage outside S
    assert mask[rs.ids[rs.ids >= 0]].all()
    _, true_ids = idx.brute_force(queries, k=10, semimask=mask)
    assert idx.recall(rs.ids, np.asarray(true_ids)) >= 0.9
    assert rs.sigma == pytest.approx(0.5, abs=0.01)
    assert rs.timings.search_ms > 0.0


def test_project_and_limit(wikidb):
    db, idx, data = wikidb
    plan = (Q.match("Chunk")
             .knn(data.embeddings[0], k=8, efs=40, heuristic="onehop_a")
             .project("cID", "is_person")
             .limit(3))
    rs = db.execute(plan)
    assert rs.ids.shape == (3,)
    valid = rs.ids >= 0
    np.testing.assert_array_equal(rs.columns["cID"][valid], rs.ids[valid])
    assert rs.ids[0] == 0          # nearest neighbor of chunk 0 is itself


def test_pure_selection_plan(wikidb):
    db, _, data = wikidb
    rs = db.execute(Q.match("Chunk").where("is_person", "==", True)
                     .project("cID").limit(10))
    assert len(rs) == 10
    assert rs.dists is None
    assert data.chunk_is_person[rs.ids].all()
    np.testing.assert_array_equal(rs.columns["cID"], rs.ids)


def test_execute_rejects_unknown_engine(wikidb):
    db, _, data = wikidb
    with pytest.raises(ValueError, match="engine"):
        db.execute(Q.match("Chunk").knn(k=3), query=data.embeddings[:4],
                   engine="bacthed")


def test_unbound_template_needs_query(wikidb):
    db, _, _ = wikidb
    with pytest.raises(ValueError, match="query vector"):
        db.execute(Q.match("Chunk").knn(k=5))


def test_explain(wikidb):
    db, _, _ = wikidb
    text = db.explain(Q.match("Chunk").where("cID", "<", 9).knn(k=3))
    assert "KnnSearch" in text and "NodeScan" in text


# -- compiled-program cache -------------------------------------------------


def test_program_cache_zero_recompiles_on_same_shape(wikidb):
    db, idx, data = wikidb
    plan = (Q.match("Chunk").where("cID", "<", 400)
             .knn(data.embeddings[0], k=5, efs=40))
    db.execute(plan)                       # may compile (cold shape)
    before = db.programs.stats.misses
    hits0 = db.programs.stats.hits
    db.execute(plan, query=data.embeddings[123])
    db.execute(plan, query=data.embeddings[77])
    assert db.programs.stats.misses == before, \
        "same-shape plan re-execution must not compile"
    assert db.programs.stats.hits == hits0 + 2


def test_program_cache_batch_bucketing(wikidb):
    db, idx, data = wikidb
    plan = (Q.match("Chunk").where("cID", "<", 400).knn(k=5, efs=40))
    rs7 = db.execute(plan, query=data.embeddings[:7])   # bucket 8
    misses = db.programs.stats.misses
    rs5 = db.execute(plan, query=data.embeddings[:5])   # same bucket
    assert db.programs.stats.misses == misses
    assert rs7.ids.shape == (7, 5) and rs5.ids.shape == (5, 5)
    # padded rows must not leak into results
    np.testing.assert_array_equal(rs7.ids[:5], rs5.ids)


def test_compat_layer_shares_cache(wikidb):
    db, idx, data = wikidb
    mask = np.zeros(data.n_chunks, bool)
    mask[:500] = True
    idx.search(data.embeddings[3], k=5, efs=40, semimask=mask)
    hits0 = db.programs.stats.hits
    misses0 = db.programs.stats.misses
    r = idx.search(data.embeddings[9], k=5, efs=40, semimask=mask)
    assert db.programs.stats.hits == hits0 + 1
    assert db.programs.stats.misses == misses0
    assert mask[np.asarray(r.ids)[np.asarray(r.ids) >= 0]].all()


# -- serving on the declarative path ---------------------------------------


def test_engine_serves_declarative_plans(wikidb):
    db, idx, data = wikidb
    eng = SearchEngine(db=db, efs=40)
    tmpl = (Q.match("Chunk").where("cID", "<", data.n_chunks // 3)
             .knn(k=6, efs=40))
    qs = make_queries(data, 5, "uncorrelated", seed=11)
    rids = [eng.submit(q, plan=tmpl) for q in qs]
    rids.append(eng.submit(qs[0], plan=None, k=6))
    resp = eng.drain()
    assert len(resp) == len(rids)
    by = {r.rid: r for r in resp}
    for rid in rids[:-1]:
        ids = by[rid].ids
        assert (ids[ids >= 0] < data.n_chunks // 3).all()
    assert by[rids[-1]].sigma == 1.0
    assert eng.latency_summary()["n"] == len(rids)


def test_group_prefilter_amortized(wikidb, monkeypatch):
    """The group's shared prefilter cost is split across its requests
    (one Q_S evaluation, not one per request)."""
    import repro.api.db as dbmod
    db, idx, data = wikidb
    real_eval = dbmod.evaluate

    def fixed_time_eval(plan, store):
        q = real_eval(plan, store)
        return dbmod.QueryResult(table=q.table, mask=q.mask, seconds=0.048)

    monkeypatch.setattr(dbmod, "evaluate", fixed_time_eval)
    eng = SearchEngine(db=db, efs=40)
    tmpl = Q.match("Chunk").where("cID", "<", 500).knn(k=4, efs=40)
    qs = make_queries(data, 4, "uncorrelated", seed=13)
    for q in qs:
        eng.submit(q, plan=tmpl)
    resp = eng.drain()
    assert len(resp) == 4
    for r in resp:
        assert r.prefilter_ms == pytest.approx(48.0 / 4)
