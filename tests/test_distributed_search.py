"""Distributed (sharded) search: runs in a subprocess with 8 placeholder
devices so the main test process keeps its single real device."""

import json
import pathlib
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
from repro.core.distributed import ShardedNavix
from repro.core.navix import NavixConfig
from repro.core.distances import brute_force_topk
import jax.numpy as jnp

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
from repro.data.synthetic import gaussian_mixture
X, _, centers = gaussian_mixture(1600, 16, 8, seed=0)
cfg = NavixConfig(m_u=8, ef_construction=48, metric="l2")
sn = ShardedNavix.build(X, cfg, mesh)

Q = (centers[:4] + 0.2 * rng.normal(size=(4, 16))).astype(np.float32)
mask = rng.random(1600) < 0.4
td, ti = brute_force_topk(jnp.asarray(Q), jnp.asarray(X), 10, "l2",
                          mask=jnp.asarray(mask))
d, ids = sn.search(Q, mask, k=10, efs=60)
ids = np.asarray(ids); ti = np.asarray(ti)
hits = sum(len(set(ids[i][ids[i]>=0].tolist()) & set(ti[i][ti[i]>=0].tolist()))
           for i in range(4))
recall = hits / max((ti >= 0).sum(), 1)

# all results must be selected + globally valid
sel_ok = bool(mask[ids[ids >= 0]].all())

# quorum: kill one shard; search still succeeds with degraded recall
alive = np.ones(4, bool); alive[2] = False
d2, ids2 = sn.search(Q, mask, k=10, efs=60, alive=alive, quorum=3)
shard = ids2[ids2 >= 0] // sn.n_local
no_dead = bool((shard != 2).all())

failed = False
try:
    sn.search(Q, mask, k=10, alive=np.array([True, False, False, False]),
              quorum=3)
except RuntimeError:
    failed = True

print(json.dumps({"recall": recall, "sel_ok": sel_ok,
                  "no_dead": no_dead, "quorum_raises": failed}))
"""


@pytest.mark.slow
def test_sharded_search_subprocess(tmp_path):
    out = subprocess.run([sys.executable, "-c", SCRIPT], timeout=900,
                         capture_output=True, text=True,
                         cwd=pathlib.Path(__file__).parent.parent,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": str(tmp_path)})
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["recall"] >= 0.8, res
    assert res["sel_ok"] and res["no_dead"] and res["quorum_raises"], res
