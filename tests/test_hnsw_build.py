import numpy as np

from repro.core.graph import check_symmetric_fraction, degree_histogram


def test_degrees_within_bounds(index):
    g = index.graph
    deg = np.asarray(g.lower_deg)
    assert deg.max() <= g.m_l
    assert deg.min() >= 1, "isolated node in lower level"
    row_counts = (np.asarray(g.lower) >= 0).sum(axis=1)
    np.testing.assert_array_equal(deg, row_counts)


def test_no_self_or_duplicate_edges(index):
    lower = np.asarray(index.graph.lower)
    n = lower.shape[0]
    for u in range(0, n, 97):
        row = lower[u][lower[u] >= 0]
        assert u not in row, f"self edge at {u}"
        assert len(set(row.tolist())) == len(row), f"duplicate edge at {u}"
        assert (row < n).all()


def test_upper_layer_structure(index):
    g = index.graph
    uids = np.asarray(g.upper_ids)
    assert len(uids) == len(set(uids.tolist()))
    assert (uids >= 0).all() and (uids < g.n).all()
    # upper adjacency points at valid positions
    up = np.asarray(g.upper)
    valid = up[up >= 0]
    assert (valid < g.n_upper).all()
    # roughly the configured sample rate
    assert abs(g.n_upper / g.n - index.config.sample_rate) < 0.02


def test_mostly_symmetric(index):
    frac = check_symmetric_fraction(index.graph, sample=300)
    assert frac > 0.5, f"edge symmetry too low: {frac}"


def test_degree_histogram_sane(index):
    h = degree_histogram(index.graph)
    assert h.sum() == index.graph.n
