"""Shared fixtures.

NOTE on devices: most tests run identically at any host device count.
The in-process distributed tests (tests/test_distributed_batch.py and
the sharded half of tests/test_serving.py) exercise shard counts up to
the number of available devices and skip above it -- CI runs tier-1 with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so S in {1, 2, 4}
all execute. The legacy subprocess-based distributed test keeps its own
device-count override."""

import numpy as np
import pytest

from repro.core.navix import NavixConfig, NavixIndex
from repro.data.synthetic import gaussian_mixture


@pytest.fixture(scope="session")
def clustered():
    """Small clustered dataset -- cluster structure makes heuristic
    crossovers (and recall) meaningful, like the paper's real datasets."""
    X, labels, centers = gaussian_mixture(2500, 32, 10, seed=0)
    return X, labels, centers


@pytest.fixture(scope="session")
def index(clustered):
    X, _, _ = clustered
    idx, stats = NavixIndex.create(
        X, NavixConfig(m_u=8, ef_construction=64, metric="l2", seed=0))
    assert stats.n == X.shape[0]
    return idx


@pytest.fixture(scope="session")
def queries(clustered):
    X, _, centers = clustered
    rng = np.random.default_rng(7)
    base = centers[rng.integers(0, len(centers), size=12)]
    return (base + 0.3 * rng.normal(size=base.shape)).astype(np.float32)


@pytest.fixture(scope="session")
def shard_env():
    """Small clustered dataset + memoized ShardedNavix builds per shard
    count (host mesh ``(data=1, model=S)``). Tests requesting S beyond
    the available device count must skip at the call site."""
    import jax

    from repro.core.distributed import ShardedNavix

    X, _, centers = gaussian_mixture(640, 16, 8, seed=0)
    rng = np.random.default_rng(7)
    base = centers[rng.integers(0, len(centers), size=8)]
    qs = (base + 0.25 * rng.normal(size=base.shape)).astype(np.float32)
    cfg = NavixConfig(m_u=8, ef_construction=48, metric="l2", seed=0)
    built: dict[int, ShardedNavix] = {}

    def factory(s: int) -> ShardedNavix:
        if s not in built:
            mesh = jax.make_mesh((1, s), ("data", "model"))
            built[s] = ShardedNavix.build(X, cfg, mesh)
        return built[s]

    return X, qs, factory
