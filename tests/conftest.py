"""Shared fixtures. NOTE: tests run with the real single CPU device --
XLA_FLAGS device-count overrides belong ONLY to the dry-run (and the
subprocess-based distributed tests)."""

import numpy as np
import pytest

from repro.core.navix import NavixConfig, NavixIndex
from repro.data.synthetic import gaussian_mixture


@pytest.fixture(scope="session")
def clustered():
    """Small clustered dataset -- cluster structure makes heuristic
    crossovers (and recall) meaningful, like the paper's real datasets."""
    X, labels, centers = gaussian_mixture(2500, 32, 10, seed=0)
    return X, labels, centers


@pytest.fixture(scope="session")
def index(clustered):
    X, _, _ = clustered
    idx, stats = NavixIndex.create(
        X, NavixConfig(m_u=8, ef_construction=64, metric="l2", seed=0))
    assert stats.n == X.shape[0]
    return idx


@pytest.fixture(scope="session")
def queries(clustered):
    X, _, centers = clustered
    rng = np.random.default_rng(7)
    base = centers[rng.integers(0, len(centers), size=12)]
    return (base + 0.3 * rng.normal(size=base.shape)).astype(np.float32)
