"""Batched-frontier engine: exact equivalence with per-query search.

The engine's contract is *lane-for-lane identity*: for any semimask and
heuristic, lane b of ``search_many`` evolves through exactly the same
beam states as ``search`` on query b alone, so ids, dists, AND the dc
stats must match exactly (not approximately)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import bitset
from repro.core.search import search, search_batch
from repro.core.search_batch import search_many

HEURISTICS = ["onehop_s", "directed", "blind", "adaptive_g",
              "adaptive_local", "onehop_a"]


def _params(index, k=10, efs=40, heuristic="adaptive_local"):
    return index._params(k, efs, heuristic)


def _sel_and_sigma(index, sigma, seed=3):
    if sigma >= 1.0:
        sel = index.full_semimask()
    else:
        rng = np.random.default_rng(seed)
        sel = bitset.pack(jnp.asarray(rng.random(index.graph.n) < sigma))
    return sel, float(bitset.count(sel)) / index.graph.n


@pytest.mark.parametrize("sigma", [1.0, 0.5, 0.15, 0.03])
def test_batched_matches_single_exactly(index, queries, sigma):
    Q = jnp.asarray(queries[:6])
    sel, sg = _sel_and_sigma(index, sigma)
    for h in HEURISTICS:
        params = _params(index, heuristic=h)
        batched = search_many(index.graph, Q, sel, params, sigma_g=sg)
        singles = [search(index.graph, Q[i], sel, params, sigma_g=sg)
                   for i in range(Q.shape[0])]
        np.testing.assert_array_equal(
            np.asarray(batched.ids),
            np.stack([np.asarray(r.ids) for r in singles]),
            err_msg=f"ids diverge for {h} at sigma={sigma}")
        np.testing.assert_array_equal(
            np.asarray(batched.dists),
            np.stack([np.asarray(r.dists) for r in singles]),
            err_msg=f"dists diverge for {h} at sigma={sigma}")


def test_batched_stats_match_single(index, queries):
    """Per-lane stats (iters, t_dc, s_dc, upper_dc, picks) are the
    single-query stats: converged lanes stop paying distance
    computations while the batch finishes."""
    Q = jnp.asarray(queries[:6])
    sel, sg = _sel_and_sigma(index, 0.2)
    params = _params(index)
    batched = search_many(index.graph, Q, sel, params, sigma_g=sg)
    singles = [search(index.graph, Q[i], sel, params, sigma_g=sg)
               for i in range(Q.shape[0])]
    for field in ("iters", "t_dc", "s_dc", "upper_dc", "picks"):
        np.testing.assert_array_equal(
            np.asarray(getattr(batched.stats, field)),
            np.stack([np.asarray(getattr(r.stats, field)) for r in singles]),
            err_msg=f"stats.{field} diverges")


def test_batched_matches_vmap_oracle(index, queries):
    Q = jnp.asarray(queries[:4])
    sel, sg = _sel_and_sigma(index, 0.3)
    params = _params(index)
    a = search_many(index.graph, Q, sel, params, sigma_g=sg)
    b = search_batch(index.graph, Q, sel, params, sigma_g=sg)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))


def test_batched_empty_and_full_masks(index, queries):
    Q = jnp.asarray(queries[:3])
    empty = bitset.full_mask(index.graph.n, value=False)
    r = search_many(index.graph, Q, empty, _params(index, k=5), sigma_g=0.0)
    assert (np.asarray(r.ids) == -1).all()
    full = index.full_semimask()
    r = search_many(index.graph, Q, full, _params(index, k=5), sigma_g=1.0)
    assert (np.asarray(r.ids) >= 0).all()


def test_search_results_contain_no_duplicate_ids(index, queries):
    """Property: neither engine may return the same id twice in one
    result list (the visited bitset + beam merge must dedupe)."""
    for sigma in (1.0, 0.4, 0.08):
        sel, sg = _sel_and_sigma(index, sigma, seed=11)
        params = _params(index, k=20, efs=60)
        Q = jnp.asarray(queries[:6])
        batched = search_many(index.graph, Q, sel, params, sigma_g=sg)
        for row in np.asarray(batched.ids):
            real = row[row >= 0]
            assert len(set(real)) == len(real), f"dup ids at sigma={sigma}"
        single = search(index.graph, Q[0], sel, params, sigma_g=sg)
        real = np.asarray(single.ids)
        real = real[real >= 0]
        assert len(set(real)) == len(real)


def test_navix_search_many_engines_agree(index, queries):
    mask = np.random.default_rng(5).random(index.graph.n) < 0.35
    a = index.search_many(queries[:5], k=8, efs=40, semimask=mask,
                          engine="batched")
    b = index.search_many(queries[:5], k=8, efs=40, semimask=mask,
                          engine="vmap")
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    with pytest.raises(ValueError, match="engine"):
        index.search_many(queries[:2], k=4, engine="nope")
