import numpy as np


def test_postfilter_reaches_k(index, queries):
    mask = np.random.default_rng(5).random(index.graph.n) < 0.5
    d, ids, stats = index.search_postfilter(queries[0], k=10, semimask=mask)
    assert (ids >= 0).sum() == 10
    assert mask[ids].all()
    assert stats.verifications >= 10


def test_postfilter_degrades_with_selectivity(index, queries):
    """Section 5.7: lower selectivity => more streamed tuples verified."""
    rng = np.random.default_rng(6)
    v_hi = v_lo = 0
    for q in queries[:6]:
        *_, s_hi = index.search_postfilter(q, k=10,
                                           semimask=rng.random(index.graph.n) < 0.8)
        *_, s_lo = index.search_postfilter(q, k=10,
                                           semimask=rng.random(index.graph.n) < 0.05)
        v_hi += s_hi.verifications
        v_lo += s_lo.verifications
    assert v_lo > 2 * v_hi, (v_lo, v_hi)


def test_quantized_search_recall(index, queries):
    """DiskANN-regime: int8 search + exact re-rank stays close to exact."""
    _, true_ids = index.brute_force(queries, k=10)
    got = []
    for q in queries:
        r = index.search_quantized(q, k=10, efs=80, heuristic="onehop_a")
        got.append(np.asarray(r.ids))
    rec = index.recall(np.stack(got), np.asarray(true_ids))
    assert rec >= 0.85, rec


def test_quantization_error_bounded(index):
    from repro.core.quantize import dequantize, quantize
    store = quantize(index.graph.vectors)
    deq = np.asarray(dequantize(store))
    orig = np.asarray(index.graph.vectors)
    rel = np.abs(deq - orig).max() / np.abs(orig).max()
    assert rel < 0.01
    assert store.nbytes() < orig.nbytes / 3.5
