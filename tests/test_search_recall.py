import numpy as np


def test_unfiltered_recall(index, queries):
    _, true_ids = index.brute_force(queries, k=10)
    got = []
    for q in queries:
        r = index.search(q, k=10, efs=80, heuristic="onehop_a")
        got.append(np.asarray(r.ids))
    rec = index.recall(np.stack(got), np.asarray(true_ids))
    assert rec >= 0.9, f"unfiltered recall {rec}"


def test_efs_monotonicity(index, queries):
    """Larger efs => recall does not get (meaningfully) worse -- the
    accuracy/latency knob of Section 2.1."""
    _, true_ids = index.brute_force(queries, k=10)
    recalls = []
    for efs in (16, 64, 160):
        got = [np.asarray(index.search(q, k=10, efs=efs,
                                       heuristic="onehop_a").ids)
               for q in queries]
        recalls.append(index.recall(np.stack(got), np.asarray(true_ids)))
    assert recalls[-1] >= recalls[0] - 0.02
    assert recalls[-1] >= 0.9


def test_results_sorted_and_unique(index, queries):
    for q in queries[:4]:
        r = index.search(q, k=20, efs=80, heuristic="onehop_a")
        d = np.asarray(r.dists)
        ids = np.asarray(r.ids)
        valid = ids >= 0
        dv = d[valid]
        assert (np.diff(dv) >= -1e-6).all(), "results not sorted"
        assert len(set(ids[valid].tolist())) == valid.sum(), "duplicates"


def test_search_stats_counters(index, queries):
    r = index.search(queries[0], k=10, efs=64, heuristic="onehop_a")
    assert int(r.stats.t_dc) > 0
    assert int(r.stats.t_dc) == int(r.stats.s_dc)  # unfiltered: all selected
    assert int(r.stats.iters) > 0
    assert int(r.stats.upper_dc) > 0
