import numpy as np
import pytest

from repro.data.synthetic import (correlation_ratio, make_queries,
                                  make_wiki_like, person_chunk_plan,
                                  two_hop_plan, uncorrelated_plan)
from repro.query.operators import (And, Filter, HopJoin, NodeScan, Not, Or,
                                   evaluate, output_table)


@pytest.fixture(scope="module")
def wiki():
    return make_wiki_like(n_person=120, n_resource=300, d=24, seed=0)


def test_scan_and_filter(wiki):
    store = wiki.store
    res = evaluate(Filter(NodeScan("Chunk"), "cID", "<", value=100), store)
    assert res.mask.sum() == 100
    assert res.table == "Chunk"
    res2 = evaluate(Filter(NodeScan("Person"), "birth_date", "range",
                           lo=0, hi=18250), store)
    bd = store.node("Person").column("birth_date")
    np.testing.assert_array_equal(res2.mask, (bd >= 0) & (bd < 18250))


def test_hop_join_matches_oracle(wiki):
    store = wiki.store
    persons = Filter(NodeScan("Person"), "pID", "<", value=10)
    plan = HopJoin(persons, "PersonChunk", "fwd")
    res = evaluate(plan, store)
    # oracle: chunks whose person id < 10
    rel = store.rel("PersonChunk")
    expect = np.zeros(store.node("Chunk").n, bool)
    for p in range(10):
        expect[rel.fwd.neighbors(p)] = True
    np.testing.assert_array_equal(res.mask, expect)
    assert output_table(plan, store) == "Chunk"


def test_two_hop_graph_rag_plan(wiki):
    store = wiki.store
    res = evaluate(two_hop_plan(store, 0.5), store)
    assert res.table == "Chunk"
    assert 0 < res.mask.sum() < store.node("Chunk").n


def test_boolean_combinators(wiki):
    store = wiki.store
    a = Filter(NodeScan("Chunk"), "cID", "<", value=200)
    b = Filter(NodeScan("Chunk"), "cID", ">=", value=100)
    both = evaluate(And(a, b), store).mask
    assert both.sum() == 100
    either = evaluate(Or(a, b), store).mask
    assert either.all()
    neither = evaluate(Not(Or(a, b)), store).mask
    assert neither.sum() == 0


def test_uncorrelated_workload_ce(wiki):
    """Tables 4: id-range filters should have ce ~= 1."""
    plan = uncorrelated_plan(0.3, wiki.n_chunks)
    mask = evaluate(plan, wiki.store).mask
    q = make_queries(wiki, 16, "uncorrelated", seed=5)
    ce = correlation_ratio(wiki.embeddings, q, mask, k=50)
    assert 0.7 < ce < 1.4, ce


def test_correlated_workloads_ce(wiki):
    """Table 5: person-chunk filters vs person/nonperson queries."""
    mask = evaluate(person_chunk_plan(wiki.store, 1.0), wiki.store).mask
    q_pos = make_queries(wiki, 16, "person", seed=6)
    q_neg = make_queries(wiki, 16, "nonperson", seed=6)
    ce_pos = correlation_ratio(wiki.embeddings, q_pos, mask, k=50)
    ce_neg = correlation_ratio(wiki.embeddings, q_neg, mask, k=50)
    assert ce_pos > 1.5, f"positive correlation too weak: {ce_pos}"
    assert ce_neg < 0.5, f"negative correlation too weak: {ce_neg}"


def test_selectivity_control(wiki):
    """birth_date range width controls |S| roughly linearly."""
    sig = []
    for frac in (0.2, 0.5, 1.0):
        mask = evaluate(person_chunk_plan(wiki.store, frac), wiki.store).mask
        sig.append(mask.mean())
    assert sig[0] < sig[1] < sig[2]
