"""Hypothesis property tests on system invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.search import _dedupe_keep_first, _take_first


@given(st.lists(st.integers(-1, 50), min_size=1, max_size=64),
       st.integers(1, 32))
@settings(max_examples=50, deadline=None)
def test_take_first_semantics(vals, width):
    vals = np.asarray(vals, np.int32)
    elig = vals >= 0
    out = np.asarray(_take_first(jnp.asarray(elig), jnp.asarray(vals), width))
    expect = vals[elig][:width]
    np.testing.assert_array_equal(out[: len(expect)], expect)
    assert (out[len(expect):] == -1).all()


@given(st.lists(st.integers(-1, 20), min_size=1, max_size=48))
@settings(max_examples=50, deadline=None)
def test_dedupe_keeps_first_occurrence(vals):
    vals = np.asarray(vals, np.int32)
    out = np.asarray(_dedupe_keep_first(jnp.asarray(vals)))
    seen = set()
    for v_in, v_out in zip(vals, out):
        if v_in < 0 or v_in in seen:
            assert v_out == -1
        else:
            assert v_out == v_in
            seen.add(int(v_in))


@given(st.integers(0, 2**31 - 1), st.integers(2, 6), st.integers(1, 10))
@settings(max_examples=25, deadline=None)
def test_sharded_topk_merge_equals_global(seed, n_shards, k):
    """The distributed merge invariant: top-k of per-shard top-k lists ==
    global top-k (as long as each shard returns >= k)."""
    rng = np.random.default_rng(seed)
    shards = [rng.random(30) for _ in range(n_shards)]
    all_vals = np.concatenate(shards)
    expect = np.sort(all_vals)[:k]
    per_shard = np.concatenate([np.sort(s)[:k] for s in shards])
    got = np.sort(per_shard)[:k]
    np.testing.assert_allclose(got, expect)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_rng_prune_keeps_nearest(seed):
    """Toussaint rule invariants: the nearest candidate is always kept;
    kept set size <= m; every kept c is closer to v than to any earlier
    kept candidate."""
    from repro.core.build import rng_prune_mask
    from repro.core.distances import dist_matrix
    rng = np.random.default_rng(seed)
    c = 20
    X = rng.normal(size=(c, 8)).astype(np.float32)
    v = rng.normal(size=8).astype(np.float32)
    d = ((X - v) ** 2).sum(-1)
    order = np.argsort(d)
    X, d = X[order], d[order]
    pd = np.asarray(dist_matrix(jnp.asarray(X), jnp.asarray(X), "l2"))
    m = 8
    keep = np.asarray(rng_prune_mask(jnp.asarray(d),
                                     jnp.asarray(pd),
                                     jnp.ones(c, bool), m))
    assert keep[0]
    assert keep.sum() <= m
    kept_idx = np.flatnonzero(keep)
    for pos, i in enumerate(kept_idx):
        for j in kept_idx[:pos]:
            assert d[i] < pd[i, j] + 1e-5


@given(st.integers(0, 2**31 - 1), st.floats(0.01, 0.99))
@settings(max_examples=20, deadline=None)
def test_adaptive_rule_monotone(seed, sigma):
    """Higher selectivity never moves the rule toward a 'lower' heuristic
    (onehop-s < directed < blind in exploration aggressiveness)."""
    from repro.core.heuristics import adaptive_rule
    m = 32
    a = int(adaptive_rule(sigma, m))
    b = int(adaptive_rule(min(sigma * 1.5, 1.0), m))
    assert b <= a


def test_correlation_metric_extremes():
    from repro.data.synthetic import correlation_ratio
    rng = np.random.default_rng(0)
    X = rng.normal(size=(500, 8)).astype(np.float32)
    q = X[:4] + 0.01
    mask_all = np.ones(500, bool)
    assert abs(correlation_ratio(X, q, mask_all, k=20) - 1.0) < 1e-6
    # S = exactly the queries' neighborhoods -> strongly positive
    from repro.core.distances import brute_force_topk
    _, ids = brute_force_topk(jnp.asarray(q), jnp.asarray(X), 20, "l2")
    mask = np.zeros(500, bool)
    mask[np.asarray(ids).ravel()] = True
    assert correlation_ratio(X, q, mask, k=20) > 3.0
