"""Int8-resident search + columnar exact re-rank tier.

The residency contract under test (the PR-9 bugfix): searching a
quantized index must NEVER materialize the [n, d] f32 store -- not per
call (the old ``dequantize``-per-search bug) and not at all. The beam
loop runs on codes + per-vector scales via the fused dequantizing
gather, and the final beam is exactly re-ranked against the host-side
:class:`~repro.storage.columnar.ExactTier`.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.navix import NavixConfig
from repro.core.quantize import QuantizedStore
from repro.storage.columnar import ExactTier


@pytest.fixture(scope="module")
def qindex(index):
    return index.quantize_resident()


# -- residency ------------------------------------------------------------

def test_quantize_resident_residency(index, qindex):
    assert not index.is_quantized
    assert qindex.is_quantized
    assert isinstance(qindex.graph.vectors, QuantizedStore)
    assert qindex.graph.n == index.graph.n
    assert qindex.graph.dim == index.graph.dim
    assert isinstance(qindex.exact, ExactTier)
    np.testing.assert_array_equal(np.asarray(qindex.exact.vectors),
                                  np.asarray(index.graph.vectors))
    # int8 codes + f32 scales: (d + 4) bytes/row vs 4d
    f32_bytes = index.graph.vector_nbytes()
    q_bytes = qindex.graph.vector_nbytes()
    d = index.graph.dim
    assert q_bytes == f32_bytes // 4 + 4 * index.graph.n
    assert q_bytes / f32_bytes == pytest.approx((d + 4) / (4 * d))


def test_no_dequantize_anywhere_in_search(monkeypatch, index, queries):
    """THE regression this PR exists for: zero full-store dequantize
    calls (zero [n, d] f32 allocations) during quantized search -- not
    one-per-call, none."""
    import repro.core.quantize as qz
    calls = []
    orig = qz.dequantize
    monkeypatch.setattr(qz, "dequantize",
                        lambda s: (calls.append(s), orig(s))[1])
    index.search_quantized(queries[0], k=10, efs=40)        # warm + steady
    index.search_quantized(queries[1], k=10, efs=40)
    index.search_quantized_many(queries[:4], k=10, efs=40)
    index.search_quantized_many(queries[:4], k=10, efs=40)
    assert calls == []


def test_quantized_many_matches_single_lane_for_lane(index, queries):
    rm = index.search_quantized_many(queries, k=8, efs=48,
                                     heuristic="onehop_a")
    for i, q in enumerate(queries):
        ri = index.search_quantized(q, k=8, efs=48, heuristic="onehop_a")
        np.testing.assert_array_equal(np.asarray(rm.ids[i]),
                                      np.asarray(ri.ids), err_msg=f"lane {i}")
        np.testing.assert_allclose(np.asarray(rm.dists[i]),
                                   np.asarray(ri.dists), rtol=1e-5, atol=1e-5)


def test_quantized_recall_within_rerank_floor(index, queries):
    """After the exact re-rank, int8 recall@k sits within 0.02 of the
    f32 engine at the same efs (paper S 5.8: the re-rank recovers the
    quantization loss)."""
    k, efs = 10, 80
    _, true_ids = index.brute_force(queries, k=k)
    f32 = index.search_many(queries, k=k, efs=efs)
    q8 = index.search_quantized_many(queries, k=k, efs=efs)
    r_f32 = index.recall(np.asarray(f32.ids), np.asarray(true_ids))
    r_q8 = index.recall(np.asarray(q8.ids), np.asarray(true_ids))
    assert r_q8 >= r_f32 - 0.02, (r_q8, r_f32)


def test_quantized_results_are_device_arrays(index, queries):
    """bench drivers call .block_until_ready() on quantized results."""
    r = index.search_quantized(queries[0], k=5, efs=30)
    r.dists.block_until_ready()
    r.ids.block_until_ready()
    assert isinstance(r.dists, jnp.ndarray)


def test_search_on_quantized_resident_index(qindex, index, queries):
    """Plain search()/search_many() run directly on a quantized-resident
    index (the engines dispatch on the store type). WITHOUT the exact
    re-rank the int8 distance error costs some recall -- that loss is
    exactly what search_quantized's re-rank tier recovers (see
    test_quantized_recall_within_rerank_floor's 0.02 bound)."""
    _, true_ids = index.brute_force(queries, k=10)
    res = qindex.search_many(queries, k=10, efs=80)
    rec = index.recall(np.asarray(res.ids), np.asarray(true_ids))
    f32 = index.search_many(queries, k=10, efs=80)
    rec_f32 = index.recall(np.asarray(f32.ids), np.asarray(true_ids))
    assert rec >= rec_f32 - 0.10


def test_brute_force_on_quantized_index_uses_exact_tier(index, qindex,
                                                        queries):
    d0, i0 = index.brute_force(queries[:4], k=7)
    d1, i1 = qindex.brute_force(queries[:4], k=7)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1),
                               rtol=1e-6, atol=1e-6)


def test_memmap_tier_matches_in_memory(index, queries, tmp_path):
    q_mem = index.quantize_resident()
    q_disk = index.quantize_resident(mmap_path=tmp_path / "vectors.f32")
    assert q_disk.exact.is_mmapped and not q_mem.exact.is_mmapped
    rm = q_mem.search_quantized_many(queries, k=8, efs=48)
    rd = q_disk.search_quantized_many(queries, k=8, efs=48)
    np.testing.assert_array_equal(np.asarray(rm.ids), np.asarray(rd.ids))
    np.testing.assert_array_equal(np.asarray(rm.dists), np.asarray(rd.dists))


# -- exact tier properties -------------------------------------------------
# hypothesis drives these when available; a seeded random sweep covers the
# same invariants otherwise (the container may lack hypothesis).

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

_TIER = ExactTier.build(
    np.random.default_rng(3).normal(size=(40, 8)).astype(np.float32), "l2")


def _check_padding_and_dup_properties(ids, k):
    """-1 padding never surfaces; duplicate ids are counted once; every
    surfaced id came from the input beam; finite slots sort ascending."""
    ids = np.asarray(ids, np.int32)
    Q = np.zeros((ids.shape[0], 8), np.float32)
    d, out = _TIER.rerank_many(Q, ids, k)
    assert out.shape == (ids.shape[0], k)
    for lane in range(ids.shape[0]):
        valid = out[lane][out[lane] >= 0]
        # no duplicates among surfaced ids
        assert len(valid) == len(set(valid.tolist()))
        # surfaced ids are a subset of the lane's non-padding candidates
        cand = set(int(x) for x in ids[lane] if x >= 0)
        assert set(valid.tolist()) <= cand
        # exactly min(k, |unique candidates|) surface
        assert len(valid) == min(k, len(cand))
        # -1 slots carry +inf and trail the finite ones
        fin = np.isfinite(d[lane])
        assert (out[lane][~fin] == -1).all()
        assert (np.diff(d[lane][fin]) >= 0).all()


def _check_lane_of_many(ids):
    ids = np.asarray(ids, np.int32)
    rng = np.random.default_rng(0)
    Q = rng.normal(size=(ids.shape[0], 8)).astype(np.float32)
    dm, im = _TIER.rerank_many(Q, ids, 4)
    for lane in range(ids.shape[0]):
        ds, js = _TIER.rerank(Q[lane], ids[lane], 4)
        np.testing.assert_array_equal(im[lane], js)
        np.testing.assert_array_equal(dm[lane], ds)


if HAVE_HYPOTHESIS:
    @given(st.lists(st.lists(st.integers(-1, 39), min_size=6, max_size=6),
                    min_size=1, max_size=5),
           st.integers(1, 8))
    @settings(max_examples=60, deadline=None)
    def test_rerank_many_padding_and_dup_properties(ids, k):
        _check_padding_and_dup_properties(ids, k)

    @given(st.lists(st.lists(st.integers(-1, 39), min_size=5, max_size=5),
                    min_size=2, max_size=4))
    @settings(max_examples=40, deadline=None)
    def test_rerank_is_lane_of_rerank_many(ids):
        _check_lane_of_many(ids)
else:
    @pytest.mark.parametrize("seed", range(25))
    def test_rerank_many_padding_and_dup_properties(seed):
        rng = np.random.default_rng(seed)
        b, w = int(rng.integers(1, 6)), 6
        # heavy -1 / duplicate density, like converging beams produce
        ids = rng.integers(-1, 12, size=(b, w))
        _check_padding_and_dup_properties(ids, int(rng.integers(1, 9)))

    @pytest.mark.parametrize("seed", range(15))
    def test_rerank_is_lane_of_rerank_many(seed):
        rng = np.random.default_rng(seed + 100)
        ids = rng.integers(-1, 12, size=(int(rng.integers(2, 5)), 5))
        _check_lane_of_many(ids)


def test_jnp_rerank_padding_and_dup_semantics():
    """The device-side rerank (repro.core.quantize.rerank/rerank_many)
    obeys the same -1 contract: padded ids never surface, duplicates
    count once."""
    from repro.core.quantize import rerank, rerank_many
    rng = np.random.default_rng(5)
    X = jnp.asarray(rng.normal(size=(20, 8)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    ids = jnp.asarray([3, 3, -1, 7, 7, 7, -1, 2], jnp.int32)
    d, out = rerank(q, X, ids, 6, "l2")
    out = np.asarray(out)
    valid = out[out >= 0]
    assert sorted(valid.tolist()) == [2, 3, 7]
    assert (np.asarray(d)[3:] == np.inf).all() and (out[3:] == -1).all()
    db_, outb = rerank_many(jnp.stack([q, q]), X, jnp.stack([ids, ids]), 6,
                            "l2")
    np.testing.assert_array_equal(np.asarray(outb[0]), out)
    np.testing.assert_array_equal(np.asarray(outb[1]), out)


# -- program cache / compiles ---------------------------------------------

def test_quantized_programs_key_on_residency(index, queries):
    """f32 and int8 programs coexist in one cache: same plan shape, two
    residency arms, no collision and no steady-state compiles across
    batch sizes within a bucket."""
    from repro.api.plan_compile import ProgramCache
    idx = dataclasses.replace(index, program_cache=ProgramCache(),
                              _qview=None, quantized=None)
    cache = idx.program_cache
    idx.search_many(queries[:5], k=6, efs=24)          # f32 program
    misses_after_f32 = cache.stats.misses
    idx.search_quantized_many(queries[:5], k=6, efs=24)   # int8 program
    assert cache.stats.misses == misses_after_f32 + 1
    steady = cache.stats.misses
    # same bucket (8): 5, 7, 8 lanes -> zero new compiles
    idx.search_quantized_many(queries[:7], k=6, efs=24)
    idx.search_quantized_many(queries[:8], k=6, efs=24)
    idx.search_quantized(queries[0], k=6, efs=24)      # single: 1 compile
    idx.search_quantized(queries[1], k=6, efs=24)      # ...then cached
    assert cache.stats.misses == steady + 1
    keys = {k_.resident for k_ in cache._programs}
    assert keys == {"f32", "int8"}


def test_zero_steady_state_compiles_across_bucket(index, queries):
    """CompileCounter gate: after warming one batch bucket, quantized
    searches at other batch sizes in the bucket compile NOTHING."""
    from repro.analysis.runtime import CompileCounter
    from repro.api.plan_compile import ProgramCache
    idx = dataclasses.replace(index, program_cache=ProgramCache(),
                              _qview=None, quantized=None)
    with CompileCounter() as cc:
        idx.search_quantized_many(queries[:8], k=6, efs=24)    # warm
        cc.mark("steady")
        idx.search_quantized_many(queries[:5], k=6, efs=24)
        idx.search_quantized_many(queries[:7], k=6, efs=24)
        idx.search_quantized_many(queries[:8], k=6, efs=24)
    assert cc.counts.get("steady", 0) == 0, cc.counts


# -- db + serving integration ---------------------------------------------

def test_db_quantize_index_execute(index, queries):
    from repro.api import NavixDB, Q

    db = NavixDB()
    db.register_index("chunks", dataclasses.replace(
        index, program_cache=None, _qview=None, quantized=None),
        table="Chunk")
    db.store.node("Chunk").add_column("cID", np.arange(index.graph.n))
    plan = Q.match("Chunk").knn(queries[0], k=6, efs=36).project("cID")
    rs_f32 = db.execute(plan)
    qidx = db.quantize_index("chunks")
    assert qidx.is_quantized and db.index("chunks") is qidx
    rs_q8 = db.execute(plan)
    assert rs_q8.ids.shape == rs_f32.ids.shape
    assert rs_q8.timings.rerank_ms > 0.0
    assert rs_f32.timings.rerank_ms == 0.0
    assert "rerank_ms" in rs_q8.timings.as_dict()
    # lane-for-lane vs the index-level API
    single = index.search_quantized(queries[0], k=6, efs=36)
    np.testing.assert_array_equal(rs_q8.ids, np.asarray(single.ids))
    # batch execute
    rs_b = db.execute(Q.match("Chunk").knn(queries[0], k=6, efs=36),
                      query=queries[:5])
    many = index.search_quantized_many(queries[:5], k=6, efs=36)
    np.testing.assert_array_equal(rs_b.ids, np.asarray(many.ids))


def test_db_quantize_sharded_rejected():
    import jax

    from repro.api.db import NavixDB
    from repro.core.distributed import ShardedNavix

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    X = np.random.default_rng(0).normal(size=(300, 16)).astype(np.float32)
    sn = ShardedNavix.build(X, NavixConfig(m_u=8, ef_construction=32), mesh)
    db = NavixDB()
    db.register_index("sharded", sn)
    with pytest.raises(ValueError, match="sharded"):
        db.quantize_index("sharded")


def test_serving_engine_over_quantized_index(index, queries):
    """The continuous scheduler serves a quantized-resident index:
    finalize re-ranks against the exact tier, and every response matches
    the single-query quantized search bitwise."""
    from repro.serving.engine import SearchEngine
    from repro.storage.columnar import GraphStore

    qidx = dataclasses.replace(index.quantize_resident(),
                               program_cache=None)
    store = GraphStore()
    store.add_node_table("Chunk", index.graph.n,
                         {"cID": np.arange(index.graph.n)})
    eng = SearchEngine(index=qidx, store=store, efs=30, max_batch=4,
                       scheduler="continuous", step_iters=3)
    rids = {eng.submit(q, k=6): i for i, q in enumerate(queries[:6])}
    responses = eng.drain()
    assert sorted(r.rid for r in responses) == sorted(rids)
    for r in responses:
        single = index.search_quantized(queries[rids[r.rid]], k=6, efs=30)
        np.testing.assert_array_equal(r.ids, np.asarray(single.ids),
                                      err_msg=f"rid {r.rid}")
        np.testing.assert_allclose(r.dists, np.asarray(single.dists),
                                   rtol=1e-5, atol=1e-5)
