"""Filtered-search behavior: the paper's Section 3/5.2 claims at test scale."""

import numpy as np
import pytest

HEURISTICS = ["onehop_s", "directed", "blind", "adaptive_g", "adaptive_local"]


def _mask(n, sigma, seed=3):
    rng = np.random.default_rng(seed)
    return rng.random(n) < sigma


def _recall_and_stats(index, queries, mask, heuristic, k=10, efs=80):
    _, true_ids = index.brute_force(queries, k=k, semimask=mask)
    got, t_dc, s_dc, picks = [], 0, 0, np.zeros(3)
    for q in queries:
        r = index.search(q, k=k, efs=efs, semimask=mask, heuristic=heuristic)
        got.append(np.asarray(r.ids))
        t_dc += int(r.stats.t_dc)
        s_dc += int(r.stats.s_dc)
        picks += np.asarray(r.stats.picks)
    rec = index.recall(np.stack(got), np.asarray(true_ids))
    return rec, t_dc / len(queries), s_dc / len(queries), picks


def test_results_respect_semimask(index, queries):
    mask = _mask(index.graph.n, 0.3)
    for h in HEURISTICS:
        r = index.search(queries[0], k=10, semimask=mask, heuristic=h)
        ids = np.asarray(r.ids)
        assert mask[ids[ids >= 0]].all(), f"{h} returned unselected ids"


@pytest.mark.parametrize("sigma", [0.5, 0.2, 0.05])
def test_two_hop_heuristics_recall(index, queries, sigma):
    mask = _mask(index.graph.n, sigma)
    for h in ("directed", "blind", "adaptive_local"):
        rec, *_ = _recall_and_stats(index, queries, mask, h)
        assert rec >= 0.85, f"{h} at sigma={sigma}: recall {rec}"


def test_onehop_s_degrades_at_low_selectivity(index, queries):
    """Figure 8: onehop-s recall collapses once the selected projection of
    G_H disconnects."""
    hi, *_ = _recall_and_stats(index, queries, _mask(index.graph.n, 0.9),
                               "onehop_s")
    lo, *_ = _recall_and_stats(index, queries, _mask(index.graph.n, 0.05),
                               "onehop_s")
    assert hi >= 0.9
    assert lo < hi - 0.2, f"expected collapse: hi={hi} lo={lo}"


def test_blind_tdc_equals_sdc(index, queries):
    """Section 5.2: for blind, t-dc always equals s-dc."""
    mask = _mask(index.graph.n, 0.2)
    _, t_dc, s_dc, _ = _recall_and_stats(index, queries, mask, "blind")
    assert t_dc == s_dc


def test_directed_pays_ordering_overhead(index, queries):
    """directed: t-dc >= s-dc, gap grows as selectivity falls."""
    for sigma in (0.5, 0.1):
        mask = _mask(index.graph.n, sigma)
        _, t_dc, s_dc, _ = _recall_and_stats(index, queries, mask, "directed")
        assert t_dc >= s_dc
    mask_lo = _mask(index.graph.n, 0.05)
    _, t_lo, s_lo, _ = _recall_and_stats(index, queries, mask_lo, "directed")
    assert t_lo / max(s_lo, 1) > 1.2, "overhead should be large at low sigma"


def test_adaptive_global_follows_rule(index, queries):
    """adaptive-g commits to ONE branch per query set, chosen by sigma_g."""
    for sigma, expected in ((0.9, 0), (0.2, 1), (0.004, 2)):
        mask = _mask(index.graph.n, sigma)
        *_, picks = _recall_and_stats(index, queries, mask, "adaptive_g")
        assert picks.argmax() == expected, (sigma, picks)


def test_adaptive_local_mixes_heuristics(index, clustered, queries):
    """Figure 11: with correlated S, adaptive-local picks different
    branches at different candidates."""
    X, labels, _ = clustered
    mask = np.isin(labels, [0, 1, 2])          # cluster-correlated subset
    *_, picks = _recall_and_stats(index, queries, mask, "adaptive_local")
    assert (picks > 0).sum() >= 2, f"expected a mix of branches: {picks}"


def test_adaptive_local_competitive_dc(index, queries):
    """adaptive-local should not use dramatically more selected-dc than the
    best fixed heuristic (it approximates the envelope)."""
    mask = _mask(index.graph.n, 0.15)
    best = None
    for h in ("onehop_s", "directed", "blind"):
        rec, t_dc, *_ = _recall_and_stats(index, queries, mask, h)
        if rec >= 0.85:
            best = min(best, t_dc) if best else t_dc
    rec_al, t_al, *_ = _recall_and_stats(index, queries, mask,
                                         "adaptive_local")
    assert rec_al >= 0.85
    assert t_al <= 2.5 * best, (t_al, best)


def test_empty_and_full_masks(index, queries):
    empty = np.zeros(index.graph.n, bool)
    r = index.search(queries[0], k=5, semimask=empty)
    assert (np.asarray(r.ids) == -1).all()
    full = np.ones(index.graph.n, bool)
    r = index.search(queries[0], k=5, semimask=full)
    assert (np.asarray(r.ids) >= 0).all()
