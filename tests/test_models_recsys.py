import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import get_arch
from repro.models import layers as L
from repro.models import recsys as R
from repro.models.api import make_retrieval_step, make_train_step, model_api

RECSYS = ["wide-deep", "deepfm", "dien", "bst"]


def make_batch(cfg, b, rng, labels=True):
    hot = max(cfg.multi_hot_sizes) if cfg.multi_hot_sizes else 1
    batch = {
        "dense": jnp.asarray(rng.normal(size=(b, cfg.n_dense)), jnp.float32),
        "sparse": jnp.asarray(np.stack(
            [rng.integers(0, cfg.field_vocabs[f], size=(b, hot))
             for f in range(cfg.n_sparse)], axis=1), jnp.int32),
    }
    if cfg.seq_len:
        batch["seq"] = jnp.asarray(
            rng.integers(0, cfg.item_vocab, size=(b, cfg.seq_len)), jnp.int32)
        batch["target_item"] = jnp.asarray(
            rng.integers(0, cfg.item_vocab, size=b), jnp.int32)
    if labels:
        batch["labels"] = jnp.asarray(rng.integers(0, 2, size=b), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", RECSYS)
def test_train_step_reduces_loss(arch_id):
    rng = np.random.default_rng(1)
    cfg = get_arch(arch_id).smoke_config
    api = model_api(cfg)
    params = api.init(jax.random.key(0))
    step, opt = make_train_step(cfg, lr=1e-2)
    opt_state = opt.init(params)
    batch = make_batch(cfg, 64, rng)
    jstep = jax.jit(step)
    first = None
    for _ in range(20):
        params, opt_state, m = jstep(params, opt_state, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < first, (arch_id, first, float(m["loss"]))


def test_embedding_bag_matches_oracle():
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    ids = np.array([[1, 2, -1], [5, 5, 7], [-1, -1, -1]], np.int32)
    out = np.asarray(L.embedding_bag(table, jnp.asarray(ids), "sum"))
    t = np.asarray(table)
    np.testing.assert_allclose(out[0], t[1] + t[2], rtol=1e-6)
    np.testing.assert_allclose(out[1], 2 * t[5] + t[7], rtol=1e-6)
    np.testing.assert_allclose(out[2], 0)
    mean = np.asarray(L.embedding_bag(table, jnp.asarray(ids), "mean"))
    np.testing.assert_allclose(mean[0], (t[1] + t[2]) / 2, rtol=1e-6)


def test_fm_interaction_identity():
    """DeepFM's FM term: sum-square identity == explicit pairwise sum."""
    rng = np.random.default_rng(3)
    cfg = get_arch("deepfm").smoke_config
    emb = rng.normal(size=(4, cfg.n_sparse, cfg.embed_dim)).astype(np.float32)
    sum_v = emb.sum(axis=1)
    fm_fast = 0.5 * (sum_v * sum_v - (emb * emb).sum(axis=1)).sum(axis=-1)
    fm_slow = np.zeros(4)
    for i in range(cfg.n_sparse):
        for j in range(i + 1, cfg.n_sparse):
            fm_slow += (emb[:, i] * emb[:, j]).sum(-1)
    np.testing.assert_allclose(fm_fast, fm_slow, rtol=1e-4, atol=1e-5)


def test_retrieval_scores_are_dot_products():
    rng = np.random.default_rng(4)
    cfg = get_arch("bst").smoke_config
    params = model_api(cfg).init(jax.random.key(0))
    batch = make_batch(cfg, 1, rng, labels=False)
    batch["candidates"] = jnp.asarray(rng.integers(0, cfg.item_vocab,
                                                   size=64), jnp.int32)
    scores = np.asarray(R.retrieval_scores(cfg, params, batch))
    assert scores.shape == (1, 64)
    vals, ids = jax.jit(make_retrieval_step(cfg, k=10))(params, batch)
    assert np.asarray(vals).shape == (1, 10)
    # top-1 really is the argmax of the scores
    assert np.asarray(ids)[0, 0] == np.asarray(batch["candidates"])[scores[0].argmax()]


def test_dien_attention_shifts_with_target():
    """DIEN: different target items must change the prediction (the AUGRU
    attention actually conditions on the target)."""
    rng = np.random.default_rng(5)
    cfg = get_arch("dien").smoke_config
    params = model_api(cfg).init(jax.random.key(0))
    batch = make_batch(cfg, 4, rng, labels=False)
    out1 = np.asarray(R.recsys_forward(cfg, params, batch))
    batch2 = dict(batch, target_item=(batch["target_item"] + 7) % cfg.item_vocab)
    out2 = np.asarray(R.recsys_forward(cfg, params, batch2))
    assert np.abs(out1 - out2).max() > 1e-6
