"""Every repro.* module imports cleanly.

Regression guard for the missing-``__init__.py`` class of packaging bug:
a subpackage that works under the repo's sys.path layout but is invisible
to ``import repro.<pkg>`` (and to wheel builds) because the marker file
is absent. Walks the source tree, derives the module name of every .py
file, and imports it.
"""

import importlib
import pathlib

import pytest

SRC = pathlib.Path(__file__).resolve().parent.parent / "src"


def _all_modules():
    mods = []
    for py in sorted((SRC / "repro").rglob("*.py")):
        if "__pycache__" in py.parts:
            continue
        rel = py.relative_to(SRC).with_suffix("")
        parts = list(rel.parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        mods.append(".".join(parts))
    return mods


def test_every_package_dir_has_init():
    missing = [str(d.relative_to(SRC))
               for d in sorted((SRC / "repro").rglob("*"))
               if d.is_dir() and d.name != "__pycache__"
               and not (d / "__init__.py").exists()]
    assert not missing, f"packages without __init__.py: {missing}"


@pytest.mark.parametrize("mod", _all_modules())
def test_module_imports(mod):
    importlib.import_module(mod)
