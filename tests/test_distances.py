import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.distances import (brute_force_topk, dist_matrix,
                                  gathered_dist, normalize)


@given(st.integers(0, 2**31 - 1), st.sampled_from(["l2", "cos", "dot"]))
@settings(max_examples=20, deadline=None)
def test_dist_matrix_vs_numpy(seed, metric):
    rng = np.random.default_rng(seed)
    Q = rng.normal(size=(5, 16)).astype(np.float32)
    X = rng.normal(size=(20, 16)).astype(np.float32)
    if metric == "cos":
        Q = np.asarray(normalize(jnp.asarray(Q)))
        X = np.asarray(normalize(jnp.asarray(X)))
    got = np.asarray(dist_matrix(jnp.asarray(Q), jnp.asarray(X), metric))
    if metric == "l2":
        exp = ((Q[:, None] - X[None]) ** 2).sum(-1)
    elif metric == "cos":
        exp = 1 - Q @ X.T
    else:
        exp = -(Q @ X.T)
    np.testing.assert_allclose(got, exp, rtol=1e-4, atol=1e-4)


def test_gathered_dist_padding():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(10, 8)).astype(np.float32))
    q = X[3]
    ids = jnp.asarray([3, 5, -1], jnp.int32)
    d = np.asarray(gathered_dist(q, X, ids, "l2"))
    assert d[0] == pytest.approx(0.0, abs=1e-5)
    assert np.isinf(d[2])


def test_brute_force_filtered():
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(size=(50, 8)).astype(np.float32))
    Q = X[:2]
    mask = jnp.asarray(np.arange(50) % 2 == 0)
    d, ids = brute_force_topk(Q, X, 5, "l2", mask=mask)
    ids = np.asarray(ids)
    assert (ids[ids >= 0] % 2 == 0).all()
    assert ids[0, 0] == 0 and ids[1, 1] != 1  # 1 is filtered out


def test_brute_force_fewer_than_k():
    rng = np.random.default_rng(2)
    X = jnp.asarray(rng.normal(size=(20, 4)).astype(np.float32))
    mask = jnp.asarray(np.arange(20) < 3)
    d, ids = brute_force_topk(X[:1], X, 10, "l2", mask=mask)
    assert (np.asarray(ids)[0] >= 0).sum() == 3
