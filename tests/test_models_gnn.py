import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import get_arch
from repro.models.api import make_train_step, model_api
from repro.models.gnn import gnn_forward


@pytest.fixture(scope="module")
def tiny_graph():
    rng = np.random.default_rng(0)
    cfg = get_arch("meshgraphnet").smoke_config
    n, e = 40, 120
    return cfg, {
        "node_feats": jnp.asarray(rng.normal(size=(n, cfg.in_node_dim)),
                                  jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, n, size=e), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, n, size=e), jnp.int32),
        "edge_feats": jnp.asarray(rng.normal(size=(e, cfg.in_edge_dim)),
                                  jnp.float32),
        "node_targets": jnp.asarray(rng.normal(size=(n, cfg.out_dim)),
                                    jnp.float32),
        "node_mask": jnp.ones(n, bool),
    }


def test_forward_shapes_and_finite(tiny_graph):
    cfg, batch = tiny_graph
    params = model_api(cfg).init(jax.random.key(0))
    out = gnn_forward(cfg, params, batch)
    assert out.shape == (40, cfg.out_dim)
    assert np.isfinite(np.asarray(out)).all()


def test_padding_edges_are_inert(tiny_graph):
    """-1-padded edges must not change predictions (padding contract of
    the dry-run's padded block sizes)."""
    cfg, batch = tiny_graph
    params = model_api(cfg).init(jax.random.key(0))
    base = np.asarray(gnn_forward(cfg, params, batch))
    padded = dict(batch)
    padded["edge_src"] = jnp.concatenate(
        [batch["edge_src"], jnp.full(16, -1, jnp.int32)])
    padded["edge_dst"] = jnp.concatenate(
        [batch["edge_dst"], jnp.full(16, -1, jnp.int32)])
    padded["edge_feats"] = jnp.concatenate(
        [batch["edge_feats"],
         jnp.ones((16, cfg.in_edge_dim), jnp.float32) * 99.0])
    got = np.asarray(gnn_forward(cfg, params, padded))
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


def test_message_passing_locality(tiny_graph):
    """Perturbing an isolated node's features must not affect others."""
    cfg, batch = tiny_graph
    n = 40
    # make node 0 isolated
    src = np.asarray(batch["edge_src"]).copy()
    dst = np.asarray(batch["edge_dst"]).copy()
    src[src == 0] = 1
    dst[dst == 0] = 1
    b = dict(batch, edge_src=jnp.asarray(src), edge_dst=jnp.asarray(dst))
    params = model_api(cfg).init(jax.random.key(0))
    base = np.asarray(gnn_forward(cfg, params, b))
    nf = np.asarray(b["node_feats"]).copy()
    nf[0] += 10.0
    got = np.asarray(gnn_forward(cfg, params,
                                 dict(b, node_feats=jnp.asarray(nf))))
    np.testing.assert_allclose(got[1:], base[1:], rtol=1e-4, atol=1e-4)
    assert np.abs(got[0] - base[0]).max() > 1e-4


def test_training_reduces_loss(tiny_graph):
    cfg, batch = tiny_graph
    api = model_api(cfg)
    params = api.init(jax.random.key(1))
    step, opt = make_train_step(cfg, lr=3e-3)
    opt_state = opt.init(params)
    jstep = jax.jit(step)
    first = None
    for i in range(25):
        params, opt_state, m = jstep(params, opt_state, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first * 0.8, (first, float(m["loss"]))


def test_neighbor_sampler_block():
    from repro.data.graph_sampler import NeighborSampler, random_power_law_graph
    csr, feats = random_power_law_graph(500, avg_degree=8, d_feat=12, seed=0)
    s = NeighborSampler(csr, fanouts=(5, 3), seed=0)
    block = s.sample_block(np.arange(16))
    n_pad = 16 * (1 + 5 + 15)
    assert block["node_ids"].shape[0] == n_pad
    assert (block["edge_dst"] < n_pad).all()
    # every real edge's endpoints map to real block nodes
    ok = block["edge_src"] >= 0
    assert (block["node_ids"][block["edge_src"][ok]] >= 0).all()
    # seeds come first
    np.testing.assert_array_equal(block["node_ids"][:16], np.arange(16))
