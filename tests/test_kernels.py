"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.distance_matrix import distance_matrix_pallas
from repro.kernels.gather_distance import (gather_distance_batch_pallas,
                                           gather_distance_pallas)
from repro.kernels.quantized import quantized_distance_pallas
from repro.kernels.segment_sum import (PAD_SENTINEL, csr_segment_sum_pallas,
                                       plan_tiles)

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("metric", ["l2", "cos", "dot"])
@pytest.mark.parametrize("b,n,d,bq,bn,bd", [
    (8, 128, 128, 8, 128, 128),
    (16, 256, 256, 16, 128, 128),
    (32, 384, 128, 8, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_distance_matrix_sweep(metric, b, n, d, bq, bn, bd, dtype):
    Q = jnp.asarray(RNG.normal(size=(b, d)), dtype)
    X = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    got = distance_matrix_pallas(Q, X, metric, bq=bq, bn=bn, bd=bd,
                                 interpret=True)
    exp = ref.distance_matrix(Q, X, metric)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("metric", ["l2", "cos", "dot"])
@pytest.mark.parametrize("n,d,k", [(64, 128, 7), (256, 256, 33), (100, 128, 1)])
def test_gather_distance_sweep(metric, n, d, k):
    q = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
    X = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    ids = jnp.asarray(RNG.integers(-1, n, size=k), jnp.int32)
    got = gather_distance_pallas(q, X, ids, metric, interpret=True)
    exp = ref.gather_distance(q, X, ids, metric)
    g, e = np.asarray(got), np.asarray(exp)
    np.testing.assert_array_equal(np.isinf(g), np.isinf(e))
    fin = np.isfinite(e)
    np.testing.assert_allclose(g[fin], e[fin], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("metric", ["l2", "cos", "dot"])
@pytest.mark.parametrize("b,n,d,k", [(4, 64, 128, 7), (8, 128, 128, 16),
                                     (1, 100, 256, 5)])
def test_gather_distance_batch_sweep(metric, b, n, d, k):
    """One pallas_call grid serves all B id lists (incl. -1 padded lanes,
    the engine's retired-query masking contract)."""
    Q = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
    X = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    ids = jnp.asarray(RNG.integers(-1, n, size=(b, k)), jnp.int32)
    ids = ids.at[0].set(-1)                     # a fully-retired lane
    got = gather_distance_batch_pallas(Q, X, ids, metric, interpret=True)
    exp = ref.gather_distance_batch(Q, X, ids, metric)
    g, e = np.asarray(got), np.asarray(exp)
    np.testing.assert_array_equal(np.isinf(g), np.isinf(e))
    fin = np.isfinite(e)
    np.testing.assert_allclose(g[fin], e[fin], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("metric", ["l2", "cos", "dot"])
@pytest.mark.parametrize("b,n,d", [(8, 128, 128), (16, 256, 256)])
def test_quantized_distance_sweep(metric, b, n, d):
    Q = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
    codes = jnp.asarray(RNG.integers(-127, 128, size=(n, d)), jnp.int8)
    scale = jnp.asarray(RNG.random(n) * 0.02 + 1e-3, jnp.float32)
    got = quantized_distance_pallas(Q, codes, scale, metric, bq=8,
                                    interpret=True)
    exp = ref.quantized_distance_matrix(Q, codes, scale, metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("e,d,n,bn,be", [
    (512, 64, 100, 128, 256),
    (1024, 128, 300, 128, 256),
    (256, 32, 1000, 128, 256),   # many empty blocks
])
def test_segment_sum_sweep(e, d, n, bn, be):
    dst = np.sort(RNG.integers(0, n, size=e)).astype(np.int32)
    msgs = jnp.asarray(RNG.normal(size=(e, d)), jnp.float32)
    first, t_max = plan_tiles(dst, n, bn, be, e)
    got = csr_segment_sum_pallas(msgs, jnp.asarray(dst), jnp.asarray(first),
                                 n, bn=bn, be=be, t_max=t_max, interpret=True)
    exp = ref.csr_segment_sum(msgs, jnp.asarray(dst), n)
    np.testing.assert_allclose(np.asarray(got)[:n], np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_segment_sum_with_sentinel_padding():
    n, e, d = 50, 256, 16
    dst = np.sort(RNG.integers(0, n, size=e - 20)).astype(np.int32)
    dst = np.concatenate([dst, np.full(20, PAD_SENTINEL, np.int32)])
    msgs = jnp.asarray(RNG.normal(size=(e, d)), jnp.float32)
    first, t_max = plan_tiles(dst, n, 128, 256, e)
    got = csr_segment_sum_pallas(msgs, jnp.asarray(dst), jnp.asarray(first),
                                 n, t_max=t_max, interpret=True)
    exp = ref.csr_segment_sum(msgs[:-20], jnp.asarray(dst[:-20]), n)
    np.testing.assert_allclose(np.asarray(got)[:n], np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_ops_wrappers_pad_odd_shapes(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    from repro.kernels import ops
    Q = jnp.asarray(RNG.normal(size=(5, 61)), jnp.float32)
    X = jnp.asarray(RNG.normal(size=(77, 61)), jnp.float32)
    got = ops.distance_matrix(Q, X, "l2")
    exp = ref.distance_matrix(Q, X, "l2")
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)
