"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.distance_matrix import distance_matrix_pallas
from repro.kernels.gather_distance import (
    gather_distance_batch_pallas, gather_distance_pallas,
    quantized_gather_distance_batch_pallas, quantized_gather_distance_pallas)
from repro.kernels.quantized import quantized_distance_pallas
from repro.kernels.segment_sum import (PAD_SENTINEL, csr_segment_sum_pallas,
                                       plan_tiles)

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("metric", ["l2", "cos", "dot"])
@pytest.mark.parametrize("b,n,d,bq,bn,bd", [
    (8, 128, 128, 8, 128, 128),
    (16, 256, 256, 16, 128, 128),
    (32, 384, 128, 8, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_distance_matrix_sweep(metric, b, n, d, bq, bn, bd, dtype):
    Q = jnp.asarray(RNG.normal(size=(b, d)), dtype)
    X = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    got = distance_matrix_pallas(Q, X, metric, bq=bq, bn=bn, bd=bd,
                                 interpret=True)
    exp = ref.distance_matrix(Q, X, metric)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("metric", ["l2", "cos", "dot"])
@pytest.mark.parametrize("n,d,k", [(64, 128, 7), (256, 256, 33), (100, 128, 1)])
def test_gather_distance_sweep(metric, n, d, k):
    q = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
    X = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    ids = jnp.asarray(RNG.integers(-1, n, size=k), jnp.int32)
    got = gather_distance_pallas(q, X, ids, metric, interpret=True)
    exp = ref.gather_distance(q, X, ids, metric)
    g, e = np.asarray(got), np.asarray(exp)
    np.testing.assert_array_equal(np.isinf(g), np.isinf(e))
    fin = np.isfinite(e)
    np.testing.assert_allclose(g[fin], e[fin], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("metric", ["l2", "cos", "dot"])
@pytest.mark.parametrize("b,n,d,k", [(4, 64, 128, 7), (8, 128, 128, 16),
                                     (1, 100, 256, 5)])
def test_gather_distance_batch_sweep(metric, b, n, d, k):
    """One pallas_call grid serves all B id lists (incl. -1 padded lanes,
    the engine's retired-query masking contract)."""
    Q = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
    X = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    ids = jnp.asarray(RNG.integers(-1, n, size=(b, k)), jnp.int32)
    ids = ids.at[0].set(-1)                     # a fully-retired lane
    got = gather_distance_batch_pallas(Q, X, ids, metric, interpret=True)
    exp = ref.gather_distance_batch(Q, X, ids, metric)
    g, e = np.asarray(got), np.asarray(exp)
    np.testing.assert_array_equal(np.isinf(g), np.isinf(e))
    fin = np.isfinite(e)
    np.testing.assert_allclose(g[fin], e[fin], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("metric", ["l2", "cos", "dot"])
@pytest.mark.parametrize("b,n,d", [(8, 128, 128), (16, 256, 256)])
def test_quantized_distance_sweep(metric, b, n, d):
    Q = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
    codes = jnp.asarray(RNG.integers(-127, 128, size=(n, d)), jnp.int8)
    scale = jnp.asarray(RNG.random(n) * 0.02 + 1e-3, jnp.float32)
    got = quantized_distance_pallas(Q, codes, scale, metric, bq=8,
                                    interpret=True)
    exp = ref.quantized_distance_matrix(Q, codes, scale, metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("metric", ["l2", "cos", "dot"])
@pytest.mark.parametrize("n,d,k", [(64, 128, 7), (200, 256, 17)])
def test_quantized_gather_distance_sweep(metric, n, d, k):
    """Int8 gather+distance kernel vs the pure-jnp dequantizing ref."""
    q = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
    codes = jnp.asarray(RNG.integers(-127, 128, size=(n, d)), jnp.int8)
    scale = jnp.asarray(RNG.random(n) * 0.02 + 1e-3, jnp.float32)
    ids = jnp.asarray(RNG.integers(-1, n, size=k), jnp.int32)
    got = quantized_gather_distance_pallas(q, codes, scale, ids, metric,
                                           interpret=True)
    exp = ref.quantized_gather_distance(q, codes, scale, ids, metric)
    g, e = np.asarray(got), np.asarray(exp)
    np.testing.assert_array_equal(np.isinf(g), np.isinf(e))
    fin = np.isfinite(e)
    np.testing.assert_allclose(g[fin], e[fin], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("metric", ["l2", "cos", "dot"])
@pytest.mark.parametrize("b,n,d,k", [(4, 64, 128, 7), (8, 128, 128, 16)])
def test_quantized_gather_distance_batch_sweep(metric, b, n, d, k):
    Q = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
    codes = jnp.asarray(RNG.integers(-127, 128, size=(n, d)), jnp.int8)
    scale = jnp.asarray(RNG.random(n) * 0.02 + 1e-3, jnp.float32)
    ids = jnp.asarray(RNG.integers(-1, n, size=(b, k)), jnp.int32)
    ids = ids.at[0].set(-1)                     # a fully-retired lane
    got = quantized_gather_distance_batch_pallas(Q, codes, scale, ids,
                                                 metric, interpret=True)
    exp = ref.quantized_gather_distance_batch(Q, codes, scale, ids, metric)
    g, e = np.asarray(got), np.asarray(exp)
    np.testing.assert_array_equal(np.isinf(g), np.isinf(e))
    fin = np.isfinite(e)
    np.testing.assert_allclose(g[fin], e[fin], rtol=1e-4, atol=1e-4)


def test_quantized_gather_matches_dequantize_then_gather():
    """The per-row dequantizing gather is bitwise what dequantize-the-
    store-then-gather computes (gather of a product == product of the
    gathers), so the quantized-resident engine's distances are exactly
    the dequantized engine's distances."""
    n, d, k = 90, 32, 21
    codes = jnp.asarray(RNG.integers(-127, 128, size=(n, d)), jnp.int8)
    scale = jnp.asarray(RNG.random(n) * 0.02 + 1e-3, jnp.float32)
    q = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
    ids = jnp.asarray(RNG.integers(-1, n, size=k), jnp.int32)
    full = codes.astype(jnp.float32) * scale[:, None]   # the [n, d] buffer
    exp = ref.gather_distance(q, full, ids, "l2")       # ...we never build
    got = ref.quantized_gather_distance(q, codes, scale, ids, "l2")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


@pytest.mark.parametrize("metric", ["l2", "cos", "dot"])
@pytest.mark.parametrize("b,n,d", [
    (5, 130, 61),      # every axis off the 128 tile
    (3, 127, 32),      # n one short of a tile
    (9, 200, 100),
])
def test_quantized_distance_matrix_padding(monkeypatch, metric, b, n, d):
    """ops.quantized_distance_matrix at non-multiple-of-128 b/n/d: the
    wrapper zero-pads codes AND scales, so padded rows carry scale == 0
    (a legal store row: an all-zero vector quantizes to scale 0). Real
    rows must come back exactly as the ref computes them."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    from repro.kernels import ops
    Q = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
    codes = jnp.asarray(RNG.integers(-127, 128, size=(n, d)), jnp.int8)
    scale = jnp.asarray(RNG.random(n) * 0.02 + 1e-3, jnp.float32)
    got = ops.quantized_distance_matrix(Q, codes, scale, metric)
    assert got.shape == (b, n)
    exp = ref.quantized_distance_matrix(Q, codes, scale, metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-3, atol=1e-3)


def test_quantized_distance_matrix_zero_scale_rows(monkeypatch):
    """Zero-scale rows INSIDE the store (all-zero vectors) under l2:
    their distance is ||q||^2, not inf/nan, both in kernel and ref."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    from repro.kernels import ops
    b, n, d = 4, 70, 48
    Q = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
    codes = jnp.asarray(RNG.integers(-127, 128, size=(n, d)), jnp.int8)
    scale = jnp.asarray(RNG.random(n) * 0.02 + 1e-3, jnp.float32)
    scale = scale.at[::7].set(0.0)
    got = np.asarray(ops.quantized_distance_matrix(Q, codes, scale, "l2"))
    exp = np.asarray(ref.quantized_distance_matrix(Q, codes, scale, "l2"))
    np.testing.assert_allclose(got, exp, rtol=1e-3, atol=1e-3)
    qn = np.sum(np.asarray(Q) ** 2, axis=1)
    np.testing.assert_allclose(got[:, ::7],
                               np.broadcast_to(qn[:, None],
                                               got[:, ::7].shape),
                               rtol=1e-3, atol=1e-3)
    assert np.isfinite(got).all()


def test_quantized_gather_ops_pad_odd_shapes(monkeypatch):
    """The ops wrappers zero-pad d to the lane multiple; padded dims
    contribute 0 under every metric, so odd-d results match the ref."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    from repro.kernels import ops
    n, d, k, b = 80, 61, 13, 5
    codes = jnp.asarray(RNG.integers(-127, 128, size=(n, d)), jnp.int8)
    scale = jnp.asarray(RNG.random(n) * 0.02 + 1e-3, jnp.float32)
    for metric in ("l2", "cos", "dot"):
        q = jnp.asarray(RNG.normal(size=(d,)), jnp.float32)
        ids = jnp.asarray(RNG.integers(-1, n, size=k), jnp.int32)
        got = ops.quantized_gather_distance(q, codes, scale, ids, metric)
        exp = ref.quantized_gather_distance(q, codes, scale, ids, metric)
        g, e = np.asarray(got), np.asarray(exp)
        fin = np.isfinite(e)
        np.testing.assert_array_equal(np.isinf(g), np.isinf(e))
        np.testing.assert_allclose(g[fin], e[fin], rtol=1e-4, atol=1e-4)
        Q = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
        idsb = jnp.asarray(RNG.integers(-1, n, size=(b, k)), jnp.int32)
        gotb = ops.quantized_gather_distance_batch(Q, codes, scale, idsb,
                                                   metric)
        expb = ref.quantized_gather_distance_batch(Q, codes, scale, idsb,
                                                   metric)
        gb, eb = np.asarray(gotb), np.asarray(expb)
        finb = np.isfinite(eb)
        np.testing.assert_array_equal(np.isinf(gb), np.isinf(eb))
        np.testing.assert_allclose(gb[finb], eb[finb], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("e,d,n,bn,be", [
    (512, 64, 100, 128, 256),
    (1024, 128, 300, 128, 256),
    (256, 32, 1000, 128, 256),   # many empty blocks
])
def test_segment_sum_sweep(e, d, n, bn, be):
    dst = np.sort(RNG.integers(0, n, size=e)).astype(np.int32)
    msgs = jnp.asarray(RNG.normal(size=(e, d)), jnp.float32)
    first, t_max = plan_tiles(dst, n, bn, be, e)
    got = csr_segment_sum_pallas(msgs, jnp.asarray(dst), jnp.asarray(first),
                                 n, bn=bn, be=be, t_max=t_max, interpret=True)
    exp = ref.csr_segment_sum(msgs, jnp.asarray(dst), n)
    np.testing.assert_allclose(np.asarray(got)[:n], np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_segment_sum_with_sentinel_padding():
    n, e, d = 50, 256, 16
    dst = np.sort(RNG.integers(0, n, size=e - 20)).astype(np.int32)
    dst = np.concatenate([dst, np.full(20, PAD_SENTINEL, np.int32)])
    msgs = jnp.asarray(RNG.normal(size=(e, d)), jnp.float32)
    first, t_max = plan_tiles(dst, n, 128, 256, e)
    got = csr_segment_sum_pallas(msgs, jnp.asarray(dst), jnp.asarray(first),
                                 n, t_max=t_max, interpret=True)
    exp = ref.csr_segment_sum(msgs[:-20], jnp.asarray(dst[:-20]), n)
    np.testing.assert_allclose(np.asarray(got)[:n], np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_ops_wrappers_pad_odd_shapes(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    from repro.kernels import ops
    Q = jnp.asarray(RNG.normal(size=(5, 61)), jnp.float32)
    X = jnp.asarray(RNG.normal(size=(77, 61)), jnp.float32)
    got = ops.distance_matrix(Q, X, "l2")
    exp = ref.distance_matrix(Q, X, "l2")
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)
