"""Sharded mixed-plan batching: distributed equivalence + properties.

The contract: ``ShardedNavix.search_many`` with per-lane masks is
lane-for-lane identical (ids, dists, aggregated stats) to the unsharded
batched engine (``core.search_batch.search_many``) run per shard over
shard-restricted masks and merged host-side under the same
(distance, global id) lexicographic rule -- for every heuristic and
shard count, with sigma in {0, small, 1} lanes fused in one batch.
Quorum drops are exactly "restrict the reference to the alive shards",
and padded rows (ShardedNavix.build pads with copies of the last row)
can never surface, even under a caller-built all-ones local bitset or
the semimask-ignoring ONEHOP_A branch.

S > 1 cases need host devices: run with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (CI does; the
merge property tests are device-count independent).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitset
from repro.core.distributed import (ShardedNavix, merge_shard_topk,
                                    per_shard_reference)
from repro.core.navix import NavixConfig

HEURISTICS = ["onehop_s", "directed", "blind", "adaptive_g",
              "adaptive_local", "onehop_a"]
#: sigma=0 and sigma=1 lanes fused with mid/low selectivities in one batch
SIGMAS = [1.0, 0.4, 0.1, 0.0, 0.03, 0.7]
K, EFS = 6, 24


def _need(s):
    return pytest.mark.skipif(
        len(jax.devices()) < s,
        reason=f"needs {s} host devices "
               f"(XLA_FLAGS=--xla_force_host_platform_device_count={s})")


SHARD_COUNTS = [pytest.param(1), pytest.param(2, marks=_need(2)),
                pytest.param(4, marks=_need(4))]

STAT_FIELDS = ("iters", "t_dc", "s_dc", "upper_dc", "picks")


def _lane_masks(n, sigmas, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for s in sigmas:
        if s >= 1.0:
            out.append(np.ones(n, bool))
        elif s <= 0.0:
            out.append(np.zeros(n, bool))
        else:
            out.append(rng.random(n) < s)
    return np.stack(out)


# -- lane-for-lane equivalence ----------------------------------------------
# (the oracle is repro.core.distributed.per_shard_reference: the unsharded
# batched engine per shard + numpy lexicographic merge -- shared with the
# bench_serving --shards drift gate so the contract has ONE definition)


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("heuristic", HEURISTICS)
def test_sharded_matches_per_shard_reference(shard_env, n_shards, heuristic):
    X, queries, factory = shard_env
    sn = factory(n_shards)
    n = sn.n_total
    masks = _lane_masks(n, SIGMAS, seed=3)
    Q = queries[:len(SIGMAS)]
    params = sn._params(K, EFS, heuristic)

    res = sn.search_many(Q, semimask=masks, k=K, efs=EFS,
                         heuristic=heuristic)
    ref_d, ref_i, ref_stats = per_shard_reference(sn, Q, masks, params)
    np.testing.assert_array_equal(np.asarray(res.ids), ref_i,
                                  err_msg=f"ids ({heuristic}, S={n_shards})")
    np.testing.assert_array_equal(np.asarray(res.dists), ref_d)
    for f in STAT_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(res.stats, f)), getattr(ref_stats, f),
            err_msg=f"stats.{f} ({heuristic}, S={n_shards})")
    if heuristic != "onehop_a":          # onehop_a ignores the semimask
        # every returned id is in that lane's own S
        ids = np.asarray(res.ids)
        for b in range(len(SIGMAS)):
            row = ids[b][ids[b] >= 0]
            assert masks[b][row].all(), f"lane {b} returned unselected ids"
        assert (ids[3] == -1).all(), "sigma=0 lane must come back empty"


@pytest.mark.parametrize("n_shards", [pytest.param(2, marks=_need(2)),
                                      pytest.param(4, marks=_need(4))])
def test_quorum_dead_shard_equals_alive_restricted(shard_env, n_shards):
    """One dead shard => results identical to the reference merged over
    the alive shards only (the unsharded search restricted to the alive
    shards' vectors), and no dead-shard id appears."""
    X, queries, factory = shard_env
    sn = factory(n_shards)
    masks = _lane_masks(sn.n_total, [0.5, 1.0, 0.08, 0.3], seed=11)
    Q = queries[:4]
    params = sn._params(K, EFS, "adaptive_local")
    dead = n_shards - 1
    alive = np.ones(n_shards, bool)
    alive[dead] = False

    res = sn.search_many(Q, semimask=masks, k=K, efs=EFS, alive=alive,
                         quorum=n_shards - 1)
    ref_d, ref_i, ref_stats = per_shard_reference(sn, Q, masks, params,
                                                  alive=alive)
    np.testing.assert_array_equal(np.asarray(res.ids), ref_i)
    np.testing.assert_array_equal(np.asarray(res.dists), ref_d)
    for f in STAT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(res.stats, f)),
                                      getattr(ref_stats, f))
    ids = np.asarray(res.ids)
    shard_of = ids[ids >= 0] // sn.n_local
    assert (shard_of != dead).all(), "dead shard leaked ids into the merge"

    with pytest.raises(RuntimeError, match="quorum"):
        sn.search_many(Q, semimask=masks, k=K, alive=alive,
                       quorum=n_shards)
    with pytest.raises(ValueError, match="alive"):
        # a wrong-length mask would silently clamp inside jit
        sn.search_many(Q, semimask=masks, k=K, alive=alive[:1])


@pytest.mark.parametrize("n_shards", [pytest.param(2, marks=_need(2))])
def test_shared_mask_fast_path_matches_per_lane(shard_env, n_shards):
    """A shared bool[n] semimask (the [S, W] broadcast fast path) returns
    exactly what the per-lane stack of B copies returns."""
    X, queries, factory = shard_env
    sn = factory(n_shards)
    mask = _lane_masks(sn.n_total, [0.35], seed=5)[0]
    Q = queries[:4]
    a = sn.search_many(Q, semimask=mask, k=K, efs=EFS)
    b = sn.search_many(Q, semimask=np.broadcast_to(mask, (4, sn.n_total)),
                       k=K, efs=EFS)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    # and a per-lane search_fn lane-broadcasts a shared [S, W] mask
    fn = sn.search_fn(K, EFS, per_lane=True)
    d, ids = fn(sn._prep_query(Q), sn.shard_semimask(mask),
                jnp.ones(n_shards, bool))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(a.ids))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(a.dists))


@pytest.mark.parametrize("n_shards", [pytest.param(2, marks=_need(2))])
def test_search_compat_wrapper(shard_env, n_shards):
    """The legacy (dists, ids) surface rides the batched engine now."""
    X, queries, factory = shard_env
    sn = factory(n_shards)
    mask = _lane_masks(sn.n_total, [0.4], seed=9)[0]
    d, ids = sn.search(queries[:4], mask, k=K, efs=EFS)
    res = sn.search_many(queries[:4], semimask=mask, k=K, efs=EFS)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(res.ids))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(res.dists))
    sel = np.asarray(ids)
    assert mask[sel[sel >= 0]].all()


# -- padded rows (ShardedNavix.build pads with copies of the last row) -------


@pytest.fixture(scope="module")
def padded_sn():
    """An index whose row count does NOT divide the shard count: 641
    rows over 2 shards -> n_local=321, one padded copy of row 640."""
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 host devices")
    from repro.data.synthetic import gaussian_mixture
    X, _, centers = gaussian_mixture(641, 16, 8, seed=2)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    sn = ShardedNavix.build(
        X, NavixConfig(m_u=8, ef_construction=48, metric="l2", seed=0), mesh)
    assert sn.n_shards * sn.n_local > sn.n_total, "fixture must pad"
    rng = np.random.default_rng(3)
    Q = (centers[:4] + 0.25 * rng.normal(size=(4, 16))).astype(np.float32)
    return sn, Q


@pytest.mark.parametrize("heuristic", ["adaptive_local", "onehop_a"])
def test_padded_index_all_ones_mask_never_returns_padded_id(
        padded_sn, heuristic):
    """Regression (ISSUE 4 satellite): an all-ones semimask on a padded
    index can never return a padded id -- including ONEHOP_A, which
    ignores the semimask entirely."""
    sn, Q = padded_sn
    res = sn.search_many(Q, semimask=None, k=K, efs=EFS,
                         heuristic=heuristic)
    ids = np.asarray(res.ids)
    assert (ids < sn.n_total).all(), "padded id surfaced"
    for b in range(ids.shape[0]):
        row = ids[b][ids[b] >= 0]
        assert len(set(row.tolist())) == len(row), \
            "padded duplicate of the last row surfaced twice"


def test_padded_index_caller_built_full_local_bitset_is_guarded(padded_sn):
    """The dangerous path: a caller hand-packs full_mask(n_local) per
    shard, which marks the padded rows selected. The structural guard in
    the merge must still drop them."""
    sn, Q = padded_sn
    full_local = jnp.broadcast_to(bitset.full_mask(sn.n_local),
                                  (sn.n_shards, sn.n_words_local))
    assert int(bitset.count_batch(full_local).sum()) \
        == sn.n_shards * sn.n_local      # padded bits genuinely set
    res = sn.search_many(Q, semimask=np.asarray(full_local), k=K, efs=EFS)
    ids = np.asarray(res.ids)
    assert (ids < sn.n_total).all(), "padded id surfaced past the guard"
    for b in range(ids.shape[0]):
        row = ids[b][ids[b] >= 0]
        assert len(set(row.tolist())) == len(row)


# -- NavixDB routing + the `sharded` program-cache arm -----------------------


@pytest.mark.parametrize("n_shards", [pytest.param(2, marks=_need(2))])
def test_db_execute_routes_sharded_with_per_query_masks(shard_env, n_shards):
    from repro.api import NavixDB
    from repro.query.operators import KnnSearch

    X, queries, factory = shard_env
    sn = factory(n_shards)
    n = sn.n_total
    db = NavixDB()
    db.register_index("default", sn)
    masks = [np.arange(n) < n // 4, None, np.arange(n) % 2 == 0]
    plan = KnnSearch(child=None, table="default", k=5, efs=30)
    rs = db.execute(plan, query=queries[:3], masks=masks)
    assert rs.ids.shape == (3, 5)
    assert rs.sigmas is not None
    assert rs.sigmas[0] == pytest.approx(0.25, abs=0.01)
    assert rs.sigmas[1] == pytest.approx(1.0)
    ids0 = rs.ids[0][rs.ids[0] >= 0]
    assert (ids0 < n // 4).all()
    ids2 = rs.ids[2][rs.ids[2] >= 0]
    assert (ids2 % 2 == 0).all()

    # identical to the direct sharded engine call
    ref = sn.search_many(queries[:3],
                         semimask=[masks[0], None, masks[2]], k=5, efs=30)
    np.testing.assert_array_equal(rs.ids, np.asarray(ref.ids))

    # the sharded arm caches: a same-shape re-execution compiles nothing
    before = db.programs.stats.misses
    rs2 = db.execute(plan, query=queries[:3], masks=masks)
    assert db.programs.stats.misses == before, \
        "same-shape sharded plan must be a cache hit"
    np.testing.assert_array_equal(rs.ids, rs2.ids)

    # single-query lift + alive threading
    rs3 = db.execute(plan, query=queries[0])
    assert rs3.ids.shape == (5,)
    alive = np.array([True, False])
    rs4 = db.execute(plan, query=queries[0], alive=alive)
    ids4 = rs4.ids[rs4.ids >= 0]
    assert (ids4 < sn.n_local).all(), "dead shard leaked through execute"

    with pytest.raises(ValueError, match="batched"):
        db.execute(plan, query=queries[:3], engine="vmap")


# -- shard-merge properties (device-count independent) -----------------------


def _random_shard_lists(s, b, l, seed, pad_frac):
    """Per-shard candidate lists with duplicate distances and random
    padding; ids unique across (shard, slot) like real shard-local
    results (shards own disjoint global id ranges)."""
    rng = np.random.default_rng(seed)
    # few distinct values => many cross-shard distance ties
    d = rng.choice([0.0, 0.25, 0.5, 1.0, 2.0], size=(s, b, l))
    ids = np.broadcast_to(
        (np.arange(s)[:, None, None] * l + np.arange(l)[None, None, :]),
        (s, b, l)).copy().astype(np.int32)
    pad = rng.random((s, b, l)) < pad_frac
    d = np.where(pad, np.inf, d).astype(np.float32)
    ids = np.where(pad, -1, ids)
    return d, ids


def test_merge_topk_properties():
    """Random shard counts / paddings / duplicate distances: the merged
    top-k is sorted, contains no padded-slot ids, no id twice, and is
    exactly the numpy lexicographic-(d, id) reference."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @given(s=st.integers(1, 5), b=st.integers(1, 3), l=st.integers(1, 6),
           k_frac=st.floats(0.1, 1.5), seed=st.integers(0, 2**31 - 1),
           pad_frac=st.sampled_from([0.0, 0.3, 0.95]))
    @settings(max_examples=40, deadline=None)
    def run(s, b, l, k_frac, seed, pad_frac):
        k = max(1, min(int(k_frac * s * l), s * l))
        d, ids = _random_shard_lists(s, b, l, seed, pad_frac)
        out_d, out_i = merge_shard_topk(jnp.asarray(d), jnp.asarray(ids), k)
        out_d, out_i = np.asarray(out_d), np.asarray(out_i)
        flat_d = np.swapaxes(d, 0, 1).reshape(b, s * l)
        flat_i = np.swapaxes(ids, 0, 1).reshape(b, s * l)
        for row in range(b):
            # sorted ascending
            assert (np.diff(out_d[row]) >= 0).all()
            finite = np.isfinite(out_d[row])
            # -1 exactly on the +inf (padded / exhausted) slots
            np.testing.assert_array_equal(out_i[row] >= 0, finite)
            got = out_i[row][finite]
            # no id twice, no padded-slot id
            assert len(set(got.tolist())) == len(got)
            assert np.isin(got, flat_i[row][flat_i[row] >= 0]).all()
            # exactly the numpy lexicographic-(d, id) reference
            order = np.lexsort((flat_i[row], flat_d[row]))[:k]
            ref_d = flat_d[row][order]
            ref_i = np.where(np.isfinite(ref_d), flat_i[row][order], -1)
            np.testing.assert_array_equal(out_d[row], ref_d)
            np.testing.assert_array_equal(out_i[row], ref_i)

    run()


def test_merge_topk_shard_order_invariant():
    """Permuting the shard axis never changes the merged output -- the
    (distance, id) tie-break is shard-order free even with duplicate
    distances across shards."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @given(s=st.integers(2, 5), b=st.integers(1, 3), l=st.integers(1, 6),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def run(s, b, l, seed):
        k = max(1, (s * l) // 2)
        d, ids = _random_shard_lists(s, b, l, seed, pad_frac=0.3)
        perm = np.random.default_rng(seed + 1).permutation(s)
        a_d, a_i = merge_shard_topk(jnp.asarray(d), jnp.asarray(ids), k)
        p_d, p_i = merge_shard_topk(jnp.asarray(d[perm]),
                                    jnp.asarray(ids[perm]), k)
        np.testing.assert_array_equal(np.asarray(a_d), np.asarray(p_d))
        np.testing.assert_array_equal(np.asarray(a_i), np.asarray(p_i))

    run()


# -- shard-aware bitset primitives (deterministic; kept out of
# test_bitset.py, whose module-level hypothesis importorskip would skip
# them in hypothesis-less environments) --------------------------------------


def test_count_members_batch_matches_vmap_oracle():
    """The flattened-gather form must stay integer-exact against
    vmap(count_members) on the 2-D lane form the engine hot loop uses."""
    rng = np.random.default_rng(0)
    mask = rng.random((5, 70)) < 0.4
    ids = rng.integers(-1, 70, size=(5, 9)).astype(np.int32)
    bits = bitset.pack(jnp.asarray(mask))
    oracle = jax.vmap(bitset.count_members)(bits, jnp.asarray(ids))
    np.testing.assert_array_equal(
        np.asarray(bitset.count_members_batch(bits, jnp.asarray(ids))),
        np.asarray(oracle))


def test_broadcast_shard_lanes():
    bits = jnp.arange(6, dtype=jnp.uint32).reshape(2, 3)      # [S=2, W=3]
    out = bitset.broadcast_shard_lanes(bits, 4)
    assert out.shape == (2, 4, 3)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.tile(np.arange(6, dtype=np.uint32)
                                          .reshape(2, 1, 3), (1, 4, 1)))
    # per-lane input passes through; wrong lane count raises
    np.testing.assert_array_equal(np.asarray(
        bitset.broadcast_shard_lanes(out, 4)), np.asarray(out))
    with pytest.raises(ValueError, match="lanes"):
        bitset.broadcast_shard_lanes(out, 5)


def test_merge_topk_rejects_overlong_k():
    d = jnp.zeros((2, 1, 3), jnp.float32)
    i = jnp.zeros((2, 1, 3), jnp.int32)
    with pytest.raises(ValueError, match="merge candidates"):
        merge_shard_topk(d, i, 7)
