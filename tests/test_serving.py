import jax
import numpy as np
import pytest

from repro.query.operators import Filter, NodeScan
from repro.serving.engine import SearchEngine
from repro.storage.columnar import GraphStore

needs_2_devices = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs 2 host devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


@pytest.fixture()
def engine(index):
    store = GraphStore()
    store.add_node_table("Chunk", index.graph.n,
                         {"cID": np.arange(index.graph.n)})
    return SearchEngine(index=index, store=store, efs=60)


def _mixed_plan_engine(index, **kw):
    store = GraphStore()
    store.add_node_table("Chunk", index.graph.n,
                         {"cID": np.arange(index.graph.n)})
    return SearchEngine(index=index, store=store, **kw)


def test_continuous_scheduler_mixed_plans_exactly_once(index, queries):
    """Mixed-plan fusing under refill: more requests than lanes, every
    plan distinct, every rid answered exactly once -- and each response
    is bitwise the single-query search over that request's own S."""
    n = index.graph.n
    eng = _mixed_plan_engine(index, efs=30, max_batch=4,
                             scheduler="continuous", step_iters=3,
                             refill_threshold=1)
    cutoffs = [n // 10, n // 5, n // 3, n // 2, 2 * n // 3, n,
               n // 8, n // 4, 3 * n // 4, n // 2, n // 6, n]
    rids = {}
    for j, cut in enumerate(cutoffs):
        plan = Filter(NodeScan("Chunk"), "cID", "<", value=cut)
        rid = eng.submit(queries[j % len(queries)], plan=plan, k=6)
        rids[rid] = (j, cut)
    responses = eng.drain()
    assert sorted(r.rid for r in responses) == sorted(rids), \
        "every rid must be answered exactly once"
    for r in responses:
        j, cut = rids[r.rid]
        mask = np.arange(n) < cut
        assert r.sigma == pytest.approx(cut / n, abs=1e-6), \
            "Response.sigma must be the request's OWN selectivity"
        single = index.search(queries[j % len(queries)], k=6, efs=30,
                              semimask=mask)
        np.testing.assert_array_equal(r.ids, np.asarray(single.ids),
                                      err_msg=f"rid {r.rid} (cut={cut})")
        np.testing.assert_array_equal(r.dists, np.asarray(single.dists))
    assert eng.latency_summary()["n"] == len(cutoffs)


def test_refill_admits_while_other_lanes_still_live(index, queries):
    """Continuous scheduling, not batch-convergence scheduling: with
    more requests than lanes and refill_threshold=1, a converged lane
    must be flushed and refilled from the queue while OTHER lanes are
    still running -- i.e. some step must shrink `pending` with live
    lanes carried over from the previous step. (Regression: counting
    converged-but-unflushed lanes out of free_count() made the
    admission test collapse to free >= thr, deferring every refill to
    whole-batch convergence.)"""
    n = index.graph.n
    eng = _mixed_plan_engine(index, efs=30, max_batch=4,
                             scheduler="continuous", step_iters=1,
                             refill_threshold=1)
    hooks = []
    eng.step_hook = lambda info: hooks.append(dict(info))
    # widely mixed selectivities so lane convergence staggers
    cutoffs = [n // 20, n, n // 10, n // 2, n // 3, n, n // 4,
               n // 5, 3 * n // 4, n // 8, n, n // 6]
    rids = set()
    for j, cut in enumerate(cutoffs):
        plan = Filter(NodeScan("Chunk"), "cID", "<", value=cut)
        rids.add(eng.submit(queries[j % len(queries)], plan=plan, k=6))
    responses = eng.drain()
    assert sorted(r.rid for r in responses) == sorted(rids)
    staggered = [j for j in range(1, len(hooks))
                 if hooks[j]["pending"] < hooks[j - 1]["pending"]
                 and hooks[j - 1]["live"] > 0]
    assert staggered, (
        "every refill waited for whole-batch convergence (live==0); "
        f"hooks={[(h['pending'], h['live'], h['done']) for h in hooks]}")


def test_continuous_matches_grouped_reference(index, queries):
    """Same mixed workload through both schedulers: identical answers."""
    n = index.graph.n
    plans = [Filter(NodeScan("Chunk"), "cID", "<", value=c)
             for c in (n // 4, n // 2, n, n // 3)]
    results = {}
    for sched in ("continuous", "grouped"):
        eng = _mixed_plan_engine(index, efs=24, max_batch=8,
                                 scheduler=sched)
        rids = [eng.submit(queries[j], plan=plans[j % len(plans)], k=5)
                for j in range(8)]
        by = {r.rid: r for r in eng.drain()}
        results[sched] = [by[rid] for rid in rids]
    for a, b in zip(results["continuous"], results["grouped"]):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.dists, b.dists)
        assert a.sigma == pytest.approx(b.sigma)


def test_per_lane_k_capped_to_batch_max(index, queries):
    """Requests with different k fuse into one batch; each response is
    sliced to its own k."""
    n = index.graph.n
    eng = _mixed_plan_engine(index, efs=40, max_batch=8,
                             scheduler="continuous")
    plan_a = Filter(NodeScan("Chunk"), "cID", "<", value=n // 2)
    plan_b = Filter(NodeScan("Chunk"), "cID", "<", value=n // 3)
    ra = eng.submit(queries[0], plan=plan_a, k=3)
    rb = eng.submit(queries[1], plan=plan_b, k=9)
    by = {r.rid: r for r in eng.drain()}
    assert by[ra].ids.shape == (3,)
    assert by[rb].ids.shape == (9,)
    mask_b = np.arange(n) < n // 3
    assert mask_b[by[rb].ids[by[rb].ids >= 0]].all()


def test_unknown_scheduler_rejected(index, queries):
    eng = _mixed_plan_engine(index, scheduler="nope")
    eng.submit(queries[0], k=3)
    with pytest.raises(ValueError, match="scheduler"):
        eng.drain()


@pytest.mark.parametrize("sched", ["continuous", "grouped"])
def test_alive_on_unsharded_index_rejected(index, queries, sched):
    """A quorum mask on an unsharded index is a misconfiguration; both
    schedulers must surface it instead of silently ignoring it (same
    contract as NavixDB.execute(alive=...))."""
    eng = _mixed_plan_engine(index, scheduler=sched, efs=20)
    eng.alive = np.array([True, False])
    eng.submit(queries[0], k=3)
    with pytest.raises(ValueError, match="unsharded|alive"):
        eng.drain()


def test_batched_requests(engine, queries):
    plan = Filter(NodeScan("Chunk"), "cID", "<", value=engine.index.graph.n // 2)
    rids = [engine.submit(q, plan=plan, k=5) for q in queries]
    rids += [engine.submit(queries[0], plan=None, k=5)]
    responses = engine.drain()
    assert len(responses) == len(rids)
    by_rid = {r.rid: r for r in responses}
    for rid in rids[:-1]:
        r = by_rid[rid]
        ids = r.ids[r.ids >= 0]
        assert (ids < engine.index.graph.n // 2).all()
        assert r.sigma == pytest.approx(0.5, abs=0.01)
    summary = engine.latency_summary()
    assert summary["n"] == len(rids)
    assert summary["p99_ms"] >= summary["p50_ms"]


# -- the continuous scheduler over a SHARDED index ---------------------------
# (per-lane k/efs capping and lane refill unchanged; lane state gains the
# shard dim, finalize merges across shards under the engine's alive mask)


def _sharded_engine(sn, **kw):
    store = GraphStore()
    store.add_node_table("Chunk", sn.n_total,
                         {"cID": np.arange(sn.n_total)})
    return SearchEngine(index=sn, store=store, **kw)


@needs_2_devices
def test_sharded_every_rid_exactly_once_under_refill(shard_env):
    """More distinct-plan requests than lanes on a sharded index: every
    rid answered exactly once, each response bitwise the one-shot
    sharded search_many over that request's own S."""
    X, queries, factory = shard_env
    sn = factory(2)
    n = sn.n_total
    eng = _sharded_engine(sn, efs=30, max_batch=4, scheduler="continuous",
                          step_iters=3, refill_threshold=1)
    cutoffs = [n // 10, n // 5, n // 3, n // 2, 2 * n // 3, n,
               n // 8, n // 4]
    rids = {}
    for j, cut in enumerate(cutoffs):
        plan = Filter(NodeScan("Chunk"), "cID", "<", value=cut)
        rid = eng.submit(queries[j % len(queries)], plan=plan, k=6)
        rids[rid] = (j, cut)
    responses = eng.drain()
    assert sorted(r.rid for r in responses) == sorted(rids), \
        "every rid must be answered exactly once"
    for r in responses:
        j, cut = rids[r.rid]
        assert not r.degraded
        assert r.sigma == pytest.approx(cut / n, abs=1e-6)
        mask = np.arange(n) < cut
        ref = sn.search_many(queries[j % len(queries)], semimask=mask,
                             k=6, efs=30)
        np.testing.assert_array_equal(r.ids, np.asarray(ref.ids)[0],
                                      err_msg=f"rid {r.rid} (cut={cut})")
        np.testing.assert_array_equal(r.dists, np.asarray(ref.dists)[0])
    assert eng.latency_summary()["n"] == len(cutoffs)


@needs_2_devices
def test_sharded_continuous_matches_grouped(shard_env):
    """Same mixed workload through both schedulers on a sharded index:
    identical answers (the grouped path goes through NavixDB.execute's
    sharded arm, the continuous path through the sharded stepping API)."""
    X, queries, factory = shard_env
    sn = factory(2)
    n = sn.n_total
    plans = [Filter(NodeScan("Chunk"), "cID", "<", value=c)
             for c in (n // 4, n // 2, n, n // 3)]
    results = {}
    for sched in ("continuous", "grouped"):
        eng = _sharded_engine(sn, efs=24, max_batch=8, scheduler=sched)
        rids = [eng.submit(queries[j % len(queries)],
                           plan=plans[j % len(plans)], k=5)
                for j in range(8)]
        by = {r.rid: r for r in eng.drain()}
        results[sched] = [by[rid] for rid in rids]
    for a, b in zip(results["continuous"], results["grouped"]):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.dists, b.dists)
        assert a.sigma == pytest.approx(b.sigma)
        assert not a.degraded and not b.degraded


@needs_2_devices
def test_sharded_straggler_flip_mid_drain_flags_degraded(shard_env):
    """The alive mask flips after the first device step (a liveness probe
    would do this from step_hook): every response finalized afterwards is
    flagged degraded, contains no dead-shard ids, and equals the one-shot
    search restricted to the alive shards."""
    X, queries, factory = shard_env
    sn = factory(2)
    n = sn.n_total
    eng = _sharded_engine(sn, efs=30, max_batch=4, scheduler="continuous",
                          step_iters=2, refill_threshold=1)
    hooks = []

    def probe(info):
        hooks.append(dict(info))
        eng.alive = np.array([True, False])     # shard 1 dies mid-drain

    eng.step_hook = probe
    cutoffs = [n // 6, n // 3, n // 2, n, n // 4, 2 * n // 3]
    rids = {}
    for j, cut in enumerate(cutoffs):
        plan = Filter(NodeScan("Chunk"), "cID", "<", value=cut)
        rid = eng.submit(queries[j % len(queries)], plan=plan, k=6)
        rids[rid] = (j, cut)
    responses = eng.drain()
    assert sorted(r.rid for r in responses) == sorted(rids)
    assert hooks, "step_hook must fire"
    assert all(r.degraded for r in responses), \
        "every lane finalized after the flip must be flagged"
    alive = np.array([True, False])
    for r in responses:
        j, cut = rids[r.rid]
        ids = r.ids[r.ids >= 0]
        assert (ids < sn.n_local).all(), "dead shard leaked ids"
        mask = np.arange(n) < cut
        ref = sn.search_many(queries[j % len(queries)], semimask=mask,
                             k=6, efs=30, alive=alive)
        np.testing.assert_array_equal(r.ids, np.asarray(ref.ids)[0])
        np.testing.assert_array_equal(r.dists, np.asarray(ref.dists)[0])
    # and the flip genuinely changed answers vs an all-alive engine
    eng2 = _sharded_engine(sn, efs=30, max_batch=4, scheduler="continuous")
    rids2 = [eng2.submit(queries[j % len(queries)],
                         plan=Filter(NodeScan("Chunk"), "cID", "<",
                                     value=cut), k=6)
             for j, cut in enumerate(cutoffs)]
    by2 = {r.rid: r for r in eng2.drain()}
    assert not any(r.degraded for r in by2.values())
    healthy = np.concatenate([by2[rid].ids for rid in rids2])
    assert (healthy[healthy >= 0] >= sn.n_local).any(), \
        "the healthy drain should use shard-1 vectors somewhere"


def test_greedy_generate_shapes():
    import jax
    import numpy as np

    from repro.config.base import get_arch
    from repro.models.api import model_api
    from repro.serving.engine import greedy_generate
    cfg = get_arch("qwen1.5-0.5b").smoke_config
    params = model_api(cfg).init(jax.random.key(0))
    prompt = np.random.default_rng(0).integers(0, cfg.vocab_size,
                                               size=(2, 8))
    out = greedy_generate(cfg, params, prompt, n_new=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
