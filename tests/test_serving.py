import numpy as np
import pytest

from repro.query.operators import Filter, NodeScan
from repro.serving.engine import SearchEngine
from repro.storage.columnar import GraphStore


@pytest.fixture()
def engine(index):
    store = GraphStore()
    store.add_node_table("Chunk", index.graph.n,
                         {"cID": np.arange(index.graph.n)})
    return SearchEngine(index=index, store=store, efs=60)


def _mixed_plan_engine(index, **kw):
    store = GraphStore()
    store.add_node_table("Chunk", index.graph.n,
                         {"cID": np.arange(index.graph.n)})
    return SearchEngine(index=index, store=store, **kw)


def test_continuous_scheduler_mixed_plans_exactly_once(index, queries):
    """Mixed-plan fusing under refill: more requests than lanes, every
    plan distinct, every rid answered exactly once -- and each response
    is bitwise the single-query search over that request's own S."""
    n = index.graph.n
    eng = _mixed_plan_engine(index, efs=30, max_batch=4,
                             scheduler="continuous", step_iters=3,
                             refill_threshold=1)
    cutoffs = [n // 10, n // 5, n // 3, n // 2, 2 * n // 3, n,
               n // 8, n // 4, 3 * n // 4, n // 2, n // 6, n]
    rids = {}
    for j, cut in enumerate(cutoffs):
        plan = Filter(NodeScan("Chunk"), "cID", "<", value=cut)
        rid = eng.submit(queries[j % len(queries)], plan=plan, k=6)
        rids[rid] = (j, cut)
    responses = eng.drain()
    assert sorted(r.rid for r in responses) == sorted(rids), \
        "every rid must be answered exactly once"
    for r in responses:
        j, cut = rids[r.rid]
        mask = np.arange(n) < cut
        assert r.sigma == pytest.approx(cut / n, abs=1e-6), \
            "Response.sigma must be the request's OWN selectivity"
        single = index.search(queries[j % len(queries)], k=6, efs=30,
                              semimask=mask)
        np.testing.assert_array_equal(r.ids, np.asarray(single.ids),
                                      err_msg=f"rid {r.rid} (cut={cut})")
        np.testing.assert_array_equal(r.dists, np.asarray(single.dists))
    assert eng.latency_summary()["n"] == len(cutoffs)


def test_continuous_matches_grouped_reference(index, queries):
    """Same mixed workload through both schedulers: identical answers."""
    n = index.graph.n
    plans = [Filter(NodeScan("Chunk"), "cID", "<", value=c)
             for c in (n // 4, n // 2, n, n // 3)]
    results = {}
    for sched in ("continuous", "grouped"):
        eng = _mixed_plan_engine(index, efs=24, max_batch=8,
                                 scheduler=sched)
        rids = [eng.submit(queries[j], plan=plans[j % len(plans)], k=5)
                for j in range(8)]
        by = {r.rid: r for r in eng.drain()}
        results[sched] = [by[rid] for rid in rids]
    for a, b in zip(results["continuous"], results["grouped"]):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.dists, b.dists)
        assert a.sigma == pytest.approx(b.sigma)


def test_per_lane_k_capped_to_batch_max(index, queries):
    """Requests with different k fuse into one batch; each response is
    sliced to its own k."""
    n = index.graph.n
    eng = _mixed_plan_engine(index, efs=40, max_batch=8,
                             scheduler="continuous")
    plan_a = Filter(NodeScan("Chunk"), "cID", "<", value=n // 2)
    plan_b = Filter(NodeScan("Chunk"), "cID", "<", value=n // 3)
    ra = eng.submit(queries[0], plan=plan_a, k=3)
    rb = eng.submit(queries[1], plan=plan_b, k=9)
    by = {r.rid: r for r in eng.drain()}
    assert by[ra].ids.shape == (3,)
    assert by[rb].ids.shape == (9,)
    mask_b = np.arange(n) < n // 3
    assert mask_b[by[rb].ids[by[rb].ids >= 0]].all()


def test_unknown_scheduler_rejected(index, queries):
    eng = _mixed_plan_engine(index, scheduler="nope")
    eng.submit(queries[0], k=3)
    with pytest.raises(ValueError, match="scheduler"):
        eng.drain()


def test_batched_requests(engine, queries):
    plan = Filter(NodeScan("Chunk"), "cID", "<", value=engine.index.graph.n // 2)
    rids = [engine.submit(q, plan=plan, k=5) for q in queries]
    rids += [engine.submit(queries[0], plan=None, k=5)]
    responses = engine.drain()
    assert len(responses) == len(rids)
    by_rid = {r.rid: r for r in responses}
    for rid in rids[:-1]:
        r = by_rid[rid]
        ids = r.ids[r.ids >= 0]
        assert (ids < engine.index.graph.n // 2).all()
        assert r.sigma == pytest.approx(0.5, abs=0.01)
    summary = engine.latency_summary()
    assert summary["n"] == len(rids)
    assert summary["p99_ms"] >= summary["p50_ms"]


def test_greedy_generate_shapes():
    import jax
    import numpy as np

    from repro.config.base import get_arch
    from repro.models.api import model_api
    from repro.serving.engine import greedy_generate
    cfg = get_arch("qwen1.5-0.5b").smoke_config
    params = model_api(cfg).init(jax.random.key(0))
    prompt = np.random.default_rng(0).integers(0, cfg.vocab_size,
                                               size=(2, 8))
    out = greedy_generate(cfg, params, prompt, n_new=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
