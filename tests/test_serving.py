import numpy as np
import pytest

from repro.query.operators import Filter, NodeScan
from repro.serving.engine import SearchEngine
from repro.storage.columnar import GraphStore


@pytest.fixture()
def engine(index):
    store = GraphStore()
    store.add_node_table("Chunk", index.graph.n,
                         {"cID": np.arange(index.graph.n)})
    return SearchEngine(index=index, store=store, efs=60)


def test_batched_requests(engine, queries):
    plan = Filter(NodeScan("Chunk"), "cID", "<", value=engine.index.graph.n // 2)
    rids = [engine.submit(q, plan=plan, k=5) for q in queries]
    rids += [engine.submit(queries[0], plan=None, k=5)]
    responses = engine.drain()
    assert len(responses) == len(rids)
    by_rid = {r.rid: r for r in responses}
    for rid in rids[:-1]:
        r = by_rid[rid]
        ids = r.ids[r.ids >= 0]
        assert (ids < engine.index.graph.n // 2).all()
        assert r.sigma == pytest.approx(0.5, abs=0.01)
    summary = engine.latency_summary()
    assert summary["n"] == len(rids)
    assert summary["p99_ms"] >= summary["p50_ms"]


def test_greedy_generate_shapes():
    import jax
    import numpy as np

    from repro.config.base import get_arch
    from repro.models.api import model_api
    from repro.serving.engine import greedy_generate
    cfg = get_arch("qwen1.5-0.5b").smoke_config
    params = model_api(cfg).init(jax.random.key(0))
    prompt = np.random.default_rng(0).integers(0, cfg.vocab_size,
                                               size=(2, 8))
    out = greedy_generate(cfg, params, prompt, n_new=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
