"""Overlapped stepping, data-axis lane parallelism, and ragged efs.

The PR-8 contract, tested at three layers:

* ``LaneBatch``: work issued while a donated chunk is in flight
  (finalize / evict / admit) queues behind it on the device stream and
  is bitwise identical to the synchronous order; the async state machine
  rejects double dispatch and waits without a dispatch.
* ``SearchEngine``: the overlapped continuous scheduler returns exactly
  the grouped (one-shot ``NavixDB.execute``) scheduler's answers and the
  one-shot ``search_many`` reference, for the flat index and for BOTH
  sharded layouts -- the ``(1, S)`` model-axis index split and the
  ``(S, 1)`` data-axis lane split -- at S in {1, 2, 4}, with per-plan
  explicit efs exercising the ragged beam-tail masking.
* ``SearchService``: a heartbeat flipping to stale while a donated chunk
  is in flight degrades every response finalized afterwards, bitwise
  equal to the alive-restricted per-shard oracle.

S > 1 cases need host devices (CI runs tier-1 with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
"""

import jax
import numpy as np
import pytest

from repro.api.db import NavixDB
from repro.core import bitset
from repro.core.distributed import ShardedNavix, per_shard_reference
from repro.core.navix import NavixConfig
from repro.query.operators import Filter, KnnSearch, NodeScan
from repro.serving.engine import SearchEngine
from repro.serving.lanes import LaneBatch
from repro.storage.columnar import GraphStore

K, EFS = 6, 24


def _need(s):
    return pytest.mark.skipif(
        len(jax.devices()) < s,
        reason=f"needs {s} host devices "
               f"(XLA_FLAGS=--xla_force_host_platform_device_count={s})")


@pytest.fixture(scope="module")
def data_env(shard_env):
    """Memoized ``(S, 1)`` data-axis builds over the shard_env dataset
    (same vectors and queries, so references are shared)."""
    from repro.data.synthetic import gaussian_mixture
    X, qs, _ = shard_env
    cfg = NavixConfig(m_u=8, ef_construction=48, metric="l2", seed=0)
    built = {}

    def factory(s: int) -> ShardedNavix:
        if s not in built:
            mesh = jax.make_mesh((s, 1), ("data", "model"))
            built[s] = ShardedNavix.build(X, cfg, mesh)
        return built[s]

    return X, qs, factory


def _engine(idx, n, **kw):
    store = GraphStore()
    store.add_node_table("Chunk", n, {"cID": np.arange(n)})
    return SearchEngine(index=idx, store=store, **kw)


def _cut_plan(cut, k=K, efs=0):
    return KnnSearch(child=Filter(NodeScan("Chunk"), "cID", "<", value=cut),
                     k=k, efs=efs)


# -- host pack (the drain-wall fix) ------------------------------------------


def test_pack_np_bitwise_matches_pack():
    """The serving tier packs semimasks on the host; the numpy pack must
    stay bit-identical to the jnp layout for every width class (full
    words, ragged tails, leading dims). Deterministic must-run copy of
    the property test in tests/test_bitset.py."""
    rng = np.random.default_rng(0)
    for n in (1, 31, 32, 33, 64, 100, 640):
        for shape in ((n,), (3, n), (2, 3, n)):
            mask = rng.random(shape) < 0.4
            np.testing.assert_array_equal(
                bitset.pack_np(mask),
                np.asarray(bitset.pack(jax.numpy.asarray(mask))),
                err_msg=f"n={n} shape={shape}")


# -- LaneBatch: the overlapped state machine ---------------------------------


def _admit_entries(idx, queries, cuts, efs_each):
    n = idx.graph.n
    prepped = np.asarray(idx._prep_query(
        np.stack([np.asarray(q, np.float32) for q in queries])), np.float32)
    entries = []
    for j, cut in enumerate(cuts):
        mask = np.arange(n) < cut
        row = bitset.pack_np(mask)
        entries.append((("req", j), prepped[j], row, cut / n, efs_each[j]))
    return entries


def test_work_issued_midflight_equals_synchronous_order(index, queries):
    """finalize / evict / admit issued BETWEEN step_async and step_wait
    queue behind the in-flight donated chunk -- results are bitwise the
    synchronous (step -> finalize -> evict -> admit) order."""
    n = index.graph.n
    cuts = [n // 5, n // 2, n, n // 3]
    entries = _admit_entries(index, queries[:4], cuts, [EFS] * 4)
    alive = np.ones(1, bool)

    a = LaneBatch(index, "adaptive_local", K, EFS, bsz=4)
    b = LaneBatch(index, "adaptive_local", K, EFS, bsz=4)
    a.admit(list(entries))
    b.admit(list(entries))

    # overlapped: dispatch, then finalize + evict + admit mid-flight
    a.step_async(3)
    assert a.step_pending
    ids_a, d_a = a.finalize(alive)           # queues behind the chunk
    a.evict([2])
    fresh = _admit_entries(index, queries[4:5], [n // 4], [EFS])
    assert a.admit(list(fresh)) == [2]
    live_a = a.step_wait()

    # synchronous: wait first, then the same host work in the same order
    live_b = b.step(3)
    ids_b, d_b = b.finalize(alive)
    b.evict([2])
    assert b.admit(list(fresh)) == [2]

    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_array_equal(d_a, d_b)
    np.testing.assert_array_equal(live_a, live_b)

    # run both to convergence: identical terminal beams
    a.step(0)
    b.step(0)
    fin_a = a.finalize(alive)
    fin_b = b.finalize(alive)
    np.testing.assert_array_equal(fin_a[0], fin_b[0])
    np.testing.assert_array_equal(fin_a[1], fin_b[1])
    # the evicted-then-readmitted lane answered the NEW request
    single = index.search(queries[4], k=K, efs=EFS,
                          semimask=np.arange(n) < n // 4)
    np.testing.assert_array_equal(fin_a[0][2][:K], np.asarray(single.ids))


def test_step_async_state_machine(index, queries):
    lanes = LaneBatch(index, "adaptive_local", K, EFS, bsz=2)
    with pytest.raises(RuntimeError, match="no device chunk"):
        lanes.step_wait()
    lanes.admit(_admit_entries(index, queries[:1],
                               [index.graph.n // 2], [EFS]))
    lanes.step_async(2)
    with pytest.raises(RuntimeError, match="in flight"):
        lanes.step_async(2)
    assert lanes.step_pending
    lanes.step_wait()
    assert not lanes.step_pending
    with pytest.raises(RuntimeError, match="no device chunk"):
        lanes.step_wait()
    t = lanes.timing()
    assert t["n_chunks"] == 1
    assert all(k in t for k in ("host_gap_ms", "host_overlap_ms",
                                "device_wait_ms"))
    lanes.reset_timing()
    assert lanes.timing()["n_chunks"] == 0


@pytest.mark.parametrize("n_shards", [pytest.param(2, marks=_need(2))])
def test_data_axis_lane_rounding_and_divisibility(data_env, n_shards):
    """A data-axis backend rounds the batch up to a lane_shards multiple;
    the one-shot path rejects indivisible batches outright."""
    X, qs, factory = data_env
    sn = factory(n_shards)
    assert sn.lane_shards == n_shards and sn.n_shards == 1
    lanes = LaneBatch(sn, "adaptive_local", K, EFS, bsz=3)
    assert lanes.bsz == 4, "batch must round up to a lane_shards multiple"
    with pytest.raises(ValueError, match="divisible"):
        sn.search_many(qs[:3], k=K, efs=EFS)


# -- engine: overlapped continuous == grouped one-shot, every layout ---------

LAYOUTS = [pytest.param("data", 1),
           pytest.param("data", 2, marks=_need(2)),
           pytest.param("data", 4, marks=_need(4)),
           pytest.param("model", 2, marks=_need(2)),
           pytest.param("model", 4, marks=_need(4))]


@pytest.mark.parametrize("layout,n_shards", LAYOUTS)
def test_continuous_overlap_matches_grouped_and_oracle(
        shard_env, data_env, layout, n_shards):
    """The overlapped continuous scheduler vs the grouped one-shot path
    vs the one-shot ``search_many`` reference, with per-plan EXPLICIT efs
    (distinct per request -> ragged beam tails): all three bitwise equal
    on both sharded layouts at S in {1, 2, 4}."""
    X, qs, model_factory = shard_env
    _, _, data_factory = data_env
    sn = data_factory(n_shards) if layout == "data" \
        else model_factory(n_shards)
    n = sn.n_total
    cuts = [n // 4, n // 2, n, n // 3, n // 5, 2 * n // 3, n // 8, n]
    efss = [12, 18, EFS, 12, EFS, 18, 15, EFS]
    plans = [_cut_plan(c, k=K, efs=e) for c, e in zip(cuts, efss)]
    results = {}
    for sched in ("continuous", "grouped"):
        eng = _engine(sn, n, efs=EFS, max_batch=4, scheduler=sched,
                      step_iters=3, refill_threshold=1)
        rids = [eng.submit(qs[j % len(qs)], plan=plans[j], k=K)
                for j in range(len(plans))]
        by = {r.rid: r for r in eng.drain()}
        assert sorted(by) == sorted(rids)
        results[sched] = [by[rid] for rid in rids]
    for j, (a, b) in enumerate(zip(results["continuous"],
                                   results["grouped"])):
        np.testing.assert_array_equal(a.ids, b.ids,
                                      err_msg=f"req {j} ({layout}, "
                                              f"S={n_shards})")
        np.testing.assert_array_equal(a.dists, b.dists)
        assert not a.degraded and not b.degraded
        mask = np.arange(n) < cuts[j]
        ref = sn.search_many(qs[j % len(qs)], semimask=mask, k=K,
                             efs=efss[j])
        np.testing.assert_array_equal(a.ids, np.asarray(ref.ids)[0])
        np.testing.assert_array_equal(a.dists, np.asarray(ref.dists)[0])


def test_ragged_efs_explicit_vs_unset_policy(index, queries):
    """Only a plan that NAMES its efs gets the ragged (masked-tail) beam:
    explicit-efs responses equal the single-query search at that efs,
    unset-efs responses equal the search at the batch cap."""
    n = index.graph.n
    eng = _engine(index, n, efs=0, max_batch=8, scheduler="continuous",
                  step_iters=4)
    explicit = [(n // 2, 12), (n // 3, 30), (n, 16)]
    plans = [_cut_plan(c, k=K, efs=e) for c, e in explicit]
    # unset efs (KnnSearch.efs == 0): keeps the cap-wide beam
    plans.append(_cut_plan(n // 4, k=K, efs=0))
    rids = [eng.submit(queries[j], plan=p, k=K)
            for j, p in enumerate(plans)]
    by = {r.rid: r for r in eng.drain()}
    efs_cap = max(30, 2 * K)
    for j, (cut, efs) in enumerate(explicit):
        single = index.search(queries[j], k=K, efs=efs,
                              semimask=np.arange(n) < cut)
        np.testing.assert_array_equal(by[rids[j]].ids,
                                      np.asarray(single.ids),
                                      err_msg=f"explicit efs={efs}")
        np.testing.assert_array_equal(by[rids[j]].dists,
                                      np.asarray(single.dists))
    single = index.search(queries[3], k=K, efs=efs_cap,
                          semimask=np.arange(n) < n // 4)
    np.testing.assert_array_equal(by[rids[3]].ids, np.asarray(single.ids),
                                  err_msg="unset efs must run at the cap")


# -- observability + LaneBatch reuse across drains ---------------------------


def test_chunk_timing_lands_in_latency_summary(index, queries):
    n = index.graph.n
    eng = _engine(index, n, efs=EFS, max_batch=4, scheduler="continuous",
                  step_iters=2)
    for j in range(6):
        eng.submit(queries[j], plan=_cut_plan(n // (j + 2)), k=K)
    eng.drain()
    s = eng.latency_summary()
    ch = s["chunks"]
    assert ch["n_chunks"] > 0
    for key in ("host_gap_ms", "host_overlap_ms", "device_wait_ms"):
        assert ch[key] >= 0.0
    # a second drain REUSES the LaneBatch (one cache entry) and keeps
    # accumulating engine-level chunk totals
    assert len(eng._lane_cache) == 1
    first_chunks = ch["n_chunks"]
    for j in range(6):
        eng.submit(queries[j], plan=_cut_plan(n // (j + 2)), k=K)
    eng.drain()
    assert len(eng._lane_cache) == 1, "same program shape must reuse"
    assert eng.latency_summary()["chunks"]["n_chunks"] > first_chunks


# -- service: heartbeat flip while a donated chunk is in flight --------------


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


@pytest.mark.parametrize("n_shards", [pytest.param(2, marks=_need(2))])
def test_heartbeat_flip_while_chunk_in_flight(shard_env, n_shards):
    """The service leaves a donated chunk in flight between ticks; a
    heartbeat aging out in that window degrades every response finalized
    afterwards, bitwise the alive-restricted per-shard oracle."""
    from repro.serving import HeartbeatMonitor, SearchService

    X, qs, factory = shard_env
    sn = factory(n_shards)
    n = sn.n_total
    store = GraphStore()
    store.add_node_table("Chunk", n, {"cID": np.arange(n)})
    db = NavixDB(store)
    db.register_index("default", sn)
    clk = FakeClock(0.0)
    hb = HeartbeatMonitor(n_shards, stale_after=2.0, clock=clk)
    svc = SearchService(db, k_cap=K, efs_cap=EFS, max_batch=4,
                        step_iters=2, heartbeats=hb)
    cuts = [n // 3, n // 2, n, n // 5]
    futs = [svc.submit(qs[j], plan=_cut_plan(cuts[j]), k=K)
            for j in range(4)]
    svc._tick()                      # admit + dispatch; nothing finalized
    assert svc.lanes.step_pending, "a donated chunk must be in flight"
    hb.suppress(1)                   # shard 1 goes silent mid-chunk
    clk.t = 10.0
    hb.beat(0)
    for _ in range(200):
        if all(f.done() for f in futs):
            break
        svc._tick()
    alive = np.array([True, False])
    params = sn._params(K, EFS, "adaptive_local")
    masks = np.stack([np.arange(n) < c for c in cuts])
    ref_d, ref_i, _ = per_shard_reference(sn, qs[:4], masks, params,
                                          alive=alive)
    for j, f in enumerate(futs):
        r = f.result(timeout=0)
        assert r.status == "ok" and r.degraded, \
            "every lane finalized after the flip must be degraded"
        np.testing.assert_array_equal(np.asarray(r.ids), ref_i[j])
        np.testing.assert_array_equal(np.asarray(r.dists), ref_d[j])
        ids = np.asarray(r.ids)
        assert (ids[ids >= 0] // sn.n_local != 1).all(), \
            "dead shard leaked ids"
    g = svc.gauges()
    assert g["chunks"]["n_chunks"] > 0
    svc.shutdown()
