"""Per-lane semimasks + the continuous-batching scheduler.

The mixed-plan batching contract: with a ``[B, W]`` per-lane semimask,
lane b of the batched engine is bitwise-identical (ids, dists, dc stats)
to single-query ``search`` run with lane b's own mask -- including lanes
at sigma=0 and sigma=1 fused into the same batch -- and the serving
scheduler answers every submitted rid exactly once while refilling lanes.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import bitset
from repro.core.search import search, search_batch
from repro.core.search_batch import search_many

HEURISTICS = ["onehop_s", "directed", "blind", "adaptive_g",
              "adaptive_local", "onehop_a"]
SIGMAS = [1.0, 0.4, 0.1, 0.0, 0.03, 0.7]


def _lane_masks(n, sigmas, seed=0):
    rng = np.random.default_rng(seed)
    masks = []
    for s in sigmas:
        if s >= 1.0:
            masks.append(np.ones(n, bool))
        elif s <= 0.0:
            masks.append(np.zeros(n, bool))
        else:
            masks.append(rng.random(n) < s)
    return np.stack(masks)


# -- engine-level equivalence ------------------------------------------------


@pytest.mark.parametrize("heuristic", HEURISTICS)
def test_per_lane_matches_single_with_own_mask(index, queries, heuristic):
    """Lane b == single-query search with lane b's own semimask, exactly
    (ids, dists AND stats), for every heuristic -- with sigma=0 and
    sigma=1 lanes fused into the same batch."""
    n = index.graph.n
    masks = _lane_masks(n, SIGMAS, seed=3)
    sel2 = bitset.pack(jnp.asarray(masks))
    sigmas = jnp.asarray(masks.mean(axis=1), jnp.float32)
    Q = jnp.asarray(queries[:len(SIGMAS)])
    params = index._params(8, 32, heuristic)

    batched = search_many(index.graph, Q, sel2, params, sigma_g=sigmas)
    for b in range(len(SIGMAS)):
        single = search(index.graph, Q[b], sel2[b], params,
                        sigma_g=sigmas[b])
        np.testing.assert_array_equal(
            np.asarray(batched.ids[b]), np.asarray(single.ids),
            err_msg=f"ids diverge at lane {b} ({heuristic})")
        np.testing.assert_array_equal(
            np.asarray(batched.dists[b]), np.asarray(single.dists),
            err_msg=f"dists diverge at lane {b} ({heuristic})")
        for f in ("iters", "t_dc", "s_dc", "upper_dc", "picks"):
            np.testing.assert_array_equal(
                np.asarray(getattr(batched.stats, f)[b]),
                np.asarray(getattr(single.stats, f)),
                err_msg=f"stats.{f} diverges at lane {b} ({heuristic})")


def test_per_lane_vmap_oracle_agrees(index, queries):
    masks = _lane_masks(index.graph.n, [0.5, 0.1, 1.0, 0.0], seed=7)
    sel2 = bitset.pack(jnp.asarray(masks))
    sigmas = jnp.asarray(masks.mean(axis=1), jnp.float32)
    Q = jnp.asarray(queries[:4])
    params = index._params(6, 24, "adaptive_local")
    a = search_many(index.graph, Q, sel2, params, sigma_g=sigmas)
    b = search_batch(index.graph, Q, sel2, params, sigma_g=sigmas)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))


def test_sigma_zero_lane_empty_sigma_one_lane_full(index, queries):
    masks = _lane_masks(index.graph.n, [0.0, 1.0], seed=1)
    sel2 = bitset.pack(jnp.asarray(masks))
    res = index.search_many(queries[:2], k=5, efs=20, semimask=masks)
    assert (np.asarray(res.ids[0]) == -1).all()
    assert (np.asarray(res.ids[1]) >= 0).all()
    assert sel2.shape[0] == 2


def test_navix_search_many_accepts_mask_list(index, queries):
    masks = _lane_masks(index.graph.n, [0.3, 0.6, 0.1], seed=5)
    a = index.search_many(queries[:3], k=6, efs=30, semimask=masks)
    b = index.search_many(queries[:3], k=6, efs=30,
                          semimask=[masks[0], masks[1], masks[2]])
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


# -- hypothesis sweep over mixed per-lane selectivities ----------------------


def test_hypothesis_mixed_selectivities(index, queries):
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    n = index.graph.n
    params = index._params(6, 24, "adaptive_local")

    @given(sigmas=st.lists(
        st.sampled_from([0.0, 0.02, 0.08, 0.25, 0.6, 1.0]),
        min_size=4, max_size=4),
        seed=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def run(sigmas, seed):
        masks = _lane_masks(n, sigmas, seed=seed)
        sel2 = bitset.pack(jnp.asarray(masks))
        sg = jnp.asarray(masks.mean(axis=1), jnp.float32)
        Q = jnp.asarray(queries[:4])
        batched = search_many(index.graph, Q, sel2, params, sigma_g=sg)
        for b in range(4):
            single = search(index.graph, Q[b], sel2[b], params,
                            sigma_g=sg[b])
            np.testing.assert_array_equal(np.asarray(batched.ids[b]),
                                          np.asarray(single.ids))
            np.testing.assert_array_equal(np.asarray(batched.dists[b]),
                                          np.asarray(single.dists))
            # every returned id is in that lane's own S
            ids = np.asarray(batched.ids[b])
            assert masks[b][ids[ids >= 0]].all()

    run()


# -- NavixDB mixed-plan execution -------------------------------------------


def test_db_execute_with_per_query_masks(index, queries):
    from repro.api import NavixDB

    db = NavixDB()
    db.register_index("default", index)
    n = index.graph.n
    masks = [np.arange(n) < n // 4, None, np.arange(n) % 2 == 0]
    from repro.query.operators import KnnSearch
    rs = db.execute(KnnSearch(child=None, table="default", k=5, efs=30),
                    query=np.asarray(queries[:3]), masks=masks)
    assert rs.ids.shape == (3, 5)
    assert rs.sigmas is not None and rs.sigmas.shape == (3,)
    assert rs.sigmas[0] == pytest.approx(0.25, abs=0.01)
    assert rs.sigmas[1] == pytest.approx(1.0)
    ids0 = rs.ids[0][rs.ids[0] >= 0]
    assert (ids0 < n // 4).all()
    ids2 = rs.ids[2][rs.ids[2] >= 0]
    assert (ids2 % 2 == 0).all()
    # masks= and a plan-level Q_S are mutually exclusive
    from repro.query.operators import Filter, NodeScan
    sel = Filter(NodeScan("default"), "cID", "<", value=3)
    with pytest.raises(ValueError, match="selection subquery"):
        db.execute(KnnSearch(child=sel, k=5), query=np.asarray(queries[:3]),
                   masks=masks)
    with pytest.raises(ValueError, match="one entry per query row"):
        db.execute(KnnSearch(child=None, table="default", k=5),
                   query=np.asarray(queries[:3]), masks=masks[:2])
    # alive= is a sharded-index knob; silently ignoring it would hide a
    # caller's quorum intent
    with pytest.raises(ValueError, match="unsharded"):
        db.execute(KnnSearch(child=None, table="default", k=5),
                   query=np.asarray(queries[:3]),
                   alive=np.array([True, False]))


def test_program_cache_per_lane_arm_no_collision(index, queries):
    """The same plan shape under shared vs per-lane semimasks compiles two
    distinct programs (per_lane_sel key arm) and each re-executes with
    zero new compilations."""
    from repro.api.plan_compile import ProgramCache

    cache = ProgramCache()
    Q = jnp.asarray(queries[:4])
    params = index._params(5, 20, "adaptive_local")
    shared = index.full_semimask()
    masks = _lane_masks(index.graph.n, [0.2, 0.5, 1.0, 0.1], seed=2)
    per_lane = bitset.pack(jnp.asarray(masks))
    sg = jnp.asarray(masks.mean(axis=1), jnp.float32)

    cache.search_many(index.graph, Q, shared, params, 1.0)
    assert cache.stats.misses == 1
    cache.search_many(index.graph, Q, per_lane, params, sg)
    assert cache.stats.misses == 2, "per-lane must be a distinct program"
    cache.search_many(index.graph, Q, shared, params, 1.0)
    cache.search_many(index.graph, Q, per_lane, params, sg)
    assert cache.stats.misses == 2 and cache.stats.hits == 2


# -- kernels.ops routing of the engine's distance primitive ------------------


def test_batch_gather_dist_backends_agree_bitwise(index):
    """The kernels.ops route (ref fallback on CPU) must match the pure-jnp
    gathered_dist_batch bitwise -- the engines' lane identity depends on
    it -- and the env toggle must reject unknown values."""
    import jax.numpy as jnp

    from repro.core.distances import gathered_dist_batch
    from repro.core.search_batch import GATHER_ENV, batch_gather_dist, \
        gather_backend
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    V = index.graph.vectors
    Q = jnp.asarray(rng.normal(size=(5, V.shape[1])).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, index.graph.n, (5, 9)).astype(np.int32))
    for metric in ("l2", "cos", "dot"):
        a = np.asarray(ops.gather_distance_batch(Q, V, ids, metric))
        b = np.asarray(gathered_dist_batch(Q, V, ids, metric))
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(
            np.asarray(batch_gather_dist(Q, V, ids, metric)), b)

    import os
    old = os.environ.get(GATHER_ENV)
    try:
        os.environ[GATHER_ENV] = "nope"
        with pytest.raises(ValueError, match=GATHER_ENV):
            gather_backend()
        os.environ[GATHER_ENV] = "xla"
        assert gather_backend() == "xla"
        np.testing.assert_array_equal(
            np.asarray(batch_gather_dist(Q, V, ids, "l2")),
            np.asarray(gathered_dist_batch(Q, V, ids, "l2")))
    finally:
        if old is None:
            os.environ.pop(GATHER_ENV, None)
        else:
            os.environ[GATHER_ENV] = old


# -- quantized + batched -----------------------------------------------------


def test_search_quantized_many_matches_single(index, queries):
    masks = _lane_masks(index.graph.n, [0.5, 0.15, 1.0, 0.05], seed=9)
    res = index.search_quantized_many(queries[:4], k=6, efs=30,
                                      semimask=masks)
    for b in range(4):
        single = index.search_quantized(queries[b], k=6, efs=30,
                                        semimask=masks[b])
        np.testing.assert_array_equal(np.asarray(res.ids[b]),
                                      np.asarray(single.ids))
        np.testing.assert_array_equal(np.asarray(res.dists[b]),
                                      np.asarray(single.dists))


def test_search_quantized_many_shared_mask(index, queries):
    mask = _lane_masks(index.graph.n, [0.3], seed=4)[0]
    res = index.search_quantized_many(queries[:3], k=5, efs=25, semimask=mask)
    ids = np.asarray(res.ids)
    assert ids.shape == (3, 5)
    assert mask[ids[ids >= 0]].all()
