"""navilint + runtime-guard coverage.

Fixture snippets live in plain strings: navilint's comment scanner runs
on tokenize output, so annotation/suppression comments inside THESE
string literals are invisible when navilint sweeps this test file
itself -- the fixtures can seed violations without dirtying the tree.

The lock-order scenarios re-run the PR-6 serving drills (thundering
herd at the backpressure gate, threaded shutdown drain, straggler-shard
heartbeat) under the instrumented-lock monitor and assert the
acquisition graph stays acyclic.
"""

import pathlib
import threading
import time

import jax
import numpy as np
import pytest

from repro.analysis import analyze_paths, analyze_source
from repro.analysis.navilint import (BARE_EXCEPT, DISCARDED_DONATION,
                                     DONATION_ALIAS, FORBIDDEN_OP,
                                     MALFORMED_SUPPRESSION, STALE_REGISTRY,
                                     STALE_SUPPRESSION, TRACE_BRANCH,
                                     TRACE_HOST, TRACE_SHAPE,
                                     UNCOVERED_INPUT, UNCOVERED_STATIC,
                                     UNKNOWN_KEY_FIELD, UNKNOWN_LOCK,
                                     UNLOCKED_ACCESS, UNUSED_IMPORT,
                                     USE_AFTER_DONATE, WALLCLOCK)
from repro.analysis.runtime import (CompileCounter, DonationError,
                                    LockOrderMonitor, guard_donation,
                                    instrument_locks)

REPO = pathlib.Path(__file__).resolve().parent.parent

needs_2_devices = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs 2 host devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _hits(findings, rule):
    return [(f.rule, f.line) for f in findings if f.rule == rule]


# -- must-flag fixtures ------------------------------------------------------

def test_flags_unlocked_annotated_field():
    src = """\
import threading

class Gauge:
    def __init__(self):
        self._lock = threading.Lock()
        self.depth = 0   # guarded-by: _lock

    def bump(self):
        with self._lock:
            self.depth += 1

    def peek(self):
        return self.depth
"""
    findings = analyze_source(src, "fixture_lock.py")
    assert _hits(findings, UNLOCKED_ACCESS) == [(UNLOCKED_ACCESS, 13)]
    assert len(findings) == 1, [f.render() for f in findings]


def test_flags_wallclock_deadline():
    src = """\
import time

def deadline_in(seconds):
    return time.time() + seconds
"""
    findings = analyze_source(src, "fixture_clock.py")
    assert _hits(findings, WALLCLOCK) == [(WALLCLOCK, 4)]
    assert len(findings) == 1


def test_flags_scatter_in_registered_hot_loop():
    """A function whose qualname is in the hot-path registry for its
    file is hot without any inline marker: reintroducing a scatter or
    top_k there flags even deep inside a nested closure."""
    src = """\
import jax.numpy as jnp
from jax import lax

def step_lanes(st, visit):
    def body(carry):
        d = carry.at[0].set(0.0)
        neg, order = lax.top_k(-d, 4)
        return lax.scatter_add(d, visit, neg, None)
    return body
"""
    findings = analyze_source(src, "src/repro/core/search_batch.py")
    assert (FORBIDDEN_OP, 6) in _hits(findings, FORBIDDEN_OP)   # .at[].set
    assert (FORBIDDEN_OP, 7) in _hits(findings, FORBIDDEN_OP)   # top_k
    assert (FORBIDDEN_OP, 8) in _hits(findings, FORBIDDEN_OP)   # scatter_add


def test_flags_stale_suppression():
    """A sync-ok left behind after the offending call was deleted is
    itself a finding -- suppressions must never outlive their reason."""
    src = """\
def finalize(x):
    # navilint: sync-ok results cross to host here
    return x
"""
    findings = analyze_source(src, "fixture_stale.py")
    assert _hits(findings, STALE_SUPPRESSION) == [(STALE_SUPPRESSION, 2)]
    assert len(findings) == 1


# -- must-pass fixtures ------------------------------------------------------

def test_passes_suppressed_sync_at_declared_boundary():
    src = """\
import numpy as np

def finalize(fin):  # navilint: hot
    # navilint: sync-ok the declared finalize boundary
    return np.asarray(fin.ids)
"""
    assert analyze_source(src, "fixture_ok_sync.py") == []


def test_passes_lock_held_annotated_helper():
    src = """\
import threading

class Q:
    def __init__(self):
        self._lock = threading.Lock()
        self._gated = False   # guarded-by: _lock

    def pop(self):
        with self._lock:
            self._maybe_ungate()

    def _maybe_ungate(self):  # navilint: lock-held _lock
        self._gated = False
"""
    assert analyze_source(src, "fixture_ok_lock.py") == []


# -- annotation hygiene ------------------------------------------------------

def test_suppression_without_reason_is_malformed():
    src = """\
import numpy as np

def step(x):  # navilint: hot
    return np.asarray(x)  # navilint: sync-ok
"""
    findings = analyze_source(src, "fixture_noreason.py")
    assert _hits(findings, MALFORMED_SUPPRESSION) == \
        [(MALFORMED_SUPPRESSION, 4)]


def test_guarded_by_unknown_lock_flags_the_class():
    src = """\
class C:
    def __init__(self):
        self.x = 0   # guarded-by: _lock

    def get(self):
        return 1
"""
    findings = analyze_source(src, "fixture_nolock.py")
    assert _hits(findings, UNKNOWN_LOCK) == [(UNKNOWN_LOCK, 1)]


def test_registry_entry_without_function_is_stale():
    src = "def something_else():\n    return 1\n"
    findings = analyze_source(src, "src/repro/serving/lanes.py")
    assert {f.rule for f in findings} == {STALE_REGISTRY}
    assert {"LaneBatch.step", "LaneBatch.finalize"} <= {
        f.message.split("'")[1] for f in findings}


def test_hygiene_unused_import_and_bare_except():
    src = """\
import os
import sys  # noqa: F401

def risky():
    try:
        return os.getpid()
    except:
        return -1
"""
    findings = analyze_source(src, "fixture_hygiene.py")
    assert _hits(findings, UNUSED_IMPORT) == []        # os used, sys noqa'd
    assert _hits(findings, BARE_EXCEPT) == [(BARE_EXCEPT, 7)]
    src2 = "import json\n\nX = 1\n"
    assert _hits(analyze_source(src2, "fixture_unused.py"),
                 UNUSED_IMPORT) == [(UNUSED_IMPORT, 1)]


# -- tracer flow (NX5xx) -----------------------------------------------------

def test_flags_tracer_branch_host_shape_in_jit_root():
    src = '''
import jax
import jax.numpy as jnp
import numpy as np

@jax.jit
def step(x, n):
    if x > 0:
        x = x + 1
    h = np.asarray(x)
    z = jnp.zeros(n)
    return x + z + h.shape[0]
'''
    findings = analyze_source(src, "src/repro/core/fixture_flow.py")
    assert _hits(findings, TRACE_BRANCH) == [(TRACE_BRANCH, 8)]
    assert _hits(findings, TRACE_HOST) == [(TRACE_HOST, 10)]
    assert _hits(findings, TRACE_SHAPE) == [(TRACE_SHAPE, 11)]


def test_flags_tracer_flow_through_transitive_helper():
    """The sink is two calls away from the jit root: the closure must
    carry traced-ness through the intermediate helper."""
    src = '''
import jax

def _decide(flag):
    if flag:
        return 1
    return 0

def _route(v):
    return _decide(v > 0)

@jax.jit
def run(x):
    return x * _route(x)
'''
    findings = analyze_source(src, "src/repro/core/fixture_deep.py")
    assert _hits(findings, TRACE_BRANCH) == [(TRACE_BRANCH, 5)]


def test_passes_static_by_structure_and_suppression():
    """Shape reads, `is None` tests, `jnp.ndim`, len() and a reasoned
    trace-ok suppression all stay clean inside a jit root."""
    src = '''
import jax
import jax.numpy as jnp

@jax.jit
def run(x, sig):
    if x.ndim == 2:
        x = x[0]
    if sig is None:
        sig = jnp.ones(x.shape[0])
    per_lane = jnp.ndim(sig) == 1
    if per_lane:
        sig = sig[0]
    if bool(x[0] > 0):  # navilint: trace-ok fixture exercises suppression
        pass
    return x * sig * len(x.shape)
'''
    assert analyze_source(src, "src/repro/core/fixture_static.py") == []


def test_regression_jit_root_static_property_stays_clean():
    """Distilled from the first full-tree sweep: `graph.n` is a
    NamedTuple *property* computing `self.vectors.shape[0]` -- a static
    int. Pre-fix, the pass treated any attribute of a traced pytree as
    traced, flagging `full_mask(graph.n)`'s shape use and every branch
    downstream (core/bitset.py, core/search.py false positives)."""
    src = '''
import jax
import jax.numpy as jnp
from typing import NamedTuple

class G(NamedTuple):
    vectors: jax.Array

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

def full_mask(n):
    if n % 32:
        n = n + 32 - n % 32
    return jnp.zeros(n // 32, jnp.uint32)

@jax.jit
def search(graph: G, q):
    sel = full_mask(graph.n)
    return q, sel
'''
    assert analyze_source(src, "src/repro/core/fixture_prop.py") == []


# -- ProgramKey coverage (NX6xx) ---------------------------------------------

_KEY_FIXTURE = '''
from typing import NamedTuple
import jax

class Params(NamedTuple):
    k: int
    efs: int

class ProgKey(NamedTuple):
    k: int
    e: int
    b: int

class Cache:
    def __init__(self):
        self._programs = {{}}

    def run(self, params: Params, Q):
        b = Q.shape[0]
        key = ProgKey(k=params.k, e={efs_arm}, b=b)
        prog = jax.jit(lambda q: q, static_argnames=("params",))
        self._programs[key] = prog
        return prog
'''


def test_flags_uncovered_static_field():
    """`params` is a static_argnames arg whose `efs` field never
    reaches the key: a call site varying efs reuses the wrong
    program."""
    src = _KEY_FIXTURE.format(efs_arm="0")
    findings = analyze_source(src, "src/repro/api/fixture_key.py")
    assert _hits(findings, UNCOVERED_STATIC), findings
    assert "efs" in [f for f in findings
                     if f.rule == UNCOVERED_STATIC][0].message


def test_passes_fully_covered_key():
    src = _KEY_FIXTURE.format(efs_arm="params.efs")
    assert analyze_source(src, "src/repro/api/fixture_key_ok.py") == []


def test_flags_unknown_key_field_rename_drift():
    src = _KEY_FIXTURE.format(efs_arm="params.efs_search")
    findings = analyze_source(src, "src/repro/api/fixture_key_drift.py")
    assert _hits(findings, UNKNOWN_KEY_FIELD), findings


def test_flags_uncovered_program_input():
    """The stored program co-varies with `engine` but the key never
    hashes it: two engines collide on one cache entry."""
    src = '''
from typing import NamedTuple
import jax

class ProgKey(NamedTuple):
    b: int

class Cache:
    def __init__(self):
        self._programs = {}

    def run(self, Q, engine):
        key = ProgKey(b=Q.shape[0])
        self._programs[key] = jax.jit(engine)
        return key
'''
    findings = analyze_source(src, "src/repro/api/fixture_key_input.py")
    assert _hits(findings, UNCOVERED_INPUT), findings


def test_regression_bound_builder_indirection_covers_caller_args():
    """Distilled from the first full-tree sweep: `self._key(graph,
    params)` binds the builder's params THROUGH the implicit receiver.
    Pre-fix, FuncInfo.bind mapped call args against `self`, shifting
    every parameter by one -- plan_compile's fully-covered arms
    false-positived NX601."""
    src = '''
from typing import NamedTuple
import jax

class Params(NamedTuple):
    k: int
    efs: int

class ProgKey(NamedTuple):
    k: int
    e: int

class Cache:
    def __init__(self):
        self._programs = {}

    def _key(self, graph, params):
        return ProgKey(k=params.k, e=params.efs)

    def run(self, graph, params: Params):
        key = self._key(graph, params)
        prog = jax.jit(lambda q: q, static_argnames=("params",))
        self._programs[key] = prog
        return prog
'''
    assert analyze_source(src, "src/repro/api/fixture_key_bind.py") == []


# -- donation safety (NX7xx) -------------------------------------------------

_DONATE_HEADER = '''
import jax
from functools import partial

@partial(jax.jit, donate_argnums=(0,))
def engine(st, q):
    return st
'''


def test_flags_use_after_donate_and_discard_and_alias():
    src = _DONATE_HEADER + '''
def drive(st, q):
    out = engine(st, q)
    bad = st + 1
    engine(out, q)
    engine(out, out)
    return bad
'''
    findings = analyze_source(src, "src/repro/serving/fixture_don.py")
    assert _hits(findings, USE_AFTER_DONATE) == [
        (USE_AFTER_DONATE, 11), (USE_AFTER_DONATE, 13)]
    assert _hits(findings, DISCARDED_DONATION) == [
        (DISCARDED_DONATION, 12), (DISCARDED_DONATION, 13)]
    assert _hits(findings, DONATION_ALIAS) == [(DONATION_ALIAS, 13)]


def test_passes_same_statement_rebind_and_suppression():
    """`self.st, live = backend.steps(..., self.st, ...)` is the
    sanctioned pattern: the rebind in the same statement revives the
    key. A reasoned donate-ok suppression covers deliberate reads."""
    src = _DONATE_HEADER + '''
class Lanes:
    def step(self, q):
        self.st = engine(self.st, q)
        return self.st

    def peek(self, q):
        out = engine(self.st, q)
        # navilint: donate-ok fixture: reads a donated alias on purpose
        stale = self.st
        self.st = out
        return stale
'''
    assert analyze_source(
        src, "src/repro/serving/fixture_don_ok.py") == []


def test_flags_donation_through_constructor_attr():
    """`self._steps = obj.steps_program(donate=True)` donates through
    the instance attribute -- the wrapper-method table must see it."""
    src = '''
import jax
from functools import partial

class Backend:
    def steps_program(self, donate=False):
        @partial(jax.jit, donate_argnums=(0,))
        def _donating(st):
            return st

        @jax.jit
        def _plain(st):
            return st

        return _donating if donate else _plain

class Lanes:
    def __init__(self, backend):
        self._steps = backend.steps_program(donate=True)
        self.st = None

    def step(self):
        self._steps(self.st)
        return self.st
'''
    findings = analyze_source(src, "src/repro/serving/fixture_ctor.py")
    assert _hits(findings, DISCARDED_DONATION), findings
    assert _hits(findings, USE_AFTER_DONATE), findings


def test_regression_duck_arity_mismatch_is_not_a_donation():
    """Distilled from the first full-tree sweep: LaneBatch.evict(
    lane_ids) shares a name with _FlatLanes.evict(st, udc, mask) which
    donates (0, 1). Pre-fix, the duck table applied the donating
    signature to the 1-arg dispatcher call, flagging service.py's
    `self.lanes.evict(occ)` as a discarded donation."""
    src = '''
import jax
from functools import partial

@partial(jax.jit, donate_argnums=(0, 1))
def engine_evict(st, udc, mask):
    return st, udc

class Flat:
    def evict(self, st, udc, mask):
        return engine_evict(st, udc, mask)

class Batch:
    def evict(self, lane_ids):
        self.st, self.udc = self.backend.evict(self.st, self.udc,
                                               lane_ids)

class Service:
    def shutdown(self):
        self.lanes.evict([0, 1])
'''
    findings = analyze_source(src, "src/repro/serving/fixture_duck.py")
    assert _hits(findings, DISCARDED_DONATION) == []
    assert _hits(findings, USE_AFTER_DONATE) == []


# -- the real tree -----------------------------------------------------------

def test_full_tree_is_clean():
    """`python -m repro.analysis --strict` must exit 0 on the repo: the
    tree carries its own annotations, so any finding here is a real
    regression (or a missing annotation) introduced by a change."""
    findings = analyze_paths([str(REPO / "src"), str(REPO / "tests"),
                              str(REPO / "benchmarks"),
                              str(REPO / "examples")])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_full_tree_analysis_stays_inside_budget():
    """The analyzer sits in the CI inner loop with a 30s contract
    (`--budget 30`); the whole-tree run -- four interprocedural passes
    included -- must stay well inside it."""
    t0 = time.monotonic()
    analyze_paths([str(REPO / "src"), str(REPO / "tests"),
                   str(REPO / "benchmarks"), str(REPO / "examples")])
    assert time.monotonic() - t0 < 30.0


def test_registry_names_resolve_against_source():
    """Every hot-path registry entry must name a function that exists --
    a refactor that renames one must update the registry (NX303)."""
    findings = analyze_paths([str(REPO / "src" / "repro")])
    stale = [f for f in findings if f.rule == STALE_REGISTRY]
    assert stale == [], "\n".join(f.render() for f in stale)


# -- lock-order runtime guard ------------------------------------------------

def test_lock_order_detects_abba_cycle():
    with instrument_locks() as mon:
        a = threading.Lock()
        b = threading.Lock()

        def fwd():
            with a:
                with b:
                    pass

        def rev():
            with b:
                with a:
                    pass

        t1 = threading.Thread(target=fwd)
        t1.start()
        t1.join()
        t2 = threading.Thread(target=rev)
        t2.start()
        t2.join()
    cycles = mon.cycles()
    assert cycles, "A->B and B->A acquisitions must report a cycle"
    assert mon.report()["cycles"]


def test_lock_order_nested_same_order_is_clean():
    with instrument_locks() as mon:
        outer = threading.Lock()
        inner = threading.Lock()
        for _ in range(3):
            with outer:
                with inner:
                    pass
    assert mon.edges and not mon.cycles()


def test_lock_order_clean_across_queue_herd():
    """The PR-6 thundering-herd drill under the monitor: blocked putters
    waking through the backpressure gate must not create lock-order
    cycles (Condition wait/notify runs through the instrumented lock)."""
    from repro.serving import SubmissionQueue
    with instrument_locks() as mon:
        q = SubmissionQueue(maxsize=4, policy="block",
                            high_watermark=2, low_watermark=1)
        q.put(1.0, None, meta=0)
        q.put(1.0, None, meta=1)                 # depth == high -> gated
        started = []
        threads = [threading.Thread(
            target=lambda j=j: started.append(q.put(1.0, None, meta=j)))
            for j in range(3)]
        for t in threads:
            t.start()
        time.sleep(0.05)
        deadline = time.monotonic() + 5.0
        while len(started) < 3 and time.monotonic() < deadline:
            q.pop_batch(2)
            time.sleep(0.01)
        for t in threads:
            t.join(5.0)
        assert len(started) == 3
    assert mon.cycles() == [], mon.report()


def test_lock_order_clean_across_threaded_shutdown(index, queries):
    """Threaded service lifecycle (start -> submit -> drain shutdown)
    under the monitor: the submit path (submit/lat locks), the device
    loop, and the queue's close/wake path must stay acyclic."""
    from repro.api.db import NavixDB
    from repro.query.operators import Filter, NodeScan
    from repro.storage.columnar import GraphStore

    n = index.graph.n
    with instrument_locks() as mon:
        store = GraphStore()
        store.add_node_table("Chunk", n, {"cID": np.arange(n)})
        db = NavixDB(store)
        db.register_index("default", index)
        with db.serve(k_cap=6, efs_cap=24, max_batch=4,
                      step_iters=4) as svc:
            futs = [svc.submit(
                queries[j],
                plan=Filter(NodeScan("Chunk"), "cID", "<",
                            value=n // (j + 1)), k=6)
                for j in range(6)]
            out = [f.result(timeout=120) for f in futs]
        assert all(r.status == "ok" for r in out)
        assert svc.gauges()["done"] == 6
    assert mon.cycles() == [], mon.report()


@needs_2_devices
def test_lock_order_clean_across_straggler_heartbeat(shard_env):
    """The sharded straggler drill (suppressed heartbeat flips responses
    to degraded) under the monitor -- heartbeat, queue, and service
    locks interleave across beats, ticks, and finalize."""
    from repro.api.db import NavixDB
    from repro.query.operators import Filter, NodeScan
    from repro.serving import HeartbeatMonitor, SearchService
    from repro.storage.columnar import GraphStore

    X, qs, factory = shard_env
    sn = factory(2)
    n = sn.n_total

    class Clk:
        t = 0.0

        def __call__(self):
            return self.t

    clk = Clk()
    with instrument_locks() as mon:
        hb = HeartbeatMonitor(2, stale_after=2.0, clock=clk)
        store = GraphStore()
        store.add_node_table("Chunk", n, {"cID": np.arange(n)})
        db = NavixDB(store)
        db.register_index("default", sn)
        svc = SearchService(db, k_cap=6, efs_cap=24, max_batch=4,
                            step_iters=4, heartbeats=hb)

        def drive(futs):
            for _ in range(500):
                if all(f.done() for f in futs):
                    return [f.result(timeout=0) for f in futs]
                svc._tick()
            raise AssertionError("service did not converge")

        plan = Filter(NodeScan("Chunk"), "cID", "<", value=n // 2)
        drive([svc.submit(qs[j], plan=plan, k=6) for j in range(4)])
        hb.suppress(1)
        clk.t = 10.0
        hb.beat(0)
        resps = drive([svc.submit(qs[j], plan=plan, k=6)
                       for j in range(4)])
        assert all(r.degraded for r in resps), \
            "stale heartbeat must degrade responses"
        svc.shutdown(drain=True)
    assert mon.cycles() == [], mon.report()


def test_lock_order_monitor_standalone_api():
    mon = LockOrderMonitor()
    mon._acquired("a.py:1")
    mon._acquired("b.py:2")
    mon._released("b.py:2")
    mon._released("a.py:1")
    mon._acquired("b.py:2")
    mon._acquired("a.py:1")
    assert mon.cycles() == [["a.py:1", "b.py:2", "a.py:1"]]


# -- zero-recompile runtime guard --------------------------------------------

def test_compile_counter_counts_then_cache_hits_zero():
    with CompileCounter() as cc:
        f = jax.jit(lambda x: x * 3 + 1)
        f(np.arange(7, dtype=np.float32)).block_until_ready()
        assert cc.counts["warmup"] >= 1
        cc.mark("steady")
        f(np.arange(7, dtype=np.float32) + 1).block_until_ready()
        f(np.arange(7, dtype=np.float32) + 2).block_until_ready()
    assert cc.counts["steady"] == 0, cc.counts
    assert cc.total == sum(cc.counts.values())


def test_db_execute_bucket_reuse_compiles_nothing(index):
    """The ProgramCache bucketing claim at the XLA level: after a warm
    execute at bucket 8, a different batch size in the same bucket and a
    different predicate must trigger ZERO backend compiles -- cache
    stats can lie (a re-keyed entry still misses), the compiler hook
    cannot."""
    from repro.api import NavixDB, Q
    from repro.storage.columnar import GraphStore

    n = index.graph.n
    store = GraphStore()
    store.add_node_table("Chunk", n, {"cID": np.arange(n)})
    db = NavixDB(store)
    db.register_index("default", index)
    rng = np.random.default_rng(3)
    qs = rng.normal(size=(8, index.graph.dim)).astype(np.float32)

    plan = Q.match("Chunk").where("cID", "<", n // 2).knn(k=5, efs=20)
    with CompileCounter() as cc:
        db.execute(plan, query=qs[:7])               # bucket 8 (cold)
        cc.mark("steady")
        db.execute(plan, query=qs[:5])               # same bucket
        db.execute(Q.match("Chunk").where("cID", "<", n // 3)
                   .knn(k=5, efs=20), query=qs[:8])  # new predicate
    assert cc.counts["steady"] == 0, cc.counts


# -- interprocedural lock discipline (NX201 via the call graph) ---------------

_UNGATE_FIXTURE = '''
import threading

class Q:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = []    # guarded-by: _lock
        self._gated = False # guarded-by: _lock

    def pop(self):
        with self._lock:
            self._items.pop()
            self._maybe_ungate()
{extra}
    def _maybe_ungate(self):
        if self._gated and not self._items:
            self._gated = False
'''


def test_private_helper_proven_locked_at_every_call_site_passes():
    """The `SubmissionQueue._maybe_ungate` pattern: a private method
    touching guarded fields needs no `lock-held` annotation when every
    intra-class call site holds the lock lexically."""
    src = _UNGATE_FIXTURE.format(extra="")
    assert analyze_source(src, "src/repro/serving/fixture_ip.py") == []


def test_private_helper_with_one_unlocked_call_site_flags():
    src = _UNGATE_FIXTURE.format(extra='''
    def poke(self):
        self._maybe_ungate()
''')
    findings = analyze_source(src, "src/repro/serving/fixture_ip2.py")
    assert _hits(findings, UNLOCKED_ACCESS), findings


def test_private_helper_escaping_as_callback_still_flags():
    """Passing the bound method out of the class defeats the call-site
    proof -- the analysis must treat an escaped method as unproven."""
    src = _UNGATE_FIXTURE.format(extra='''
    def register(self, bus):
        bus.on_drain(self._maybe_ungate)
''')
    findings = analyze_source(src, "src/repro/serving/fixture_ip3.py")
    assert _hits(findings, UNLOCKED_ACCESS), findings


# -- donation runtime guard ---------------------------------------------------

def _lane_batch(index, queries, bsz=2):
    from repro.serving.lanes import LaneBatch
    lanes = LaneBatch(index, "adaptive_local", k_cap=6, efs_cap=24,
                      bsz=bsz)
    full = lanes.backend.full_row()
    q = np.asarray(index._prep_query(np.stack(queries[:bsz])))
    lanes.admit([((j,), q[j], full, 1.0, 24) for j in range(bsz)])
    return lanes


def test_donation_guard_blocks_lane_state_access_in_flight(index,
                                                           queries):
    """Inside a step_async/step_wait window the chunk owns the donated
    lane state: evict/finalize/admit raise, host mirrors are frozen.
    After step_wait everything is legal again."""
    with guard_donation() as g:
        lanes = _lane_batch(index, queries)
        lanes.step_async(2)
        with pytest.raises(DonationError):
            lanes.evict([0])
        with pytest.raises(DonationError):
            lanes.finalize(np.ones(1, bool))
        with pytest.raises(ValueError):
            lanes.Qh[0] = 0.0            # frozen mirror
        lanes.step_wait()
        lanes.finalize(np.ones(1, bool))
        lanes.evict([0, 1])
        lanes.Qh[0] = 0.0                # thawed
    assert g.windows == 1
    assert len(g.violations) == 2
    # class-wide patch restored on exit
    from repro.serving.lanes import LaneBatch
    assert LaneBatch.step_async.__qualname__.startswith("LaneBatch.")


def test_donation_guard_is_transparent_to_a_clean_driver(index,
                                                         queries):
    """The synchronous step() spelling and the admit->step->finalize
    cycle run unchanged under the guard (windows counted, nothing
    raised) -- the guard must not perturb what it measures."""
    with guard_donation() as g:
        lanes = _lane_batch(index, queries)
        lanes.step(2)
        lanes.step(0)
        ids, dists = lanes.finalize(np.ones(1, bool))
        assert ids.shape[0] == 2
    assert g.windows == 2 and g.violations == []


def test_regression_nondrain_shutdown_waits_for_inflight_chunk(
        index, queries):
    """The real defect this guard family caught: `shutdown(
    drain=False)` joins the loop thread right after a tick dispatched a
    chunk (tick step 5), then evicted the occupied lanes with that
    chunk still in flight. Statically legal -- the device stream
    serializes -- but a violation of the donation window the guard
    enforces; the fix step_waits first. The whole lifecycle must now
    run clean under the guard."""
    from repro.api.db import NavixDB
    from repro.storage.columnar import GraphStore

    n = index.graph.n
    store = GraphStore()
    store.add_node_table("Chunk", n, {"cID": np.arange(n)})
    db = NavixDB(store)
    db.register_index("default", index)
    with guard_donation() as g:
        svc = db.serve(k_cap=6, efs_cap=24, max_batch=4,
                       step_iters=1).start()
        futs = [svc.submit(queries[j], k=6) for j in range(6)]
        time.sleep(0.02)             # let the loop dispatch chunks
        assert svc.shutdown(drain=False, timeout=60)
        for f in futs:
            assert f.done()
    assert g.violations == []


# -- analysis baseline / changed-only ----------------------------------------

def test_changed_only_reports_only_edited_files(tmp_path, monkeypatch):
    """--changed-only plumbing: a baseline write, an edit, and the
    changed-set diff (new and edited files count, untouched ones
    don't)."""
    from repro.analysis import __main__ as cli

    (tmp_path / "ROADMAP.md").write_text("x")
    tree = tmp_path / "src"
    tree.mkdir()
    (tree / "a.py").write_text("A = 1\n")
    (tree / "b.py").write_text("B = 2\n")
    monkeypatch.setattr(cli, "repo_root", lambda: tmp_path)

    cli.write_baseline([str(tree)])
    assert cli.changed_files([str(tree)]) == set()

    (tree / "b.py").write_text("B = 3\n")
    (tree / "c.py").write_text("C = 4\n")
    assert cli.changed_files([str(tree)]) == {"src/b.py", "src/c.py"}


def test_committed_baseline_is_current():
    """ANALYSIS_baseline.json must be refreshed alongside any file
    change (python -m repro.analysis --write-baseline): a stale
    baseline makes --changed-only report stale diffs."""
    from repro.analysis import __main__ as cli

    baseline = REPO / cli.BASELINE_NAME
    assert baseline.exists(), "run: python -m repro.analysis " \
                              "--write-baseline"
    paths = [str(REPO / t) for t in cli.DEFAULT_TREES
             if (REPO / t).exists()]
    changed = cli.changed_files(paths)
    assert changed == set(), (
        f"{len(changed)} file(s) differ from {cli.BASELINE_NAME}; "
        f"refresh it with: python -m repro.analysis --write-baseline")
