"""navilint + runtime-guard coverage.

Fixture snippets live in plain strings: navilint's comment scanner runs
on tokenize output, so annotation/suppression comments inside THESE
string literals are invisible when navilint sweeps this test file
itself -- the fixtures can seed violations without dirtying the tree.

The lock-order scenarios re-run the PR-6 serving drills (thundering
herd at the backpressure gate, threaded shutdown drain, straggler-shard
heartbeat) under the instrumented-lock monitor and assert the
acquisition graph stays acyclic.
"""

import pathlib
import threading
import time

import jax
import numpy as np
import pytest

from repro.analysis import analyze_paths, analyze_source
from repro.analysis.navilint import (BARE_EXCEPT, FORBIDDEN_OP,
                                     MALFORMED_SUPPRESSION, STALE_REGISTRY,
                                     STALE_SUPPRESSION, UNKNOWN_LOCK,
                                     UNLOCKED_ACCESS, UNUSED_IMPORT,
                                     WALLCLOCK)
from repro.analysis.runtime import (CompileCounter, LockOrderMonitor,
                                    instrument_locks)

REPO = pathlib.Path(__file__).resolve().parent.parent

needs_2_devices = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs 2 host devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _hits(findings, rule):
    return [(f.rule, f.line) for f in findings if f.rule == rule]


# -- must-flag fixtures ------------------------------------------------------

def test_flags_unlocked_annotated_field():
    src = """\
import threading

class Gauge:
    def __init__(self):
        self._lock = threading.Lock()
        self.depth = 0   # guarded-by: _lock

    def bump(self):
        with self._lock:
            self.depth += 1

    def peek(self):
        return self.depth
"""
    findings = analyze_source(src, "fixture_lock.py")
    assert _hits(findings, UNLOCKED_ACCESS) == [(UNLOCKED_ACCESS, 13)]
    assert len(findings) == 1, [f.render() for f in findings]


def test_flags_wallclock_deadline():
    src = """\
import time

def deadline_in(seconds):
    return time.time() + seconds
"""
    findings = analyze_source(src, "fixture_clock.py")
    assert _hits(findings, WALLCLOCK) == [(WALLCLOCK, 4)]
    assert len(findings) == 1


def test_flags_scatter_in_registered_hot_loop():
    """A function whose qualname is in the hot-path registry for its
    file is hot without any inline marker: reintroducing a scatter or
    top_k there flags even deep inside a nested closure."""
    src = """\
import jax.numpy as jnp
from jax import lax

def step_lanes(st, visit):
    def body(carry):
        d = carry.at[0].set(0.0)
        neg, order = lax.top_k(-d, 4)
        return lax.scatter_add(d, visit, neg, None)
    return body
"""
    findings = analyze_source(src, "src/repro/core/search_batch.py")
    assert (FORBIDDEN_OP, 6) in _hits(findings, FORBIDDEN_OP)   # .at[].set
    assert (FORBIDDEN_OP, 7) in _hits(findings, FORBIDDEN_OP)   # top_k
    assert (FORBIDDEN_OP, 8) in _hits(findings, FORBIDDEN_OP)   # scatter_add


def test_flags_stale_suppression():
    """A sync-ok left behind after the offending call was deleted is
    itself a finding -- suppressions must never outlive their reason."""
    src = """\
def finalize(x):
    # navilint: sync-ok results cross to host here
    return x
"""
    findings = analyze_source(src, "fixture_stale.py")
    assert _hits(findings, STALE_SUPPRESSION) == [(STALE_SUPPRESSION, 2)]
    assert len(findings) == 1


# -- must-pass fixtures ------------------------------------------------------

def test_passes_suppressed_sync_at_declared_boundary():
    src = """\
import numpy as np

def finalize(fin):  # navilint: hot
    # navilint: sync-ok the declared finalize boundary
    return np.asarray(fin.ids)
"""
    assert analyze_source(src, "fixture_ok_sync.py") == []


def test_passes_lock_held_annotated_helper():
    src = """\
import threading

class Q:
    def __init__(self):
        self._lock = threading.Lock()
        self._gated = False   # guarded-by: _lock

    def pop(self):
        with self._lock:
            self._maybe_ungate()

    def _maybe_ungate(self):  # navilint: lock-held _lock
        self._gated = False
"""
    assert analyze_source(src, "fixture_ok_lock.py") == []


# -- annotation hygiene ------------------------------------------------------

def test_suppression_without_reason_is_malformed():
    src = """\
import numpy as np

def step(x):  # navilint: hot
    return np.asarray(x)  # navilint: sync-ok
"""
    findings = analyze_source(src, "fixture_noreason.py")
    assert _hits(findings, MALFORMED_SUPPRESSION) == \
        [(MALFORMED_SUPPRESSION, 4)]


def test_guarded_by_unknown_lock_flags_the_class():
    src = """\
class C:
    def __init__(self):
        self.x = 0   # guarded-by: _lock

    def get(self):
        return 1
"""
    findings = analyze_source(src, "fixture_nolock.py")
    assert _hits(findings, UNKNOWN_LOCK) == [(UNKNOWN_LOCK, 1)]


def test_registry_entry_without_function_is_stale():
    src = "def something_else():\n    return 1\n"
    findings = analyze_source(src, "src/repro/serving/lanes.py")
    assert {f.rule for f in findings} == {STALE_REGISTRY}
    assert {"LaneBatch.step", "LaneBatch.finalize"} <= {
        f.message.split("'")[1] for f in findings}


def test_hygiene_unused_import_and_bare_except():
    src = """\
import os
import sys  # noqa: F401

def risky():
    try:
        return os.getpid()
    except:
        return -1
"""
    findings = analyze_source(src, "fixture_hygiene.py")
    assert _hits(findings, UNUSED_IMPORT) == []        # os used, sys noqa'd
    assert _hits(findings, BARE_EXCEPT) == [(BARE_EXCEPT, 7)]
    src2 = "import json\n\nX = 1\n"
    assert _hits(analyze_source(src2, "fixture_unused.py"),
                 UNUSED_IMPORT) == [(UNUSED_IMPORT, 1)]


# -- the real tree -----------------------------------------------------------

def test_full_tree_is_clean():
    """`python -m repro.analysis --strict` must exit 0 on the repo: the
    tree carries its own annotations, so any finding here is a real
    regression (or a missing annotation) introduced by a change."""
    findings = analyze_paths([str(REPO / "src"), str(REPO / "tests"),
                              str(REPO / "benchmarks")])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_registry_names_resolve_against_source():
    """Every hot-path registry entry must name a function that exists --
    a refactor that renames one must update the registry (NX303)."""
    findings = analyze_paths([str(REPO / "src" / "repro")])
    stale = [f for f in findings if f.rule == STALE_REGISTRY]
    assert stale == [], "\n".join(f.render() for f in stale)


# -- lock-order runtime guard ------------------------------------------------

def test_lock_order_detects_abba_cycle():
    with instrument_locks() as mon:
        a = threading.Lock()
        b = threading.Lock()

        def fwd():
            with a:
                with b:
                    pass

        def rev():
            with b:
                with a:
                    pass

        t1 = threading.Thread(target=fwd)
        t1.start()
        t1.join()
        t2 = threading.Thread(target=rev)
        t2.start()
        t2.join()
    cycles = mon.cycles()
    assert cycles, "A->B and B->A acquisitions must report a cycle"
    assert mon.report()["cycles"]


def test_lock_order_nested_same_order_is_clean():
    with instrument_locks() as mon:
        outer = threading.Lock()
        inner = threading.Lock()
        for _ in range(3):
            with outer:
                with inner:
                    pass
    assert mon.edges and not mon.cycles()


def test_lock_order_clean_across_queue_herd():
    """The PR-6 thundering-herd drill under the monitor: blocked putters
    waking through the backpressure gate must not create lock-order
    cycles (Condition wait/notify runs through the instrumented lock)."""
    from repro.serving import SubmissionQueue
    with instrument_locks() as mon:
        q = SubmissionQueue(maxsize=4, policy="block",
                            high_watermark=2, low_watermark=1)
        q.put(1.0, None, meta=0)
        q.put(1.0, None, meta=1)                 # depth == high -> gated
        started = []
        threads = [threading.Thread(
            target=lambda j=j: started.append(q.put(1.0, None, meta=j)))
            for j in range(3)]
        for t in threads:
            t.start()
        time.sleep(0.05)
        deadline = time.monotonic() + 5.0
        while len(started) < 3 and time.monotonic() < deadline:
            q.pop_batch(2)
            time.sleep(0.01)
        for t in threads:
            t.join(5.0)
        assert len(started) == 3
    assert mon.cycles() == [], mon.report()


def test_lock_order_clean_across_threaded_shutdown(index, queries):
    """Threaded service lifecycle (start -> submit -> drain shutdown)
    under the monitor: the submit path (submit/lat locks), the device
    loop, and the queue's close/wake path must stay acyclic."""
    from repro.api.db import NavixDB
    from repro.query.operators import Filter, NodeScan
    from repro.storage.columnar import GraphStore

    n = index.graph.n
    with instrument_locks() as mon:
        store = GraphStore()
        store.add_node_table("Chunk", n, {"cID": np.arange(n)})
        db = NavixDB(store)
        db.register_index("default", index)
        with db.serve(k_cap=6, efs_cap=24, max_batch=4,
                      step_iters=4) as svc:
            futs = [svc.submit(
                queries[j],
                plan=Filter(NodeScan("Chunk"), "cID", "<",
                            value=n // (j + 1)), k=6)
                for j in range(6)]
            out = [f.result(timeout=120) for f in futs]
        assert all(r.status == "ok" for r in out)
        assert svc.gauges()["done"] == 6
    assert mon.cycles() == [], mon.report()


@needs_2_devices
def test_lock_order_clean_across_straggler_heartbeat(shard_env):
    """The sharded straggler drill (suppressed heartbeat flips responses
    to degraded) under the monitor -- heartbeat, queue, and service
    locks interleave across beats, ticks, and finalize."""
    from repro.api.db import NavixDB
    from repro.query.operators import Filter, NodeScan
    from repro.serving import HeartbeatMonitor, SearchService
    from repro.storage.columnar import GraphStore

    X, qs, factory = shard_env
    sn = factory(2)
    n = sn.n_total

    class Clk:
        t = 0.0

        def __call__(self):
            return self.t

    clk = Clk()
    with instrument_locks() as mon:
        hb = HeartbeatMonitor(2, stale_after=2.0, clock=clk)
        store = GraphStore()
        store.add_node_table("Chunk", n, {"cID": np.arange(n)})
        db = NavixDB(store)
        db.register_index("default", sn)
        svc = SearchService(db, k_cap=6, efs_cap=24, max_batch=4,
                            step_iters=4, heartbeats=hb)

        def drive(futs):
            for _ in range(500):
                if all(f.done() for f in futs):
                    return [f.result(timeout=0) for f in futs]
                svc._tick()
            raise AssertionError("service did not converge")

        plan = Filter(NodeScan("Chunk"), "cID", "<", value=n // 2)
        drive([svc.submit(qs[j], plan=plan, k=6) for j in range(4)])
        hb.suppress(1)
        clk.t = 10.0
        hb.beat(0)
        resps = drive([svc.submit(qs[j], plan=plan, k=6)
                       for j in range(4)])
        assert all(r.degraded for r in resps), \
            "stale heartbeat must degrade responses"
        svc.shutdown(drain=True)
    assert mon.cycles() == [], mon.report()


def test_lock_order_monitor_standalone_api():
    mon = LockOrderMonitor()
    mon._acquired("a.py:1")
    mon._acquired("b.py:2")
    mon._released("b.py:2")
    mon._released("a.py:1")
    mon._acquired("b.py:2")
    mon._acquired("a.py:1")
    assert mon.cycles() == [["a.py:1", "b.py:2", "a.py:1"]]


# -- zero-recompile runtime guard --------------------------------------------

def test_compile_counter_counts_then_cache_hits_zero():
    with CompileCounter() as cc:
        f = jax.jit(lambda x: x * 3 + 1)
        f(np.arange(7, dtype=np.float32)).block_until_ready()
        assert cc.counts["warmup"] >= 1
        cc.mark("steady")
        f(np.arange(7, dtype=np.float32) + 1).block_until_ready()
        f(np.arange(7, dtype=np.float32) + 2).block_until_ready()
    assert cc.counts["steady"] == 0, cc.counts
    assert cc.total == sum(cc.counts.values())


def test_db_execute_bucket_reuse_compiles_nothing(index):
    """The ProgramCache bucketing claim at the XLA level: after a warm
    execute at bucket 8, a different batch size in the same bucket and a
    different predicate must trigger ZERO backend compiles -- cache
    stats can lie (a re-keyed entry still misses), the compiler hook
    cannot."""
    from repro.api import NavixDB, Q
    from repro.storage.columnar import GraphStore

    n = index.graph.n
    store = GraphStore()
    store.add_node_table("Chunk", n, {"cID": np.arange(n)})
    db = NavixDB(store)
    db.register_index("default", index)
    rng = np.random.default_rng(3)
    qs = rng.normal(size=(8, index.graph.dim)).astype(np.float32)

    plan = Q.match("Chunk").where("cID", "<", n // 2).knn(k=5, efs=20)
    with CompileCounter() as cc:
        db.execute(plan, query=qs[:7])               # bucket 8 (cold)
        cc.mark("steady")
        db.execute(plan, query=qs[:5])               # same bucket
        db.execute(Q.match("Chunk").where("cID", "<", n // 3)
                   .knn(k=5, efs=20), query=qs[:8])  # new predicate
    assert cc.counts["steady"] == 0, cc.counts
