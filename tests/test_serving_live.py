"""Live serving subsystem: queue backpressure, deadline eviction,
heartbeat shard liveness, exactly-once shutdown.

The deterministic tests drive ``SearchService._tick()`` by hand with an
injected fake clock -- no threads, no sleeps -- so deadline semantics
are exact: a deadline that passes in-queue or mid-flight must produce
``Response.timeout`` with ALL ids ``-1`` (never a truncated id list),
unless the evicted lane's beam already covers k valid candidates
(``"partial"``). The sharded test reuses the distributed suite's oracle
(``per_shard_reference``): heartbeat staleness flipping ``alive``
mid-service must equal the alive-restricted reference bitwise.
"""

import threading

import jax
import numpy as np
import pytest

from repro.api.db import NavixDB
from repro.core.distributed import per_shard_reference
from repro.query.operators import Filter, NodeScan
from repro.serving import (HeartbeatMonitor, LaneBatch, QueueFull,
                           SearchService, ServiceClosed, SubmissionQueue,
                           resolve_alive, sigma_bin)
from repro.storage.columnar import GraphStore

needs_2_devices = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs 2 host devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def _db(idx, n):
    store = GraphStore()
    store.add_node_table("Chunk", n, {"cID": np.arange(n)})
    db = NavixDB(store)
    db.register_index("default", idx)
    return db


def _cut_plan(cut):
    return Filter(NodeScan("Chunk"), "cID", "<", value=cut)


def _drive(svc, futs, max_ticks=500):
    """Tick the service until every future resolves (manual driver)."""
    for _ in range(max_ticks):
        if all(f.done() for f in futs):
            return
        svc._tick()
    raise AssertionError("service did not resolve all futures")


# -- SubmissionQueue ---------------------------------------------------------

def test_sigma_bins_are_geometric():
    assert sigma_bin(1.0, 4) == 0
    assert sigma_bin(0.6, 4) == 0
    assert sigma_bin(0.4, 4) == 1
    assert sigma_bin(0.2, 4) == 2
    assert sigma_bin(0.01, 4) == 3          # clamped to the last bin
    assert sigma_bin(0.0, 4) == 3


def test_queue_backpressure_reject_with_hysteresis():
    q = SubmissionQueue(maxsize=8, policy="reject",
                        high_watermark=3, low_watermark=1)
    for j in range(3):
        q.put(1.0, None, meta=j)
    with pytest.raises(QueueFull):
        q.put(1.0, None, meta=99)
    assert q.gauges()["gated"] and q.gauges()["rejected"] == 1
    # hysteresis: popping to depth 2 (> low) keeps the gate closed ...
    assert len(q.pop_batch(1)) == 1
    with pytest.raises(QueueFull):
        q.put(1.0, None, meta=99)
    # ... and reaching the low watermark reopens it
    assert len(q.pop_batch(1)) == 1
    q.put(1.0, None, meta=100)
    assert not q.gauges()["gated"]


def test_queue_backpressure_block_unblocks_at_low_watermark():
    q = SubmissionQueue(maxsize=8, policy="block",
                        high_watermark=2, low_watermark=1)
    q.put(1.0, None, meta=0)
    q.put(1.0, None, meta=1)
    got = []
    t = threading.Thread(
        target=lambda: got.append(q.put(1.0, None, meta=2)))
    t.start()
    t.join(0.2)
    assert t.is_alive(), "put must block while gated"
    q.pop_batch(1)                           # depth 1 == low -> reopen
    t.join(5.0)
    assert not t.is_alive() and got[0].meta == 2
    q.pop_batch(1)                           # back below the gate
    q.put(1.0, None, meta=3)                 # depth 2 again
    # a blocked put with a timeout gives up as QueueFull
    with pytest.raises(QueueFull):
        q.put(1.0, None, meta=4, timeout=0.05)


def test_queue_block_woken_putters_recheck_depth():
    """N putters blocked on the gate must NOT all append when it
    reopens: each woken putter re-checks depth, so the documented bound
    (depth never exceeds the high watermark) holds even under a
    thundering herd."""
    q = SubmissionQueue(maxsize=4, policy="block",
                        high_watermark=2, low_watermark=1)
    q.put(1.0, None, meta=0)
    q.put(1.0, None, meta=1)                 # depth == high -> gated
    n_blocked = 3
    started = []
    threads = [threading.Thread(
        target=lambda j=j: started.append(q.put(1.0, None, meta=10 + j)))
        for j in range(n_blocked)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(0.2)
    assert all(t.is_alive() for t in threads), "puts must block gated"
    q.pop_batch(1)                           # depth 1 == low -> reopen
    deadline = 5.0
    import time as _time
    t0 = _time.monotonic()
    while len(started) < 1 and _time.monotonic() - t0 < deadline:
        _time.sleep(0.01)
    _time.sleep(0.1)                         # let the herd race the gate
    assert len(q) <= 2, ("woken putters must re-check depth; got depth "
                         f"{len(q)} > high=2")
    # drain: every blocked putter eventually gets in, one reopen at a time
    while len(started) < n_blocked and _time.monotonic() - t0 < deadline:
        q.pop_batch(2)
        _time.sleep(0.01)
    for t in threads:
        t.join(deadline)
    assert len(started) == n_blocked
    assert len(q) <= 2


def test_queue_close_wakes_blocked_putter_with_service_closed():
    q = SubmissionQueue(maxsize=4, policy="block", high_watermark=1)
    q.put(1.0, None, meta=0)
    err = []

    def blocked():
        try:
            q.put(1.0, None, meta=1)
        except ServiceClosed as e:
            err.append(e)

    t = threading.Thread(target=blocked)
    t.start()
    t.join(0.2)
    assert t.is_alive()
    q.close()
    t.join(5.0)
    assert not t.is_alive() and len(err) == 1
    with pytest.raises(ServiceClosed):
        q.put(1.0, None, meta=2)
    # queued items remain drainable after close
    assert [it.meta for it in q.drain_remaining()] == [0]


def test_queue_pop_is_deadline_ordered_and_bin_affine():
    q = SubmissionQueue(maxsize=16)
    q.put(1.0, 10.0, meta="a")               # bin 0, later deadline
    q.put(0.9, None, meta="b")               # bin 0, no deadline
    q.put(0.10, 5.0, meta="c")               # bin 3, EARLIEST deadline
    q.put(0.12, None, meta="d")              # bin 3
    # the urgent item (c) anchors the bin; d rides along before a/b
    assert [it.meta for it in q.pop_batch(2)] == ["c", "d"]
    assert [it.meta for it in q.pop_batch(4)] == ["a", "b"]
    # prefer_sigma overrides the anchor (running-lane affinity)
    q.put(1.0, 10.0, meta="a")
    q.put(0.1, 5.0, meta="c")
    assert [it.meta for it in q.pop_batch(1, prefer_sigma=1.0)] == ["a"]


def test_queue_expire_removes_past_deadline_items():
    q = SubmissionQueue(maxsize=8)
    q.put(1.0, 5.0, meta="dead")
    q.put(1.0, 50.0, meta="ok")
    q.put(1.0, None, meta="forever")
    dead = q.expire(now=10.0)
    assert [it.meta for it in dead] == ["dead"]
    assert len(q) == 2


# -- liveness config ---------------------------------------------------------

def test_resolve_alive_validation():
    hb = HeartbeatMonitor(2, stale_after=1.0)
    with pytest.raises(ValueError, match="not both"):
        resolve_alive(2, np.ones(2, bool), hb)
    with pytest.raises(ValueError, match="unsharded"):
        resolve_alive(0, None, hb)
    with pytest.raises(ValueError, match="unsharded|alive"):
        resolve_alive(0, np.ones(2, bool), None)
    with pytest.raises(ValueError, match="shards"):
        resolve_alive(3, None, hb)
    np.testing.assert_array_equal(resolve_alive(2, None, hb),
                                  [True, True])


def test_heartbeat_staleness_and_suppression():
    clk = FakeClock(100.0)
    hb = HeartbeatMonitor(2, stale_after=2.0, clock=clk)
    assert hb.alive().all()
    clk.t = 101.0
    hb.beat(0)
    clk.t = 103.0                            # shard 1's last beat: t=100
    np.testing.assert_array_equal(hb.alive(), [True, False])
    hb.beat(1)
    assert hb.alive().all()
    hb.suppress(1)                           # straggler: beats dropped
    clk.t = 105.0
    hb.beat(0)
    hb.beat(1)                               # dropped: shard 1 stays at 103
    clk.t = 106.0
    np.testing.assert_array_equal(hb.alive(), [True, False])
    hb.restore(1)
    assert hb.alive().all()


# -- lane eviction (device op) -----------------------------------------------

def test_evict_lanes_parks_only_flagged_lanes(index, queries):
    lanes = LaneBatch(index, "adaptive_local", k_cap=6, efs_cap=24, bsz=2)
    full = lanes.backend.full_row()
    lanes.admit([(("a",), np.asarray(index._prep_query(queries[0][None]))[0],
                  full, 1.0, 24),
                 (("b",), np.asarray(index._prep_query(queries[1][None]))[0],
                  full, 1.0, 24)])
    lanes.step(2)
    lanes.evict([0])
    assert lanes.meta[0] is None and lanes.meta[1] is not None
    live = lanes.step(0)                     # run lane 1 to convergence
    assert not live.any(), "evicted lanes must report live=False"
    ids, dists = lanes.finalize(np.ones(1, bool))
    assert (ids[0] == -1).all(), "an evicted lane finalizes to all -1"
    single = index.search(queries[1], k=6, efs=24)
    np.testing.assert_array_equal(ids[1][:6], np.asarray(single.ids),
                                  err_msg="surviving lane must be intact")


# -- SearchService (manual driver, fake clock) -------------------------------

def test_service_serves_and_matches_single_query_oracle(index, queries):
    n = index.graph.n
    db = _db(index, n)
    svc = SearchService(db, k_cap=6, efs_cap=24, max_batch=4, step_iters=4)
    futs, cuts = [], [n // 8, n // 3, n // 2, n, 2 * n // 3, n // 5]
    for j, cut in enumerate(cuts):
        futs.append(svc.submit(queries[j], plan=_cut_plan(cut), k=6))
    _drive(svc, futs)
    for j, (cut, f) in enumerate(zip(cuts, futs)):
        r = f.result(timeout=0)
        assert r.status == "ok" and not r.degraded
        single = index.search(queries[j], k=6, efs=24,
                              semimask=np.arange(n) < cut)
        np.testing.assert_array_equal(np.asarray(r.ids),
                                      np.asarray(single.ids))
    assert {f.result().rid for f in futs} == {r.result().rid for r in futs}
    svc.shutdown()


def test_service_queue_expiry_is_timeout_never_partial_ids(index, queries):
    """A request whose deadline passes while still queued resolves to
    Response.timeout with ALL ids -1 -- no lane, no partial id list."""
    n = index.graph.n
    db = _db(index, n)
    clk = FakeClock(0.0)
    svc = SearchService(db, k_cap=6, efs_cap=24, max_batch=1,
                        step_iters=2, clock=clk)
    # admission is deadline-ordered: the EARLIER deadline takes the only
    # lane, leaving f_dead queued past its own deadline
    f_first = svc.submit(queries[0], k=6, deadline_s=3.0)
    f_dead = svc.submit(queries[1], k=6, deadline_s=5.0)
    svc._tick()                                      # admits f_first only
    assert svc.lanes.occupied_count() == 1 and not f_dead.done()
    clk.t = 10.0                                     # f_dead expires queued
    svc._tick()
    r = f_dead.result(timeout=0)
    assert r.timeout and r.status == "timeout"
    assert (np.asarray(r.ids) == -1).all() and np.isinf(r.dists).all()
    assert r.exec_ms == 0.0, "an expired-in-queue request never ran"
    assert f_first.done(), "the overdue lane must be evicted too"
    svc.shutdown()


def test_service_midflight_eviction_timeout_when_k_uncovered(index, queries):
    """A lane evicted mid-flight whose selection holds fewer than k valid
    nodes can never cover k: it must resolve to timeout (all -1), and its
    lane must be reusable afterwards."""
    n = index.graph.n
    db = _db(index, n)
    clk = FakeClock(0.0)
    svc = SearchService(db, k_cap=6, efs_cap=24, max_batch=1,
                        step_iters=1, clock=clk)
    f = svc.submit(queries[0], plan=_cut_plan(3), k=6,   # |S|=3 < k=6
                   deadline_s=5.0)
    svc._tick()                                      # admit + 1 chunk
    assert svc.lanes.occupied_count() == 1
    clk.t = 10.0
    svc._tick()                                      # overdue -> evict
    r = f.result(timeout=0)
    assert r.status == "timeout" and (np.asarray(r.ids) == -1).all()
    assert svc.lanes.occupied_count() == 0, "evicted lane must free up"
    f2 = svc.submit(queries[1], k=6)                 # lane is reusable
    _drive(svc, [f2])
    assert f2.result(timeout=0).status == "ok"
    assert svc.n_timeout == 1
    svc.shutdown()


def test_service_midflight_eviction_salvages_partial(index, queries):
    """An evicted lane whose beam already covers k valid candidates comes
    back status='partial' with k real ids (best-effort answer)."""
    n = index.graph.n
    db = _db(index, n)
    clk = FakeClock(0.0)
    svc = SearchService(db, k_cap=4, efs_cap=16, max_batch=1,
                        step_iters=8, clock=clk)
    f = svc.submit(queries[0], k=4, deadline_s=5.0)  # unfiltered: beam
    svc._tick()                                      # fills fast
    if f.done():                                     # converged already:
        assert f.result().status == "ok"             # nothing to evict
        svc.shutdown()
        return
    clk.t = 10.0
    svc._tick()
    r = f.result(timeout=0)
    if r.status == "ok":                             # converged in the
        svc.shutdown()                               # in-flight chunk
        return                                       # before the check
    assert r.status == "partial" and not r.timeout
    assert (np.asarray(r.ids) >= 0).all() and len(r.ids) == 4
    svc.shutdown()


def test_service_shutdown_drains_every_rid_exactly_once(index, queries):
    n = index.graph.n
    db = _db(index, n)
    svc = SearchService(db, k_cap=6, efs_cap=24, max_batch=2, step_iters=3)
    futs = [svc.submit(queries[j % len(queries)],
                       plan=_cut_plan(n // (j + 2)), k=6)
            for j in range(9)]
    svc.shutdown(drain=True)                 # manual driver drains inline
    rids = [f.result(timeout=0).rid for f in futs]
    assert sorted(rids) == sorted(set(rids)) and len(rids) == 9
    assert all(f.result().status == "ok" for f in futs)
    assert svc.n_done == 9 and svc.n_submitted == 9
    with pytest.raises(ServiceClosed):
        svc.submit(queries[0], k=6)
    svc.shutdown()                           # idempotent


def test_service_shutdown_without_drain_cancels(index, queries):
    db = _db(index, index.graph.n)
    svc = SearchService(db, k_cap=6, efs_cap=24, max_batch=1, step_iters=1)
    f_run = svc.submit(queries[0], k=6)
    f_queued = svc.submit(queries[1], k=6)
    svc._tick()                              # f_run takes the lane
    svc.shutdown(drain=False)
    assert f_run.cancelled() and f_queued.cancelled()
    assert svc.lanes.occupied_count() == 0


def test_service_shutdown_join_timeout_leaves_thread_owner(index, queries):
    """If join() times out, the background thread still owns the lane
    state: shutdown must NOT tick inline (that would race it), must
    keep the thread handle, and must report not-drained (False). A
    later shutdown call finishes once the thread has exited."""
    n = index.graph.n
    db = _db(index, n)
    svc = SearchService(db, k_cap=6, efs_cap=24, max_batch=2, step_iters=3)
    futs = [svc.submit(queries[j % len(queries)],
                       plan=_cut_plan(n // (j + 2)), k=6)
            for j in range(5)]
    # stand-in for a device loop that outlives the join timeout: a
    # thread we gate explicitly, so the race window is deterministic
    release = threading.Event()
    stuck = threading.Thread(target=release.wait)
    stuck.start()
    svc._thread = stuck
    assert svc.shutdown(drain=True, timeout=0.05) is False
    assert not svc.closed and svc._thread is stuck
    assert not any(f.done() for f in futs), \
        "shutdown must not drain inline while the thread is alive"
    release.set()
    assert svc.shutdown(drain=True, timeout=5.0) is True
    assert svc.closed
    rids = [f.result(timeout=0).rid for f in futs]
    assert sorted(rids) == sorted(set(rids)) and len(rids) == 5


def test_service_sel_cache_is_lru_bounded(index, queries):
    """The prefilter memo is an LRU with a size cap: distinct selection
    subqueries beyond the cap evict the oldest entry, and an evicted
    Q_S is re-prefiltered (its next carrier pays wall time again)."""
    n = index.graph.n
    db = _db(index, n)
    svc = SearchService(db, k_cap=6, efs_cap=24, max_batch=4,
                        step_iters=4, sel_cache_size=2)
    cuts = [n // 2, n // 3, n // 4]          # 3 distinct Q_S, cap 2
    futs = [svc.submit(queries[j], plan=_cut_plan(c), k=6)
            for j, c in enumerate(cuts)]
    assert len(svc._sel_cache) == 2, "cache must stay at its cap"
    assert all(f.result(timeout=0).prefilter_ms > 0 for f in
               (_drive(svc, futs) or futs)), \
        "each first carrier pays its prefilter"
    # cuts[0] was evicted by cuts[2]; re-submitting it re-prefilters
    f_again = svc.submit(queries[0], plan=_cut_plan(cuts[0]), k=6)
    assert f_again not in futs
    _drive(svc, [f_again])
    assert f_again.result(timeout=0).prefilter_ms > 0, \
        "an evicted Q_S must be re-prefiltered, not served stale"
    # a still-cached Q_S is a hit: no prefilter charge
    f_hit = svc.submit(queries[1], plan=_cut_plan(cuts[0]), k=6)
    _drive(svc, [f_hit])
    assert f_hit.result(timeout=0).prefilter_ms == 0.0
    n_ans = svc.n_done
    svc.shutdown(drain=True)
    assert svc.n_done == n_ans, "shutdown answers nothing twice"


def test_service_backpressure_reject_via_submit(index, queries):
    db = _db(index, index.graph.n)
    svc = SearchService(db, k_cap=6, efs_cap=24, max_batch=1,
                        queue_size=4, policy="reject",
                        high_watermark=2, low_watermark=1)
    svc.submit(queries[0], k=6)
    svc.submit(queries[1], k=6)
    with pytest.raises(QueueFull):
        svc.submit(queries[2], k=6)
    assert svc.gauges()["queue"]["gated"]
    svc.shutdown(drain=True)


def test_service_rejects_requests_exceeding_program_caps(index, queries):
    db = _db(index, index.graph.n)
    svc = SearchService(db, k_cap=6, efs_cap=24)
    with pytest.raises(ValueError, match="caps"):
        svc.submit(queries[0], k=7)
    with pytest.raises(ValueError, match="heuristic"):
        from repro.query.operators import KnnSearch
        svc.submit(queries[0],
                   plan=KnnSearch(child=None, table="Chunk", k=4,
                                  heuristic="onehop_a"))
    svc.shutdown()


def test_service_thread_driver_end_to_end(index, queries):
    n = index.graph.n
    db = _db(index, n)
    with db.serve(k_cap=6, efs_cap=24, max_batch=4, step_iters=4) as svc:
        futs = [svc.submit(queries[j], plan=_cut_plan(n // (j + 1)), k=6)
                for j in range(6)]
        out = [f.result(timeout=120) for f in futs]
    assert all(r.status == "ok" for r in out)
    assert svc.closed and svc.n_done == 6
    g = svc.gauges()
    assert g["in_flight"] == 0 and g["queue"]["depth"] == 0
    assert g["p50_ms"] >= 0 and g["p99_ms"] >= g["p50_ms"]


# -- heartbeat liveness on a sharded service ---------------------------------

@needs_2_devices
def test_heartbeat_staleness_equals_alive_restricted_reference(shard_env,
                                                               queries):
    """Suppressing one shard's heartbeats mid-service flips responses to
    degraded AUTOMATICALLY (no caller-set alive mask), and the answers
    equal the per-shard host oracle restricted to the alive shards --
    the same contract as the distributed suite's quorum test."""
    X, qs, factory = shard_env
    sn = factory(2)
    n = sn.n_total
    db = _db(sn, n)
    clk = FakeClock(0.0)
    hb = HeartbeatMonitor(2, stale_after=2.0, clock=clk)
    svc = SearchService(db, k_cap=6, efs_cap=24, max_batch=4,
                        step_iters=4, heartbeats=hb)
    params = sn._params(6, 24, "adaptive_local")
    cuts = [n // 3, n // 2, n, n // 5]
    masks = np.stack([np.arange(n) < c for c in cuts])
    Q = qs[:4]

    # phase 1: all shards beating -> full-quorum answers
    futs = [svc.submit(Q[j], plan=_cut_plan(cuts[j]), k=6)
            for j in range(4)]
    _drive(svc, futs)
    ref_d, ref_i, _ = per_shard_reference(sn, Q, masks, params)
    for j, f in enumerate(futs):
        r = f.result(timeout=0)
        assert r.status == "ok" and not r.degraded
        np.testing.assert_array_equal(np.asarray(r.ids), ref_i[j])
        np.testing.assert_array_equal(np.asarray(r.dists), ref_d[j])

    # phase 2: shard 1's worker goes silent; its heartbeat ages out and
    # every response finalized afterwards is degraded + alive-restricted
    hb.suppress(1)
    clk.t = 10.0
    hb.beat(0)
    alive = np.array([True, False])
    futs = [svc.submit(Q[j], plan=_cut_plan(cuts[j]), k=6)
            for j in range(4)]
    _drive(svc, futs)
    ref_d, ref_i, _ = per_shard_reference(sn, Q, masks, params,
                                          alive=alive)
    for j, f in enumerate(futs):
        r = f.result(timeout=0)
        assert r.status == "ok" and r.degraded, \
            "stale heartbeat must degrade responses automatically"
        np.testing.assert_array_equal(
            np.asarray(r.ids), ref_i[j],
            err_msg=f"lane {j} != alive-restricted reference")
        np.testing.assert_array_equal(np.asarray(r.dists), ref_d[j])
        ids = np.asarray(r.ids)
        assert (ids[ids >= 0] // sn.n_local != 1).all(), \
            "dead shard leaked ids"
    svc.shutdown()


# -- latency summary satellite (closed-queue engine) -------------------------

def test_latency_summary_splits_queue_and_service(index, queries):
    from repro.serving.engine import SearchEngine
    store = GraphStore()
    store.add_node_table("Chunk", index.graph.n,
                         {"cID": np.arange(index.graph.n)})
    eng = SearchEngine(index=index, store=store, efs=24)
    for j in range(5):
        eng.submit(queries[j], plan=_cut_plan(index.graph.n // (j + 1)),
                   k=5)
    eng.drain()
    s = eng.latency_summary()
    assert s["n"] == 5
    for key in ("p50_ms", "p95_ms", "p99_ms", "mean_ms", "queue_p50_ms",
                "queue_p99_ms", "service_p50_ms", "service_p95_ms",
                "service_p99_ms"):
        assert key in s and np.isfinite(s[key]) and s[key] >= 0.0, key
    assert s["p99_ms"] >= s["p50_ms"]
    # the split is recorded in lockstep with the totals
    assert len(eng.queue_waits_ms) == len(eng.service_ms) == 5
    np.testing.assert_allclose(
        np.asarray(eng.queue_waits_ms) + np.asarray(eng.service_ms),
        np.asarray(eng.latencies_ms))
