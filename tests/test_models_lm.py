"""LM correctness: per-arch smoke + decode-vs-forward consistency + MoE."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import get_arch
from repro.models import transformer as T
from repro.models.api import make_train_step, model_api

LM_ARCHS = ["gemma-7b", "qwen1.5-0.5b", "gemma2-9b", "kimi-k2-1t-a32b",
            "granite-moe-3b-a800m"]


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_smoke_train_step(arch_id, rng):
    cfg = get_arch(arch_id).smoke_config
    api = model_api(cfg)
    params = api.init(jax.random.key(0))
    step, opt = make_train_step(cfg)
    opt_state = opt.init(params)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(2, 24)), jnp.int32)}
    p2, o2, m = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, p2))
    assert delta > 0


@pytest.mark.parametrize("arch_id", ["qwen1.5-0.5b", "gemma2-9b",
                                     "granite-moe-3b-a800m"])
def test_decode_matches_forward(arch_id, rng):
    """prefill(s) + decode(t) must reproduce the full-forward logits --
    the KV cache, RoPE positions, windows and softcaps all line up.

    MoE archs get a no-drop capacity factor: capacity-based token dropping
    legitimately differs between a (s+1)-token forward and an s-token
    prefill (different T -> different capacity -> different drops)."""
    cfg = get_arch(arch_id).smoke_config
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    api = model_api(cfg)
    params = api.init(jax.random.key(1))
    b, s = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, s + 1)),
                         jnp.int32)
    full = T.lm_forward(cfg, params, tokens, chunked=False)   # [b, s+1, V]
    cache, logits_pre = T.prefill(cfg, params, tokens[:, :s], max_len=s + 2)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(full[:, s - 1]), rtol=2e-3,
                               atol=2e-3)
    cache, logits_dec = T.decode_step(cfg, params, cache, tokens[:, s])
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(full[:, s]), rtol=2e-3, atol=2e-3)


def test_chunked_attention_matches_full(rng):
    cfg = get_arch("gemma2-9b").smoke_config
    api = model_api(cfg)
    params = api.init(jax.random.key(2))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 64)),
                         jnp.int32)
    full = T.lm_forward(cfg, params, tokens, chunked=False)
    chk = T.lm_forward(cfg, params, tokens, chunked=True)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(full),
                               rtol=3e-3, atol=3e-3)


def test_local_window_masks_past(rng):
    """A gemma2 local layer must not attend beyond its window: perturbing a
    token older than every layer's reach must not change the last logit."""
    cfg = dataclasses.replace(get_arch("gemma2-9b").smoke_config,
                              n_layers=2, local_window=4)
    api = model_api(cfg)
    params = api.init(jax.random.key(3))
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(1, 40)),
                         jnp.int32)
    base = T.lm_forward(cfg, params, tokens, chunked=False)[0, -1]
    # layer 0 local(w=4), layer 1 global -> the last position CAN see
    # position 0 through the global layer; but a pure-local config cannot:
    cfg_local = dataclasses.replace(cfg, attn_pattern="global")
    # instead validate window via direct mask comparison on a local-only run
    w = T.layer_windows(cfg)
    assert int(w[0]) == 4 and int(w[1]) == T.GLOBAL_WINDOW


def test_moe_dispatch_mass_conservation(rng):
    """Every token's gates sum to 1; dropped tokens produce zero output but
    the shared expert still contributes."""
    cfg = get_arch("kimi-k2-1t-a32b").smoke_config
    api = model_api(cfg)
    params = api.init(jax.random.key(4))
    x = jnp.asarray(rng.normal(size=(32, cfg.d_model)), jnp.float32)
    p0 = jax.tree.map(lambda a: a[0], params["blocks"]["mlp"])
    out = T.moe_apply(p0, x, cfg.moe, cfg.activation)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()


def test_moe_capacity_drops_dont_nan(rng):
    import repro.config.base as cb
    moe = cb.MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                       capacity_factor=0.25)  # force heavy drops
    cfg = dataclasses.replace(get_arch("kimi-k2-1t-a32b").smoke_config,
                              moe=moe)
    api = model_api(cfg)
    params = api.init(jax.random.key(5))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 16)),
                         jnp.int32)
    loss, _ = api.loss(params, {"tokens": tokens})
    assert np.isfinite(float(loss))


def test_qwen_bias_present():
    cfg = get_arch("qwen1.5-0.5b").smoke_config
    params = model_api(cfg).init(jax.random.key(0))
    assert "bq" in params["blocks"]["attn"]


def test_param_count_analytic_matches_init():
    from repro.common.util import tree_params
    for arch_id in ["qwen1.5-0.5b", "granite-moe-3b-a800m"]:
        cfg = get_arch(arch_id).smoke_config
        params = model_api(cfg).init(jax.random.key(0))
        got = tree_params(params)
        exp = cfg.n_params()
        assert abs(got - exp) / exp < 0.02, (arch_id, got, exp)
