"""Figure 16/20 + Table 7: prefiltering (NaviX) vs postfiltering, and the
prefilter-vs-search time split.

Postfiltering (PGVectorScale/VBase style) streams unfiltered neighbors and
verifies; it wins at very high selectivity (cheap verification, no upfront
Q_S scan) and degrades sharply as selectivity falls. Prefiltering pays Q_S
upfront and stays robust."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, measure, n_queries
from benchmarks.datasets import wiki_db
from repro.api import Q
from repro.data.synthetic import make_queries, person_chunk_plan


def run() -> list[dict]:
    db, idx, data = wiki_db()
    nq = n_queries()
    queries = make_queries(data, nq, "uncorrelated", seed=31)
    rows = []
    for sigma in (0.9, 0.5, 0.3, 0.1, 0.05, 0.01):
        plan = (Q.match("Chunk")
                 .where("cID", "<", int(data.n_chunks * sigma)).plan())
        qres = db.prefilter(plan)
        mask = qres.mask
        # --- prefiltering: NaviX ---
        m = measure(idx, queries, mask, "adaptive_local")
        rows.append({
            "bench": "fig16_pre_vs_post", "system": "navix_prefilter",
            "sigma": sigma, "recall": round(m.recall, 4),
            "prefilter_ms": round(qres.seconds * 1e3, 3),
            "search_ms": round(m.ms_per_query, 2),
            "total_ms": round(qres.seconds * 1e3 + m.ms_per_query, 2),
            "t_dc": round(m.t_dc, 1), "verifications": 0,
        })
        # --- postfiltering ---
        _, true_ids = idx.brute_force(queries, k=100, semimask=mask)
        hits = denom = 0
        times, verifs, tdc = [], 0, 0
        for qi, q in enumerate(queries):
            t0 = time.perf_counter()
            d, ids, stats = idx.search_postfilter(q, k=100, semimask=mask)
            times.append(time.perf_counter() - t0)
            verifs += stats.verifications
            tdc += stats.t_dc
            t = set(int(x) for x in np.asarray(true_ids)[qi] if x >= 0)
            hits += len(set(int(x) for x in ids if x >= 0) & t)
            denom += len(t)
        rows.append({
            "bench": "fig16_pre_vs_post", "system": "postfilter",
            "sigma": sigma, "recall": round(hits / max(denom, 1), 4),
            "prefilter_ms": 0.0,
            "search_ms": round(float(np.mean(times) * 1e3), 2),
            "total_ms": round(float(np.mean(times) * 1e3), 2),
            "t_dc": round(tdc / nq, 1),
            "verifications": round(verifs / nq, 1),
        })
    emit(rows, "fig16_postfilter")
    return rows


def run_split() -> list[dict]:
    """Table 7: prefilter vs vector-search share, uncorrelated (cheap id
    filter) vs negatively correlated (1-hop join) Q_S."""
    db, idx, data = wiki_db()
    nq = n_queries()
    rows = []
    person_frac = data.chunk_is_person.mean()
    for workload, sigmas in (("uncorrelated", (0.9, 0.5, 0.3, 0.1, 0.01)),
                             ("negative_join", (0.229, 0.15, 0.099, 0.05))):
        for sigma in sigmas:
            if workload == "uncorrelated":
                plan = (Q.match("Chunk")
                         .where("cID", "<", int(data.n_chunks * sigma))
                         .plan())
                queries = make_queries(data, nq, "uncorrelated", seed=41)
            else:
                plan = person_chunk_plan(data.store,
                                         min(sigma / person_frac, 1.0))
                queries = make_queries(data, nq, "nonperson", seed=42)
            # prefilter time: repeat the Q_S evaluation like a fresh query
            t0 = time.perf_counter()
            for _ in range(3):
                qres = db.prefilter(plan)
            pf_ms = (time.perf_counter() - t0) / 3 * 1e3
            m = measure(idx, queries, qres.mask, "adaptive_local")
            total = pf_ms + m.ms_per_query
            rows.append({
                "bench": "table7_split", "workload": workload,
                "sigma": round(float(qres.mask.mean()), 4),
                "prefilter_ms": round(pf_ms, 3),
                "search_ms": round(m.ms_per_query, 2),
                "prefilter_pct": round(100 * pf_ms / total, 1),
                "recall": round(m.recall, 4),
            })
    emit(rows, "table7_split")
    return rows


def validate(rows) -> list[str]:
    fails = []
    post = {r["sigma"]: r for r in rows if r["system"] == "postfilter"}
    pre = {r["sigma"]: r for r in rows if r["system"] == "navix_prefilter"}
    # postfilter verification cost explodes as sigma falls
    if post and post[0.01]["verifications"] <= post[0.9]["verifications"] * 3:
        fails.append("postfilter verifications did not grow at low sigma")
    # prefilter more robust: dc ratio lo/hi much smaller than postfilter's
    if post and pre:
        post_ratio = max(post[0.01]["t_dc"], 1) / max(post[0.9]["t_dc"], 1)
        pre_ratio = max(pre[0.01]["t_dc"], 1) / max(pre[0.9]["t_dc"], 1)
        if not pre_ratio < post_ratio:
            fails.append(f"prefilter not more robust: {pre_ratio} vs {post_ratio}")
    return fails


if __name__ == "__main__":
    rows = run()
    run_split()
    for f in validate(rows):
        print("CLAIM-FAIL:", f)
