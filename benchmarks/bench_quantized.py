"""Figure 18 analogue (DiskANN regime): int8-quantized search + exact
re-rank vs full-precision search. On TPU the quantized path reads 4x fewer
HBM bytes (the memory-bound decode regime win); here we verify the
algorithmic side: recall parity after re-rank and the dc accounting."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, n_queries
from benchmarks.datasets import uncorrelated_dataset


def run() -> list[dict]:
    idx, X, _, queries = uncorrelated_dataset("tiny-like")
    queries = queries[: n_queries()]
    _, true_ids = idx.brute_force(queries, k=100)
    rows = []
    for mode in ("full", "quantized"):
        got, times, tdc = [], [], 0
        for q in queries:
            t0 = time.perf_counter()
            if mode == "full":
                r = idx.search(q, k=100, efs=200, heuristic="onehop_a")
            else:
                r = idx.search_quantized(q, k=100, efs=200,
                                         heuristic="onehop_a")
            r.dists.block_until_ready()
            times.append(time.perf_counter() - t0)
            got.append(np.asarray(r.ids))
            tdc += int(r.stats.t_dc)
        rec = idx.recall(np.stack(got), np.asarray(true_ids))
        rows.append({
            "bench": "fig18_quantized", "mode": mode,
            "recall": round(rec, 4),
            "ms_per_query": round(float(np.mean(times[1:]) * 1e3), 2),
            "t_dc": round(tdc / len(queries), 1),
            "hbm_bytes_per_dc": (X.shape[1] * 1 + 4) if mode == "quantized"
                                else X.shape[1] * 4,
        })
    emit(rows, "fig18_quantized")
    return rows


def validate(rows) -> list[str]:
    fails = []
    full = next(r for r in rows if r["mode"] == "full")
    quant = next(r for r in rows if r["mode"] == "quantized")
    if quant["recall"] < full["recall"] - 0.05:
        fails.append(f"quantized recall dropped too much: {rows}")
    if not quant["hbm_bytes_per_dc"] < full["hbm_bytes_per_dc"] / 3:
        fails.append("quantized path does not reduce bytes")
    return fails


if __name__ == "__main__":
    for f in validate(run()):
        print("CLAIM-FAIL:", f)
