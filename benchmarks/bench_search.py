"""Search-engine throughput: vmap oracle vs the batched-frontier engine.

Tracks the serving story per PR: for each batch size B in {1, 8, 32, 128}
both batch engines run the same filtered workload and report QPS, batch
latency percentiles, and recall@k against the brute-force oracle. Results
go to ``experiments/bench/BENCH_search.json`` (plus the usual CSV sink)
so the perf trajectory is diffable across PRs.

Claim gated by validate(): the batched engine's QPS at B=32 is >= 1.5x
the vmap path (>= 1.0x sanity floor in REPRO_BENCH_QUICK mode, where the
problem is too small for the margin to be stable), and -- since the
engines are lane-for-lane equivalent -- identical recall.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import bitset
from repro.core.navix import NavixConfig
from repro.core.search import SearchParams, search_batch
from repro.core.search_batch import search_many
from repro.data.synthetic import gaussian_mixture

# quick (smoke) runs write a separate file so they never clobber the
# committed full-mode result
JSON_OUT = pathlib.Path("experiments") / "bench" / (
    "BENCH_search.quick.json" if common.QUICK else "BENCH_search.json")

BATCHES = (1, 8, 32, 128)
K = 10
EFS = 60
SIGMA = 0.3
SPEEDUP_AT_B = 32
SPEEDUP_FLOOR = 1.0 if common.QUICK else 1.5

_ENGINES = {"vmap": search_batch, "batched": search_many}


def run() -> list[dict]:
    n, d = (1500, 16) if common.QUICK else (4000, 32)
    reps = 3 if common.QUICK else 8
    X, _, centers = gaussian_mixture(n, d, 10, seed=0)
    index = common.cached_index(f"bench_search_{n}",
                                X, NavixConfig(m_u=8, ef_construction=64,
                                               metric="l2", seed=0))
    rng = np.random.default_rng(7)
    mask = rng.random(n) < SIGMA
    sel = bitset.pack(jnp.asarray(mask))
    sigma_g = float(bitset.count(sel)) / n
    params = SearchParams(k=K, efs=EFS, heuristic=4, metric="l2")

    rows: list[dict] = []
    for b in BATCHES:
        Q = (centers[rng.integers(0, len(centers), size=b)]
             + 0.3 * rng.normal(size=(b, d))).astype(np.float32)
        Qj = jnp.asarray(Q)
        _, true_ids = index.brute_force(Q, k=K, semimask=mask)
        for engine, fn in _ENGINES.items():
            res = fn(index.graph, Qj, sel, params, sigma_g=sigma_g)
            res.dists.block_until_ready()               # warm-up compile
            times = []
            for _ in range(reps):
                t0 = time.perf_counter()
                res = fn(index.graph, Qj, sel, params, sigma_g=sigma_g)
                res.dists.block_until_ready()
                times.append(time.perf_counter() - t0)
            times_ms = np.asarray(times) * 1e3
            rows.append({
                "engine": engine,
                "B": b,
                "qps": round(b / float(np.mean(times)), 2),
                "p50_ms": round(float(np.percentile(times_ms, 50)), 3),
                "p95_ms": round(float(np.percentile(times_ms, 95)), 3),
                "recall": round(index.recall(np.asarray(res.ids),
                                             np.asarray(true_ids)), 4),
            })
    common.emit(rows, "search_engines")

    by = {(r["engine"], r["B"]): r for r in rows}
    speedups = {str(b): round(by[("batched", b)]["qps"]
                              / max(by[("vmap", b)]["qps"], 1e-9), 3)
                for b in BATCHES}
    JSON_OUT.parent.mkdir(parents=True, exist_ok=True)
    JSON_OUT.write_text(json.dumps({
        "workload": {"n": n, "d": d, "k": K, "efs": EFS, "sigma": SIGMA,
                     "heuristic": "adaptive_local", "reps": reps,
                     "quick": common.QUICK},
        "rows": rows,
        "batched_over_vmap_qps": speedups,
    }, indent=2) + "\n")
    return rows


def validate(rows: list[dict]) -> list[str]:
    fails: list[str] = []
    by = {(r["engine"], r["B"]): r for r in rows}
    v = by.get(("vmap", SPEEDUP_AT_B))
    b = by.get(("batched", SPEEDUP_AT_B))
    if not v or not b:
        return [f"missing B={SPEEDUP_AT_B} rows"]
    speedup = b["qps"] / max(v["qps"], 1e-9)
    if speedup < SPEEDUP_FLOOR:
        fails.append(f"batched engine QPS at B={SPEEDUP_AT_B} is only "
                     f"{speedup:.2f}x the vmap path (need >= "
                     f"{SPEEDUP_FLOOR}x)")
    for bb in BATCHES:
        rv, rb = by.get(("vmap", bb)), by.get(("batched", bb))
        if rv and rb and abs(rv["recall"] - rb["recall"]) > 1e-9:
            fails.append(f"engines disagree on recall at B={bb}: "
                         f"vmap={rv['recall']} batched={rb['recall']}")
    return fails
