"""Search-engine throughput: vmap oracle vs the batched-frontier engine.

Tracks the serving story per PR: for each batch size B in {1, 8, 32, 128}
both batch engines run the same filtered workload and report QPS, batch
latency percentiles, and recall@k against the brute-force oracle. Results
go to ``experiments/bench/BENCH_search.json`` (plus the usual CSV sink)
so the perf trajectory is diffable across PRs.

Claim gated by validate(): the batched engine's QPS at B=32 is >= 1.5x
the vmap path (>= 1.0x sanity floor in REPRO_BENCH_QUICK mode, where the
problem is too small for the margin to be stable), and -- since the
engines are lane-for-lane equivalent -- identical recall.

A second, larger-n arm (``_run_quantized``) benches the int8-resident
store against the f32 engine and gates the residency claims: resident
vector bytes <= 0.30x f32, recall@k within 0.02 after the ExactTier
re-rank, and zero steady-state compiles at off-bucket batch sizes.
Its payload lands under the ``"quantized"`` key of the same JSON.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import bitset
from repro.core.navix import NavixConfig
from repro.core.search import SearchParams, search_batch
from repro.core.search_batch import search_many
from repro.data.synthetic import gaussian_mixture

# quick (smoke) runs write a separate file so they never clobber the
# committed full-mode result
JSON_OUT = pathlib.Path("experiments") / "bench" / (
    "BENCH_search.quick.json" if common.QUICK else "BENCH_search.json")

BATCHES = (1, 8, 32, 128)
K = 10
EFS = 60
SIGMA = 0.3
SPEEDUP_AT_B = 32
SPEEDUP_FLOOR = 1.0 if common.QUICK else 1.5

_ENGINES = {"vmap": search_batch, "batched": search_many}

# quantized-resident arm: 4x the main bench size (the capacity story only
# shows at scale), one bucketed batch size, plus off-bucket batch sizes
# that must compile NOTHING once the bucket is warm
QUANT_B = 32
QUANT_OFF_BUCKET = (17, 24)
BYTES_RATIO_CEIL = 0.30        # resident vector bytes vs the f32 engine
RECALL_DELTA_CEIL = 0.02       # recall@k loss allowed after exact re-rank

# validate() needs the quantized payload, not just the per-B rows
_QUANT_PAYLOAD: dict = {}


def _run_quantized(reps: int) -> dict:
    """The residency arm: f32-resident vs int8-resident (+ exact re-rank)
    over the SAME graph at n >= 4x the main bench, both through the
    compiled-program cache. Emits QPS/recall/resident-bytes plus the
    CompileCounter proof that off-bucket batch sizes compile nothing."""
    from repro.analysis.runtime import CompileCounter
    from repro.api.plan_compile import ProgramCache

    n, d = (3000, 32) if common.QUICK else (16000, 32)
    X, _, centers = gaussian_mixture(n, d, 10, seed=0)
    index = common.cached_index(f"bench_search_q_{n}",
                                X, NavixConfig(m_u=8, ef_construction=64,
                                               metric="l2", seed=0))
    index = dataclasses.replace(index, program_cache=ProgramCache())
    qidx = index.quantize_resident()        # shares the program cache
    rng = np.random.default_rng(11)
    Q = (centers[rng.integers(0, len(centers), size=QUANT_B)]
         + 0.3 * rng.normal(size=(QUANT_B, d))).astype(np.float32)
    _, true_ids = index.brute_force(Q, k=K)
    true_ids = np.asarray(true_ids)

    def timed(fn):
        fn()                                # warm-up compile
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            res = fn()
            res.dists.block_until_ready()
            times.append(time.perf_counter() - t0)
        return res, float(np.mean(times))

    res_f, t_f = timed(lambda: index.search_many(Q, k=K, efs=EFS))
    recall_f = index.recall(np.asarray(res_f.ids), true_ids)

    with CompileCounter() as cc:
        res_q, t_q = timed(
            lambda: qidx.search_quantized_many(Q, k=K, efs=EFS))
        cc.mark("steady")
        for bb in QUANT_OFF_BUCKET + (QUANT_B,):
            qidx.search_quantized_many(Q[:bb], k=K, efs=EFS)
    steady = int(cc.counts.get("steady", 0))
    recall_q = index.recall(np.asarray(res_q.ids), true_ids)

    f32_bytes = index.graph.vector_nbytes()
    q_bytes = qidx.graph.vector_nbytes()
    rows = [
        {"resident": "f32", "B": QUANT_B,
         "qps": round(QUANT_B / t_f, 2), "recall": round(recall_f, 4),
         "vector_bytes": f32_bytes},
        {"resident": "int8+rerank", "B": QUANT_B,
         "qps": round(QUANT_B / t_q, 2), "recall": round(recall_q, 4),
         "vector_bytes": q_bytes},
    ]
    common.emit(rows, "search_quantized_resident")
    return {
        "workload": {"n": n, "d": d, "k": K, "efs": EFS,
                     "heuristic": "adaptive_local", "reps": reps,
                     "quick": common.QUICK},
        "rows": rows,
        "resident_bytes_ratio": round(q_bytes / f32_bytes, 4),
        "recall_delta": round(recall_f - recall_q, 4),
        "exact_tier_host_bytes": qidx.exact.nbytes(),
        "steady_compiles": steady,
        "compiles": dict(cc.counts),
    }


def run() -> list[dict]:
    n, d = (1500, 16) if common.QUICK else (4000, 32)
    reps = 3 if common.QUICK else 8
    X, _, centers = gaussian_mixture(n, d, 10, seed=0)
    index = common.cached_index(f"bench_search_{n}",
                                X, NavixConfig(m_u=8, ef_construction=64,
                                               metric="l2", seed=0))
    rng = np.random.default_rng(7)
    mask = rng.random(n) < SIGMA
    sel = bitset.pack(jnp.asarray(mask))
    sigma_g = float(bitset.count(sel)) / n
    params = SearchParams(k=K, efs=EFS, heuristic=4, metric="l2")

    rows: list[dict] = []
    for b in BATCHES:
        Q = (centers[rng.integers(0, len(centers), size=b)]
             + 0.3 * rng.normal(size=(b, d))).astype(np.float32)
        Qj = jnp.asarray(Q)
        _, true_ids = index.brute_force(Q, k=K, semimask=mask)
        for engine, fn in _ENGINES.items():
            res = fn(index.graph, Qj, sel, params, sigma_g=sigma_g)
            res.dists.block_until_ready()               # warm-up compile
            times = []
            for _ in range(reps):
                t0 = time.perf_counter()
                res = fn(index.graph, Qj, sel, params, sigma_g=sigma_g)
                res.dists.block_until_ready()
                times.append(time.perf_counter() - t0)
            times_ms = np.asarray(times) * 1e3
            rows.append({
                "engine": engine,
                "B": b,
                "qps": round(b / float(np.mean(times)), 2),
                "p50_ms": round(float(np.percentile(times_ms, 50)), 3),
                "p95_ms": round(float(np.percentile(times_ms, 95)), 3),
                "recall": round(index.recall(np.asarray(res.ids),
                                             np.asarray(true_ids)), 4),
            })
    common.emit(rows, "search_engines")

    global _QUANT_PAYLOAD
    _QUANT_PAYLOAD = _run_quantized(reps)

    by = {(r["engine"], r["B"]): r for r in rows}
    speedups = {str(b): round(by[("batched", b)]["qps"]
                              / max(by[("vmap", b)]["qps"], 1e-9), 3)
                for b in BATCHES}
    JSON_OUT.parent.mkdir(parents=True, exist_ok=True)
    JSON_OUT.write_text(json.dumps({
        "workload": {"n": n, "d": d, "k": K, "efs": EFS, "sigma": SIGMA,
                     "heuristic": "adaptive_local", "reps": reps,
                     "quick": common.QUICK},
        "rows": rows,
        "batched_over_vmap_qps": speedups,
        "quantized": _QUANT_PAYLOAD,
    }, indent=2) + "\n")
    return rows


def validate(rows: list[dict]) -> list[str]:
    fails: list[str] = []
    by = {(r["engine"], r["B"]): r for r in rows}
    v = by.get(("vmap", SPEEDUP_AT_B))
    b = by.get(("batched", SPEEDUP_AT_B))
    if not v or not b:
        return [f"missing B={SPEEDUP_AT_B} rows"]
    speedup = b["qps"] / max(v["qps"], 1e-9)
    if speedup < SPEEDUP_FLOOR:
        fails.append(f"batched engine QPS at B={SPEEDUP_AT_B} is only "
                     f"{speedup:.2f}x the vmap path (need >= "
                     f"{SPEEDUP_FLOOR}x)")
    for bb in BATCHES:
        rv, rb = by.get(("vmap", bb)), by.get(("batched", bb))
        if rv and rb and abs(rv["recall"] - rb["recall"]) > 1e-9:
            fails.append(f"engines disagree on recall at B={bb}: "
                         f"vmap={rv['recall']} batched={rb['recall']}")

    qp = _QUANT_PAYLOAD
    if not qp:
        fails.append("quantized arm did not run")
        return fails
    ratio = qp["resident_bytes_ratio"]
    if ratio > BYTES_RATIO_CEIL:
        fails.append(f"int8-resident vector bytes are {ratio:.4f}x the "
                     f"f32 store (need <= {BYTES_RATIO_CEIL}x)")
    delta = qp["recall_delta"]
    if delta > RECALL_DELTA_CEIL:
        fails.append(f"quantized recall@{K} trails the f32 engine by "
                     f"{delta:.4f} after exact re-rank (allowed "
                     f"{RECALL_DELTA_CEIL})")
    if qp["steady_compiles"] != 0:
        fails.append(f"quantized arm compiled {qp['steady_compiles']} "
                     f"program(s) at off-bucket batch sizes "
                     f"{QUANT_OFF_BUCKET} after the B={QUANT_B} warm-up")
    return fails
