"""Figures 10/11/19 + Tables 4/5: adaptive-global vs NaviX (adaptive-local)
under uncorrelated / positively / negatively correlated workloads, with the
heuristic-pick distributions and the correlation-ratio (ce) table."""

from __future__ import annotations

from benchmarks.common import emit, measure, n_queries
from benchmarks.datasets import wiki_dataset
from repro.configs.navix_paper import CORR_SELECTIVITIES
from repro.data.synthetic import (correlation_ratio, make_queries,
                                  person_chunk_plan, uncorrelated_plan)
from repro.query.operators import evaluate


def _workloads(idx, data):
    nq = n_queries()
    out = []
    # uncorrelated: id filter + mixture queries
    for sigma in (0.5, 0.3, 0.1, 0.01):
        mask = evaluate(uncorrelated_plan(sigma, data.n_chunks),
                        data.store).mask
        out.append(("uncorrelated", sigma,
                    make_queries(data, nq, "uncorrelated", seed=21), mask))
    # correlated: person-chunk joins, date-range selectivity control
    person_frac = data.chunk_is_person.mean()
    for sigma in CORR_SELECTIVITIES:
        frac = min(sigma / person_frac, 1.0)
        mask = evaluate(person_chunk_plan(data.store, frac),
                        data.store).mask
        out.append(("positive", mask.mean(),
                    make_queries(data, nq, "person", seed=22), mask))
        out.append(("negative", mask.mean(),
                    make_queries(data, nq, "nonperson", seed=23), mask))
    return out


def run() -> list[dict]:
    idx, data = wiki_dataset()
    rows = []
    # ce's kNN horizon must stay inside one topic cluster (the paper's 15M
    # chunks easily satisfy this at k=100; quick-mode scaling does not)
    ce_k = max(20, min(100, data.n_chunks // 300))
    for corr, sigma, queries, mask in _workloads(idx, data):
        ce = correlation_ratio(data.embeddings, queries, mask, k=ce_k,
                               metric="cos")
        for h in ("adaptive_g", "adaptive_local"):
            m = measure(idx, queries, mask, h)
            p = m.picks / max(m.picks.sum(), 1)
            rows.append({
                "bench": "fig10_adaptive", "workload": corr,
                "sigma": round(float(sigma), 4), "ce": round(ce, 3),
                "heuristic": h, "efs": m.efs, "recall": round(m.recall, 4),
                "ms_per_query": round(m.ms_per_query, 2),
                "t_dc": round(m.t_dc, 1), "s_dc": round(m.s_dc, 1),
                "pick_onehop": round(float(p[0]), 3),
                "pick_directed": round(float(p[1]), 3),
                "pick_blind": round(float(p[2]), 3),
            })
    emit(rows, "fig10_adaptive")
    return rows


def validate(rows) -> list[str]:
    fails = []
    # Table 4/5: ce ~ 1 uncorrelated, >> 1 positive, << 1 negative
    ces = {}
    for r in rows:
        ces.setdefault(r["workload"], []).append(r["ce"])
    # ce granularity at sigma=1% is coarse (the paper's own uncorrelated
    # table shows up to 1.18); gate at 2.0
    if not all(0.6 < c < 2.0 for c in ces.get("uncorrelated", [])):
        fails.append(f"uncorrelated ce off: {ces.get('uncorrelated')}")
    if not all(c > 2.0 for c in ces.get("positive", [])):
        fails.append(f"positive ce too weak: {ces.get('positive')}")
    if not all(c < 0.5 for c in ces.get("negative", [])):
        fails.append(f"negative ce too strong: {ces.get('negative')}")
    # Fig 10: adaptive-local must beat adaptive-g clearly (the paper: "up
    # to 1.7x") at multiple correlated points via the onehop-s switching
    # mechanism, and wins must dominate regressions. A regression band
    # where sigma_l falls in directed's region is a documented dataset
    # dependence (directed's mid-band edge is weaker on synthetic
    # mixtures; lf is the paper's own knob for this trade) -- see
    # EXPERIMENTS.md SSClaims. Points missing the recall target are
    # excluded (the paper's cross marks, Section 5.1.4).
    wins = big_wins = regressions = 0
    for corr in ("positive", "negative"):
        sub = [r for r in rows if r["workload"] == corr]
        for s in sorted({r["sigma"] for r in sub}):
            ag = next(r for r in sub if r["sigma"] == s
                      and r["heuristic"] == "adaptive_g")
            al = next(r for r in sub if r["sigma"] == s
                      and r["heuristic"] == "adaptive_local")
            if ag["recall"] < 0.93 or al["recall"] < 0.93:
                continue
            ratio = ag["t_dc"] / max(al["t_dc"], 1e-9)
            if ratio >= 1.05:
                wins += 1
            if ratio >= 1.5:
                big_wins += 1
            if ratio < 1 / 1.6:
                regressions += 1
    if big_wins == 0:
        fails.append("adaptive-local never beat adaptive-g >=1.5x on "
                     "correlated workloads")
    if regressions > wins:
        fails.append(f"adaptive-local regressions ({regressions}) exceed "
                     f"wins ({wins})")
    # Fig 11: adaptive-g commits (one pick dominates); adaptive-local mixes
    for r in rows:
        picks = [r["pick_onehop"], r["pick_directed"], r["pick_blind"]]
        if r["heuristic"] == "adaptive_g" and max(picks) < 0.99:
            fails.append("adaptive-g did not commit to one heuristic")
            break
    mixed = any(sorted([r["pick_onehop"], r["pick_directed"],
                        r["pick_blind"]])[1] > 0.05
                for r in rows if r["heuristic"] == "adaptive_local")
    if not mixed:
        fails.append("adaptive-local never mixed heuristics")
    return fails


if __name__ == "__main__":
    for f in validate(run()):
        print("CLAIM-FAIL:", f)
