"""Section 4.2.1 / Appendix A.3 analogue: in-buffer-manager (zero-copy)
distance computation, plus kernel rooflines.

CPU measurement: the fused gather+distance (one jit: gather and distance in
a single fusion, data never round-trips through an intermediate buffer) vs
copy-then-compute (two jits with a materialized gathered matrix between
them -- the 'copy into operator-local buffer' the paper eliminates).

TPU roofline: analytic bytes/flops of the Pallas kernels at serving shapes
(the kernels themselves are validated in interpret mode by the tests)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, emit
from repro.common.hardware import TARGET


def _time(fn, *args, reps=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6   # us


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    n, d, k = (20000, 256, 512) if not QUICK else (5000, 128, 256)
    X = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, n, size=k), jnp.int32)

    @jax.jit
    def fused(q, X, ids):
        rows = X[ids]
        diff = rows - q
        return jnp.sum(diff * diff, axis=-1)

    @jax.jit
    def gather_only(X, ids):
        return X[ids] + 0.0          # forces materialization

    @jax.jit
    def dist_only(q, rows):
        diff = rows - q
        return jnp.sum(diff * diff, axis=-1)

    fused_us = _time(lambda a, b, c: fused(a, b, c), q, X, ids)

    def copy_then(qq, XX, ii):
        return dist_only(qq, gather_only(XX, ii))
    copy_us = _time(copy_then, q, X, ids)

    rows = [{
        "bench": "a3_inbm_distance", "variant": "fused_zero_copy",
        "us_per_call": round(fused_us, 1), "k": k, "d": d,
    }, {
        "bench": "a3_inbm_distance", "variant": "copy_then_compute",
        "us_per_call": round(copy_us, 1), "k": k, "d": d,
        "slowdown_vs_fused": round(copy_us / fused_us, 2),
    }]

    # --- analytic TPU kernel rooflines at serving shapes -----------------
    for name, (b, nn, dd, bytes_per_elt) in {
        "distance_matrix_bf16": (128, 1_000_000, 128, 2),
        "quantized_distance_int8": (128, 1_000_000, 128, 1),
    }.items():
        flops = 2 * b * nn * dd
        bts = nn * dd * bytes_per_elt + b * dd * 2 + b * nn * 4
        t_c = flops / TARGET.peak_bf16_flops
        t_m = bts / TARGET.hbm_bandwidth
        rows.append({
            "bench": "kernel_roofline", "variant": name,
            "flops": flops, "hbm_bytes": bts,
            "t_compute_us": round(t_c * 1e6, 1),
            "t_memory_us": round(t_m * 1e6, 1),
            "bound": "compute" if t_c > t_m else "memory",
            "arith_intensity": round(flops / bts, 2),
        })
    emit(rows, "a3_kernels")
    return rows


def validate(rows) -> list[str]:
    fails = []
    fused = next(r for r in rows if r["variant"] == "fused_zero_copy")
    copy = next(r for r in rows if r["variant"] == "copy_then_compute")
    if copy["us_per_call"] < fused["us_per_call"] * 0.95:
        fails.append("fused gather+distance not faster than copy-then-compute")
    # int8 kernel must raise arithmetic intensity vs bf16
    ks = {r["variant"]: r for r in rows if r["bench"] == "kernel_roofline"}
    if ks["quantized_distance_int8"]["arith_intensity"] <= \
            ks["distance_matrix_bf16"]["arith_intensity"]:
        fails.append("int8 kernel did not improve arithmetic intensity")
    return fails


if __name__ == "__main__":
    for f in validate(run()):
        print("CLAIM-FAIL:", f)
