"""Figure 8: vector search time / dc vs selectivity per fixed heuristic +
adaptive-global, uncorrelated workloads at 95% recall.

Claims validated:
  * onehop-s cheapest at high selectivity but recall collapses below ~0.3;
  * directed beats blind in the medium band (s-dc edge), blind wins at very
    low selectivity (no ordering overhead);
  * adaptive-g tracks the best fixed heuristic's envelope.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, measure, n_queries
from benchmarks.datasets import uncorrelated_dataset
from repro.configs.navix_paper import SELECTIVITIES

HEURISTICS = ("onehop_s", "directed", "blind", "adaptive_g")


def run(dataset: str = "tiny-like") -> list[dict]:
    idx, X, _, queries = uncorrelated_dataset(dataset)
    queries = queries[: n_queries()]
    n = idx.graph.n
    rng = np.random.default_rng(11)
    rows = []
    for sigma in SELECTIVITIES:
        mask = rng.permutation(n) < sigma * n      # id-range-like uniform S
        for h in HEURISTICS:
            m = measure(idx, queries, mask, h)
            rows.append({
                "bench": "fig8_heuristics", "dataset": dataset,
                "sigma": round(sigma, 3), "heuristic": h, "efs": m.efs,
                "recall": round(m.recall, 4),
                "ms_per_query": round(m.ms_per_query, 2),
                "t_dc": round(m.t_dc, 1), "s_dc": round(m.s_dc, 1),
                "reached_95": m.reached_target,
            })
    emit(rows, f"fig8_{dataset}")
    return rows


def validate(rows) -> list[str]:
    """Machine-checked paper claims; returns failure strings."""
    fails = []
    by = {(r["sigma"], r["heuristic"]): r for r in rows}
    sig_hi = max(r["sigma"] for r in rows)
    sig_lo = min(r["sigma"] for r in rows)
    # onehop-s cheapest (by dc) at the highest selectivity among reaching-95
    hi = {h: by[(sig_hi, h)] for h in HEURISTICS}
    if hi["onehop_s"]["reached_95"]:
        others = [hi[h]["t_dc"] for h in ("directed", "blind")]
        if not hi["onehop_s"]["t_dc"] <= min(others) * 1.1:
            fails.append("onehop-s not cheapest at high sigma")
    # onehop-s must fail (or need huge efs) somewhere at low sigma
    low_fail = any(not by[(s, "onehop_s")]["reached_95"] or
                   by[(s, "onehop_s")]["recall"] < 0.95
                   for s in (0.05, 0.03, 0.01) if (s, "onehop_s") in by)
    if not low_fail:
        fails.append("onehop-s did not degrade at low sigma")
    # blind: t_dc == s_dc everywhere
    for r in rows:
        if r["heuristic"] == "blind" and abs(r["t_dc"] - r["s_dc"]) > 1e-6:
            fails.append("blind t_dc != s_dc")
            break
    # directed s-dc <= blind s-dc in the medium band (search effectiveness)
    for s in (0.3, 0.2, 0.1):
        if (s, "directed") in by and (s, "blind") in by:
            if by[(s, "directed")]["s_dc"] > by[(s, "blind")]["s_dc"] * 1.3:
                fails.append(f"directed not effective at sigma={s}")
    # adaptive-g "follows the lowest-LATENCY fixed heuristic in almost all
    # ranges" (paper 5.2 -- latency, not dc: directed's ordering dc hits
    # contiguous neighbor rows and is cheap in wall time, which is the
    # paper's own argument). Strict at the regime extremes; off-envelope
    # (>2x best fixed latency) allowed in at most ~a third of the sweep
    # (the paper notes its own exception band below the 50% threshold).
    off = 0
    total = 0
    for s in SELECTIVITIES:
        fixed = [by[(round(s, 3), h)] for h in ("onehop_s", "directed", "blind")
                 if (round(s, 3), h) in by and by[(round(s, 3), h)]["reached_95"]]
        ag = by.get((round(s, 3), "adaptive_g"))
        if not (fixed and ag and ag["reached_95"]):
            continue
        total += 1
        best = min(f["ms_per_query"] for f in fixed)
        if ag["ms_per_query"] > 2.0 * best:
            off += 1
            if s >= 0.5 or s <= 0.03:
                fails.append(f"adaptive-g off-envelope at extreme sigma={s}")
    if total and off / total > 0.34:
        fails.append(f"adaptive-g off-envelope in {off}/{total} of ranges")
    return fails


if __name__ == "__main__":
    rows = run()
    for f in validate(rows):
        print("CLAIM-FAIL:", f)
