"""Shared benchmark harness.

Implements the paper's measurement protocol (Section 5.1.4/5.1.7):
  * every workload has 50 query vectors in the paper; we default to a
    laptop-scale subset (configurable);
  * recall targeting: efs is grown until recall@k >= target (0.95) against
    the exact brute-force oracle, then latency/dc are reported at that efs;
  * per query: one warm-up execution per compiled shape, then timed runs;
  * latency is end-to-end per query; distance computations (t-dc / s-dc)
    are reported as the hardware-independent primary metric (the paper's
    own drill-down, Fig. 9).

Index/dataset construction is cached under experiments/cache/.
"""

from __future__ import annotations

import dataclasses
import os
import pathlib
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.graph import HnswGraph
from repro.core.navix import NavixConfig, NavixIndex

CACHE = pathlib.Path(os.environ.get("REPRO_CACHE", "experiments/cache"))
QUICK = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"

EFS_GRID = (100, 200, 400, 800)
TARGET_RECALL = 0.95
K = 100


def n_queries() -> int:
    return 6 if QUICK else 15


def cached_index(name: str, vectors: np.ndarray, cfg: NavixConfig
                 ) -> NavixIndex:
    CACHE.mkdir(parents=True, exist_ok=True)
    f = CACHE / f"{name}_n{len(vectors)}_m{cfg.m_u}.npz"
    if f.exists():
        z = np.load(f)
        graph = HnswGraph(
            lower=jnp.asarray(z["lower"]), lower_deg=jnp.asarray(z["lower_deg"]),
            upper=jnp.asarray(z["upper"]), upper_deg=jnp.asarray(z["upper_deg"]),
            upper_ids=jnp.asarray(z["upper_ids"]),
            entry_pos=jnp.asarray(z["entry_pos"]),
            vectors=jnp.asarray(z["vectors"]))
        return NavixIndex.from_graph(graph, cfg)
    idx, stats = NavixIndex.create(vectors, cfg)
    g = idx.graph
    np.savez(f, lower=np.asarray(g.lower), lower_deg=np.asarray(g.lower_deg),
             upper=np.asarray(g.upper), upper_deg=np.asarray(g.upper_deg),
             upper_ids=np.asarray(g.upper_ids),
             entry_pos=np.asarray(g.entry_pos),
             vectors=np.asarray(g.vectors))
    (CACHE / f"{name}_build.txt").write_text(
        f"seconds={stats.seconds}\ndc={stats.search_dc}\nn={stats.n}\n")
    return idx


@dataclasses.dataclass
class Measurement:
    heuristic: str
    sigma: float
    efs: int
    recall: float
    ms_per_query: float
    t_dc: float
    s_dc: float
    picks: np.ndarray
    reached_target: bool


def measure(index: NavixIndex, queries: np.ndarray, mask: Optional[np.ndarray],
            heuristic: str, k: int = K, target: float = TARGET_RECALL,
            efs_grid=EFS_GRID) -> Measurement:
    """Grow efs until recall target; report metrics at that efs."""
    sel = None if mask is None else mask
    _, true_ids = index.brute_force(queries, k=k, semimask=sel)
    true_ids = np.asarray(true_ids)
    sigma = 1.0 if mask is None else float(np.mean(mask))
    last = None
    for efs in efs_grid:
        got, times, t_dc, s_dc = [], [], 0, 0
        picks = np.zeros(3)
        # warm-up compile on the first query
        index.search(queries[0], k=k, efs=efs, semimask=sel,
                     heuristic=heuristic)
        for q in queries:
            t0 = time.perf_counter()
            r = index.search(q, k=k, efs=efs, semimask=sel,
                             heuristic=heuristic)
            r.dists.block_until_ready()
            times.append(time.perf_counter() - t0)
            got.append(np.asarray(r.ids))
            t_dc += int(r.stats.t_dc)
            s_dc += int(r.stats.s_dc)
            picks += np.asarray(r.stats.picks)
        recall = index.recall(np.stack(got), true_ids)
        last = Measurement(
            heuristic=heuristic, sigma=sigma, efs=efs, recall=recall,
            ms_per_query=float(np.mean(times) * 1e3),
            t_dc=t_dc / len(queries), s_dc=s_dc / len(queries),
            picks=picks, reached_target=recall >= target)
        if recall >= target:
            break
    return last


def emit(rows: list[dict], name: str) -> None:
    """Append rows to the global CSV sink (printed by benchmarks.run)."""
    import csv
    import sys
    out = pathlib.Path("experiments") / "bench" / f"{name}.csv"
    out.parent.mkdir(parents=True, exist_ok=True)
    if rows:
        fields: list[str] = []
        for r in rows:
            for k in r:
                if k not in fields:
                    fields.append(k)
        with open(out, "w", newline="") as fh:
            w = csv.DictWriter(fh, fieldnames=fields, restval="")
            w.writeheader()
            w.writerows(rows)
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    sys.stdout.flush()
