"""Benchmark orchestrator -- one module per paper table/figure.

Prints ``name,us_per_call,derived`` style CSV rows (each module also writes
its full table under experiments/bench/*.csv) and finishes with a
paper-claim validation summary. Set REPRO_BENCH_QUICK=1 for a fast pass.

  fig8      heuristics vs selectivity      (Figure 8)
  dc        t-dc vs s-dc                   (Figure 9; folded into fig8 cols)
  adaptive  adaptive-g vs NaviX + ce       (Figures 10/11, Tables 4/5)
  postfilter pre vs post + time split      (Figures 16/20, Table 7)
  construction build throughput/sizes      (Table 6, Section 5.1.6)
  quantized int8 + re-rank                 (Figure 18 regime)
  kernels   in-BM zero-copy + rooflines    (Section 4.2.1, Appendix A.3)
  distributed shard-and-merge + quorum     (beyond paper)
  search    vmap vs batched-frontier QPS   (Section 6 serving; emits
                                            experiments/bench/BENCH_search.json)
  serving   mixed-plan continuous batching  (per-lane semimasks; emits
                                            experiments/bench/BENCH_serving.json)

``--check-trend`` diffs the current BENCH_search.json AND
BENCH_serving.json against previous artifacts (``--baseline`` /
``--serving-baseline``) and exits non-zero on a >20% QPS regression in
either (``--trend-tol`` overrides); see benchmarks/trend.py.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig8,adaptive,postfilter,construction,"
                         "quantized,kernels,distributed,search,serving")
    ap.add_argument("--check-trend", action="store_true",
                    help="diff BENCH_search.json + BENCH_serving.json QPS "
                         "against baselines and fail on regressions > "
                         "--trend-tol (no suites run)")
    ap.add_argument("--baseline",
                    default="experiments/bench/prev/BENCH_search.json",
                    help="previous BENCH_search.json artifact to diff against")
    ap.add_argument("--current", default=None,
                    help="bench JSON to check (default: the quick/full "
                         "BENCH_search.json the last run emitted)")
    ap.add_argument("--serving-baseline",
                    default="experiments/bench/prev/BENCH_serving.json",
                    help="previous BENCH_serving.json artifact to diff "
                         "against")
    ap.add_argument("--serving-current", default=None,
                    help="serving bench JSON to check (default: the "
                         "quick/full BENCH_serving.json the last run "
                         "emitted)")
    ap.add_argument("--trend-tol", type=float, default=None,
                    help="allowed fractional QPS drop (default 0.20)")
    ap.add_argument("--compile-baseline",
                    default="experiments/bench/COMPILE_baseline.json",
                    help="committed compile-count baseline the serving "
                         "arm's recompile gate checks against")
    args = ap.parse_args()

    if args.check_trend:
        from benchmarks import bench_search, bench_serving, trend
        tol = (args.trend_tol if args.trend_tol is not None
               else trend.DEFAULT_TOL)
        rc = trend.check_trend(args.current or str(bench_search.JSON_OUT),
                               args.baseline, tol=tol)
        rc_serving = trend.check_trend(
            args.serving_current or str(bench_serving.JSON_OUT),
            args.serving_baseline, tol=tol)
        rc_compiles = trend.check_compiles(
            args.serving_current or str(bench_serving.JSON_OUT),
            args.compile_baseline)
        rc_shards = trend.check_shard_ratio(
            args.serving_current or str(bench_serving.JSON_OUT))
        rc_quant = trend.check_quantized(
            args.current or str(bench_search.JSON_OUT),
            args.baseline, tol=tol)
        sys.exit(rc or rc_serving or rc_compiles or rc_shards or rc_quant)

    from benchmarks import (bench_adaptive, bench_construction,
                            bench_distributed, bench_heuristics,
                            bench_kernels, bench_postfilter, bench_quantized,
                            bench_search, bench_serving)

    def post_run():                 # two tables (Fig 16 + Table 7)
        rows = bench_postfilter.run()
        bench_postfilter.run_split()
        return rows

    suites = {
        "fig8": (bench_heuristics.run, bench_heuristics.validate),
        "adaptive": (bench_adaptive.run, bench_adaptive.validate),
        "postfilter": (post_run, bench_postfilter.validate),
        "construction": (bench_construction.run, bench_construction.validate),
        "quantized": (bench_quantized.run, bench_quantized.validate),
        "kernels": (bench_kernels.run, bench_kernels.validate),
        "distributed": (bench_distributed.run, bench_distributed.validate),
        "search": (bench_search.run, bench_search.validate),
        "serving": (bench_serving.run, bench_serving.validate),
    }

    wanted = (args.only.split(",") if args.only else list(suites))
    all_fails: list[str] = []
    for name in wanted:
        run_fn, val_fn = suites[name]
        print(f"=== {name} ===", flush=True)
        t0 = time.perf_counter()
        try:
            rows = run_fn()
            fails = val_fn(rows) if val_fn else []
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            fails = [f"{name} crashed: {e}"]
        for f in fails:
            print(f"CLAIM-FAIL[{name}]: {f}")
        all_fails += fails
        print(f"=== {name} done in {time.perf_counter()-t0:.0f}s ===",
              flush=True)

    print("\n==== paper-claim validation summary ====")
    if all_fails:
        for f in all_fails:
            print("FAIL:", f)
        sys.exit(1)
    print(f"all claims validated across {len(wanted)} suites")


if __name__ == "__main__":
    main()
