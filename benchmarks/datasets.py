"""Benchmark datasets: laptop-scale analogues of the paper's Table 2 +
the Wiki-like graph for correlated/join workloads."""

from __future__ import annotations

import functools

import numpy as np

from benchmarks.common import QUICK, cached_index
from repro.configs.navix_paper import BENCH_INDEX
from repro.core.navix import NavixConfig
from repro.data.synthetic import gaussian_mixture, make_wiki_like


def scale(n: int) -> int:
    return max(2000, n // 4) if QUICK else n


@functools.lru_cache(maxsize=None)
def uncorrelated_dataset(name: str = "tiny-like"):
    """Clustered vectors + uncorrelated query set (paper's GIST/Tiny/Arxiv
    regime: object embeddings, id-range filters)."""
    sizes = {"gist-like": (scale(16000), 96, "l2", 24),
             "tiny-like": (scale(24000), 48, "l2", 32),
             "arxiv-like": (scale(16000), 64, "cos", 40)}
    n, d, metric, n_clusters = sizes[name]
    X, labels, centers = gaussian_mixture(n, d, n_clusters, seed=17)
    cfg = NavixConfig(m_u=BENCH_INDEX.m_u,
                      ef_construction=BENCH_INDEX.ef_construction,
                      metric=metric)
    idx = cached_index(name, X, cfg)
    rng = np.random.default_rng(5)
    qi = centers[rng.integers(0, n_clusters, size=50)]
    queries = (qi + 0.3 * rng.normal(size=qi.shape)).astype(np.float32)
    return idx, X, labels, queries


@functools.lru_cache(maxsize=None)
def wiki_dataset():
    """The Wiki-analogue graph dataset (joins + correlations)."""
    data = make_wiki_like(n_person=scale(700), n_resource=scale(3200),
                         chunks_per_person=6, chunks_per_resource=3,
                         d=64, seed=3)
    cfg = NavixConfig(m_u=BENCH_INDEX.m_u,
                      ef_construction=BENCH_INDEX.ef_construction,
                      metric="cos")
    idx = cached_index("wiki-like", data.embeddings, cfg)
    return idx, data


@functools.lru_cache(maxsize=None)
def wiki_db():
    """wiki_dataset wrapped in a NavixDB: the (possibly disk-cached) index
    is adopted into the catalog, so benchmark searches flow through the
    shared compiled-program cache like production queries."""
    from repro.api import NavixDB

    idx, data = wiki_dataset()
    db = NavixDB(data.store)
    db.register_index("chunk_emb", idx, table="Chunk")
    return db, idx, data
