"""QPS trend check: diff a BENCH_*.json against a previous artifact.

``python -m benchmarks.run --check-trend`` loads the current
``experiments/bench/BENCH_search.json`` AND ``BENCH_serving.json`` (or
``--current`` / ``--serving-current`` paths) and baselines from a
previous run (``--baseline`` / ``--serving-baseline``, e.g. the
artifacts CI downloaded from the last main build) and fails when any
(engine, B) / (sched, shards) row's QPS regressed by more than
``--trend-tol`` (default 20%). Speedups and new rows never fail; a
missing baseline is a skip, not a failure, so the first run of a fresh
branch stays green.
"""

from __future__ import annotations

import json
import pathlib

DEFAULT_TOL = 0.20

#: workload keys that must match for a QPS comparison to be meaningful
_WORKLOAD_KEYS = ("n", "d", "k", "efs", "quick")

#: measured (run-varying) fields excluded from a row's identity
_METRIC_KEYS = ("qps", "p50_ms", "p95_ms", "p99_ms", "recall", "mean_ms",
                "drain_ms", "offered_qps", "timeout_rate")

#: open-loop p99 rows tolerate 2x the QPS tolerance: tail latency under
#: a random arrival process is noisier than closed-drain throughput
_P99_TOL_SCALE = 2.0


def _row_key(row: dict) -> tuple:
    """Identity of one measured configuration within a bench file."""
    return tuple(sorted((k, v) for k, v in row.items()
                 if k not in _METRIC_KEYS))


def compare(current: dict, baseline: dict,
            tol: float = DEFAULT_TOL) -> tuple[list[str], list[str]]:
    """Return (failures, notes) from diffing two bench JSON payloads."""
    notes: list[str] = []
    cw, bw = current.get("workload", {}), baseline.get("workload", {})
    mismatched = [k for k in _WORKLOAD_KEYS
                  if k in cw and k in bw and cw[k] != bw[k]]
    if mismatched:
        return [], [f"workload changed ({', '.join(mismatched)}); "
                    f"skipping QPS comparison"]

    base_rows = {_row_key(r): r for r in baseline.get("rows", [])}
    fails: list[str] = []
    for row in current.get("rows", []):
        prev = base_rows.get(_row_key(row))
        if prev is None:
            continue
        label = ", ".join(f"{k}={row[k]}"
                          for k in ("engine", "resident", "B", "sched",
                                    "shards", "lam_frac")
                          if k in row)
        if ("qps" in row and "qps" in prev and prev["qps"] > 0):
            ratio = row["qps"] / prev["qps"]
            if ratio < 1.0 - tol:
                fails.append(f"QPS regression at ({label}): "
                             f"{prev['qps']:.1f} -> {row['qps']:.1f} "
                             f"({ratio:.2f}x, floor {1.0 - tol:.2f}x)")
            else:
                notes.append(f"({label}): {prev['qps']:.1f} -> "
                             f"{row['qps']:.1f} ({ratio:.2f}x) ok")
        # open-loop rows additionally gate tail latency: a p99 blow-up at
        # fixed offered load means the live service regressed even if
        # completion QPS (== arrival rate) looks unchanged
        if (row.get("sched") == "open-loop" and "p99_ms" in row
                and prev.get("p99_ms", 0) > 0):
            # offered load is DERIVED from the measured closed-drain QPS
            # (lam = frac * closed_qps), so a big closed-queue speedup
            # moves the operating point; tails at different offered
            # loads are not comparable
            off, boff = row.get("offered_qps"), prev.get("offered_qps")
            if off and boff and not (1 - tol <= off / boff <= 1 + tol):
                notes.append(f"({label}) p99 comparison skipped: offered "
                             f"load moved {boff:.0f} -> {off:.0f} qps "
                             f"with the closed-drain QPS it derives from")
                continue
            p99_tol = tol * _P99_TOL_SCALE
            ratio = row["p99_ms"] / prev["p99_ms"]
            if ratio > 1.0 + p99_tol:
                fails.append(f"open-loop p99 regression at ({label}): "
                             f"{prev['p99_ms']:.1f}ms -> "
                             f"{row['p99_ms']:.1f}ms ({ratio:.2f}x, "
                             f"ceiling {1.0 + p99_tol:.2f}x)")
            else:
                notes.append(f"({label}) p99: {prev['p99_ms']:.1f}ms -> "
                             f"{row['p99_ms']:.1f}ms ({ratio:.2f}x) ok")
    return fails, notes


#: warmup compile counts may shift across jax versions (CI installs
#: unpinned jax[cpu]); gate them loosely. Steady-state counts are gated
#: at exactly the baseline (which commits 0).
_WARMUP_TOL_SCALE = 2.0
_WARMUP_TOL_ABS = 8


def check_compiles(current_path: str, baseline_path: str) -> int:
    """Gate the serving arm's XLA compile counts against the committed
    ``COMPILE_baseline.json``. Two gates with different teeth:

    * ``steady_compiles`` must not exceed the baseline's (0): a compile
      in the timed steady state is the recompile hazard navilint exists
      to catch -- hard fail, no tolerance;
    * total warmup compiles get a generous ceiling (2x + 8 over the
      baseline) -- warmup counts drift with jax versions, but a blow-up
      still means the program set grew unintentionally.

    A current file without compile counts (the open-loop arm didn't
    run) or a missing baseline is a skip, not a failure.
    """
    cur_p, base_p = pathlib.Path(current_path), pathlib.Path(baseline_path)
    if not cur_p.exists():
        print(f"compiles: no current bench file {cur_p}; skipping")
        return 0
    ol = json.loads(cur_p.read_text()).get("open_loop", {})
    comp = ol.get("compiles")
    if comp is None:
        print("compiles: current bench has no compile counts "
              "(open-loop arm not run); skipping")
        return 0
    steady = ol.get("steady_compiles",
                    sum(v for k, v in comp.items()
                        if k.startswith("steady")))
    warmup = sum(comp.values()) - steady
    if not base_p.exists():
        print(f"compiles: no baseline at {base_p}; skipping (current: "
              f"warmup={warmup}, steady={steady})")
        return 0
    base = json.loads(base_p.read_text()).get("open_loop_smoke", {})
    fails: list[str] = []
    base_steady = base.get("steady_compiles", 0)
    if steady > base_steady:
        fails.append(f"steady-state compiles {steady} > baseline "
                     f"{base_steady}: something recompiles while "
                     f"serving (bucket/program-cache regression)")
    base_warmup = base.get("warmup_compiles")
    if base_warmup is not None:
        ceiling = base_warmup * _WARMUP_TOL_SCALE + _WARMUP_TOL_ABS
        if warmup > ceiling:
            fails.append(f"warmup compiles {warmup} > ceiling "
                         f"{ceiling:.0f} (baseline {base_warmup}): the "
                         f"compiled program set grew")
    cycles = ol.get("lock_order", {}).get("cycles", [])
    if cycles:
        fails.append("lock-order cycles recorded during the serving "
                     "arm: " + "; ".join(cycles))
    for f in fails:
        print(f"COMPILE-FAIL: {f}")
    if not fails:
        print(f"compiles: warmup={warmup} steady={steady} within "
              f"baseline (warmup<={base_warmup}, steady<="
              f"{base_steady}); no lock cycles")
    return 1 if fails else 0


#: hard floor for S=2 sharded continuous QPS relative to the unsharded
#: engine, measured in the SAME subprocess (bench_serving --shards):
#: sharding that slows serving down is a regression by definition
SHARD_RATIO_FLOOR = 0.9


def check_shard_ratio(current_path: str,
                      floor: float = SHARD_RATIO_FLOOR) -> int:
    """Gate the sharded serving arm: S=2 continuous must reach at least
    ``floor`` x the unsharded engine's QPS (both measured back-to-back
    in the sharded subprocess, so host load cancels out). A current file
    without a sharded payload -- or one whose sharded arm errored (e.g.
    too few host devices) -- is a skip, not a failure."""
    cur_p = pathlib.Path(current_path)
    if not cur_p.exists():
        print(f"shard-ratio: no current bench file {cur_p}; skipping")
        return 0
    sharded = json.loads(cur_p.read_text()).get("sharded")
    if not isinstance(sharded, dict) or "error" in sharded:
        print("shard-ratio: no sharded payload in the current bench "
              "(sharded arm not run or errored); skipping")
        return 0
    ratio = sharded.get("sharded_over_unsharded_qps")
    if ratio is None:
        print("shard-ratio: sharded payload has no ratio field; skipping")
        return 0
    shards = sharded.get("shards", "?")
    if ratio < floor:
        print(f"SHARD-RATIO-FAIL: S={shards} continuous at {ratio:.3f}x "
              f"the unsharded QPS (floor {floor:.2f}x) -- sharding must "
              f"not slow serving down")
        return 1
    print(f"shard-ratio: S={shards} continuous at {ratio:.3f}x unsharded "
          f"(floor {floor:.2f}x) ok")
    return 0


#: hard invariants on the quantized-resident arm, mirrored from
#: benchmarks.bench_search -- these hold regardless of any baseline
_QUANT_BYTES_CEIL = 0.30
_QUANT_RECALL_DELTA_CEIL = 0.02


def check_quantized(current_path: str, baseline_path: str,
                    tol: float = DEFAULT_TOL) -> int:
    """Gate the quantized-resident search arm in BENCH_search.json.

    Three baseline-free invariants (they restate the arm's own
    ``validate()`` gates so a hand-edited artifact can't dodge them):
    resident vector bytes <= 0.30x the f32 store, recall@k within 0.02
    of the f32 engine after exact re-rank, and zero steady-state
    compiles at off-bucket batch sizes. Plus the usual QPS-regression
    diff against the baseline's quantized rows when the workloads
    match. Missing payload or baseline is a skip, not a failure.
    """
    cur_p, base_p = pathlib.Path(current_path), pathlib.Path(baseline_path)
    if not cur_p.exists():
        print(f"quantized: no current bench file {cur_p}; skipping")
        return 0
    qp = json.loads(cur_p.read_text()).get("quantized")
    if not isinstance(qp, dict) or "rows" not in qp:
        print("quantized: no quantized payload in the current bench; "
              "skipping")
        return 0
    fails: list[str] = []
    ratio = qp.get("resident_bytes_ratio")
    if ratio is not None and ratio > _QUANT_BYTES_CEIL:
        fails.append(f"resident vector bytes {ratio:.4f}x f32 "
                     f"(ceiling {_QUANT_BYTES_CEIL}x)")
    delta = qp.get("recall_delta")
    if delta is not None and delta > _QUANT_RECALL_DELTA_CEIL:
        fails.append(f"recall delta {delta:.4f} after exact re-rank "
                     f"(ceiling {_QUANT_RECALL_DELTA_CEIL})")
    steady = qp.get("steady_compiles")
    if steady:
        fails.append(f"{steady} steady-state compile(s) at off-bucket "
                     f"batch sizes -- residency arm must reuse the "
                     f"bucketed program")

    if base_p.exists():
        bqp = json.loads(base_p.read_text()).get("quantized")
        if isinstance(bqp, dict):
            sub_f, sub_n = compare(qp, bqp, tol)
            fails.extend(sub_f)
            for n in sub_n:
                print(f"quantized: {n}")
        else:
            print("quantized: baseline has no quantized payload; "
                  "skipping QPS diff")
    else:
        print(f"quantized: no baseline at {base_p}; skipping QPS diff")

    for f in fails:
        print(f"QUANT-FAIL: {f}")
    if not fails:
        print(f"quantized: bytes {ratio}x, recall delta {delta}, "
              f"steady compiles {steady} -- all within gates")
    return 1 if fails else 0


def check_trend(current_path: str, baseline_path: str,
                tol: float = DEFAULT_TOL) -> int:
    """CLI body: print the diff, return a process exit code."""
    cur_p, base_p = pathlib.Path(current_path), pathlib.Path(baseline_path)
    if not cur_p.exists():
        print(f"trend: current bench file {cur_p} missing; run the "
              f"benchmark first")
        return 1
    if not base_p.exists():
        print(f"trend: no baseline at {base_p}; skipping (first run?)")
        return 0
    current = json.loads(cur_p.read_text())
    baseline = json.loads(base_p.read_text())
    fails, notes = compare(current, baseline, tol)
    for n in notes:
        print(f"trend: {n}")
    for f in fails:
        print(f"TREND-FAIL: {f}")
    if not fails:
        print(f"trend: no QPS regression beyond {tol:.0%} "
              f"({len(notes)} comparisons)")
    return 1 if fails else 0
