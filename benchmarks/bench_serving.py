"""Mixed-plan serving throughput: continuous batching vs per-group drain.

The workload the per-group scheduler cannot batch: every request carries
its OWN selection subquery (distinct ``cID < cutoff`` predicates spanning
selectivities from ~5% to 100%), so plan-grouping degenerates to B=1
device calls. The continuous scheduler fuses them anyway -- per-lane
``[B, W]`` semimasks, per-lane k/efs capped to the batch max, converged
lanes compacted out and refilled from the queue between device steps.

Both schedulers serve the identical request stream through the same
``SearchEngine`` surface; results are checked equal request-for-request.
QPS, latency percentiles, and the continuous/grouped speedup go to
``experiments/bench/BENCH_serving.json``.

The ``--shards`` arm serves the identical stream on a
:class:`~repro.core.distributed.ShardedNavix` (continuous scheduler,
per-lane ``[S, B, W]`` semimasks) in a subprocess with placeholder host
devices, reports sharded-vs-unsharded QPS, and checks every sharded
answer against the *unsharded batched engine* run per shard over
shard-restricted masks and merged host-side -- zero drift is a gated
claim. Two mesh layouts run: ``data`` (the headline: lanes split over
the data axis, each shard steps B/S lanes of the full index) and
``model`` (legacy: the index split over the model axis, every shard
steps all B lanes; keeps the heartbeat straggler drill, which needs
more than one index shard). Both engines in the arm run a batch sized
to ``SHARD_ARM_LANES_PER_SHARD`` lanes per shard: splitting a
16-lane batch over S shards leaves every device call dispatch-bound,
and the "ratio" then measures fixed per-call overhead rather than the
cost of sharding itself. The sharded/unsharded QPS ratio is computed
from the SAME rounded qps fields the artifact carries and printed
directly, so the ratio can never drift from the row data again;
``trend.check_shard_ratio`` gates the data-layout ratio at >= 0.9x.

The ``--open-loop`` arm serves the same request mix through the LIVE
:class:`~repro.serving.service.SearchService` (thread driver) under a
Poisson arrival process, sweeping the offered load as a fraction of the
measured closed-queue drain QPS. Per-λ rows (p50/p99 latency, timeout
rate) land next to the closed-queue rows in ``BENCH_serving.json`` so
``trend.py --check-trend`` can gate open-loop p99 across runs.

Claims gated by validate(): continuous-batching QPS >= 1.3x the
per-group-drain path (>= 1.0x sanity floor in REPRO_BENCH_QUICK mode,
where the problem is too small for the margin to be stable), with
identical per-request answers; zero sharded answer drift; and -- in the
sharded arm -- a suppressed shard heartbeat flips responses to degraded
automatically with zero drift vs the alive-restricted reference.
Open-loop claims (``validate_open_loop``): at offered load <= 0.7x the
closed-drain QPS with generous deadlines, the timeout rate is 0 and p99
latency stays bounded by the closed-queue full-drain wall time (i.e. no
unbounded queue growth).
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

from benchmarks import common
from repro.core.navix import NavixConfig
from repro.data.synthetic import gaussian_mixture
from repro.query.operators import Filter, NodeScan
from repro.serving.engine import SearchEngine
from repro.storage.columnar import GraphStore

JSON_OUT = pathlib.Path("experiments") / "bench" / (
    "BENCH_serving.quick.json" if common.QUICK else "BENCH_serving.json")

K = 10
EFS = 30
MAX_BATCH = 16
STEP_ITERS = 32
SPEEDUP_FLOOR = 1.0 if common.QUICK else 1.3
SHARDS = 2                       # the --shards arm run() spawns by default
#: the sharded arm's batch: sized so each data shard holds >= 32 lanes.
#: Splitting B=16 lanes over S shards leaves every device call
#: overhead-bound (the comparison then measures per-call dispatch cost,
#: not sharding), so the arm pins per-shard occupancy instead -- both
#: engines in the arm run the SAME batch, so the ratio stays apples to
#: apples.
SHARD_ARM_LANES_PER_SHARD = 32
#: request selectivities -- each request gets its own predicate
SELECTIVITIES = (0.05, 0.1, 0.2, 0.35, 0.5, 0.75, 0.9, 1.0)
#: open-loop offered loads as fractions of the closed-drain QPS
OPEN_LOOP_FRACS = (0.3, 0.7) if common.QUICK else (0.3, 0.5, 0.7)
OPEN_LOOP_DEADLINE_S = 60.0      # generous: timeouts at <= 0.7x load are
                                 # a service bug, not an SLO miss


def _requests(n: int, centers, d: int, n_req: int, rng):
    """(query, plan) stream: distinct per-request predicates at varied
    selectivities."""
    reqs = []
    for j in range(n_req):
        sigma = SELECTIVITIES[j % len(SELECTIVITIES)]
        # distinct cutoffs even at equal sigma (jitter) => distinct plans
        cut = min(n, max(K, int(sigma * n) - (j // len(SELECTIVITIES))))
        q = (centers[rng.integers(0, len(centers))]
             + 0.3 * rng.normal(size=d)).astype(np.float32)
        reqs.append((q, Filter(NodeScan("Chunk"), "cID", "<", value=cut)))
    return reqs


def _serve(engine: SearchEngine, reqs) -> tuple[float, dict]:
    rids = [engine.submit(q, plan=plan, k=K) for q, plan in reqs]
    t0 = time.perf_counter()
    responses = engine.drain()
    wall = time.perf_counter() - t0
    by = {r.rid: r for r in responses}
    assert sorted(by) == sorted(rids), "every rid answered exactly once"
    return wall, {rid: by[rid] for rid in rids}


def _workload() -> tuple[int, int, int, int]:
    """(n, d, n_req, reps) -- shared by the main run and the --shards arm
    so both serve the identical request stream."""
    n, d = (1500, 16) if common.QUICK else (4000, 32)
    n_req = 24 if common.QUICK else 128
    reps = 2 if common.QUICK else 5
    return n, d, n_req, reps


def _request_stream(n: int, d: int, n_req: int):
    X, _, centers = gaussian_mixture(n, d, 10, seed=0)
    rng = np.random.default_rng(11)
    return X, _requests(n, centers, d, n_req, rng)


def run() -> list[dict]:
    n, d, n_req, reps = _workload()
    X, reqs = _request_stream(n, d, n_req)
    index = common.cached_index(f"bench_search_{n}",
                                X, NavixConfig(m_u=8, ef_construction=64,
                                               metric="l2", seed=0))

    def make_engine(sched: str) -> SearchEngine:
        store = GraphStore()
        store.add_node_table("Chunk", n, {"cID": np.arange(n)})
        return SearchEngine(index=index, store=store, efs=EFS,
                            max_batch=MAX_BATCH, scheduler=sched,
                            step_iters=STEP_ITERS)

    # engines are warmed up front and their timed drains interleaved
    # (grouped rep, continuous rep, ...) so host load drift hits both
    # schedulers equally; medians keep one noisy drain from deciding
    engines = {s: make_engine(s) for s in ("grouped", "continuous")}
    for engine in engines.values():
        _serve(engine, reqs)                        # warm-up compile
        engine.latencies_ms.clear()
    walls: dict[str, list[float]] = {s: [] for s in engines}
    answers: dict[str, dict] = {}
    for _ in range(reps):
        for sched, engine in engines.items():
            wall, got = _serve(engine, reqs)
            walls[sched].append(wall)
            answers[sched] = got
    rows: list[dict] = []
    for sched, engine in engines.items():
        lat = engine.latency_summary()
        med = float(np.median(walls[sched]))
        rows.append({
            "sched": sched,
            "n_req": n_req,
            "qps": round(n_req / med, 2),
            "drain_ms": round(med * 1e3, 2),
            "p50_ms": round(lat["p50_ms"], 3),
            "p95_ms": round(lat["p95_ms"], 3),
        })

    mismatched = sum(
        1 for rid in answers["grouped"]
        if not np.array_equal(answers["grouped"][rid].ids,
                              answers["continuous"][rid].ids))
    by = {r["sched"]: r for r in rows}
    speedup = round(by["continuous"]["qps"] / max(by["grouped"]["qps"], 1e-9),
                    3)

    # --shards arms: the same stream on a ShardedNavix, in subprocesses
    # with placeholder host devices (this process keeps its one device).
    # "data" (lane split) is the headline payload check_shard_ratio
    # gates; "model" (index split) is the legacy arm with the heartbeat
    # drill.
    sharded = _spawn_sharded(SHARDS, "data")
    sharded_model = _spawn_sharded(SHARDS, "model")
    for payload in (sharded, sharded_model):
        if "row" in payload:
            rows.append(payload["row"])
        if "sharded_over_unsharded_qps" in payload:
            print(f"sharded S={payload['shards']} "
                  f"layout={payload.get('layout', '?')}: "
                  f"{payload['sharded_over_unsharded_qps']:.3f}x "
                  f"unsharded QPS")
    common.emit(rows, "serving_schedulers")

    JSON_OUT.parent.mkdir(parents=True, exist_ok=True)
    JSON_OUT.write_text(json.dumps({
        "workload": {"n": n, "d": d, "k": K, "efs": EFS,
                     "n_req": n_req, "max_batch": MAX_BATCH,
                     "step_iters": STEP_ITERS, "reps": reps,
                     "selectivities": list(SELECTIVITIES),
                     "distinct_plans": len({p for _, p in reqs}),
                     "quick": common.QUICK},
        "rows": rows,
        "continuous_over_grouped_qps": speedup,
        "mismatched_answers": mismatched,
        "sharded": sharded,
        "sharded_model_axis": sharded_model,
    }, indent=2) + "\n")
    for r in rows:
        r["_mismatched"] = mismatched
        r["_sharded"] = sharded
        r["_sharded_model"] = sharded_model
    return rows


def run_open_loop(smoke: bool = False) -> list[dict]:
    """The ``--open-loop`` arm: Poisson arrivals into the live
    SearchService at offered loads swept as fractions of the measured
    closed-queue drain QPS. Rows merge into BENCH_serving.json next to
    the closed-queue rows (kept for trend continuity).

    The whole arm runs under the three runtime guards from
    ``repro.analysis.runtime``: the compile counter proves the timed
    steady state compiles NOTHING (every XLA program is built during
    warmup; a steady-state compile is a silent latency cliff that
    masquerades as an algorithmic regression), the lock monitor proves
    the serving tier's lock acquisition graph stays acyclic under real
    concurrency, and the donation guard turns any lane-state access
    inside a step_async/step_wait window into a hard DonationError
    (donation is a no-op on CPU, so without the guard such a bug would
    pass here and corrupt on TPU/GPU). All three land in the JSON
    payload; the compile counts are drift-checked against the
    committed ``experiments/bench/COMPILE_baseline.json`` by
    ``trend.py``."""
    from repro.analysis.runtime import (
        CompileCounter, guard_donation, instrument_locks)
    from repro.api.db import NavixDB

    n, d, n_req, reps = _workload()
    if smoke:
        n_req, reps = min(n_req, 16), 1
    X, reqs = _request_stream(n, d, n_req)
    index = common.cached_index(f"bench_search_{n}",
                                X, NavixConfig(m_u=8, ef_construction=64,
                                               metric="l2", seed=0))

    def make_store() -> GraphStore:
        store = GraphStore()
        store.add_node_table("Chunk", n, {"cID": np.arange(n)})
        return store

    with CompileCounter() as cc, instrument_locks() as locks, \
            guard_donation() as donate:
        # closed-queue anchor: the continuous scheduler's drain QPS on
        # the identical stream sets the offered-load scale
        engine = SearchEngine(index=index, store=make_store(), efs=EFS,
                              max_batch=MAX_BATCH, scheduler="continuous",
                              step_iters=STEP_ITERS)
        _serve(engine, reqs)                        # warm-up compile
        closed_walls = [_serve(engine, reqs)[0] for _ in range(reps)]
        closed_drain_ms = float(np.median(closed_walls)) * 1e3
        closed_qps = n_req / (closed_drain_ms / 1e3)

        db = NavixDB(make_store())
        db.register_index("default", index)
        fracs = OPEN_LOOP_FRACS[-1:] if smoke else OPEN_LOOP_FRACS
        rng = np.random.default_rng(23)
        rows: list[dict] = []
        for frac in fracs:
            lam = frac * closed_qps
            cc.mark(f"warmup@{frac}")
            svc = db.serve(k_cap=K, efs_cap=EFS, max_batch=MAX_BATCH,
                           step_iters=STEP_ITERS,
                           default_deadline_s=OPEN_LOOP_DEADLINE_S,
                           queue_size=max(64, 2 * n_req)).start()
            # warm the service program before the timed arrival process
            for f in [svc.submit(q, plan=p, k=K) for q, p in reqs[:2]]:
                f.result(timeout=600)
            gaps = rng.exponential(1.0 / lam, size=n_req)
            cc.mark(f"steady@{frac}")
            t0 = time.perf_counter()
            futs = []
            for (q, plan), gap in zip(reqs, gaps):
                time.sleep(gap)
                futs.append(svc.submit(q, plan=plan, k=K))
            resps = [f.result(timeout=600) for f in futs]
            wall = time.perf_counter() - t0
            svc.shutdown(drain=True)
            lats = [r.queue_ms + r.exec_ms + r.prefilter_ms for r in resps]
            n_timeout = sum(1 for r in resps if r.timeout)
            rows.append({
                "sched": "open-loop", "lam_frac": frac, "n_req": n_req,
                "offered_qps": round(lam, 2),
                "qps": round(len(resps) / wall, 2),
                "p50_ms": round(float(np.percentile(lats, 50)), 3),
                "p99_ms": round(float(np.percentile(lats, 99)), 3),
                "timeout_rate": round(n_timeout / len(resps), 4),
            })
    steady_compiles = sum(v for k, v in cc.counts.items()
                          if k.startswith("steady"))
    lock_report = locks.report()
    donation_report = donate.report()
    common.emit(rows, "serving_open_loop")

    # merge next to the closed-queue rows (replacing any previous
    # open-loop rows) so one file carries the whole serving story
    payload = (json.loads(JSON_OUT.read_text()) if JSON_OUT.exists()
               else {"workload": {"n": n, "d": d, "k": K, "efs": EFS,
                                  "quick": common.QUICK}, "rows": []})
    payload["rows"] = ([r for r in payload.get("rows", [])
                        if r.get("sched") != "open-loop"] + rows)
    payload["open_loop"] = {"closed_drain_ms": round(closed_drain_ms, 2),
                            "closed_qps": round(closed_qps, 2),
                            "deadline_s": OPEN_LOOP_DEADLINE_S,
                            "n_req": n_req, "smoke": smoke,
                            "compiles": dict(cc.counts),
                            "steady_compiles": steady_compiles,
                            "lock_order": lock_report,
                            "donation_guard": donation_report}
    JSON_OUT.parent.mkdir(parents=True, exist_ok=True)
    JSON_OUT.write_text(json.dumps(payload, indent=2) + "\n")
    for r in rows:
        r["_closed_drain_ms"] = closed_drain_ms
        r["_steady_compiles"] = steady_compiles
        r["_lock_cycles"] = lock_report["cycles"]
        r["_donation_windows"] = donation_report["windows"]
    return rows


def validate_open_loop(rows: list[dict]) -> list[str]:
    """Open-loop gates: 0 timeouts at generous deadlines, p99 bounded
    by the closed-queue FULL-drain wall time at <= 0.7x load (an
    unbounded queue would blow straight past it), ZERO steady-state XLA
    compiles, an acyclic lock acquisition graph, and a live donation
    guard (>= 1 observed donation window -- violations raise inside
    the run itself)."""
    fails: list[str] = []
    if not rows:
        return ["open-loop produced no rows"]
    r0 = rows[0]
    if r0.get("_steady_compiles"):
        fails.append(f"{r0['_steady_compiles']} XLA compile(s) in the "
                     f"open-loop steady state (warmup must build every "
                     f"program; a steady-state compile is a hidden "
                     f"latency cliff)")
    if r0.get("_lock_cycles"):
        fails.append("lock-order cycles in the serving tier: "
                     + "; ".join(r0["_lock_cycles"]))
    if not r0.get("_donation_windows"):
        fails.append("the donation guard saw zero step_async/step_wait "
                     "windows: the open-loop arm is no longer running "
                     "under guard_donation (a use-after-donate would "
                     "go undetected)")
    for r in rows:
        if r["timeout_rate"] > 0:
            fails.append(f"open-loop timeout rate {r['timeout_rate']:.2%} "
                         f"at lam_frac={r['lam_frac']} (deadline "
                         f"{OPEN_LOOP_DEADLINE_S}s is generous; want 0)")
        bound = r["_closed_drain_ms"]
        if r["lam_frac"] <= 0.7 and r["p99_ms"] > bound:
            fails.append(f"open-loop p99 {r['p99_ms']:.1f}ms exceeds the "
                         f"closed-drain bound {bound:.1f}ms at lam_frac="
                         f"{r['lam_frac']} (queue growth?)")
    return fails


def _spawn_sharded(shards: int, layout: str = "data") -> dict:
    """Run one --shards arm in a subprocess with enough host devices and
    return its JSON payload ({"error": ...} on failure). The parent's
    XLA_FLAGS / PYTHONPATH are preserved (device-count flag replaced, not
    clobbered) so both arms run under the same XLA configuration."""
    import re

    flag = f"--xla_force_host_platform_device_count={max(4, shards)}"
    xla = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                 os.environ.get("XLA_FLAGS", ""))
    parent_pp = os.environ.get("PYTHONPATH", "")
    env = dict(os.environ,
               PYTHONPATH="src" + (os.pathsep + parent_pp if parent_pp
                                   else ""),
               HOME=os.environ.get("HOME", "/tmp"),
               XLA_FLAGS=f"{xla} {flag}".strip())
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serving",
         "--shards", str(shards), "--layout", layout],
        timeout=3600, capture_output=True, text=True,
        cwd=pathlib.Path(__file__).parent.parent, env=env)
    if out.returncode != 0:
        return {"shards": shards, "layout": layout,
                "error": out.stderr[-500:]}
    return json.loads(out.stdout.strip().splitlines()[-1])


def run_sharded(shards: int, layout: str = "data") -> dict:
    """One --shards arm body (run with >= ``shards`` host devices).

    Serves the identical mixed-predicate stream through the continuous
    scheduler on (a) the unsharded index and (b) a ShardedNavix, and
    checks every sharded answer against the unsharded batched engine run
    per shard over shard-restricted masks + a host lexicographic merge.

    ``layout`` picks the mesh: ``data`` splits the LANES over the data
    axis (one index replica, each shard steps B/S lanes -- total device
    work equals the unsharded engine's); ``model`` splits the INDEX over
    the model axis (every shard steps all B lanes over its slice). The
    per-shard reference covers both: under ``data`` the model axis has
    one shard, so the reference degenerates to the plain unsharded run.
    The heartbeat straggler drill needs >1 index shard and only runs
    under ``model``.
    """
    import jax

    from repro.core.distributed import ShardedNavix, per_shard_reference

    n, d, n_req, reps = _workload()
    X, reqs = _request_stream(n, d, n_req)
    cfg = NavixConfig(m_u=8, ef_construction=64, metric="l2", seed=0)
    index = common.cached_index(f"bench_search_{n}", X, cfg)
    shape = (shards, 1) if layout == "data" else (1, shards)
    mesh = jax.make_mesh(shape, ("data", "model"))
    sn = ShardedNavix.build(X, cfg, mesh)
    # per-shard lane occupancy pinned (see SHARD_ARM_LANES_PER_SHARD);
    # both engines run this same batch so the ratio isolates sharding
    mb = max(MAX_BATCH, SHARD_ARM_LANES_PER_SHARD * shards)

    def make_engine(idx) -> SearchEngine:
        store = GraphStore()
        store.add_node_table("Chunk", n, {"cID": np.arange(n)})
        return SearchEngine(index=idx, store=store, efs=EFS,
                            max_batch=mb, scheduler="continuous",
                            step_iters=STEP_ITERS)

    engines = {"unsharded": make_engine(index), "sharded": make_engine(sn)}
    for engine in engines.values():
        _serve(engine, reqs)                        # warm-up compile
        engine.latencies_ms.clear()
    walls: dict[str, list[float]] = {s: [] for s in engines}
    answers: dict[str, dict] = {}
    for _ in range(reps):
        for name, engine in engines.items():
            wall, got = _serve(engine, reqs)
            walls[name].append(wall)
            answers[name] = got

    # zero-drift check against the SAME oracle the equivalence suite
    # asserts lane-for-lane identity with: the unsharded batched engine
    # per shard over shard-restricted masks + host lexicographic merge
    params = sn._params(K, EFS, "adaptive_local")
    Q = np.stack([q for q, _ in reqs])
    masks = np.stack([np.arange(n) < plan.value for _, plan in reqs])
    _, ref_ids, _ = per_shard_reference(sn, Q, masks, params)
    drift = 0
    rids = sorted(answers["sharded"])
    for j, rid in enumerate(rids):
        if not np.array_equal(answers["sharded"][rid].ids, ref_ids[j]):
            drift += 1

    med = {name: float(np.median(walls[name])) for name in engines}
    lat = engines["sharded"].latency_summary()
    row = {"sched": "continuous", "shards": shards, "layout": layout,
           "n_req": n_req, "max_batch": mb,
           "qps": round(n_req / med["sharded"], 2),
           "drain_ms": round(med["sharded"] * 1e3, 2),
           "p50_ms": round(lat["p50_ms"], 3),
           "p95_ms": round(lat["p95_ms"], 3)}
    qps_unsharded = round(n_req / med["unsharded"], 2)
    # the ratio is derived from the SAME rounded qps fields the artifact
    # carries -- recomputing it from the rows always reproduces it
    ratio = round(row["qps"] / qps_unsharded, 3)
    print(f"shards={shards} layout={layout}: sharded/unsharded QPS "
          f"ratio = {ratio:.3f} ({row['qps']:.2f}/{qps_unsharded:.2f})",
          file=sys.stderr)
    out = {
        "shards": shards,
        "layout": layout,
        "row": row,
        "qps_sharded": row["qps"],
        "qps_unsharded": qps_unsharded,
        "sharded_over_unsharded_qps": ratio,
        "answer_drift_vs_unsharded_engine": drift,
    }
    if layout == "model":
        hb_degraded, hb_drift = _heartbeat_scenario(sn, reqs, params,
                                                    shards)
        out["heartbeat_degraded"] = hb_degraded
        out["heartbeat_drift"] = hb_drift
    return out


def _heartbeat_scenario(sn, reqs, params, shards: int) -> tuple[bool, int]:
    """Straggler-shard drill on the LIVE service: suppress the last
    shard's heartbeats mid-run; responses finalized after staleness must
    flip to degraded AUTOMATICALLY (no caller-set alive mask) and equal
    the alive-restricted per-shard reference. Returns
    (all_phase2_degraded, phase2_drift_count)."""
    from repro.api.db import NavixDB
    from repro.core.distributed import per_shard_reference
    from repro.serving import HeartbeatMonitor, SearchService

    class _Clk:
        t = 0.0

        def __call__(self):
            return self.t

    clk = _Clk()
    hb = HeartbeatMonitor(shards, stale_after=2.0, clock=clk)
    n = sn.n_total
    store = GraphStore()
    store.add_node_table("Chunk", n, {"cID": np.arange(n)})
    db = NavixDB(store)
    db.register_index("default", sn)
    svc = SearchService(db, k_cap=K, efs_cap=EFS, max_batch=MAX_BATCH,
                        step_iters=STEP_ITERS, heartbeats=hb)

    def drive(futs, max_ticks=2000):
        for _ in range(max_ticks):
            if all(f.done() for f in futs):
                return [f.result(timeout=0) for f in futs]
            svc._tick()
        raise RuntimeError("heartbeat scenario did not converge")

    sub = reqs[:min(len(reqs), 8)]
    drive([svc.submit(q, plan=p, k=K) for q, p in sub])    # warm, healthy

    # the straggler: last shard's worker goes silent, heartbeat ages out
    hb.suppress(shards - 1)
    clk.t = 10.0
    for s in range(shards - 1):
        hb.beat(s)
    resps = drive([svc.submit(q, plan=p, k=K) for q, p in sub])
    svc.shutdown(drain=True)

    alive = np.ones(shards, bool)
    alive[shards - 1] = False
    Q = np.stack([q for q, _ in sub])
    masks = np.stack([np.arange(n) < plan.value for _, plan in sub])
    _, ref_ids, _ = per_shard_reference(sn, Q, masks, params, alive=alive)
    degraded = all(r.degraded for r in resps)
    drift = sum(1 for j, r in enumerate(resps)
                if not np.array_equal(np.asarray(r.ids), ref_ids[j]))
    return degraded, drift


def validate(rows: list[dict]) -> list[str]:
    fails: list[str] = []
    by = {r["sched"]: r for r in rows if not r.get("shards")}
    if "grouped" not in by or "continuous" not in by:
        return ["missing scheduler rows"]
    speedup = by["continuous"]["qps"] / max(by["grouped"]["qps"], 1e-9)
    if speedup < SPEEDUP_FLOOR:
        fails.append(f"continuous batching QPS is only {speedup:.2f}x the "
                     f"per-group drain on the mixed-plan workload (need >= "
                     f"{SPEEDUP_FLOOR}x)")
    if rows[0].get("_mismatched"):
        fails.append(f"{rows[0]['_mismatched']} requests got different "
                     f"answers from the two schedulers")
    for which in ("_sharded", "_sharded_model"):
        sharded = rows[0].get(which, {})
        label = sharded.get("layout", which.lstrip("_"))
        if "error" in sharded:
            fails.append(f"sharded serving arm ({label}) failed: "
                         f"{sharded['error']}")
            continue
        if sharded.get("answer_drift_vs_unsharded_engine"):
            fails.append(
                f"{sharded['answer_drift_vs_unsharded_engine']} sharded "
                f"({label}) responses drifted from the per-shard "
                f"unsharded-engine reference merge")
        if not sharded.get("heartbeat_degraded", True):
            fails.append("suppressed shard heartbeat did NOT flip "
                         "responses to degraded automatically")
        if sharded.get("heartbeat_drift"):
            fails.append(
                f"{sharded['heartbeat_drift']} degraded responses "
                f"drifted from the alive-restricted reference")
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=0,
                    help="run ONLY the sharded arm in this process "
                         "(needs >= that many host devices) and print "
                         "its JSON payload")
    ap.add_argument("--layout", choices=("data", "model"), default="data",
                    help="with --shards: split lanes over the data axis "
                         "(headline) or the index over the model axis "
                         "(legacy; runs the heartbeat drill)")
    ap.add_argument("--open-loop", action="store_true",
                    help="run the live-service open-loop arm (Poisson "
                         "arrivals, deadline/timeout gates) and merge "
                         "its rows into BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="with --open-loop: single offered load, fewer "
                         "requests (CI smoke)")
    args = ap.parse_args()
    if args.shards:
        print(json.dumps(run_sharded(args.shards, args.layout)))
        return
    if args.open_loop:
        fails = validate_open_loop(run_open_loop(smoke=args.smoke))
        for f in fails:
            print("CLAIM-FAIL:", f)
        sys.exit(1 if fails else 0)
    fails = validate(run())
    for f in fails:
        print("CLAIM-FAIL:", f)
    if fails:
        sys.exit(1)


if __name__ == "__main__":
    main()
