"""Mixed-plan serving throughput: continuous batching vs per-group drain.

The workload the per-group scheduler cannot batch: every request carries
its OWN selection subquery (distinct ``cID < cutoff`` predicates spanning
selectivities from ~5% to 100%), so plan-grouping degenerates to B=1
device calls. The continuous scheduler fuses them anyway -- per-lane
``[B, W]`` semimasks, per-lane k/efs capped to the batch max, converged
lanes compacted out and refilled from the queue between device steps.

Both schedulers serve the identical request stream through the same
``SearchEngine`` surface; results are checked equal request-for-request.
QPS, latency percentiles, and the continuous/grouped speedup go to
``experiments/bench/BENCH_serving.json``.

The ``--shards`` arm serves the identical stream on a
:class:`~repro.core.distributed.ShardedNavix` (continuous scheduler,
per-lane ``[S, B, W]`` semimasks) in a subprocess with placeholder host
devices, reports sharded-vs-unsharded QPS, and checks every sharded
answer against the *unsharded batched engine* run per shard over
shard-restricted masks and merged host-side -- zero drift is a gated
claim.

Claims gated by validate(): continuous-batching QPS >= 1.3x the
per-group-drain path (>= 1.0x sanity floor in REPRO_BENCH_QUICK mode,
where the problem is too small for the margin to be stable), with
identical per-request answers; and zero sharded answer drift.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

from benchmarks import common
from repro.core.navix import NavixConfig
from repro.data.synthetic import gaussian_mixture
from repro.query.operators import Filter, NodeScan
from repro.serving.engine import SearchEngine
from repro.storage.columnar import GraphStore

JSON_OUT = pathlib.Path("experiments") / "bench" / (
    "BENCH_serving.quick.json" if common.QUICK else "BENCH_serving.json")

K = 10
EFS = 30
MAX_BATCH = 16
STEP_ITERS = 32
SPEEDUP_FLOOR = 1.0 if common.QUICK else 1.3
SHARDS = 2                       # the --shards arm run() spawns by default
#: request selectivities -- each request gets its own predicate
SELECTIVITIES = (0.05, 0.1, 0.2, 0.35, 0.5, 0.75, 0.9, 1.0)


def _requests(n: int, centers, d: int, n_req: int, rng):
    """(query, plan) stream: distinct per-request predicates at varied
    selectivities."""
    reqs = []
    for j in range(n_req):
        sigma = SELECTIVITIES[j % len(SELECTIVITIES)]
        # distinct cutoffs even at equal sigma (jitter) => distinct plans
        cut = min(n, max(K, int(sigma * n) - (j // len(SELECTIVITIES))))
        q = (centers[rng.integers(0, len(centers))]
             + 0.3 * rng.normal(size=d)).astype(np.float32)
        reqs.append((q, Filter(NodeScan("Chunk"), "cID", "<", value=cut)))
    return reqs


def _serve(engine: SearchEngine, reqs) -> tuple[float, dict]:
    rids = [engine.submit(q, plan=plan, k=K) for q, plan in reqs]
    t0 = time.perf_counter()
    responses = engine.drain()
    wall = time.perf_counter() - t0
    by = {r.rid: r for r in responses}
    assert sorted(by) == sorted(rids), "every rid answered exactly once"
    return wall, {rid: by[rid] for rid in rids}


def _workload() -> tuple[int, int, int, int]:
    """(n, d, n_req, reps) -- shared by the main run and the --shards arm
    so both serve the identical request stream."""
    n, d = (1500, 16) if common.QUICK else (4000, 32)
    n_req = 24 if common.QUICK else 128
    reps = 2 if common.QUICK else 5
    return n, d, n_req, reps


def _request_stream(n: int, d: int, n_req: int):
    X, _, centers = gaussian_mixture(n, d, 10, seed=0)
    rng = np.random.default_rng(11)
    return X, _requests(n, centers, d, n_req, rng)


def run() -> list[dict]:
    n, d, n_req, reps = _workload()
    X, reqs = _request_stream(n, d, n_req)
    index = common.cached_index(f"bench_search_{n}",
                                X, NavixConfig(m_u=8, ef_construction=64,
                                               metric="l2", seed=0))

    def make_engine(sched: str) -> SearchEngine:
        store = GraphStore()
        store.add_node_table("Chunk", n, {"cID": np.arange(n)})
        return SearchEngine(index=index, store=store, efs=EFS,
                            max_batch=MAX_BATCH, scheduler=sched,
                            step_iters=STEP_ITERS)

    # engines are warmed up front and their timed drains interleaved
    # (grouped rep, continuous rep, ...) so host load drift hits both
    # schedulers equally; medians keep one noisy drain from deciding
    engines = {s: make_engine(s) for s in ("grouped", "continuous")}
    for engine in engines.values():
        _serve(engine, reqs)                        # warm-up compile
        engine.latencies_ms.clear()
    walls: dict[str, list[float]] = {s: [] for s in engines}
    answers: dict[str, dict] = {}
    for _ in range(reps):
        for sched, engine in engines.items():
            wall, got = _serve(engine, reqs)
            walls[sched].append(wall)
            answers[sched] = got
    rows: list[dict] = []
    for sched, engine in engines.items():
        lat = engine.latency_summary()
        med = float(np.median(walls[sched]))
        rows.append({
            "sched": sched,
            "n_req": n_req,
            "qps": round(n_req / med, 2),
            "drain_ms": round(med * 1e3, 2),
            "p50_ms": round(lat["p50_ms"], 3),
            "p95_ms": round(lat["p95_ms"], 3),
        })

    mismatched = sum(
        1 for rid in answers["grouped"]
        if not np.array_equal(answers["grouped"][rid].ids,
                              answers["continuous"][rid].ids))
    by = {r["sched"]: r for r in rows}
    speedup = round(by["continuous"]["qps"] / max(by["grouped"]["qps"], 1e-9),
                    3)

    # --shards arm: the same stream on a ShardedNavix, in a subprocess
    # with placeholder host devices (this process keeps its one device)
    sharded = _spawn_sharded(SHARDS)
    if "row" in sharded:
        rows.append(sharded["row"])
    common.emit(rows, "serving_schedulers")

    JSON_OUT.parent.mkdir(parents=True, exist_ok=True)
    JSON_OUT.write_text(json.dumps({
        "workload": {"n": n, "d": d, "k": K, "efs": EFS,
                     "n_req": n_req, "max_batch": MAX_BATCH,
                     "step_iters": STEP_ITERS, "reps": reps,
                     "selectivities": list(SELECTIVITIES),
                     "distinct_plans": len({p for _, p in reqs}),
                     "quick": common.QUICK},
        "rows": rows,
        "continuous_over_grouped_qps": speedup,
        "mismatched_answers": mismatched,
        "sharded": sharded,
    }, indent=2) + "\n")
    for r in rows:
        r["_mismatched"] = mismatched
        r["_sharded"] = sharded
    return rows


def _spawn_sharded(shards: int) -> dict:
    """Run the --shards arm in a subprocess with enough host devices and
    return its JSON payload ({"error": ...} on failure). The parent's
    XLA_FLAGS / PYTHONPATH are preserved (device-count flag replaced, not
    clobbered) so both arms run under the same XLA configuration."""
    import re

    flag = f"--xla_force_host_platform_device_count={max(4, shards)}"
    xla = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                 os.environ.get("XLA_FLAGS", ""))
    parent_pp = os.environ.get("PYTHONPATH", "")
    env = dict(os.environ,
               PYTHONPATH="src" + (os.pathsep + parent_pp if parent_pp
                                   else ""),
               HOME=os.environ.get("HOME", "/tmp"),
               XLA_FLAGS=f"{xla} {flag}".strip())
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serving",
         "--shards", str(shards)],
        timeout=3600, capture_output=True, text=True,
        cwd=pathlib.Path(__file__).parent.parent, env=env)
    if out.returncode != 0:
        return {"shards": shards, "error": out.stderr[-500:]}
    return json.loads(out.stdout.strip().splitlines()[-1])


def run_sharded(shards: int) -> dict:
    """The --shards arm body (run with >= ``shards`` host devices).

    Serves the identical mixed-predicate stream through the continuous
    scheduler on (a) the unsharded index and (b) a ShardedNavix, and
    checks every sharded answer against the unsharded batched engine run
    per shard over shard-restricted masks + a host lexicographic merge.
    """
    import jax

    from repro.core.distributed import ShardedNavix, per_shard_reference

    n, d, n_req, reps = _workload()
    X, reqs = _request_stream(n, d, n_req)
    cfg = NavixConfig(m_u=8, ef_construction=64, metric="l2", seed=0)
    index = common.cached_index(f"bench_search_{n}", X, cfg)
    mesh = jax.make_mesh((1, shards), ("data", "model"))
    sn = ShardedNavix.build(X, cfg, mesh)

    def make_engine(idx) -> SearchEngine:
        store = GraphStore()
        store.add_node_table("Chunk", n, {"cID": np.arange(n)})
        return SearchEngine(index=idx, store=store, efs=EFS,
                            max_batch=MAX_BATCH, scheduler="continuous",
                            step_iters=STEP_ITERS)

    engines = {"unsharded": make_engine(index), "sharded": make_engine(sn)}
    for engine in engines.values():
        _serve(engine, reqs)                        # warm-up compile
        engine.latencies_ms.clear()
    walls: dict[str, list[float]] = {s: [] for s in engines}
    answers: dict[str, dict] = {}
    for _ in range(reps):
        for name, engine in engines.items():
            wall, got = _serve(engine, reqs)
            walls[name].append(wall)
            answers[name] = got

    # zero-drift check against the SAME oracle the equivalence suite
    # asserts lane-for-lane identity with: the unsharded batched engine
    # per shard over shard-restricted masks + host lexicographic merge
    params = sn._params(K, EFS, "adaptive_local")
    Q = np.stack([q for q, _ in reqs])
    masks = np.stack([np.arange(n) < plan.value for _, plan in reqs])
    _, ref_ids, _ = per_shard_reference(sn, Q, masks, params)
    drift = 0
    rids = sorted(answers["sharded"])
    for j, rid in enumerate(rids):
        if not np.array_equal(answers["sharded"][rid].ids, ref_ids[j]):
            drift += 1

    med = {name: float(np.median(walls[name])) for name in engines}
    lat = engines["sharded"].latency_summary()
    row = {"sched": "continuous", "shards": shards, "n_req": n_req,
           "qps": round(n_req / med["sharded"], 2),
           "drain_ms": round(med["sharded"] * 1e3, 2),
           "p50_ms": round(lat["p50_ms"], 3),
           "p95_ms": round(lat["p95_ms"], 3)}
    return {
        "shards": shards,
        "row": row,
        "qps_sharded": row["qps"],
        "qps_unsharded": round(n_req / med["unsharded"], 2),
        "sharded_over_unsharded_qps": round(
            med["unsharded"] / med["sharded"], 3),
        "answer_drift_vs_unsharded_engine": drift,
    }


def validate(rows: list[dict]) -> list[str]:
    fails: list[str] = []
    by = {r["sched"]: r for r in rows if not r.get("shards")}
    if "grouped" not in by or "continuous" not in by:
        return ["missing scheduler rows"]
    speedup = by["continuous"]["qps"] / max(by["grouped"]["qps"], 1e-9)
    if speedup < SPEEDUP_FLOOR:
        fails.append(f"continuous batching QPS is only {speedup:.2f}x the "
                     f"per-group drain on the mixed-plan workload (need >= "
                     f"{SPEEDUP_FLOOR}x)")
    if rows[0].get("_mismatched"):
        fails.append(f"{rows[0]['_mismatched']} requests got different "
                     f"answers from the two schedulers")
    sharded = rows[0].get("_sharded", {})
    if "error" in sharded:
        fails.append(f"sharded serving arm failed: {sharded['error']}")
    elif sharded.get("answer_drift_vs_unsharded_engine"):
        fails.append(
            f"{sharded['answer_drift_vs_unsharded_engine']} sharded "
            f"responses drifted from the per-shard unsharded-engine "
            f"reference merge")
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=0,
                    help="run ONLY the sharded arm in this process "
                         "(needs >= that many host devices) and print "
                         "its JSON payload")
    args = ap.parse_args()
    if args.shards:
        print(json.dumps(run_sharded(args.shards)))
        return
    fails = validate(run())
    for f in fails:
        print("CLAIM-FAIL:", f)
    if fails:
        sys.exit(1)


if __name__ == "__main__":
    main()
