"""Table 6: index construction throughput (batched morsel-parallel insert),
plus index-size accounting (the paper's Section 5.1.6 ratio: upper layer
tiny vs vectors + lower level)."""

from __future__ import annotations

import time

from benchmarks.common import QUICK, emit
from repro.core.build import BuildParams, build
from repro.data.synthetic import gaussian_mixture


def run() -> list[dict]:
    rows = []
    sizes = [2000, 6000] if QUICK else [4000, 12000, 24000]
    for n in sizes:
        X, _, _ = gaussian_mixture(n, 48, 24, seed=9)
        t0 = time.perf_counter()
        graph, stats = build(X, BuildParams(m_u=16, ef_construction=100))
        dt = time.perf_counter() - t0
        vec_bytes = graph.vectors.size * 4
        lower_bytes = graph.lower.size * 4
        upper_bytes = graph.upper.size * 4 + graph.upper_ids.size * 4
        rows.append({
            "bench": "table6_construction", "n": n,
            "seconds": round(dt, 1),
            "vectors_per_s": round(n / dt, 1),
            "insert_dc_per_vector": round(stats.search_dc / n, 1),
            "vector_mb": round(vec_bytes / 2**20, 2),
            "lower_mb": round(lower_bytes / 2**20, 2),
            "upper_mb": round(upper_bytes / 2**20, 3),
            "upper_vs_total_pct": round(
                100 * upper_bytes / (vec_bytes + lower_bytes), 2),
        })
    emit(rows, "table6_construction")
    return rows


def validate(rows) -> list[str]:
    fails = []
    # Section 5.1.6: the in-memory upper layer is a tiny fraction
    for r in rows:
        if r["upper_vs_total_pct"] > 5.0:
            fails.append(f"upper layer too large: {r}")
    # throughput should not collapse with n (roughly n log n build)
    if len(rows) >= 2 and rows[-1]["vectors_per_s"] < rows[0]["vectors_per_s"] / 6:
        fails.append("construction throughput collapsed with n")
    return fails


if __name__ == "__main__":
    for f in validate(run()):
        print("CLAIM-FAIL:", f)
