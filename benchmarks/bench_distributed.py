"""Beyond-paper: distributed shard-and-merge search (the production layout)
-- recall + dc cost vs shard count, and quorum degradation. Runs in a
subprocess with placeholder devices so the bench process keeps 1 device."""

from __future__ import annotations

import json
import pathlib
import subprocess
import sys

from benchmarks.common import QUICK, emit

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import ShardedNavix
from repro.core.navix import NavixConfig, NavixIndex
from repro.core.distances import brute_force_topk
from repro.data.synthetic import gaussian_mixture

n = int(os.environ.get("BENCH_N", "4000"))
X, _, centers = gaussian_mixture(n, 32, 16, seed=0)
rng = np.random.default_rng(0)
Q = (centers[rng.integers(0, 16, size=8)] + 0.25*rng.normal(size=(8, 32))).astype(np.float32)
mask = rng.random(n) < 0.3
cfg = NavixConfig(m_u=8, ef_construction=64)
td, ti = brute_force_topk(jnp.asarray(Q), jnp.asarray(X), 10, "l2", mask=jnp.asarray(mask))
ti = np.asarray(ti)

def recall(ids):
    ids = np.asarray(ids)
    hits = sum(len(set(ids[i][ids[i]>=0].tolist()) & set(ti[i][ti[i]>=0].tolist())) for i in range(len(Q)))
    return hits / max((ti>=0).sum(), 1)

out = []
for model in (2, 4, 8):
    mesh = jax.make_mesh((8//model, model), ("data", "model"))
    sn = ShardedNavix.build(X, cfg, mesh)
    d, ids = sn.search(Q, mask, k=10, efs=40)
    rec = recall(ids)
    # quorum: drop one shard
    alive = np.ones(model, bool); alive[-1] = False
    d2, ids2 = sn.search(Q, mask, k=10, efs=40, alive=alive, quorum=model-1)
    out.append({"shards": model, "recall": rec, "recall_quorum": recall(ids2)})
print(json.dumps(out))
"""


def run() -> list[dict]:
    env = dict(PYTHONPATH="src", PATH="/usr/bin:/bin", HOME="/tmp",
               BENCH_N="2000" if QUICK else "4000")
    out = subprocess.run([sys.executable, "-c", SCRIPT], timeout=1800,
                         capture_output=True, text=True,
                         cwd=pathlib.Path(__file__).parent.parent, env=env)
    if out.returncode != 0:
        return [{"bench": "distributed_search", "error": out.stderr[-300:]}]
    rows = [dict(bench="distributed_search", **r)
            for r in json.loads(out.stdout.strip().splitlines()[-1])]
    emit(rows, "distributed_search")
    return rows


def validate(rows) -> list[str]:
    fails = []
    for r in rows:
        if "error" in r:
            fails.append(f"distributed bench failed: {r['error']}")
            return fails
        if r["recall"] < 0.85:
            fails.append(f"sharded recall low: {r}")
        # killing 1 of S shards loses ~1/S of the database; recall should
        # degrade gracefully toward that bound, not collapse below it
        alive_frac = (r["shards"] - 1) / r["shards"]
        if r["recall_quorum"] < r["recall"] * alive_frac - 0.12:
            fails.append(f"quorum degradation too steep: {r}")
    return fails


if __name__ == "__main__":
    for f in validate(run()):
        print("CLAIM-FAIL:", f)
