"""RecSys retrieval serving: score one user against a million-scale
candidate set -- the retrieval_cand production shape, powered by the
NaviX brute-force path (distance kernel + top-k) AND the HNSW index,
comparing cost.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import get_arch
from repro.core.navix import NavixConfig, NavixIndex
from repro.kernels import ops
from repro.models.api import model_api


def main():
    n_cand = 60_000            # laptop-scale stand-in for the 1M cell
    d = 32
    rng = np.random.default_rng(0)
    cfg = get_arch("bst").smoke_config
    params = model_api(cfg).init(jax.random.key(0))

    # candidate item embeddings come from the (here random-init) item tower
    cand = rng.normal(size=(n_cand, d)).astype(np.float32)
    user = rng.normal(size=(1, d)).astype(np.float32)

    # --- exact scoring: the distance kernel path ------------------------
    t0 = time.perf_counter()
    scores = -np.asarray(ops.distance_matrix(jnp.asarray(user),
                                             jnp.asarray(cand), "dot"))
    top = np.argsort(-scores[0])[:10]
    t_exact = time.perf_counter() - t0
    print(f"exact MIPS over {n_cand} candidates: {t_exact*1e3:.1f}ms "
          f"top-10 = {top}")

    # --- ANN: NaviX index over the candidates ---------------------------
    idx, stats = NavixIndex.create(
        cand, NavixConfig(m_u=8, ef_construction=64, metric="dot"))
    print(f"index build: {stats.seconds:.1f}s")
    idx.search(user[0], k=10, efs=100, heuristic="onehop_a")  # warm-up
    t0 = time.perf_counter()
    r = idx.search(user[0], k=10, efs=100, heuristic="onehop_a")
    t_ann = time.perf_counter() - t0
    hits = len(set(np.asarray(r.ids).tolist()) & set(top.tolist()))
    print(f"NaviX ANN: {t_ann*1e3:.1f}ms, recall@10={hits/10:.2f}, "
          f"dc={int(r.stats.t_dc)} ({int(r.stats.t_dc)/n_cand:.1%} of brute)")

    # --- filtered retrieval: only 'in-stock' candidates ------------------
    in_stock = rng.random(n_cand) < 0.25
    rf = idx.search(user[0], k=10, efs=100, semimask=in_stock,
                    heuristic="adaptive_local")
    ids = np.asarray(rf.ids)
    print(f"filtered (sigma=0.25): ids={ids[:5]}..., all selected: "
          f"{bool(in_stock[ids[ids>=0]].all())}")


if __name__ == "__main__":
    main()
