"""RecSys retrieval serving: score one user against a large candidate set
-- the retrieval_cand production shape, powered by the NaviX brute-force
path (distance kernel + top-k) AND a NavixDB item index, comparing cost.
The filtered variant ("in-stock items only") is one declarative plan over
the item table, no manual mask threading.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import NavixDB, Q
from repro.config.base import get_arch
from repro.core.navix import NavixConfig
from repro.kernels import ops
from repro.models.api import model_api


def main():
    n_cand = 60_000            # laptop-scale stand-in for the 1M cell
    d = 32
    rng = np.random.default_rng(0)
    cfg = get_arch("bst").smoke_config
    params = model_api(cfg).init(jax.random.key(0))

    # candidate item embeddings come from the (here random-init) item tower
    cand = rng.normal(size=(n_cand, d)).astype(np.float32)
    user = rng.normal(size=(1, d)).astype(np.float32)

    # --- exact scoring: the distance kernel path ------------------------
    t0 = time.perf_counter()
    scores = -np.asarray(ops.distance_matrix(jnp.asarray(user),
                                             jnp.asarray(cand), "dot"))
    top = np.argsort(-scores[0])[:10]
    t_exact = time.perf_counter() - t0
    print(f"exact MIPS over {n_cand} candidates: {t_exact*1e3:.1f}ms "
          f"top-10 = {top}")

    # --- ANN: NavixDB item catalog over the candidates -------------------
    db = NavixDB()
    _, stats = db.create_index(
        "items", "Item", column="embedding", vectors=cand,
        config=NavixConfig(m_u=8, ef_construction=64, metric="dot"))
    db.store.node("Item").add_column("in_stock", rng.random(n_cand) < 0.25)
    print(f"index build: {stats.seconds:.1f}s")

    plan = Q.match("Item").knn(user[0], k=10, efs=100, heuristic="onehop_a")
    db.execute(plan)                                   # warm-up compile
    t0 = time.perf_counter()
    rs = db.execute(plan)
    t_ann = time.perf_counter() - t0
    hits = len(set(rs.ids.tolist()) & set(top.tolist()))
    print(f"NaviX ANN: {t_ann*1e3:.1f}ms, recall@10={hits/10:.2f}, "
          f"dc={int(rs.stats.t_dc)} ({int(rs.stats.t_dc)/n_cand:.1%} of "
          f"brute), cache={db.programs.info()}")

    # --- filtered retrieval: only 'in-stock' candidates ------------------
    rf = db.execute(Q.match("Item").where("in_stock", "==", True)
                     .knn(user[0], k=10, efs=100).project("in_stock"))
    ids = rf.ids
    print(f"filtered (sigma={rf.sigma:.2f}): ids={ids[:5]}..., "
          f"all in stock: {bool(rf.columns['in_stock'][ids >= 0].all())}")


if __name__ == "__main__":
    main()
