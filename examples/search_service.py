"""End-to-end driver: a filtered vector-search service on the Wiki-like
graph store serving batched requests (the paper's kind of system is a
serving system, so the end-to-end driver serves batched requests).

Requests carry declarative plan templates (built with ``repro.api.Q``,
query vector bound per request by the engine). The default scheduler is
continuous batching: requests with *different* plans fuse into one
device batch (each lane carries its own selection subquery's semimask),
converged lanes are compacted out and refilled from the queue, and each
distinct prefilter runs exactly once per drain. Latency percentiles are
reported like a production tier. ``SearchEngine(scheduler="grouped")``
selects the per-plan reference path (which also exercises the shared
compiled-program cache through NavixDB.execute).

``--shards S`` serves the same workload on a sharded index
(:class:`repro.core.distributed.ShardedNavix`): the chunk embeddings
split into S shard-local HNSW subgraphs, every request's semimask
becomes a ``[S, B, W_local]`` per-lane stack, and per-shard candidates
merge into the global top-k in one device op. The demo ends by killing
one shard mid-service: responses degrade gracefully (flagged
``degraded``, no dead-shard ids) instead of failing.

    PYTHONPATH=src python examples/search_service.py [--requests 60]
    PYTHONPATH=src python examples/search_service.py --shards 2
"""

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--shards", type=int, default=0,
                    help="serve on a ShardedNavix with this many shards "
                         "(spawns placeholder host devices)")
    args = ap.parse_args()
    if args.shards:
        # must be set before jax initializes its backend; a pre-existing
        # XLA_FLAGS keeps its other options, and an existing (too-small)
        # device count is raised rather than trusted
        import re
        need = max(4, args.shards)
        prev = os.environ.get("XLA_FLAGS", "")
        m = re.search(r"--xla_force_host_platform_device_count=(\d+)", prev)
        if m is None or int(m.group(1)) < need:
            prev = re.sub(r"--xla_force_host_platform_device_count=\d+",
                          "", prev)
            os.environ["XLA_FLAGS"] = (
                f"{prev} --xla_force_host_platform_device_count={need}"
            ).strip()

    import numpy as np

    from repro.api import NavixDB, Q
    from repro.core.navix import NavixConfig
    from repro.data.synthetic import make_queries, make_wiki_like
    from repro.serving.engine import SearchEngine

    print("== building the Wiki-like graph + index catalog ==")
    data = make_wiki_like(n_person=300, n_resource=1200, d=48, seed=0)
    db = NavixDB(data.store)
    config = NavixConfig(m_u=8, ef_construction=64, metric="cos")
    if args.shards:
        import jax

        from repro.core.distributed import ShardedNavix
        mesh = jax.make_mesh((1, args.shards), ("data", "model"))
        sn = ShardedNavix.build(data.embeddings.astype(np.float32), config,
                                mesh)
        db.store.add_vector_column("Chunk", "embedding",
                                   data.embeddings.astype(np.float32))
        db.register_index("chunk_emb", sn, table="Chunk",
                          column="embedding")
        print(f"chunks={data.n_chunks} shards={sn.n_shards} "
              f"n_local={sn.n_local}")
    else:
        _, stats = db.create_index(
            "chunk_emb", "Chunk", column="embedding",
            vectors=data.embeddings, config=config)
        print(f"chunks={data.n_chunks} build={stats.seconds:.1f}s")

    engine = SearchEngine(db=db, efs=80)

    # a mix of production-ish request types, as declarative plan templates
    plans = {
        "id_filter": Q.match("Chunk")
                      .where("cID", "<", int(0.3 * data.n_chunks))
                      .knn(k=10, efs=80),
        "person_join": Q.match("Person")
                        .where("birth_date", "range", lo=0, hi=18250)
                        .hop("PersonChunk", "fwd")
                        .knn(k=10, efs=80),
        "graph_rag_2hop": Q.match("Person")
                           .where("birth_date", "range", lo=0, hi=18250)
                           .hop("WikiLink", "fwd")
                           .hop("ResourceChunk", "fwd")
                           .knn(k=10, efs=80),
        "unfiltered": None,
    }
    rng = np.random.default_rng(0)
    kinds = list(plans)
    queries = make_queries(data, args.requests, "uncorrelated", seed=7)
    for i in range(args.requests):
        kind = kinds[rng.integers(0, len(kinds))]
        engine.submit(queries[i], plan=plans[kind], k=10)

    print(f"== serving {args.requests} requests ==")
    responses = engine.drain()
    ok = sum(1 for r in responses if (r.ids >= 0).any())
    print(f"answered {len(responses)} requests ({ok} non-empty)")
    for r in responses[:3]:
        print(f"  rid={r.rid} sigma={r.sigma:.2f} ids={r.ids[:5]}"
              f" prefilter={r.prefilter_ms:.3f}ms exec={r.exec_ms:.1f}ms")
    print("latency summary:", engine.latency_summary())
    # the program cache serves the grouped path + NavixDB.execute; the
    # continuous scheduler runs the stepping engine's own jit programs
    print("program cache:", db.programs.info())

    if args.shards:
        sn = db.index("chunk_emb")
        print(f"== quorum demo: killing shard {sn.n_shards - 1} ==")
        alive = np.ones(sn.n_shards, bool)
        alive[-1] = False
        engine.alive = alive
        for i in range(8):
            engine.submit(queries[i % len(queries)],
                          plan=plans["id_filter"], k=10)
        degraded = engine.drain()
        dead_lo = (sn.n_shards - 1) * sn.n_local
        leaked = sum(int(((r.ids >= dead_lo) & (r.ids >= 0)).sum())
                     for r in degraded)
        print(f"served {len(degraded)} requests degraded="
              f"{all(r.degraded for r in degraded)} "
              f"dead-shard ids leaked={leaked} (must be 0)")


if __name__ == "__main__":
    main()
