"""End-to-end driver: a filtered vector-search service on the Wiki-like
graph store serving batched requests (the paper's kind of system is a
serving system, so the end-to-end driver serves batched requests).

Requests carry declarative plan templates (built with ``repro.api.Q``,
query vector bound per request by the engine). The default scheduler is
continuous batching: requests with *different* plans fuse into one
device batch (each lane carries its own selection subquery's semimask),
converged lanes are compacted out and refilled from the queue, and each
distinct prefilter runs exactly once per drain. Latency percentiles are
reported like a production tier. ``SearchEngine(scheduler="grouped")``
selects the per-plan reference path (which also exercises the shared
compiled-program cache through NavixDB.execute).

    PYTHONPATH=src python examples/search_service.py [--requests 60]
"""

import argparse

import numpy as np

from repro.api import NavixDB, Q
from repro.core.navix import NavixConfig
from repro.data.synthetic import make_queries, make_wiki_like
from repro.serving.engine import SearchEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=60)
    args = ap.parse_args()

    print("== building the Wiki-like graph + index catalog ==")
    data = make_wiki_like(n_person=300, n_resource=1200, d=48, seed=0)
    db = NavixDB(data.store)
    _, stats = db.create_index(
        "chunk_emb", "Chunk", column="embedding", vectors=data.embeddings,
        config=NavixConfig(m_u=8, ef_construction=64, metric="cos"))
    print(f"chunks={data.n_chunks} build={stats.seconds:.1f}s")

    engine = SearchEngine(db=db, efs=80)

    # a mix of production-ish request types, as declarative plan templates
    plans = {
        "id_filter": Q.match("Chunk")
                      .where("cID", "<", int(0.3 * data.n_chunks))
                      .knn(k=10, efs=80),
        "person_join": Q.match("Person")
                        .where("birth_date", "range", lo=0, hi=18250)
                        .hop("PersonChunk", "fwd")
                        .knn(k=10, efs=80),
        "graph_rag_2hop": Q.match("Person")
                           .where("birth_date", "range", lo=0, hi=18250)
                           .hop("WikiLink", "fwd")
                           .hop("ResourceChunk", "fwd")
                           .knn(k=10, efs=80),
        "unfiltered": None,
    }
    rng = np.random.default_rng(0)
    kinds = list(plans)
    queries = make_queries(data, args.requests, "uncorrelated", seed=7)
    for i in range(args.requests):
        kind = kinds[rng.integers(0, len(kinds))]
        engine.submit(queries[i], plan=plans[kind], k=10)

    print(f"== serving {args.requests} requests ==")
    responses = engine.drain()
    ok = sum(1 for r in responses if (r.ids >= 0).any())
    print(f"answered {len(responses)} requests ({ok} non-empty)")
    for r in responses[:3]:
        print(f"  rid={r.rid} sigma={r.sigma:.2f} ids={r.ids[:5]}"
              f" prefilter={r.prefilter_ms:.3f}ms exec={r.exec_ms:.1f}ms")
    print("latency summary:", engine.latency_summary())
    # the program cache serves the grouped path + NavixDB.execute; the
    # continuous scheduler runs the stepping engine's own jit programs
    print("program cache:", db.programs.info())


if __name__ == "__main__":
    main()
