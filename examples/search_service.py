"""End-to-end driver: a filtered vector-search service on the Wiki-like
graph store serving batched requests (the paper's kind of system is a
serving system, so the end-to-end driver serves batched requests).

Flow per request: selection subquery (Cypher-analogue operator tree) ->
semimask via sideways information passing -> adaptive-local kNN -> results;
latency percentiles reported like a production tier.

    PYTHONPATH=src python examples/search_service.py [--requests 60]
"""

import argparse

import numpy as np

from repro.core.navix import NavixConfig, NavixIndex
from repro.data.synthetic import (make_queries, make_wiki_like,
                                  person_chunk_plan, two_hop_plan,
                                  uncorrelated_plan)
from repro.query.operators import evaluate
from repro.serving.engine import SearchEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=60)
    args = ap.parse_args()

    print("== building the Wiki-like graph + index ==")
    data = make_wiki_like(n_person=300, n_resource=1200, d=48, seed=0)
    idx, stats = NavixIndex.create(
        data.embeddings, NavixConfig(m_u=8, ef_construction=64, metric="cos"))
    print(f"chunks={data.n_chunks} build={stats.seconds:.1f}s")

    engine = SearchEngine(index=idx, store=data.store, efs=80)

    # a mix of production-ish request types
    plans = {
        "id_filter": uncorrelated_plan(0.3, data.n_chunks),
        "person_join": person_chunk_plan(data.store, 0.5),
        "graph_rag_2hop": two_hop_plan(data.store, 0.5),
        "unfiltered": None,
    }
    rng = np.random.default_rng(0)
    kinds = list(plans)
    queries = make_queries(data, args.requests, "uncorrelated", seed=7)
    for i in range(args.requests):
        kind = kinds[rng.integers(0, len(kinds))]
        engine.submit(queries[i], plan=plans[kind], k=10)

    print(f"== serving {args.requests} requests ==")
    responses = engine.drain()
    ok = sum(1 for r in responses if (r.ids >= 0).any())
    print(f"answered {len(responses)} requests ({ok} non-empty)")
    for r in responses[:3]:
        print(f"  rid={r.rid} sigma={r.sigma:.2f} ids={r.ids[:5]}"
              f" prefilter={r.prefilter_ms:.2f}ms exec={r.exec_ms:.1f}ms")
    print("latency summary:", engine.latency_summary())


if __name__ == "__main__":
    main()
