"""End-to-end driver: a LIVE filtered vector-search service on the
Wiki-like graph store.

Unlike the closed-queue engine demo this used to be, the service here is
the real long-running shape: a :class:`~repro.serving.service
.SearchService` device loop runs in a background thread while client
code ``submit()``s requests against declarative plan templates (built
with ``repro.api.Q``) under a Poisson arrival process. Between step
chunks the loop admits new lanes from the bounded submission queue
(deadline-ordered, selectivity-binned), evicts lanes past their
deadline (``timeout`` / salvaged ``partial`` responses), and applies
backpressure when the queue gates. A few doomed requests carry
millisecond deadlines to show the timeout path; live gauges print at
the end.

``--shards S`` serves on a sharded index
(:class:`repro.core.distributed.ShardedNavix`) with HEARTBEAT-derived
shard liveness: a beater thread heartbeats every shard, the demo
suppresses one shard's beats mid-run (a straggler), and responses flip
to ``degraded`` automatically -- no caller-set alive mask -- with no
dead-shard ids.

    PYTHONPATH=src python examples/search_service.py [--requests 60]
    PYTHONPATH=src python examples/search_service.py --shards 2
"""

import argparse
import os
import re
import sys
import threading
import time


def _ensure_host_devices(need: int) -> None:
    """Make sure >= ``need`` host platform devices exist.

    ``--xla_force_host_platform_device_count`` only works if it lands in
    ``XLA_FLAGS`` BEFORE jax initializes its backend. If some earlier
    import already initialized jax with too few devices, mutating the
    env var would be silently ignored -- so detect that and raise a
    clear error instead of serving on too few devices.
    """
    jax_mod = sys.modules.get("jax")
    if jax_mod is not None:
        try:
            from jax._src import xla_bridge
            initialized = bool(xla_bridge._backends)
        except (ImportError, AttributeError):
            initialized = True  # private API moved: assume initialized
        if initialized:
            have = len(jax_mod.devices())
            if have < need:
                raise RuntimeError(
                    f"jax already initialized with {have} host device(s) "
                    f"but --shards needs {need}; XLA_FLAGS set now would "
                    f"be ignored. Relaunch with XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={need} in "
                    f"the environment (before any jax import).")
            return              # enough devices: nothing to do
    prev = os.environ.get("XLA_FLAGS", "")
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)", prev)
    if m is None or int(m.group(1)) < need:
        prev = re.sub(r"--xla_force_host_platform_device_count=\d+",
                      "", prev)
        os.environ["XLA_FLAGS"] = (
            f"{prev} --xla_force_host_platform_device_count={need}"
        ).strip()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--qps", type=float, default=200.0,
                    help="offered load (Poisson arrival rate)")
    ap.add_argument("--deadline", type=float, default=5.0,
                    help="per-request deadline (seconds)")
    ap.add_argument("--shards", type=int, default=0,
                    help="serve on a ShardedNavix with this many shards "
                         "(spawns placeholder host devices)")
    args = ap.parse_args()
    if args.shards:
        _ensure_host_devices(max(4, args.shards))

    import numpy as np

    from repro.api import NavixDB, Q
    from repro.core.navix import NavixConfig
    from repro.data.synthetic import make_queries, make_wiki_like
    from repro.serving import HeartbeatMonitor

    print("== building the Wiki-like graph + index catalog ==")
    data = make_wiki_like(n_person=300, n_resource=1200, d=48, seed=0)
    db = NavixDB(data.store)
    config = NavixConfig(m_u=8, ef_construction=64, metric="cos")
    if args.shards:
        import jax

        from repro.core.distributed import ShardedNavix
        mesh = jax.make_mesh((1, args.shards), ("data", "model"))
        sn = ShardedNavix.build(data.embeddings.astype(np.float32), config,
                                mesh)
        db.store.add_vector_column("Chunk", "embedding",
                                   data.embeddings.astype(np.float32))
        db.register_index("chunk_emb", sn, table="Chunk",
                          column="embedding")
        print(f"chunks={data.n_chunks} shards={sn.n_shards} "
              f"n_local={sn.n_local}")
    else:
        _, stats = db.create_index(
            "chunk_emb", "Chunk", column="embedding",
            vectors=data.embeddings, config=config)
        print(f"chunks={data.n_chunks} build={stats.seconds:.1f}s")

    # heartbeat-derived shard liveness: a beater thread stands in for
    # per-shard workers; the straggler drill below suppresses one shard
    hb = (HeartbeatMonitor(args.shards, stale_after=0.5)
          if args.shards else None)
    stop_beating = threading.Event()
    if hb is not None:
        def beater():
            while not stop_beating.is_set():
                hb.beat_all()
                time.sleep(0.1)
        threading.Thread(target=beater, daemon=True).start()

    svc = db.serve(index="chunk_emb", k_cap=10, efs_cap=80, max_batch=16,
                   step_iters=16, default_deadline_s=args.deadline,
                   queue_size=max(64, args.requests), policy="block",
                   heartbeats=hb).start()

    # a mix of production-ish request types, as declarative plan templates
    plans = {
        "id_filter": Q.match("Chunk")
                      .where("cID", "<", int(0.3 * data.n_chunks))
                      .knn(k=10, efs=80),
        "person_join": Q.match("Person")
                        .where("birth_date", "range", lo=0, hi=18250)
                        .hop("PersonChunk", "fwd")
                        .knn(k=10, efs=80),
        "graph_rag_2hop": Q.match("Person")
                           .where("birth_date", "range", lo=0, hi=18250)
                           .hop("WikiLink", "fwd")
                           .hop("ResourceChunk", "fwd")
                           .knn(k=10, efs=80),
        "unfiltered": None,
    }
    rng = np.random.default_rng(0)
    kinds = list(plans)
    queries = make_queries(data, args.requests, "uncorrelated", seed=7)

    print(f"== open-loop serving: {args.requests} requests at "
          f"~{args.qps:.0f} qps offered ==")
    futs = []
    suppressed_at = None
    for i in range(args.requests):
        time.sleep(rng.exponential(1.0 / args.qps))
        kind = kinds[rng.integers(0, len(kinds))]
        # a few doomed requests demo the deadline/timeout path: their
        # deadline passes before (or while) they hold a lane
        ddl = 0.001 if i % 20 == 19 else None
        futs.append((kind, svc.submit(queries[i], plan=plans[kind],
                                      k=10, deadline_s=ddl)))
        if hb is not None and suppressed_at is None \
                and i >= args.requests // 2:
            print(f"== straggler drill: suppressing shard "
                  f"{args.shards - 1}'s heartbeats mid-run ==")
            hb.suppress(args.shards - 1)
            suppressed_at = i

    responses = [(kind, f.result(timeout=300)) for kind, f in futs]
    svc.shutdown(drain=True)
    stop_beating.set()

    by_status: dict = {}
    for kind, r in responses:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    print(f"answered {len(responses)} requests: {by_status}")
    for kind, r in responses[:3]:
        print(f"  rid={r.rid} kind={kind} sigma={r.sigma:.2f} "
              f"ids={np.asarray(r.ids)[:5]} queue={r.queue_ms:.1f}ms "
              f"exec={r.exec_ms:.1f}ms")
    for kind, r in responses:
        if r.timeout:
            print(f"  rid={r.rid} TIMED OUT (deadline demo): "
                  f"ids={np.asarray(r.ids)[:5]} (all -1, never partial)")
            break
    print("gauges:", svc.gauges())

    if hb is not None:
        sn = db.index("chunk_emb")
        dead_lo = (sn.n_shards - 1) * sn.n_local
        after = [r for kind, r in responses[suppressed_at + 1:]]
        degraded = [r for r in after if r.degraded]
        leaked = sum(int(((np.asarray(r.ids) >= dead_lo)
                          & (np.asarray(r.ids) >= 0)).sum())
                     for r in degraded)
        print(f"== straggler drill: {len(degraded)}/{len(after)} "
              f"post-suppression responses degraded automatically, "
              f"dead-shard ids leaked={leaked} (must be 0) ==")
        print("heartbeats:", hb.snapshot())


if __name__ == "__main__":
    main()
