"""Graph-RAG pipeline: filtered retrieval feeding a (tiny) LM's decode loop
-- the paper's motivating application (Section 1): "100 nearest chunks of
v_Q among chunks mentioning person X", then generate with the retrieved
context.

The LM is an untrained smoke-size qwen config (the framework trains real
ones; here the point is the serving integration), the retrieval is the
full NaviX stack: selection subquery -> semimask -> adaptive-local search.

    PYTHONPATH=src python examples/rag_pipeline.py
"""

import jax
import numpy as np

from repro.config.base import get_arch
from repro.core.navix import NavixConfig, NavixIndex
from repro.data.synthetic import make_queries, make_wiki_like, person_chunk_plan
from repro.models.api import model_api
from repro.query.operators import evaluate
from repro.serving.engine import greedy_generate


def main():
    print("== graph store + index ==")
    data = make_wiki_like(n_person=200, n_resource=800, d=48, seed=1)
    idx, _ = NavixIndex.create(
        data.embeddings, NavixConfig(m_u=8, ef_construction=64, metric="cos"))

    # "question about a person" -> embed -> retrieve among person chunks
    q = make_queries(data, 1, "person", seed=3)[0]
    plan = person_chunk_plan(data.store, 1.0)   # chunks of any person
    qres = evaluate(plan, data.store)
    print(f"selection subquery: {qres.mask.sum()} of {data.n_chunks} chunks "
          f"(sigma={qres.selectivity:.2f}), {qres.seconds*1e3:.2f}ms")

    res = idx.search(q, k=8, semimask=qres.mask, heuristic="adaptive_local")
    ids = np.asarray(res.ids)
    print(f"retrieved chunks: {ids} (t_dc={int(res.stats.t_dc)})")
    assert qres.mask[ids[ids >= 0]].all(), "retrieval leaked unselected chunks"

    print("\n== generation with retrieved context ==")
    cfg = get_arch("qwen1.5-0.5b").smoke_config
    params = model_api(cfg).init(jax.random.key(0))
    # context tokens stand in for the retrieved chunks' text
    rng = np.random.default_rng(0)
    context = rng.integers(0, cfg.vocab_size, size=(1, 24))
    out = greedy_generate(cfg, params, context, n_new=8)
    print("generated token ids:", out[0])
    print("\n(RAG loop complete: Q_S -> semimask -> filtered kNN -> LM)")


if __name__ == "__main__":
    main()
