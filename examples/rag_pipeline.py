"""Graph-RAG pipeline: declarative filtered retrieval feeding a (tiny) LM's
decode loop -- the paper's motivating application (Section 1): "100 nearest
chunks of v_Q among chunks mentioning person X", then generate with the
retrieved context.

The LM is an untrained smoke-size qwen config (the framework trains real
ones; here the point is the serving integration); the retrieval is one
NavixDB plan -- selection subquery -> KnnSearch -> projection -- with no
manual mask threading.

    PYTHONPATH=src python examples/rag_pipeline.py
"""

import jax
import numpy as np

from repro.api import NavixDB, Q
from repro.config.base import get_arch
from repro.core.navix import NavixConfig
from repro.data.synthetic import make_queries, make_wiki_like
from repro.models.api import model_api
from repro.serving.engine import greedy_generate


def main():
    print("== graph store + index catalog ==")
    data = make_wiki_like(n_person=200, n_resource=800, d=48, seed=1)
    db = NavixDB(data.store)
    db.create_index(
        "chunk_emb", "Chunk", column="embedding", vectors=data.embeddings,
        config=NavixConfig(m_u=8, ef_construction=64, metric="cos"))

    # "question about a person" -> embed -> retrieve among person chunks,
    # all as one declarative plan
    q = make_queries(data, 1, "person", seed=3)[0]
    plan = (Q.match("Person")
             .where("birth_date", "range", lo=0, hi=36500)
             .hop("PersonChunk", "fwd")
             .knn(q, k=8, heuristic="adaptive_local")
             .project("cID", "is_person"))
    print(db.explain(plan))

    rs = db.execute(plan)
    ids = rs.ids
    print(f"selection subquery: {int(rs.mask.sum())} of {data.n_chunks} "
          f"chunks (sigma={rs.sigma:.2f}), "
          f"{rs.timings.prefilter_ms:.2f}ms prefilter")
    print(f"retrieved chunks: {ids} (t_dc={int(rs.stats.t_dc)}, "
          f"search {rs.timings.search_ms:.1f}ms)")
    assert rs.mask[ids[ids >= 0]].all(), "retrieval leaked unselected chunks"
    assert rs.columns["is_person"][ids >= 0].all()

    print("\n== generation with retrieved context ==")
    cfg = get_arch("qwen1.5-0.5b").smoke_config
    params = model_api(cfg).init(jax.random.key(0))
    # context tokens stand in for the retrieved chunks' text
    rng = np.random.default_rng(0)
    context = rng.integers(0, cfg.vocab_size, size=(1, 24))
    out = greedy_generate(cfg, params, context, n_new=8)
    print("generated token ids:", out[0])
    print("\n(RAG loop complete: one NavixDB plan -> filtered kNN -> LM)")


if __name__ == "__main__":
    main()
