"""Quickstart: stand up a NavixDB, create an index (CREATE_HNSW_INDEX),
and run declarative filtered kNN plans (QUERY_HNSW_INDEX) -- plus the
per-heuristic drill-down through the compatibility layer.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.api import NavixDB, Q
from repro.core.navix import NavixConfig
from repro.data.synthetic import gaussian_mixture


def main():
    print("== NaviX quickstart ==")
    X, labels, centers = gaussian_mixture(4000, 32, 12, seed=0)
    print(f"dataset: {X.shape[0]} vectors, dim {X.shape[1]}")

    db = NavixDB()
    idx, stats = db.create_index(
        "chunks", "Chunk", column="embedding", vectors=X,
        config=NavixConfig(m_u=8, ef_construction=64))
    db.store.node("Chunk").add_column("year",
                                      2015 + (np.arange(4000) % 10))
    print(f"built 2-level HNSW in {stats.seconds:.1f}s "
          f"({stats.n} vectors, {stats.n_upper} upper, "
          f"{stats.search_dc} insert distance computations)")

    q = (centers[3] + 0.2 * np.random.default_rng(1).normal(size=32)
         ).astype(np.float32)

    # unfiltered kNN: MATCH (c:Chunk) -> knn
    rs = db.execute(Q.match("Chunk").knn(q, k=5, heuristic="onehop_a"))
    print("\nunfiltered top-5:", rs.ids, "dc:", int(rs.stats.t_dc))

    # declarative filtered search: WHERE year >= 2020 -> knn -> project
    plan = (Q.match("Chunk").where("year", ">=", 2020)
             .knn(q, k=5).project("year"))
    print("\nplan:\n" + db.explain(plan))
    rs = db.execute(plan)
    print(f"filtered (sigma={rs.sigma:.2f}): ids={rs.ids} "
          f"years={rs.columns['year']}")
    print("stage timings:",
          {k: round(v, 2) for k, v in rs.timings.as_dict().items()})

    # the same shape re-executes with zero new compilations
    db.execute(plan, query=X[0])
    print("program cache:", db.programs.info())

    # heuristic drill-down (paper Table 1) via the compatibility layer
    mask = np.random.default_rng(2).random(4000) < 0.2
    _, exact = idx.brute_force(q, k=5, semimask=mask)
    print(f"\nheuristics at sigma={mask.mean():.2f}, exact:",
          np.asarray(exact)[0])
    for h in ("onehop_s", "directed", "blind", "adaptive_g",
              "adaptive_local"):
        r = idx.search(q, k=5, semimask=mask, heuristic=h)
        print(f"  {h:15s} ids={np.asarray(r.ids)} t_dc={int(r.stats.t_dc):5d}"
              f" s_dc={int(r.stats.s_dc):5d} picks={np.asarray(r.stats.picks)}")

    print("\n(adaptive_local is NaviX's default: the per-candidate rule of"
          " paper Section 3.2)")


if __name__ == "__main__":
    main()
