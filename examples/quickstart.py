"""Quickstart: build a NaviX index, run filtered kNN with every heuristic.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.navix import NavixConfig, NavixIndex
from repro.data.synthetic import gaussian_mixture


def main():
    print("== NaviX quickstart ==")
    X, labels, centers = gaussian_mixture(4000, 32, 12, seed=0)
    print(f"dataset: {X.shape[0]} vectors, dim {X.shape[1]}")

    idx, stats = NavixIndex.create(X, NavixConfig(m_u=8, ef_construction=64))
    print(f"built 2-level HNSW in {stats.seconds:.1f}s "
          f"({stats.n} vectors, {stats.n_upper} upper, "
          f"{stats.search_dc} insert distance computations)")

    q = (centers[3] + 0.2 * np.random.default_rng(1).normal(size=32)
         ).astype(np.float32)

    # unfiltered kNN
    r = idx.search(q, k=5, heuristic="onehop_a")
    print("\nunfiltered top-5:", np.asarray(r.ids),
          "dc:", int(r.stats.t_dc))

    # predicate-agnostic filtered search: S = an arbitrary 20% subset
    mask = np.random.default_rng(2).random(4000) < 0.2
    _, exact = idx.brute_force(q, k=5, semimask=mask)
    print(f"\nfiltered search (sigma={mask.mean():.2f}), exact:",
          np.asarray(exact)[0])
    for h in ("onehop_s", "directed", "blind", "adaptive_g",
              "adaptive_local"):
        r = idx.search(q, k=5, semimask=mask, heuristic=h)
        print(f"  {h:15s} ids={np.asarray(r.ids)} t_dc={int(r.stats.t_dc):5d}"
              f" s_dc={int(r.stats.s_dc):5d} picks={np.asarray(r.stats.picks)}")

    print("\n(adaptive_local is NaviX's default: the per-candidate rule of"
          " paper Section 3.2)")


if __name__ == "__main__":
    main()
