"""Sharded checkpointing with elastic (mesh-migrating) restore.

Layout: one directory per step containing a JSON manifest (leaf paths,
shapes, dtypes, partition specs, mesh shape, step metadata) + one .npy per
leaf. Arrays are fetched shard-by-shard via addressable shards (on a real
multi-host slice each host writes only its shards; here a single process
owns all of them -- the manifest format is identical).

Elastic restore: ``load`` takes the *target* mesh and the policy's specs,
so a checkpoint taken on a (16,16) mesh restores onto (2,16,16), (4,8), or
a single device -- resharding happens at device_put. Integrity: manifest
lists per-leaf SHA1 of the host buffer; a truncated/partial checkpoint
(e.g. preempted mid-write) is detected and ``latest_complete`` skips it
(the COMMIT file is written last).
"""

from __future__ import annotations

import hashlib
import json
import pathlib
import shutil
import time
from typing import Any, Optional

import jax
import numpy as np


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def save(ckpt_dir: str | pathlib.Path, step: int, tree: Any,
         extra: Optional[dict] = None) -> pathlib.Path:
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    tmp = d.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step,
                "created": time.time(),  # navilint: wallclock-ok manifest timestamp, not duration math
                "extra": extra or {},
                "leaves": {}}
    for path, leaf in leaves:
        key = _leaf_key(path)
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "_") + ".npy"
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in logical_dtype:
            # numpy can't round-trip ml_dtypes (bf16 loads back as V2):
            # store a uint16 view, record the logical dtype in the manifest
            np.save(tmp / fname, arr.view(np.uint16))
        else:
            np.save(tmp / fname, arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": logical_dtype,
            "sha1": hashlib.sha1(arr.tobytes()).hexdigest(),
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    (tmp / "COMMIT").write_text("ok")          # written last: atomicity mark
    if d.exists():
        shutil.rmtree(d)
    tmp.rename(d)
    return d


def latest_complete(ckpt_dir: str | pathlib.Path) -> Optional[pathlib.Path]:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted(p for p in d.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and (p / "COMMIT").exists())
    return steps[-1] if steps else None


def load(step_dir: str | pathlib.Path, like: Any,
         shardings: Any = None, verify: bool = True) -> Any:
    """Restore a pytree. ``like`` provides the tree structure;
    ``shardings`` (same structure, NamedSharding leaves) retargets the
    arrays onto the current mesh (elastic restore)."""
    step_dir = pathlib.Path(step_dir)
    manifest = json.loads((step_dir / "manifest.json").read_text())
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    out = []
    for (path, leaf), sh in zip(paths, shard_leaves):
        key = _leaf_key(path)
        meta = manifest["leaves"][key]
        arr = np.load(step_dir / meta["file"])
        if meta["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if verify:
            got = hashlib.sha1(arr.tobytes()).hexdigest()
            if got != meta["sha1"]:
                raise IOError(f"checksum mismatch for {key}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {leaf.shape}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def load_manifest(step_dir: str | pathlib.Path) -> dict:
    return json.loads((pathlib.Path(step_dir) / "manifest.json").read_text())
