"""Checkpointing: index/graph persistence."""
