"""gemma2-9b [arXiv:2408.00118]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000; alternating local(4096-window)/global attention, attn logit
softcap 50, final logit softcap 30, sandwich (pre+post) RMSNorms, GeGLU.

The only assigned LM that runs long_500k: its local layers are
sub-quadratic sliding-window attention (hybrid pattern)."""

from repro.config.base import ArchDef, LMConfig, register_arch
from repro.configs.lm_shapes import lm_shapes

CONFIG = LMConfig(
    arch_id="gemma2-9b",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000, activation="geglu",
    attn_pattern="local_global", local_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0, post_norms=True,
    rope_theta=10000.0, tie_embeddings=True, embedding_scale=True,
)

SMOKE = LMConfig(
    arch_id="gemma2-9b-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, activation="geglu",
    attn_pattern="local_global", local_window=16,
    attn_logit_softcap=50.0, final_logit_softcap=30.0, post_norms=True,
    param_dtype="float32", compute_dtype="float32", remat=False,
    optimizer="adamw",
)

ARCH = register_arch(ArchDef(
    arch_id="gemma2-9b", config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(long_context_ok=True),
    description="Gemma-2 9B (local+global alternating, logit softcap)",
    source="arXiv:2408.00118; hf",
))
