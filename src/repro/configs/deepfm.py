"""deepfm [arXiv:1703.04247]: n_sparse=39 embed_dim=10 mlp=400-400-400,
interaction=FM (pairwise via the sum-square identity) + linear terms."""

from repro.config.base import ArchDef, RecsysConfig, register_arch
from repro.configs.recsys_shapes import (RECSYS_SHAPES, field_vocabs,
                                         multi_hot_sizes, smoke_vocabs)

N_FIELDS = 39

CONFIG = RecsysConfig(
    arch_id="deepfm", model="deepfm",
    n_sparse=N_FIELDS, embed_dim=10, mlp_dims=(400, 400, 400),
    interaction="fm",
    field_vocabs=field_vocabs(N_FIELDS),
    multi_hot_sizes=multi_hot_sizes(N_FIELDS),
    item_vocab=1_000_000,
)

SMOKE = RecsysConfig(
    arch_id="deepfm-smoke", model="deepfm",
    n_sparse=5, embed_dim=6, mlp_dims=(24, 24), interaction="fm",
    field_vocabs=smoke_vocabs(5), multi_hot_sizes=multi_hot_sizes(5),
    item_vocab=500,
)

ARCH = register_arch(ArchDef(
    arch_id="deepfm", config=CONFIG, smoke_config=SMOKE, shapes=RECSYS_SHAPES,
    description="DeepFM CTR (FM + deep tower)",
    source="arXiv:1703.04247",
))
