"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B]: 24L d_model=1024 16H (kv=16)
d_ff=2816 vocab=151936, SwiGLU, QKV bias, tied embeddings."""

from repro.config.base import ArchDef, LMConfig, register_arch
from repro.configs.lm_shapes import lm_shapes

CONFIG = LMConfig(
    arch_id="qwen1.5-0.5b",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=2816, vocab_size=151936, activation="swiglu", qkv_bias=True,
    rope_theta=1_000_000.0, tie_embeddings=True, embedding_scale=False,
)

SMOKE = LMConfig(
    arch_id="qwen1.5-0.5b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=176, vocab_size=512, activation="swiglu", qkv_bias=True,
    embedding_scale=False, param_dtype="float32", compute_dtype="float32",
    remat=False, optimizer="adamw",
)

ARCH = register_arch(ArchDef(
    arch_id="qwen1.5-0.5b", config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(long_context_ok=False),
    description="Qwen1.5 0.5B dense decoder (QKV bias)",
    source="hf:Qwen/Qwen1.5-0.5B",
))
