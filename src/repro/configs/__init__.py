"""Assigned-architecture registry. Importing this package registers all 10
architectures (+ the paper's own index configurations) with
repro.config.base; resolve them via get_arch("<id>") / --arch <id>."""

from repro.configs import (bst, deepfm, dien, gemma2_9b, gemma_7b,
                           granite_moe, kimi_k2, meshgraphnet, qwen15_05b,
                           wide_deep)  # noqa: F401
from repro.configs import navix_paper  # noqa: F401
