"""gemma-7b [arXiv:2403.08295]: 28L d_model=3072 16H (GQA kv=16, i.e. MHA)
d_ff=24576 vocab=256000, GeGLU, head_dim=256, RoPE, tied embeddings with
sqrt(d) scaling."""

from repro.config.base import ArchDef, LMConfig, register_arch
from repro.configs.lm_shapes import lm_shapes

CONFIG = LMConfig(
    arch_id="gemma-7b",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000, activation="geglu",
    rope_theta=10000.0, tie_embeddings=True, embedding_scale=True,
)

SMOKE = LMConfig(
    arch_id="gemma-7b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=192, vocab_size=512, activation="geglu",
    param_dtype="float32", compute_dtype="float32", remat=False,
    optimizer="adamw",
)

ARCH = register_arch(ArchDef(
    arch_id="gemma-7b", config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(long_context_ok=False),
    description="Gemma 7B dense decoder (GeGLU, MHA, 256k vocab)",
    source="arXiv:2403.08295; hf",
))
