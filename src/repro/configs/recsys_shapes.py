"""The recsys-family shape set (shared by all 4 recsys archs)."""

from repro.config.base import ShapeSpec

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "recsys_train", {"batch": 65536}),
    ShapeSpec("serve_p99", "recsys_serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "recsys_serve", {"batch": 262144}),
    ShapeSpec("retrieval_cand", "recsys_retrieval",
              {"batch": 1, "n_candidates": 1_000_000}),
)

#: per-field vocabulary sizes: a realistic skewed mixture (a few huge id
#: spaces, many small categorical fields), Criteo-style. Total ~= 89M rows
#: for 40 fields -- the embedding store is the dominant parameter payload
#: and is row-sharded over the mesh in production. All sizes are multiples
#: of 16 so the model-axis row sharding divides them exactly.
_VOCAB_CYCLE = (10_000_000, 1_000_000, 100_000, 10_000, 1_024)


def field_vocabs(n_fields: int) -> tuple[int, ...]:
    return tuple(_VOCAB_CYCLE[i % len(_VOCAB_CYCLE)] for i in range(n_fields))


def multi_hot_sizes(n_fields: int, every: int = 5, hot: int = 10) -> tuple[int, ...]:
    """Every ``every``-th field is a multi-hot bag (EmbeddingBag path)."""
    return tuple(hot if i % every == every - 1 else 1 for i in range(n_fields))


def smoke_vocabs(n_fields: int) -> tuple[int, ...]:
    return tuple(100 + 13 * (i % 7) for i in range(n_fields))
