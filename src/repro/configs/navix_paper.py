"""The paper's own index/workload configurations (benchmark presets).

Laptop-scale analogues of the paper's four datasets (Table 2): same
metric mix and relative scale ordering; dimensions/sizes reduced so the
full heuristic sweep runs on CPU. Paper-scale settings (M=32 upper /
64 lower, efC=200) are preserved in PAPER_INDEX for reference and used in
the dry-run sizing of the distributed search cells."""

from repro.core.navix import NavixConfig

#: index hyperparameters exactly as the paper's evaluation (Section 5.1.5)
PAPER_INDEX = NavixConfig(m_u=32, ef_construction=200, sample_rate=0.05)

#: benchmark-scale index (same structure, laptop-sized)
BENCH_INDEX = NavixConfig(m_u=16, ef_construction=100, sample_rate=0.05)

#: dataset analogues: (name, n_vectors, dim, metric)
BENCH_DATASETS = (
    ("gist-like", 20_000, 96, "l2"),
    ("tiny-like", 40_000, 48, "l2"),
    ("arxiv-like", 25_000, 64, "cos"),
    ("wiki-like", 30_000, 64, "cos"),
)

#: the paper's selectivity sweep (Figure 8)
SELECTIVITIES = (0.9, 0.75, 0.5, 0.4, 0.3, 0.2, 0.1, 0.05, 0.03, 0.01)

#: correlated-workload selectivities (Table 5)
CORR_SELECTIVITIES = (0.229, 0.15, 0.099, 0.051, 0.01)
