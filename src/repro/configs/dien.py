"""dien [arXiv:1809.03672]: embed_dim=18 seq_len=100 gru_dim=108
mlp=200-80, interaction=AUGRU (interest evolution over the behavior
sequence with attentional update gates)."""

from repro.config.base import ArchDef, RecsysConfig, register_arch
from repro.configs.recsys_shapes import (RECSYS_SHAPES, field_vocabs,
                                         multi_hot_sizes, smoke_vocabs)

N_FIELDS = 8   # user/context categorical fields beside the behavior seq

CONFIG = RecsysConfig(
    arch_id="dien", model="dien",
    n_sparse=N_FIELDS, embed_dim=18, mlp_dims=(200, 80),
    interaction="augru", seq_len=100, gru_dim=108,
    field_vocabs=field_vocabs(N_FIELDS),
    multi_hot_sizes=multi_hot_sizes(N_FIELDS),
    item_vocab=5_000_000,
)

SMOKE = RecsysConfig(
    arch_id="dien-smoke", model="dien",
    n_sparse=4, embed_dim=6, mlp_dims=(24, 12), interaction="augru",
    seq_len=12, gru_dim=16,
    field_vocabs=smoke_vocabs(4), multi_hot_sizes=multi_hot_sizes(4),
    item_vocab=500,
)

ARCH = register_arch(ArchDef(
    arch_id="dien", config=CONFIG, smoke_config=SMOKE, shapes=RECSYS_SHAPES,
    description="DIEN (GRU interest extraction + AUGRU evolution)",
    source="arXiv:1809.03672 (unverified)",
))
