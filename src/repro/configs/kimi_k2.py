"""kimi-k2-1t-a32b [arXiv:2501.kimi2; paper-table, unverified]: 61L
d_model=7168 64H (GQA kv=8, head_dim=128) vocab=163840; MoE with 384
experts, top-8 routing, d_ff_expert=2048, +1 shared expert (K2 design).

~1T total / ~32B active parameters. Uses Adafactor: even fully sharded
over 512 chips, Adam's 2x fp32 state for 1T params (8TB) would exceed
16GB/chip HBM together with bf16 params + grads (see DESIGN.md)."""

from repro.config.base import ArchDef, LMConfig, MoEConfig, register_arch
from repro.configs.lm_shapes import lm_shapes

CONFIG = LMConfig(
    arch_id="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, vocab_size=163840, activation="swiglu",
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, capacity_factor=1.25),
    rope_theta=50000.0, tie_embeddings=False, embedding_scale=False,
    optimizer="adafactor",
)

SMOKE = LMConfig(
    arch_id="kimi-k2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=512, activation="swiglu",
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64, n_shared_experts=1),
    tie_embeddings=False, embedding_scale=False,
    param_dtype="float32", compute_dtype="float32", remat=False,
    optimizer="adamw",
)

ARCH = register_arch(ArchDef(
    arch_id="kimi-k2-1t-a32b", config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(long_context_ok=False),
    description="Kimi K2 trillion-param MoE (384e top-8 + shared)",
    source="arXiv:2501.kimi2 (paper-table; unverified)",
))
