"""wide-deep [arXiv:1606.07792]: n_sparse=40 embed_dim=32 mlp=1024-512-256,
interaction=concat, plus the linear "wide" path over sparse features."""

from repro.config.base import ArchDef, RecsysConfig, register_arch
from repro.configs.recsys_shapes import (RECSYS_SHAPES, field_vocabs,
                                         multi_hot_sizes, smoke_vocabs)

N_FIELDS = 40

CONFIG = RecsysConfig(
    arch_id="wide-deep", model="wide_deep",
    n_sparse=N_FIELDS, embed_dim=32, mlp_dims=(1024, 512, 256),
    interaction="concat",
    field_vocabs=field_vocabs(N_FIELDS),
    multi_hot_sizes=multi_hot_sizes(N_FIELDS),
    item_vocab=1_000_000,
)

SMOKE = RecsysConfig(
    arch_id="wide-deep-smoke", model="wide_deep",
    n_sparse=6, embed_dim=8, mlp_dims=(32, 16), interaction="concat",
    field_vocabs=smoke_vocabs(6), multi_hot_sizes=multi_hot_sizes(6),
    item_vocab=500,
)

ARCH = register_arch(ArchDef(
    arch_id="wide-deep", config=CONFIG, smoke_config=SMOKE,
    shapes=RECSYS_SHAPES,
    description="Wide & Deep CTR (concat interaction + wide linear path)",
    source="arXiv:1606.07792",
))
