"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base family]:
32L d_model=1536 24H (GQA kv=8) vocab=49155; MoE 40 experts top-8,
d_ff_expert=512, SwiGLU, tied embeddings."""

from repro.config.base import ArchDef, LMConfig, MoEConfig, register_arch
from repro.configs.lm_shapes import lm_shapes

CONFIG = LMConfig(
    arch_id="granite-moe-3b-a800m",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155, activation="swiglu",
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512,
                  n_shared_experts=0, capacity_factor=1.25),
    rope_theta=10000.0, tie_embeddings=True, embedding_scale=False,
    optimizer="adamw",
)

SMOKE = LMConfig(
    arch_id="granite-moe-smoke",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=2, head_dim=8,
    d_ff=64, vocab_size=256, activation="swiglu",
    moe=MoEConfig(n_experts=8, top_k=4, d_ff_expert=32, n_shared_experts=0),
    embedding_scale=False, param_dtype="float32", compute_dtype="float32",
    remat=False, optimizer="adamw",
)

ARCH = register_arch(ArchDef(
    arch_id="granite-moe-3b-a800m", config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(long_context_ok=False),
    description="IBM Granite 3B-A800M MoE (40e top-8)",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
