"""bst [arXiv:1905.06874]: Behavior Sequence Transformer (Alibaba):
embed_dim=32 seq_len=20 n_blocks=1 n_heads=8 mlp=1024-512-256,
interaction=transformer over [behavior sequence; target item]."""

from repro.config.base import ArchDef, RecsysConfig, register_arch
from repro.configs.recsys_shapes import (RECSYS_SHAPES, field_vocabs,
                                         multi_hot_sizes, smoke_vocabs)

N_FIELDS = 8

CONFIG = RecsysConfig(
    arch_id="bst", model="bst",
    n_sparse=N_FIELDS, embed_dim=32, mlp_dims=(1024, 512, 256),
    interaction="transformer-seq", seq_len=20, n_blocks=1, n_heads=8,
    field_vocabs=field_vocabs(N_FIELDS),
    multi_hot_sizes=multi_hot_sizes(N_FIELDS),
    item_vocab=5_000_000,
)

SMOKE = RecsysConfig(
    arch_id="bst-smoke", model="bst",
    n_sparse=4, embed_dim=16, mlp_dims=(32, 16),
    interaction="transformer-seq", seq_len=6, n_blocks=1, n_heads=4,
    field_vocabs=smoke_vocabs(4), multi_hot_sizes=multi_hot_sizes(4),
    item_vocab=500,
)

ARCH = register_arch(ArchDef(
    arch_id="bst", config=CONFIG, smoke_config=SMOKE, shapes=RECSYS_SHAPES,
    description="Behavior Sequence Transformer (1 block, 8 heads)",
    source="arXiv:1905.06874",
))
