"""meshgraphnet [arXiv:2010.03409]: encode-process-decode GNN, 15 processor
layers, d_hidden=128, sum aggregation, 2-layer MLPs.

Shape set spans three GNN regimes: full-batch small (Cora-like), sampled
minibatch on a large power-law graph (Reddit-like, fanout 15-10), full-batch
large (ogbn-products scale), and batched small graphs (molecules)."""

from repro.config.base import ArchDef, GNNConfig, ShapeSpec, register_arch

CONFIG = GNNConfig(
    arch_id="meshgraphnet",
    n_layers=15, d_hidden=128, aggregator="sum", mlp_layers=2,
    in_node_dim=16, in_edge_dim=4, out_dim=3,
)

SMOKE = GNNConfig(
    arch_id="meshgraphnet-smoke",
    n_layers=3, d_hidden=32, aggregator="sum", mlp_layers=2,
    in_node_dim=8, in_edge_dim=4, out_dim=3,
    compute_dtype="float32", remat=False,
)

SHAPES = (
    ShapeSpec("full_graph_sm", "graph_full",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
    ShapeSpec("minibatch_lg", "graph_minibatch",
              {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
               "fanout1": 15, "fanout2": 10, "d_feat": 602}),
    ShapeSpec("ogb_products", "graph_full",
              {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100}),
    ShapeSpec("molecule", "graph_batched",
              {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 16}),
)

ARCH = register_arch(ArchDef(
    arch_id="meshgraphnet", config=CONFIG, smoke_config=SMOKE, shapes=SHAPES,
    description="MeshGraphNet encode-process-decode (segment-sum MP)",
    source="arXiv:2010.03409",
))
