"""The LM-family shape set (shared by all 5 LM archs).

``decode_*`` / ``long_*`` lower serve-side ``decode_step`` (one new token
against a KV cache of seq_len), not train_step. ``long_500k`` requires
sub-quadratic attention; per the assignment it is run only for the hybrid
local+global arch (gemma2-9b) and recorded as a documented skip for the
pure full-attention archs (see DESIGN.md Section 5).
"""

from repro.config.base import ShapeSpec

FULL_ATTN_SKIP = ("long-context decode requires sub-quadratic attention; "
                  "this arch is pure full attention (every layer would need "
                  "the complete 512k-token KV cache) -- documented skip per "
                  "assignment instructions")


def lm_shapes(long_context_ok: bool) -> tuple[ShapeSpec, ...]:
    return (
        ShapeSpec("train_4k", "train",
                  {"seq_len": 4096, "global_batch": 256}),
        ShapeSpec("prefill_32k", "prefill",
                  {"seq_len": 32768, "global_batch": 32}),
        ShapeSpec("decode_32k", "decode",
                  {"seq_len": 32768, "global_batch": 128}),
        ShapeSpec("long_500k", "decode",
                  {"seq_len": 524288, "global_batch": 1},
                  skip_reason=None if long_context_ok else FULL_ATTN_SKIP),
    )
