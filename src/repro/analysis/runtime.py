"""Runtime verification: the invariants static analysis cannot see.

Three guards, all context managers, all designed to wrap an existing
test or benchmark without changing what it measures:

:class:`CompileCounter` hooks ``jax.monitoring``'s event-duration
listener stream and counts backend compiles
(``/jax/core/compile/backend_compile_duration`` fires once per XLA
compilation). The serving open-loop smoke and the ``db.execute``
batch-bucket reuse path are supposed to compile a fixed program set up
front and *zero* programs afterwards -- a recompile in the steady state
is the silent 100x regression NaviX's robustness argument forbids, and
this counter turns it into a test failure instead of a mystery latency
spike.

:class:`LockOrderMonitor` (via :func:`instrument_locks`) swaps
``threading.Lock`` for a recording wrapper, keeps the per-thread stack
of held locks, and adds an edge ``A -> B`` whenever B is acquired while
A is held. Locks are keyed by *creation site* (file:line), lockdep
style, so every instance of ``SubmissionQueue._lock`` is one node. A
cycle in the graph is a deadlock that merely hasn't fired yet; the
PR-6 herd/shutdown/straggler tests run under this monitor.

:class:`DonationGuard` (via :func:`guard_donation`) is the *temporal*
complement to navilint's static NX7xx donation rules. The static pass
proves no code path reads a donated buffer after the donating call; it
cannot see a second thread (or a later method call) touching lane
state while a donated chunk is in flight. The guard patches
``LaneBatch`` class-wide so that between ``step_async`` and
``step_wait`` (the donation window) the host mirrors are frozen
read-only and every device-state entry point (``admit``/``finalize``/
``evict``) raises :class:`DonationError`. JAX silently ignores
donation on CPU, so these bugs pass every CPU suite and corrupt
results only on TPU/GPU -- the guard makes the window a hard error on
any backend. The open-loop serving smoke runs under it.

jax is imported lazily so navilint's AST side stays importable (and
fast) in environments without an accelerator stack.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Iterator, Optional

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

# jax.monitoring has no deregistration API, so register ONE module-level
# listener the first time a counter starts and fan out to whichever
# counters are active.
_active_counters: set["CompileCounter"] = set()
_listener_installed = False
_listener_lock = threading.Lock()


def _ensure_listener() -> None:
    global _listener_installed
    with _listener_lock:
        if _listener_installed:
            return
        import jax

        def _on_event(event: str, duration: float, **kwargs) -> None:
            if _COMPILE_EVENT not in event:
                return
            for counter in tuple(_active_counters):
                counter._record(event)

        jax.monitoring.register_event_duration_secs_listener(_on_event)
        _listener_installed = True


class CompileCounter:
    """Counts XLA backend compiles while active.

    >>> with CompileCounter() as cc:
    ...     warmup()
    ...     cc.mark("steady")
    ...     serve_traffic()
    >>> cc.counts  # {"warmup": 3, "steady": 0}

    ``mark(phase)`` closes the current phase and opens a new one; the
    per-phase counts are the artifact the zero-recompile gate checks
    (steady phases must stay at exactly 0).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._phase = "warmup"
        self.counts: dict[str, int] = {"warmup": 0}
        self.total = 0

    def _record(self, event: str) -> None:
        with self._lock:
            self.counts[self._phase] = self.counts.get(self._phase, 0) + 1
            self.total += 1

    def mark(self, phase: str) -> None:
        """Begin a new counting phase (e.g. the post-warmup steady state)."""
        with self._lock:
            self._phase = phase
            self.counts.setdefault(phase, 0)

    def __enter__(self) -> "CompileCounter":
        _ensure_listener()
        _active_counters.add(self)
        return self

    def __exit__(self, *exc) -> None:
        _active_counters.discard(self)


# -- lock-order monitoring ---------------------------------------------------


class _InstrumentedLock:
    """Drop-in ``threading.Lock`` that reports acquisitions to a monitor.

    Also duck-types the private hooks ``threading.Condition`` calls
    (``_release_save``/``_acquire_restore``/``_is_owned``) by falling
    back to plain release/acquire, so ``Condition(instrumented_lock)``
    and the default ``Condition()`` both keep working under
    instrumentation.
    """

    def __init__(self, monitor: "LockOrderMonitor", site: str):
        self._inner = monitor._real_lock()
        self._monitor = monitor
        self._site = site

    def acquire(self, blocking: bool = True, timeout: float = -1):
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._monitor._acquired(self._site)
        return got

    def release(self) -> None:
        self._inner.release()
        self._monitor._released(self._site)

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    # Condition-compatibility fallbacks
    def _release_save(self):
        self.release()
        return None

    def _acquire_restore(self, state) -> None:
        self.acquire()

    def _is_owned(self) -> bool:
        # Lock (unlike RLock) has no owner notion; mirror Condition's
        # own fallback: if we can't acquire without blocking, somebody
        # (assumed: us) holds it.
        if self._inner.acquire(False):
            self._inner.release()
            return False
        return True


class LockOrderMonitor:
    """Builds the lock-acquisition graph and detects ordering cycles.

    Nodes are lock *classes* (creation file:line), edges mean "held A
    while acquiring B". :meth:`cycles` runs a DFS over the edge set;
    any cycle is a latent deadlock even if this run never interleaved
    the two threads badly.
    """

    def __init__(self) -> None:
        self._real_lock = threading.Lock  # captured before patching
        self._graph_lock = self._real_lock()
        self._held = threading.local()
        #: directed edges with one sample stack for the report
        self.edges: dict[tuple[str, str], int] = {}
        self.sites: set[str] = set()

    # -- wrapper callbacks ---------------------------------------------
    def _stack(self) -> list[str]:
        if not hasattr(self._held, "stack"):
            self._held.stack = []
        return self._held.stack

    def _acquired(self, site: str) -> None:
        stack = self._stack()
        with self._graph_lock:
            self.sites.add(site)
            for held in stack:
                if held != site:
                    edge = (held, site)
                    self.edges[edge] = self.edges.get(edge, 0) + 1
        stack.append(site)

    def _released(self, site: str) -> None:
        stack = self._stack()
        # release order need not be LIFO; drop the innermost match
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == site:
                del stack[i]
                break

    # -- analysis -------------------------------------------------------
    def cycles(self) -> list[list[str]]:
        """All elementary cycles reachable in the acquisition graph."""
        with self._graph_lock:
            adj: dict[str, list[str]] = {}
            for (a, b) in self.edges:
                adj.setdefault(a, []).append(b)
        out: list[list[str]] = []
        seen_cycles: set[tuple[str, ...]] = set()

        def dfs(node: str, path: list[str], on_path: set[str]) -> None:
            for nxt in adj.get(node, ()):
                if nxt in on_path:
                    cyc = path[path.index(nxt):] + [nxt]
                    # canonicalize rotation so each cycle reports once
                    body = cyc[:-1]
                    k = body.index(min(body))
                    key = tuple(body[k:] + body[:k])
                    if key not in seen_cycles:
                        seen_cycles.add(key)
                        out.append(cyc)
                elif nxt not in visited:
                    visited.add(nxt)
                    dfs(nxt, path + [nxt], on_path | {nxt})

        visited: set[str] = set()
        for start in sorted(adj):
            if start not in visited:
                visited.add(start)
                dfs(start, [start], {start})
        return out

    def report(self) -> dict:
        """JSON-able summary for bench artifacts."""
        return {
            "sites": len(self.sites),
            "edges": len(self.edges),
            "cycles": [" -> ".join(c) for c in self.cycles()],
        }


def _creation_site(depth: int = 2) -> str:
    import sys

    frame = sys._getframe(depth)
    # walk out of this module so the site names the caller's code
    while frame is not None and frame.f_globals.get(
            "__name__") == __name__:
        frame = frame.f_back
    if frame is None:  # pragma: no cover
        return "<unknown>"
    return f"{frame.f_code.co_filename.rsplit('/', 1)[-1]}:{frame.f_lineno}"


# -- donation-window guarding ------------------------------------------------


class DonationError(RuntimeError):
    """Lane state touched while a donated device chunk was in flight."""


class DonationGuard:
    """Counts donation windows and records any in-window violation.

    A *window* opens when ``step_async`` dispatches a chunk (the state
    buffers are donated: the pre-dispatch ``st`` is dead, the output
    handle is still being written) and closes at ``step_wait``. Inside
    the window the only legal host work is work that does not touch
    lane state -- queue expiry, future resolution, response building.
    """

    def __init__(self) -> None:
        self.windows = 0
        self.violations: list[str] = []

    def report(self) -> dict:
        """JSON-able summary for bench artifacts."""
        return {"windows": self.windows,
                "violations": list(self.violations)}

    def _violate(self, what: str) -> None:
        msg = (f"{what} while a donated device chunk is in flight: the "
               f"chunk owns the lane state until step_wait() (JAX "
               f"ignores donation on CPU, so this corrupts silently on "
               f"TPU/GPU) -- step_wait() first")
        self.violations.append(msg)
        raise DonationError(msg)


def _lane_mirrors(lanes) -> list:
    """The numpy host mirrors a LaneBatch owns (best effort: sharded
    backends may use non-numpy sel buffers; those are skipped)."""
    out = []
    for name in ("Qh", "selh", "sigh", "efsh"):
        arr = getattr(lanes, name, None)
        if arr is not None and hasattr(arr, "flags"):
            out.append(arr)
    return out


@contextlib.contextmanager
def guard_donation(guard: Optional[DonationGuard] = None
                   ) -> Iterator[DonationGuard]:
    """Patch :class:`~repro.serving.lanes.LaneBatch` so the donation
    window between ``step_async`` and ``step_wait`` is enforced at
    runtime: host mirrors go read-only (an ``admit`` writing ``Qh``
    trips numpy's writeable check even before the explicit raise) and
    ``admit``/``finalize``/``evict`` raise :class:`DonationError`.

    The patch is class-wide, so every LaneBatch created before or
    during the block is guarded; state is restored on exit even when
    the block raises.
    """
    from repro.serving.lanes import LaneBatch

    g = guard if guard is not None else DonationGuard()
    orig = {name: getattr(LaneBatch, name)
            for name in ("step_async", "step_wait", "admit",
                         "finalize", "evict")}
    frozen: dict[int, list] = {}      # id(lanes) -> [(arr, writeable)]

    def _freeze(self) -> None:
        saved = []
        for arr in _lane_mirrors(self):
            saved.append((arr, bool(arr.flags.writeable)))
            try:
                arr.flags.writeable = False
            except ValueError:      # pragma: no cover - foreign base
                saved.pop()
        frozen[id(self)] = saved

    def _thaw(self) -> None:
        for arr, writeable in frozen.pop(id(self), ()):
            try:
                arr.flags.writeable = writeable
            except ValueError:      # pragma: no cover
                pass

    def step_async(self, n_steps):
        orig["step_async"](self, n_steps)
        g.windows += 1
        _freeze(self)

    def step_wait(self):
        _thaw(self)
        return orig["step_wait"](self)

    def _gated(name):
        def method(self, *args, **kwargs):
            if getattr(self, "_live_pending", None) is not None:
                g._violate(f"LaneBatch.{name}()")
            return orig[name](self, *args, **kwargs)
        return method

    LaneBatch.step_async = step_async
    LaneBatch.step_wait = step_wait
    for name in ("admit", "finalize", "evict"):
        setattr(LaneBatch, name, _gated(name))
    try:
        yield g
    finally:
        for name, fn in orig.items():
            setattr(LaneBatch, name, fn)
        for lanes_id in list(frozen):
            for arr, writeable in frozen.pop(lanes_id, ()):
                try:
                    arr.flags.writeable = writeable
                except ValueError:      # pragma: no cover
                    pass


@contextlib.contextmanager
def instrument_locks(monitor: Optional[LockOrderMonitor] = None
                     ) -> Iterator[LockOrderMonitor]:
    """Patch ``threading.Lock`` so locks created inside the block feed
    *monitor*'s acquisition graph. Locks created before (or after) the
    block are plain locks -- instrument the code under test by creating
    its objects inside the ``with``.

    ``threading.Condition()``'s default RLock is left unpatched on
    purpose: it keeps executor/queue internals out of the graph unless
    the caller passes an instrumented lock explicitly.
    """
    mon = monitor if monitor is not None else LockOrderMonitor()

    def make_lock() -> _InstrumentedLock:
        return _InstrumentedLock(mon, _creation_site())

    orig = threading.Lock
    threading.Lock = make_lock  # type: ignore[misc,assignment]
    try:
        yield mon
    finally:
        threading.Lock = orig  # type: ignore[misc]
