"""Tracer-flow analysis (NX5xx): host-Python operations on traced values.

The lexical hot-path rules (NX1xx) only see code *inside* registered hot
functions. This pass instead starts from every JAX entry point -- ``jit``
decorations, ``shard_map`` bodies, ``pallas_call`` kernels -- in
``repro/core/``, ``repro/kernels/``, and ``repro/api/plan_compile.py``,
and propagates *traced-ness* through the transitive call closure:

* a root's parameters are traced except ``static_argnames`` /
  ``static_argnums``;
* a callee's parameter is traced when any resolvable call site passes a
  traced argument there; the join is monotone, so the fixpoint is small;
* function results are traced when any ``return`` expression is traced,
  and ``jnp.* / lax.* / jax.*`` library calls are traced by construction;
* values that are static *by structure* stay static: ``.shape/.ndim/
  .dtype/.size`` reads, attributes of static parameters (``params.ub``),
  ``x is None`` tests, ``len()``/``isinstance()`` results, and the
  truthiness of a ``*args`` tuple (``efsl[0] if efsl else None`` -- the
  element is traced, the emptiness test is not).

Three sink rules fire anywhere in the closure:

* **NX501** -- Python-level control flow (``if``/``while``/``assert``/
  conditional expressions) on a traced value: under ``jit`` this raises
  ``TracerBoolConversionError`` at trace time on real inputs, or worse,
  silently freezes a data-dependent decision at trace-time constants.
* **NX502** -- host conversion of a traced value (``np.*`` calls,
  ``.item()/.tolist()/.block_until_ready()``, ``int/float/bool(...)``,
  ``jax.device_get``): a device sync inside the traced region.
* **NX503** -- a traced value used as a *shape* (``jnp.zeros(n, ...)``,
  ``x.reshape(m, -1)``, ``jnp.broadcast_to(x, shp)`` where ``n/m/shp``
  are traced): shapes must be static under XLA; this retraces per value
  at best and fails to lower at worst.

Suppression kind: ``# navilint: trace-ok <reason>``.
"""

from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.callgraph import (
    TRACED_HOF_ARGS, FuncInfo, Project, attr_chain)

TRACE_BRANCH = "NX501"
TRACE_HOST = "NX502"
TRACE_SHAPE = "NX503"

#: traced-ness lattice: STATIC < CONTAINER (static tuple that may hold
#: traced elements, e.g. ``*args``) < TRACED
STATIC, CONTAINER, TRACED = 0, 1, 2

#: attribute reads that are static even on a traced value
_STATIC_ATTRS = frozenset(
    {"shape", "ndim", "dtype", "size", "weak_type", "sharding"})
#: builtins whose result is static regardless of argument traced-ness
_STATIC_BUILTINS = frozenset(
    {"len", "range", "isinstance", "issubclass", "hasattr", "type",
     "id", "repr", "str", "format", "print", "enumerate"})
#: library roots whose call results are traced arrays
_TRACED_ROOTS = frozenset({"jnp", "jax", "lax", "pl", "plgpu", "pltpu"})
#: library helpers whose result is static even on traced input
#: (``jnp.ndim(x)`` is a Python int, not a tracer)
_STATIC_LIB_FNS = frozenset(
    {"ndim", "shape", "size", "result_type", "issubdtype",
     "iscomplexobj"})
#: numpy aliases: calling these on a traced value is a host conversion
_NUMPY_ROOTS = ("np", "numpy", "onp")
_SYNC_METHODS = ("item", "tolist", "block_until_ready", "copy_to_host",
                 "__array__")
#: jnp constructors whose FIRST positional argument is a shape
_SHAPE_ARG0 = frozenset({"zeros", "ones", "empty", "full"})


def _root_scope(rel_path: str) -> bool:
    return (rel_path.startswith("repro/core/")
            or rel_path.startswith("repro/kernels/")
            or rel_path == "repro/api/plan_compile.py")


def _property_is_static(fn: ast.FunctionDef) -> bool:
    """True for one-expression properties that compute from static
    structure only (``HnswGraph.n -> self.vectors.shape[0]``)."""
    body = [s for s in fn.body
            if not (isinstance(s, ast.Expr)
                    and isinstance(s.value, ast.Constant))]
    if len(body) != 1 or not isinstance(body[0], ast.Return):
        return False

    def ok(e: ast.AST) -> bool:
        if e is None or isinstance(e, ast.Constant):
            return True
        if isinstance(e, ast.Attribute):
            return e.attr in _STATIC_ATTRS
        if isinstance(e, ast.Subscript):
            return ok(e.value) and ok(e.slice)
        if isinstance(e, ast.BinOp):
            return ok(e.left) and ok(e.right)
        if isinstance(e, ast.UnaryOp):
            return ok(e.operand)
        if isinstance(e, ast.Compare):
            return ok(e.left) and all(ok(c) for c in e.comparators)
        if isinstance(e, ast.Tuple):
            return all(ok(x) for x in e.elts)
        if isinstance(e, ast.Call):
            chain = attr_chain(e.func)
            return (len(chain) == 1
                    and chain[0] in (_STATIC_BUILTINS
                                     | {"int", "min", "max"})
                    and all(ok(a) for a in e.args))
        return False

    return ok(body[0].value)


def _static_property_names(project: Project) -> frozenset:
    """Property names that are static in *every* class defining them."""
    static: set = set()
    traced: set = set()
    for fi in project.iter_funcs():
        if fi.cls is None:
            continue
        is_prop = any(
            (isinstance(d, ast.Name) and d.id == "property")
            or (isinstance(d, ast.Attribute)
                and d.attr == "cached_property")
            for d in fi.node.decorator_list)
        if not is_prop:
            continue
        if _property_is_static(fi.node):
            static.add(fi.node.name)
        else:
            traced.add(fi.node.name)
    return frozenset(static - traced)


def _init_params(fi: FuncInfo) -> dict:
    env: dict[str, int] = {}
    statics = fi.static_names
    for i, p in enumerate(fi.params):
        if fi.root_kind == "jit" and (p in statics or i in fi.static_nums):
            env[p] = STATIC
        else:
            env[p] = TRACED
    for p in fi.kwonly:
        env[p] = STATIC if (fi.root_kind == "jit" and p in statics) \
            else TRACED
    if fi.vararg:
        env[fi.vararg] = CONTAINER
    return env


class _FnFlow:
    """One traversal of a closure member under a parameter state."""

    def __init__(self, pass_, fi: FuncInfo, params: dict, report):
        self.pass_ = pass_
        self.fi = fi
        self.env = dict(params)
        self.report = report       # emit callback or None (summary mode)
        self.returns_traced = False
        self.span = (fi.node.lineno, fi.node.lineno)

    # -- expression traced-ness ----------------------------------------
    def traced(self, node: ast.AST) -> int:
        if node is None or isinstance(node, (ast.Constant, ast.Lambda,
                                             ast.JoinedStr)):
            return STATIC
        if isinstance(node, ast.Name):
            return self.env.get(node.id, STATIC)
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS \
                    or node.attr in self.pass_.static_props:
                return STATIC
            chain = attr_chain(node)
            if chain:
                key = ".".join(chain)
                if key in self.env:
                    return self.env[key]
                base = self.env.get(chain[0], STATIC)
                # attributes of a static value (params.ub) are static;
                # attributes of a traced pytree are traced leaves
                return TRACED if base == TRACED else STATIC
            return self.traced(node.value)
        if isinstance(node, ast.Subscript):
            base = self.traced(node.value)
            if base == CONTAINER:
                return TRACED
            return base
        if isinstance(node, ast.Starred):
            return self.traced(node.value)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            vals = [self.traced(e) for e in node.elts]
            if any(v == TRACED for v in vals):
                return CONTAINER
            return STATIC
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot))
                   for op in node.ops):
                return STATIC
            vals = [self.traced(node.left)] + [
                self.traced(c) for c in node.comparators]
            return TRACED if TRACED in vals else STATIC
        if isinstance(node, ast.BoolOp):
            vals = [self.traced(v) for v in node.values]
            return max(vals) if vals else STATIC
        if isinstance(node, ast.BinOp):
            return max(self.traced(node.left), self.traced(node.right))
        if isinstance(node, ast.UnaryOp):
            return self.traced(node.operand)
        if isinstance(node, ast.IfExp):
            self.check_test(node.test, node)
            return max(self.traced(node.body), self.traced(node.orelse))
        if isinstance(node, ast.NamedExpr):
            v = self.traced(node.value)
            if isinstance(node.target, ast.Name):
                self.env[node.target.id] = v
            return v
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            vals = [self.traced(g.iter) for g in node.generators]
            return CONTAINER if TRACED in vals or CONTAINER in vals \
                else STATIC
        if isinstance(node, ast.Call):
            return self.call(node)
        if isinstance(node, ast.Dict):
            vals = [self.traced(v) for v in node.values if v is not None]
            return CONTAINER if TRACED in vals else STATIC
        return STATIC

    # -- calls ----------------------------------------------------------
    def _arg_vals(self, node: ast.Call) -> list:
        return ([self.traced(a) for a in node.args]
                + [self.traced(kw.value) for kw in node.keywords])

    def call(self, node: ast.Call) -> int:
        chain = attr_chain(node.func)
        arg_vals = self._arg_vals(node)
        any_traced = TRACED in arg_vals
        # sinks first ---------------------------------------------------
        if self.report is not None:
            self._call_sinks(node, chain, arg_vals, any_traced)
        # library results -----------------------------------------------
        if chain:
            root = chain[0]
            if root in _NUMPY_ROOTS:
                return STATIC          # host now (and flagged above)
            if root in _TRACED_ROOTS:
                if chain[-1] in _STATIC_LIB_FNS:
                    return STATIC
                return TRACED
            if len(chain) == 1 and root in _STATIC_BUILTINS:
                return STATIC
            if len(chain) == 1 and root in ("int", "float", "bool"):
                return STATIC          # concretized (flagged above)
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in _SYNC_METHODS):
            return STATIC
        # resolved callees ----------------------------------------------
        callee = self.pass_.resolve_call(self.fi, node)
        if callee is not None:
            self.pass_.observe_edge(self.fi, callee, node, self)
            if callee in self.pass_.closure:
                return TRACED if self.pass_.returns_traced.get(
                    callee, False) else STATIC
        # method on a traced object, or unknown helper fed traced args
        if (isinstance(node.func, ast.Attribute)
                and self.traced(node.func.value) == TRACED):
            return TRACED
        return TRACED if any_traced else STATIC

    def _call_sinks(self, node: ast.Call, chain: list, arg_vals: list,
                    any_traced: bool) -> None:
        dotted = ".".join(chain)
        if chain and chain[0] in _NUMPY_ROOTS and any_traced:
            self.emit(TRACE_HOST, node,
                      f"'{dotted}' pulls a traced value to host inside "
                      f"the jit closure (device sync / trace break)")
            return
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in _SYNC_METHODS
                and self.traced(node.func.value) == TRACED):
            self.emit(TRACE_HOST, node,
                      f"'.{node.func.attr}()' on a traced value inside "
                      f"the jit closure")
            return
        if dotted in ("jax.device_get", "device_get") and any_traced:
            self.emit(TRACE_HOST, node,
                      "'jax.device_get' on a traced value inside the "
                      "jit closure")
            return
        if (len(chain) == 1 and chain[0] in ("int", "float", "bool")
                and node.args and self.traced(node.args[0]) == TRACED):
            self.emit(TRACE_HOST, node,
                      f"'{chain[0]}(...)' concretizes a traced value "
                      f"(TracerBoolConversionError under jit)")
            return
        # shape sinks ---------------------------------------------------
        if len(chain) >= 2 and chain[-2] in ("jnp", "numpy"):
            fn = chain[-1]
            if (fn in _SHAPE_ARG0 and node.args
                    and self.traced(node.args[0]) == TRACED):
                self.emit(TRACE_SHAPE, node,
                          f"traced value as the shape of 'jnp.{fn}': "
                          f"XLA shapes are static; this cannot lower")
            elif (fn in ("reshape", "broadcast_to", "tile")
                  and len(node.args) >= 2
                  and self.traced(node.args[1]) == TRACED):
                self.emit(TRACE_SHAPE, node,
                          f"traced value as the target shape of "
                          f"'jnp.{fn}'")
            elif fn == "arange" and any(
                    self.traced(a) == TRACED for a in node.args):
                self.emit(TRACE_SHAPE, node,
                          "traced bound in 'jnp.arange': the result "
                          "shape would be data-dependent")
        elif (isinstance(node.func, ast.Attribute)
              and node.func.attr == "reshape"
              and self.traced(node.func.value) == TRACED
              and any(self.traced(a) == TRACED for a in node.args)):
            self.emit(TRACE_SHAPE, node,
                      "traced value as a '.reshape' dimension")

    # -- statements -----------------------------------------------------
    def check_test(self, test: ast.AST, node: ast.AST) -> None:
        if self.report is not None and self.traced(test) == TRACED:
            self.emit(TRACE_BRANCH, node,
                      "Python control flow on a traced value: under jit "
                      "this either raises at trace time or freezes the "
                      "decision at trace-time constants -- use lax.cond/"
                      "lax.select/jnp.where")

    def assign(self, target: ast.AST, value_tr: int,
               value: Optional[ast.AST]) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = max(
                value_tr, self.env.get(target.id, STATIC)) \
                if self.pass_.widen else value_tr
        elif isinstance(target, ast.Attribute):
            chain = attr_chain(target)
            if chain:
                self.env[".".join(chain)] = value_tr
        elif isinstance(target, (ast.Tuple, ast.List)):
            elts = value.elts if isinstance(
                value, (ast.Tuple, ast.List)) and len(value.elts) == len(
                    target.elts) else None
            for i, t in enumerate(target.elts):
                if elts is not None:
                    self.assign(t, self.traced(elts[i]), elts[i])
                else:
                    tr = TRACED if value_tr in (TRACED, CONTAINER) \
                        else STATIC
                    self.assign(t, tr, None)
        elif isinstance(target, ast.Starred):
            self.assign(target.value, value_tr, None)

    def walk_body(self, body: list) -> None:
        for stmt in body:
            self.span = (stmt.lineno, stmt.end_lineno or stmt.lineno)
            self.stmt(stmt)

    def stmt(self, node: ast.stmt) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return                      # nested defs analyzed separately
        if isinstance(node, ast.Return):
            if node.value is not None and self.traced(
                    node.value) in (TRACED, CONTAINER):
                self.returns_traced = True
            return
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            value = getattr(node, "value", None)
            if value is None:
                return
            tr = self.traced(value)
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                self.assign(t, tr, value)
            return
        if isinstance(node, ast.AugAssign):
            tr = max(self.traced(node.target), self.traced(node.value))
            self.assign(node.target, tr, None)
            return
        if isinstance(node, ast.Expr):
            self.traced(node.value)
            return
        if isinstance(node, ast.If):
            self.check_test(node.test, node)
            self.walk_nested(node.body)
            self.walk_nested(node.orelse)
            return
        if isinstance(node, ast.While):
            self.check_test(node.test, node)
            self.walk_nested(node.body)
            self.walk_nested(node.orelse)
            return
        if isinstance(node, ast.Assert):
            self.check_test(node.test, node)
            return
        if isinstance(node, ast.For):
            it = self.traced(node.iter)
            tr = TRACED if it in (TRACED, CONTAINER) else STATIC
            self.assign(node.target, tr, None)
            self.walk_nested(node.body)
            self.walk_nested(node.orelse)
            return
        if isinstance(node, ast.With):
            for item in node.items:
                tr = self.traced(item.context_expr)
                if item.optional_vars is not None:
                    self.assign(item.optional_vars, tr, None)
            self.walk_nested(node.body)
            return
        if isinstance(node, ast.Try):
            self.walk_nested(node.body)
            for h in node.handlers:
                self.walk_nested(h.body)
            self.walk_nested(node.orelse)
            self.walk_nested(node.finalbody)
            return
        if isinstance(node, (ast.Raise,)):
            if node.exc is not None:
                self.traced(node.exc)
            return
        # default: evaluate child expressions for sink detection
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self.traced(child)

    def walk_nested(self, body: list) -> None:
        outer = self.span
        self.walk_body(body)
        self.span = outer

    def run(self) -> None:
        # two passes over the body pick up loop-carried traced-ness
        self.walk_body(self.fi.node.body)
        if self.report is None:
            self.walk_body(self.fi.node.body)

    def emit(self, rule: str, node: ast.AST, message: str) -> None:
        self.report(rule, self.fi.module, node, self.span, message)


class TracerFlowPass:
    """Fixpoint over the traced-call closure, then one reporting pass."""

    def __init__(self, project: Project, emit):
        self.project = project
        self.emit = emit
        self.closure: dict[FuncInfo, dict] = {}
        self.returns_traced: dict[FuncInfo, bool] = {}
        self.callers: dict[FuncInfo, set] = {}
        self.widen = True
        self.static_props = _static_property_names(project)
        self._work: list[FuncInfo] = []

    # -- closure membership --------------------------------------------
    def _enter(self, fi: FuncInfo, params: dict) -> None:
        if fi not in self.closure:
            self.closure[fi] = dict(params)
            self.returns_traced.setdefault(fi, False)
            self._work.append(fi)
            self._enter_nested(fi)

    def _enter_nested(self, fi: FuncInfo) -> None:
        """Functions defined lexically inside a closure member run
        traced (loop bodies, shard_map locals, returned step closures)."""
        prefix = f"{fi.qualname}.<locals>."
        for qual, sub in fi.module.funcs.items():
            if qual.startswith(prefix) and "<locals>" not in qual[
                    len(prefix):]:
                env = {p: TRACED for p in sub.params}
                env.update({p: TRACED for p in sub.kwonly})
                if sub.vararg:
                    env[sub.vararg] = CONTAINER
                self._enter(sub, env)

    def resolve_call(self, caller: FuncInfo, node: ast.Call):
        return self.project.resolve(
            caller.module, caller.qualname, node.func)

    def observe_edge(self, caller: FuncInfo, callee: FuncInfo,
                     node: ast.Call, flow: _FnFlow) -> None:
        if callee is caller:
            return
        binding = callee.bind(node)
        env = {}
        for p, expr in binding.items():
            env[p] = flow.traced(expr)
        # unbound params (defaults, *args call sites) stay static
        for p in callee.params + callee.kwonly:
            env.setdefault(p, STATIC)
        if callee.vararg:
            env[callee.vararg] = CONTAINER
        if all(v == STATIC for v in env.values()) \
                and callee not in self.closure:
            return                      # host-only edge: not traced
        self.callers.setdefault(callee, set()).add(caller)
        old = self.closure.get(callee)
        if old is None:
            self._enter(callee, env)
            return
        changed = False
        for p, v in env.items():
            if v > old.get(p, STATIC):
                old[p] = v
                changed = True
        if changed and callee not in self._work:
            self._work.append(callee)

    def _hof_edges(self, fi: FuncInfo) -> None:
        """Name arguments passed to lax/jax higher-order entry points
        from inside the closure run traced with all params traced."""
        for call in ast.walk(fi.node):
            if not isinstance(call, ast.Call):
                continue
            chain = attr_chain(call.func)
            if not chain or chain[-1] not in TRACED_HOF_ARGS:
                continue
            if chain[0] not in _TRACED_ROOTS and len(chain) > 1:
                continue
            for pos in TRACED_HOF_ARGS[chain[-1]]:
                if pos < len(call.args):
                    target = self.project.resolve(
                        fi.module, fi.qualname, call.args[pos])
                    if target is not None:
                        env = {p: TRACED for p in target.params}
                        if target.vararg:
                            env[target.vararg] = CONTAINER
                        self._enter(target, env)

    # -- driver ---------------------------------------------------------
    def run(self) -> None:
        for fi in self.project.iter_funcs():
            if fi.root_kind and _root_scope(fi.module.rel_path):
                self._enter(fi, _init_params(fi))
        rounds = 0
        while self._work and rounds < 4000:
            rounds += 1
            fi = self._work.pop()
            flow = _FnFlow(self, fi, self.closure[fi], report=None)
            flow.run()
            self._hof_edges(fi)
            if flow.returns_traced and not self.returns_traced.get(fi):
                self.returns_traced[fi] = True
                for caller in self.callers.get(fi, ()):
                    if caller not in self._work:
                        self._work.append(caller)
        # reporting pass under the stable state
        self.widen = False
        for fi in sorted(self.closure,
                         key=lambda f: (f.module.path, f.node.lineno)):
            _FnFlow(self, fi, self.closure[fi], report=self.emit).run()


def check(project: Project, emit) -> None:
    """Run the tracer-flow pass; findings go through ``emit``."""
    TracerFlowPass(project, emit).run()
