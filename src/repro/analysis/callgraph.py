"""Project-wide call graph for navilint's flow passes.

navilint's lexical rules see one file at a time; the flow families
(NX5xx tracer-flow, NX6xx key coverage, NX7xx donation safety, and the
interprocedural NX201 lock proof) need to know *who calls whom* across
the whole sweep. This module parses every swept file once into a
:class:`Project` -- modules, function definitions under their
``__qualname__`` spelling (nested functions use ``<locals>``, matching
the hot-path registry), import aliases -- and resolves call expressions
to definitions:

* ``name(...)``            -> enclosing scopes, then module level, then
  ``from m import name`` targets in other swept modules;
* ``self.method(...)``     -> the enclosing class's method;
* ``alias.attr(...)``      -> ``import repro.core.x as alias`` /
  ``from repro.core import x`` module aliases.

Resolution is deliberately conservative: anything it cannot prove
(library calls, duck-typed dispatch, getattr) resolves to ``None`` and
the flow passes fall back to their safe default for that edge.

The module also extracts the JAX *entry-point* metadata the passes key
on: ``jit`` decorations (including ``functools.partial(jax.jit, ...)``),
``static_argnames``/``static_argnums``, and ``donate_argnums`` --
including the conditional ``(3,) if donate else ()`` spelling the
sharded program builders use (recorded as ``donate_cond="donate"``).
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Optional

#: higher-order jax/lax entry points whose function-valued arguments run
#: traced (positions of those arguments per callee name)
TRACED_HOF_ARGS: dict[str, tuple[int, ...]] = {
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "scan": (0,),
    "cond": (1, 2),
    "switch": (1, 2, 3, 4, 5),
    "vmap": (0,),
    "checkpoint": (0,),
    "remat": (0,),
    "custom_jvp": (0,),
    "custom_vjp": (0,),
    "associative_scan": (0,),
}


@dataclasses.dataclass(eq=False)      # identity hash: one node, one info
class FuncInfo:
    """One function definition, with its jit/donation metadata."""
    qualname: str
    module: "ModuleInfo"
    node: ast.AST                      # FunctionDef | AsyncFunctionDef
    cls: Optional[str] = None          # enclosing class qualname
    root_kind: Optional[str] = None    # "jit" | "shard_map" | "pallas"
    static_names: frozenset = frozenset()
    static_nums: frozenset = frozenset()
    donate_idx: tuple = ()             # donated positional indices
    donate_cond: Optional[str] = None  # name gating donation (IfExp test)

    @property
    def params(self) -> list[str]:
        a = self.node.args
        return [p.arg for p in a.posonlyargs + a.args]

    @property
    def kwonly(self) -> list[str]:
        return [p.arg for p in self.node.args.kwonlyargs]

    @property
    def vararg(self) -> Optional[str]:
        va = self.node.args.vararg
        return va.arg if va is not None else None

    def param_index(self, name: str) -> Optional[int]:
        try:
            return self.params.index(name)
        except ValueError:
            return None

    def bind(self, call: ast.Call) -> dict[str, ast.expr]:
        """Map parameter names to call-site argument expressions (best
        effort; ``*args``/``**kwargs`` at the call site stop binding).
        For a method called through an attribute (``obj.m(a)``) the
        receiver is implicit, so ``self``/``cls`` is skipped."""
        out: dict[str, ast.expr] = {}
        params = self.params
        if (self.cls is not None and params
                and params[0] in ("self", "cls")
                and isinstance(call.func, ast.Attribute)):
            params = params[1:]
        for i, arg in enumerate(call.args):
            if isinstance(arg, ast.Starred):
                break
            if i < len(params):
                out[params[i]] = arg
        for kw in call.keywords:
            if kw.arg is not None:
                out[kw.arg] = kw.value
        return out


def attr_chain(node: ast.AST) -> list[str]:
    """``a.b.c`` -> ["a", "b", "c"]; [] when not a plain name chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return []


def _const_strs(node: ast.AST) -> frozenset:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return frozenset([node.value])
    if isinstance(node, (ast.Tuple, ast.List)):
        return frozenset(e.value for e in node.elts
                         if isinstance(e, ast.Constant))
    return frozenset()


def _const_ints(node: ast.AST) -> tuple:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant))
    return ()


def parse_jit_kwargs(call: ast.Call) -> dict:
    """static/donate metadata from a ``jit(...)``/``partial(jit, ...)``
    call's keywords. ``donate_argnums=(3,) if donate else ()`` records
    the body tuple plus the gating name in ``donate_cond``."""
    out = {"static_names": frozenset(), "static_nums": frozenset(),
           "donate_idx": (), "donate_cond": None}
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            out["static_names"] = _const_strs(kw.value)
        elif kw.arg == "static_argnums":
            out["static_nums"] = frozenset(_const_ints(kw.value))
        elif kw.arg in ("donate_argnums", "donate_argnames"):
            val = kw.value
            if (isinstance(val, ast.IfExp)
                    and isinstance(val.test, ast.Name)):
                out["donate_cond"] = val.test.id
                val = val.body
            out["donate_idx"] = _const_ints(val)
    return out


def _is_jit_chain(chain: list) -> bool:
    return bool(chain) and chain[-1] == "jit" and (
        len(chain) == 1 or chain[0] in ("jax", "functools"))


def _is_partial_jit(call: ast.Call) -> bool:
    chain = attr_chain(call.func)
    return (bool(chain) and chain[-1] == "partial" and call.args
            and _is_jit_chain(attr_chain(call.args[0])))


class ModuleInfo:
    """One parsed file: definitions under registry-style qualnames plus
    the import aliases call resolution needs."""

    def __init__(self, path: str, rel_path: str, tree: ast.Module):
        self.path = path
        self.rel_path = rel_path
        self.tree = tree
        self.name = self._module_name(rel_path)
        self.funcs: dict[str, FuncInfo] = {}
        self.classes: dict[str, ast.ClassDef] = {}
        self.import_alias: dict[str, str] = {}
        self.from_names: dict[str, tuple] = {}
        self._collect_imports(tree)
        self._collect_defs(tree, qual="", cls=None)

    @staticmethod
    def _module_name(rel_path: str) -> str:
        stem = rel_path[:-3] if rel_path.endswith(".py") else rel_path
        if stem.endswith("/__init__"):
            stem = stem[: -len("/__init__")]
        return stem.replace("/", ".")

    def _collect_imports(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.import_alias[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    if a.name != "*":
                        self.from_names[a.asname or a.name] = (
                            node.module, a.name)

    def _collect_defs(self, node: ast.AST, qual: str,
                      cls: Optional[str]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{qual}{child.name}"
                info = FuncInfo(q, self, child, cls=cls)
                self._apply_decorators(info)
                self.funcs[q] = info
                self._collect_defs(child, f"{q}.<locals>.", cls=None)
            elif isinstance(child, ast.ClassDef):
                cq = f"{qual}{child.name}"
                self.classes[cq] = child
                self._collect_defs(child, f"{cq}.", cls=cq)
            else:
                self._collect_defs(child, qual, cls)

    def _apply_decorators(self, info: FuncInfo) -> None:
        for dec in info.node.decorator_list:
            if _is_jit_chain(attr_chain(dec)):
                info.root_kind = "jit"
            elif isinstance(dec, ast.Call):
                if _is_partial_jit(dec) or _is_jit_chain(
                        attr_chain(dec.func)):
                    info.root_kind = "jit"
                    for k, v in parse_jit_kwargs(dec).items():
                        setattr(info, k, v)


class Project:
    """Every swept module, with cross-module call resolution."""

    def __init__(self, modules: list):
        self.modules: list[ModuleInfo] = list(modules)
        self.by_name: dict[str, ModuleInfo] = {}
        for m in self.modules:
            self.by_name.setdefault(m.name, m)
        self._mark_call_roots()

    # -- construction ---------------------------------------------------
    def _mark_call_roots(self) -> None:
        """Functions passed (by name) into shard_map / pallas_call /
        jax.jit calls are traced entry points too."""
        for mod in self.modules:
            for fi in list(mod.funcs.values()):
                for call in ast.walk(fi.node):
                    if not isinstance(call, ast.Call):
                        continue
                    chain = attr_chain(call.func)
                    if not chain or not call.args:
                        continue
                    target = self.resolve(mod, fi.qualname, call.args[0])
                    if target is None:
                        continue
                    last = chain[-1]
                    if last in ("shard_map", "_shard_map"):
                        target.root_kind = target.root_kind or "shard_map"
                    elif last == "pallas_call":
                        target.root_kind = target.root_kind or "pallas"
                    elif _is_jit_chain(chain):
                        target.root_kind = target.root_kind or "jit"
                        for k, v in parse_jit_kwargs(call).items():
                            if v:
                                setattr(target, k, v)

    # -- resolution -----------------------------------------------------
    def _scope_prefixes(self, qual: str) -> list:
        """Lexical scopes a name is looked up in, innermost first."""
        parts = qual.split(".<locals>.")
        out = []
        for i in range(len(parts), 0, -1):
            out.append(".<locals>.".join(parts[:i]) + ".<locals>.")
        out.append("")
        return out

    def resolve(self, mod: ModuleInfo, caller_qual: str,
                expr: ast.AST) -> Optional[FuncInfo]:
        """Resolve a callee expression to its definition, or ``None``."""
        if isinstance(expr, ast.Name):
            for prefix in self._scope_prefixes(caller_qual):
                hit = mod.funcs.get(prefix + expr.id)
                if hit is not None:
                    return hit
            src = mod.from_names.get(expr.id)
            if src is not None:
                target = self.by_name.get(src[0])
                if target is not None:
                    return target.funcs.get(src[1])
            return None
        if isinstance(expr, ast.Attribute) and isinstance(
                expr.value, ast.Name):
            base, attr = expr.value.id, expr.attr
            if base == "self":
                caller = mod.funcs.get(caller_qual)
                if caller is not None and caller.cls:
                    return mod.funcs.get(f"{caller.cls}.{attr}")
                return None
            target_name = None
            if base in mod.import_alias:
                target_name = mod.import_alias[base]
            elif base in mod.from_names:
                m, a = mod.from_names[base]
                target_name = f"{m}.{a}"
            if target_name is not None:
                target = self.by_name.get(target_name)
                if target is not None:
                    return target.funcs.get(attr)
        return None

    def iter_funcs(self):
        for mod in self.modules:
            yield from mod.funcs.values()


def build_project(parsed: list) -> Project:
    """``parsed``: iterable of (path, rel_path, ast.Module)."""
    return Project([ModuleInfo(p, rel, tree) for p, rel, tree in parsed])


# -- class-local call sites (interprocedural NX201) -------------------------

@dataclasses.dataclass
class MethodCallSite:
    caller: str                 # enclosing method name
    lexical_locks: frozenset    # self.<lock> With-blocks around the call


def class_call_sites(cls: ast.ClassDef
                     ) -> tuple[dict[str, list], set]:
    """Intra-class ``self.m(...)`` call sites with the ``with
    self.<lock>`` context lexically around each, plus the set of methods
    that *escape* -- referenced as ``self.m`` in non-call position
    (callbacks, thread targets), where no caller-side lock proof holds.
    """
    sites: dict[str, list] = {}
    escapes: set = set()

    def walk(node: ast.AST, held: frozenset, method: str) -> None:
        for child in ast.iter_child_nodes(node):
            child_held = held
            if isinstance(child, ast.With):
                acquired = set()
                for item in child.items:
                    ce = item.context_expr
                    if (isinstance(ce, ast.Attribute)
                            and isinstance(ce.value, ast.Name)
                            and ce.value.id == "self"):
                        acquired.add(ce.attr)
                child_held = held | frozenset(acquired)
            if (isinstance(child, ast.Call)
                    and isinstance(child.func, ast.Attribute)
                    and isinstance(child.func.value, ast.Name)
                    and child.func.value.id == "self"):
                sites.setdefault(child.func.attr, []).append(
                    MethodCallSite(method, child_held))
                for sub in child.args + [kw.value for kw in child.keywords]:
                    walk(sub, child_held, method)
                continue
            walk_refs_shallow(child)
            walk(child, child_held, method)

    def walk_refs_shallow(node: ast.AST) -> None:
        # a bare `self.m` that is not the func of a Call escapes
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and isinstance(node.ctx, ast.Load)):
            escapes.add(node.attr)

    for item in cls.body:
        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            walk(item, frozenset(), item.name)
    return sites, escapes
