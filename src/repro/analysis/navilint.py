"""navilint: repo-native static analysis for the invariants reviews kept
catching by hand.

Three rule families, all AST-level and lexical (no imports, no
execution -- safe to run on any tree, fast enough for a pre-commit):

**Hot-loop purity (NX1xx)** -- functions in the hot-path registry
(:mod:`repro.analysis.registry`) or marked ``# navilint: hot`` may not
contain host-sync forms (``np.*`` calls, ``.item()``, ``.tolist()``,
``.block_until_ready()``, ``jax.device_get``) or the CPU-hostile device
ops PR 3 purged from the engine loop (``lax.scatter*``, ``lax.top_k``,
``.at[...].set/add/...``). ``time.time()`` is banned *everywhere*
(deadline/duration math must be monotonic; wall clocks step under NTP).

**Lock discipline (NX2xx)** -- a shared field annotated at its
``__init__`` assignment with ``# guarded-by: <lock>`` must only be read
or written lexically inside ``with self.<lock>:`` (or in a method
annotated ``# navilint: lock-held <lock>``, for helpers documented as
called with the lock held). This is exactly the bug class of the PR-6
review fixes: the ``gauges()`` deque race and the woken-putter depth
race were both unlocked accesses to fields everyone "knew" were guarded.

**Suppression hygiene (NX3xx)** -- every suppression carries a reason
and must actually suppress something: a stale ``# navilint: sync-ok``
left behind after the sync call moved is itself a finding, so the
annotation layer can never drift from the code.

Plus a small built-in hygiene family (NX4xx: unused imports, bare
``except:``) so the tree gets pyflakes-grade checks even where ruff is
not installed -- ruff, when present, runs alongside from the same
``python -m repro.analysis`` entry point.

Suppression syntax (trailing on the offending statement, or on a
comment-only line directly above it)::

    x = np.asarray(live)   # navilint: sync-ok chunk boundary, host branches
    # navilint: op-ok single fused top_k merge (the allowed form)
    neg, order = lax.top_k(-d, efs)

Annotation syntax::

    self.depth = 0         # guarded-by: _lock
    def _bump(self):       # navilint: lock-held _lock
    def step(st):          # navilint: hot
"""

from __future__ import annotations

import ast
import dataclasses
import io
import pathlib
import re
import tokenize
from typing import Iterable, Optional

from repro.analysis import callgraph, dataflow, donation, keycover, registry

# -- rule ids ---------------------------------------------------------------
SYNC_IN_HOT = "NX101"          # host sync inside a hot-path function
FORBIDDEN_OP = "NX102"         # CPU-hostile device op in a hot-path function
WALLCLOCK = "NX103"            # time.time() (monotonic only)
UNLOCKED_ACCESS = "NX201"      # guarded field touched outside its lock
UNKNOWN_LOCK = "NX202"         # guarded-by names a lock the class never binds
STALE_SUPPRESSION = "NX301"    # suppression that suppressed nothing
MALFORMED_SUPPRESSION = "NX302"  # suppression without a reason
STALE_REGISTRY = "NX303"       # registry qualname not found in the file
UNUSED_IMPORT = "NX401"        # module-level import never used
BARE_EXCEPT = "NX402"          # except: with no exception type
# flow families (repro.analysis.dataflow / keycover / donation)
TRACE_BRANCH = dataflow.TRACE_BRANCH            # NX501
TRACE_HOST = dataflow.TRACE_HOST                # NX502
TRACE_SHAPE = dataflow.TRACE_SHAPE              # NX503
UNCOVERED_STATIC = keycover.UNCOVERED_STATIC    # NX601
UNCOVERED_INPUT = keycover.UNCOVERED_INPUT      # NX602
UNKNOWN_KEY_FIELD = keycover.UNKNOWN_KEY_FIELD  # NX603
USE_AFTER_DONATE = donation.USE_AFTER_DONATE    # NX701
DISCARDED_DONATION = donation.DISCARDED_DONATION  # NX702
DONATION_ALIAS = donation.DONATION_ALIAS        # NX703

#: suppression kind accepted per rule (None = not suppressible)
_SUPPRESS_KIND = {
    SYNC_IN_HOT: "sync-ok",
    FORBIDDEN_OP: "op-ok",
    WALLCLOCK: "wallclock-ok",
    UNLOCKED_ACCESS: "lock-ok",
    TRACE_BRANCH: "trace-ok",
    TRACE_HOST: "trace-ok",
    TRACE_SHAPE: "trace-ok",
    UNCOVERED_STATIC: "key-ok",
    UNCOVERED_INPUT: "key-ok",
    UNKNOWN_KEY_FIELD: "key-ok",
    USE_AFTER_DONATE: "donate-ok",
    DISCARDED_DONATION: "donate-ok",
    DONATION_ALIAS: "donate-ok",
}

#: method names whose call on any object is a host sync
_SYNC_METHODS = ("item", "tolist", "block_until_ready", "copy_to_host")
#: `.at[...].<setter>(...)` forms PR 3 removed from the engine loop
_AT_SETTERS = ("set", "add", "mul", "min", "max", "apply", "get")
#: aliases conventionally bound to the numpy module
_NUMPY_ROOTS = ("np", "numpy", "onp")

_SUPPRESS_RE = re.compile(
    r"#\s*navilint:\s*(sync-ok|op-ok|wallclock-ok|lock-ok|trace-ok"
    r"|key-ok|donate-ok)\b\s*(.*)")
_HOT_RE = re.compile(r"#\s*navilint:\s*hot\b")
_LOCK_HELD_RE = re.compile(r"#\s*navilint:\s*lock-held\s+(\w+)")
_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*(\w+)")
_NOQA_RE = re.compile(r"#\s*noqa\b", re.IGNORECASE)


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def github(self) -> str:
        """GitHub Actions workflow-command annotation."""
        return (f"::error file={self.path},line={self.line},"
                f"title=navilint {self.rule}::{self.message}")


@dataclasses.dataclass
class _Suppression:
    line: int
    kind: str
    reason: str
    used: bool = False


class _Comments:
    """Per-line comment facts extracted with tokenize (never matches
    text inside string literals, unlike a regex over raw source)."""

    def __init__(self, source: str):
        self.suppressions: dict[int, _Suppression] = {}
        self.hot_lines: set[int] = set()
        self.lock_held: dict[int, str] = {}
        self.guarded: dict[int, str] = {}
        self.noqa_lines: set[int] = set()
        #: comment-only lines (suppression may sit above its statement)
        self.standalone: set[int] = set()
        code_lines: set[int] = set()
        try:
            tokens = list(tokenize.generate_tokens(
                io.StringIO(source).readline))
        except (tokenize.TokenError, IndentationError):  # pragma: no cover
            return
        for tok in tokens:
            if tok.type == tokenize.COMMENT:
                line, text = tok.start[0], tok.string
                m = _SUPPRESS_RE.search(text)
                if m:
                    self.suppressions[line] = _Suppression(
                        line, m.group(1), m.group(2).strip())
                if _HOT_RE.search(text):
                    self.hot_lines.add(line)
                m = _LOCK_HELD_RE.search(text)
                if m:
                    self.lock_held[line] = m.group(1)
                m = _GUARDED_RE.search(text)
                if m:
                    self.guarded[line] = m.group(1)
                if _NOQA_RE.search(text):
                    self.noqa_lines.add(line)
            elif tok.type not in (tokenize.NL, tokenize.NEWLINE,
                                  tokenize.INDENT, tokenize.DEDENT,
                                  tokenize.ENCODING, tokenize.ENDMARKER):
                code_lines.add(tok.start[0])
        self.standalone = {
            line for line in (set(self.suppressions) | set(self.lock_held)
                              | set(self.hot_lines))
            if line not in code_lines}


def _attr_chain(node: ast.AST) -> list[str]:
    """``a.b.c`` -> ["a", "b", "c"]; [] when not a plain name chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return []


def _contains_call(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Call) for n in ast.walk(node))


class _FileAnalyzer:
    """One source file's full navilint pass."""

    def __init__(self, path: str, source: str, rel_path: str):
        self.path = path
        self.source = source
        self.rel_path = rel_path
        self.comments = _Comments(source)
        self.findings: list[Finding] = []
        self.hot_registry = set(registry.hot_names_for(rel_path))
        self.seen_qualnames: set[str] = set()
        # statement line-span stack: suppressions attach to statements
        self._stmt_spans: list[tuple[int, int]] = []
        self.tree: Optional[ast.Module] = None
        #: NX201 candidates in private methods, resolved interprocedurally
        #: against the class call graph after the lexical pass
        self._deferred_nx201: list[tuple] = []

    # -- plumbing -------------------------------------------------------
    def emit(self, rule: str, node: ast.AST, message: str,
             span: Optional[tuple] = None) -> None:
        line = getattr(node, "lineno", 1)
        if span is None:
            span = self._stmt_spans[-1] if self._stmt_spans \
                else (line, line)
        kind = _SUPPRESS_KIND.get(rule)
        if kind is not None:
            for ln in range(span[0] - 1, span[1] + 1):
                sup = self.comments.suppressions.get(ln)
                if sup is None or sup.kind != kind:
                    continue
                # a comment-only line above the span only binds to the
                # statement immediately below it
                if ln == span[0] - 1 and ln not in self.comments.standalone:
                    continue
                sup.used = True
                if not sup.reason:
                    self.findings.append(Finding(
                        MALFORMED_SUPPRESSION, self.path, ln,
                        f"suppression 'navilint: {kind}' needs a reason "
                        f"(why is this site exempt?)"))
                return
        self.findings.append(Finding(rule, self.path, line, message))

    def _fn_annotations(self, node: ast.AST) -> Iterable[int]:
        """Lines a def-level annotation may sit on: the def line and a
        comment-only line directly above (or above its decorators)."""
        first = min([node.lineno]
                    + [d.lineno for d in getattr(node, "decorator_list",
                                                 [])])
        yield node.lineno
        if first - 1 in self.comments.standalone:
            yield first - 1

    def _is_marked_hot(self, node: ast.AST) -> bool:
        return any(ln in self.comments.hot_lines
                   for ln in self._fn_annotations(node))

    def _lock_held_name(self, node: ast.AST) -> Optional[str]:
        for ln in self._fn_annotations(node):
            if ln in self.comments.lock_held:
                return self.comments.lock_held[ln]
        return None

    # -- entry ----------------------------------------------------------
    def run_pre(self) -> None:
        """Lexical pass: everything except suppression staleness (the
        flow passes still mark suppressions used) and the deferred
        interprocedural NX201 resolution."""
        try:
            self.tree = ast.parse(self.source, filename=self.path)
        except SyntaxError as e:
            self.findings.append(Finding(
                "NX000", self.path, e.lineno or 1,
                f"syntax error: {e.msg}"))
            return
        self._scan_functions(self.tree, qual="", hot=False)
        self._scan_wallclock(self.tree)
        self._scan_classes(self.tree)
        self._scan_hygiene(self.tree)
        self._finish_registry()

    def finish(self) -> None:
        if self.tree is None:
            return
        self._resolve_deferred_nx201()
        self._finish_suppressions()

    def run(self) -> list[Finding]:
        self.run_pre()
        self.finish()
        return self.findings

    # -- hot-loop purity ------------------------------------------------
    def _scan_functions(self, node: ast.AST, qual: str, hot: bool) -> None:
        """Walk the def tree, tracking qualnames and hotness lexically."""
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{qual}{child.name}"
                self.seen_qualnames.add(q)
                child_hot = (hot or q in self.hot_registry
                             or self._is_marked_hot(child))
                if child_hot and not hot:
                    self._purity_scan(child)
                self._scan_functions(child, f"{q}.<locals>.", child_hot)
            elif isinstance(child, ast.ClassDef):
                self.seen_qualnames.add(f"{qual}{child.name}")
                self._scan_functions(child, f"{qual}{child.name}.", hot)
            else:
                self._scan_functions(child, qual, hot)

    def _purity_scan(self, fn: ast.AST) -> None:
        """Flag host syncs and forbidden device ops anywhere lexically
        inside a hot function (nested closures included)."""
        self._walk_stmts(fn, self._purity_node)

    def _walk_stmts(self, node: ast.AST, visit) -> None:
        for child in ast.iter_child_nodes(node):
            is_stmt = isinstance(child, ast.stmt)
            if is_stmt:
                self._stmt_spans.append(
                    (child.lineno, child.end_lineno or child.lineno))
            visit(child)
            self._walk_stmts(child, visit)
            if is_stmt:
                self._stmt_spans.pop()

    def _purity_node(self, node: ast.AST) -> None:
        if not isinstance(node, ast.Call):
            return
        chain = _attr_chain(node.func)
        dotted = ".".join(chain)
        # host syncs ----------------------------------------------------
        if chain and chain[0] in _NUMPY_ROOTS:
            self.emit(SYNC_IN_HOT, node,
                      f"host call '{dotted}' inside a hot-path function "
                      f"(move it to a finalize boundary or annotate "
                      f"'# navilint: sync-ok <reason>')")
            return
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in _SYNC_METHODS):
            self.emit(SYNC_IN_HOT, node,
                      f"'.{node.func.attr}()' forces a host sync inside "
                      f"a hot-path function")
            return
        if dotted in ("jax.device_get", "device_get"):
            self.emit(SYNC_IN_HOT, node,
                      "'jax.device_get' inside a hot-path function")
            return
        if (isinstance(node.func, ast.Name)
                and node.func.id in ("float", "int", "bool") and node.args
                and _contains_call(node.args[0])):
            self.emit(SYNC_IN_HOT, node,
                      f"'{node.func.id}(...)' on a computed value "
                      f"concretizes (= host-syncs) inside a hot-path "
                      f"function")
            return
        # forbidden device ops ------------------------------------------
        if len(chain) >= 2 and chain[-2] == "lax" and (
                chain[-1].startswith("scatter") or chain[-1] == "top_k"):
            self.emit(FORBIDDEN_OP, node,
                      f"'{dotted}' in a hot-path function: XLA CPU "
                      f"serializes it (PR 3 purged these from the engine "
                      f"loop); use the mask/one-hot/searchsorted forms "
                      f"or annotate '# navilint: op-ok <reason>'")
            return
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in _AT_SETTERS
                and isinstance(node.func.value, ast.Subscript)
                and isinstance(node.func.value.value, ast.Attribute)
                and node.func.value.value.attr == "at"):
            self.emit(FORBIDDEN_OP, node,
                      f"'.at[...].{node.func.attr}(...)' scatter in a "
                      f"hot-path function: XLA CPU serializes per-lane "
                      f"scatters; use mask arithmetic")

    # -- wall clock (file-wide) ----------------------------------------
    def _scan_wallclock(self, tree: ast.AST) -> None:
        def visit(node: ast.AST) -> None:
            if not isinstance(node, ast.Call):
                return
            if _attr_chain(node.func) == ["time", "time"]:
                self.emit(WALLCLOCK, node,
                          "time.time() is a wall clock (steps under NTP); "
                          "deadline/duration math must use time.monotonic "
                          "or time.perf_counter")
        self._walk_stmts(tree, visit)

    # -- lock discipline ------------------------------------------------
    def _scan_classes(self, tree: ast.AST) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                self._scan_class(node)

    def _class_guard_map(self, cls: ast.ClassDef
                         ) -> tuple[dict[str, str], set[str]]:
        """(guarded field -> lock name, all self-assigned names)."""
        guarded: dict[str, str] = {}
        bound: set[str] = set()
        for node in ast.walk(cls):
            targets: list[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                targets = [node.target]
            for t in targets:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    bound.add(t.attr)
                    lock = self.comments.guarded.get(node.lineno)
                    if lock:
                        guarded.setdefault(t.attr, lock)
        return guarded, bound

    def _scan_class(self, cls: ast.ClassDef) -> None:
        guarded, bound = self._class_guard_map(cls)
        if not guarded:
            return
        for field, lock in sorted(guarded.items()):
            if lock not in bound:
                self.findings.append(Finding(
                    UNKNOWN_LOCK, self.path, cls.lineno,
                    f"field '{field}' is guarded-by '{lock}' but "
                    f"{cls.name} never binds 'self.{lock}'"))
        for node in cls.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_method(node, guarded, cls)

    def _scan_method(self, fn: ast.AST, guarded: dict[str, str],
                     cls: ast.ClassDef) -> None:
        if fn.name in ("__init__", "__del__"):
            return                  # construction happens-before sharing
        held0 = {self._lock_held_name(fn)} - {None}
        # a private helper may be provable lock-held from its intra-class
        # call sites; defer those candidates to the call-graph resolution
        defer = fn.name.startswith("_") and not fn.name.startswith("__")

        def walk(node: ast.AST, held: set) -> None:
            for child in ast.iter_child_nodes(node):
                is_stmt = isinstance(child, ast.stmt)
                if is_stmt:
                    self._stmt_spans.append(
                        (child.lineno, child.end_lineno or child.lineno))
                child_held = held
                if isinstance(child, ast.With):
                    acquired = set()
                    for item in child.items:
                        ce = item.context_expr
                        if (isinstance(ce, ast.Attribute)
                                and isinstance(ce.value, ast.Name)
                                and ce.value.id == "self"):
                            acquired.add(ce.attr)
                    child_held = held | acquired
                elif isinstance(child, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    extra = {self._lock_held_name(child)} - {None}
                    child_held = held | extra
                if (isinstance(child, ast.Attribute)
                        and isinstance(child.value, ast.Name)
                        and child.value.id == "self"
                        and child.attr in guarded
                        and guarded[child.attr] not in held):
                    verb = ("write to" if isinstance(
                        child.ctx, (ast.Store, ast.Del)) else "read of")
                    message = (
                        f"{verb} 'self.{child.attr}' outside 'with "
                        f"self.{guarded[child.attr]}' (field is "
                        f"'# guarded-by: {guarded[child.attr]}'; "
                        f"hold the lock, call the method only from "
                        f"'with self.{guarded[child.attr]}' blocks, or "
                        f"annotate the method '# navilint: lock-held "
                        f"{guarded[child.attr]}')")
                    if defer:
                        span = (self._stmt_spans[-1]
                                if self._stmt_spans
                                else (child.lineno, child.lineno))
                        self._deferred_nx201.append(
                            (cls, fn.name, guarded[child.attr], child,
                             span, message))
                    else:
                        self.emit(UNLOCKED_ACCESS, child, message)
                walk(child, child_held)
                if is_stmt:
                    self._stmt_spans.pop()

        walk(fn, held0)

    def _resolve_deferred_nx201(self) -> None:
        """Interprocedural NX201: a private method's unlocked access to
        a guarded field passes when EVERY intra-class call site provably
        holds the lock -- lexically inside ``with self.<lock>``, in a
        ``lock-held``-annotated method, or (recursively) in a method
        that is itself proven lock-held. Methods that escape as bare
        ``self.m`` references (thread targets, callbacks) or have no
        intra-class call sites at all get no proof and are reported."""
        if not self._deferred_nx201:
            return
        by_cls: dict[int, tuple] = {}
        for cand in self._deferred_nx201:
            by_cls.setdefault(id(cand[0]), (cand[0], []))[1].append(cand)
        for cls, cands in by_cls.values():
            sites, escapes = callgraph.class_call_sites(cls)
            annotated: dict[str, set] = {}
            methods: set[str] = set()
            for item in cls.body:
                if isinstance(item, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    methods.add(item.name)
                    annotated[item.name] = (
                        {self._lock_held_name(item)} - {None})
            all_locks = {c[2] for c in cands}
            # optimistic init, decreasing fixpoint over the call graph
            resolved: dict[str, set] = {}
            for m in methods:
                eligible = (m.startswith("_")
                            and not m.startswith("__")
                            and sites.get(m) and m not in escapes)
                resolved[m] = set(all_locks) if eligible else set()
            for _ in range(len(methods) + 2):
                changed = False
                for m in methods:
                    if not resolved[m]:
                        continue
                    meet: Optional[set] = None
                    for s in sites.get(m, ()):
                        held = (set(s.lexical_locks)
                                | annotated.get(s.caller, set())
                                | resolved.get(s.caller, set()))
                        meet = held if meet is None else meet & held
                    new = resolved[m] & (meet if meet is not None
                                         else set())
                    if new != resolved[m]:
                        resolved[m] = new
                        changed = True
                if not changed:
                    break
            for _cls, method, lock, node, span, message in cands:
                if lock not in resolved.get(method, set()):
                    self.emit(UNLOCKED_ACCESS, node, message, span=span)

    # -- hygiene (pyflakes-grade, for trees without ruff) ---------------
    def _scan_hygiene(self, tree: ast.Module) -> None:
        if pathlib.Path(self.path).name != "__init__.py":
            self._scan_unused_imports(tree)
        for node in ast.walk(tree):
            if (isinstance(node, ast.ExceptHandler) and node.type is None
                    and node.lineno not in self.comments.noqa_lines):
                self.findings.append(Finding(
                    BARE_EXCEPT, self.path, node.lineno,
                    "bare 'except:' catches SystemExit/KeyboardInterrupt; "
                    "name the exception (or 'except Exception:')"))

    def _scan_unused_imports(self, tree: ast.Module) -> None:
        imported: dict[str, tuple[int, str]] = {}
        for node in tree.body:
            if isinstance(node, ast.Import):
                for a in node.names:
                    name = a.asname or a.name.split(".")[0]
                    imported[name] = (node.lineno, a.name)
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__":
                    continue
                for a in node.names:
                    if a.name == "*":
                        continue
                    name = a.asname or a.name
                    imported[name] = (node.lineno,
                                      f"{node.module}.{a.name}")
        if not imported:
            return
        used: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Name) and not isinstance(
                    node.ctx, ast.Store):
                used.add(node.id)
            elif isinstance(node, ast.Attribute):
                chain = _attr_chain(node)
                if chain:
                    used.add(chain[0])
        # names exported via __all__ are used
        for node in tree.body:
            if (isinstance(node, ast.Assign) and node.targets
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == "__all__"
                    and isinstance(node.value, (ast.List, ast.Tuple))):
                for elt in node.value.elts:
                    if isinstance(elt, ast.Constant):
                        used.add(str(elt.value))
        for name, (line, full) in sorted(imported.items(),
                                         key=lambda kv: kv[1][0]):
            if name in used or name == "_":
                continue
            if line in self.comments.noqa_lines:
                continue
            self.findings.append(Finding(
                UNUSED_IMPORT, self.path, line,
                f"'{full}' imported but unused (remove, or mark the "
                f"re-export with '# noqa: F401')"))

    # -- closers --------------------------------------------------------
    def _finish_registry(self) -> None:
        for qual in sorted(self.hot_registry - self.seen_qualnames):
            self.findings.append(Finding(
                STALE_REGISTRY, self.path, 1,
                f"hot-path registry names '{qual}' but {self.rel_path} "
                f"defines no such function -- update "
                f"repro/analysis/registry.py alongside the refactor"))

    def _finish_suppressions(self) -> None:
        for sup in self.comments.suppressions.values():
            if not sup.used:
                self.findings.append(Finding(
                    STALE_SUPPRESSION, self.path, sup.line,
                    f"stale suppression 'navilint: {sup.kind}': nothing "
                    f"here triggers that rule any more -- delete the "
                    f"comment so suppressions stay trustworthy"))


# -- public API -------------------------------------------------------------

def _analyze_project(specs: list) -> list[Finding]:
    """The full pipeline over (path, source, rel_path) specs: per-file
    lexical pass, then the cross-file flow passes (tracer-flow, key
    coverage, donation safety) over one shared call graph, then the
    suppression-staleness closers -- so flow-rule suppressions are never
    falsely stale."""
    analyzers: list[_FileAnalyzer] = []
    parsed: list[tuple] = []
    for path, source, rel in specs:
        a = _FileAnalyzer(path, source, rel)
        a.run_pre()
        analyzers.append(a)
        if a.tree is not None:
            parsed.append((path, rel, a.tree))
    project = callgraph.build_project(parsed)
    by_path = {a.path: a for a in analyzers}

    def emit(rule: str, module, node: ast.AST, span: tuple,
             message: str) -> None:
        by_path[module.path].emit(rule, node, message, span=span)

    dataflow.check(project, emit)
    keycover.check(project, emit)
    donation.check(project, emit)
    findings: list[Finding] = []
    for a in analyzers:
        a.finish()
        findings.extend(a.findings)
    return findings


def analyze_source(source: str, path: str = "<string>",
                   rel_path: Optional[str] = None) -> list[Finding]:
    """Analyze one source string (the test-fixture entry point). Flow
    passes run over a single-file project, so fixtures exercise them."""
    rel = rel_path if rel_path is not None else registry.normalize_path(
        path)
    return sorted(_analyze_project([(path, source, rel)]),
                  key=lambda f: (f.path, f.line, f.rule))


def analyze_file(path: pathlib.Path) -> list[Finding]:
    source = path.read_text(encoding="utf-8")
    return analyze_source(source, str(path))


def iter_python_files(paths: Iterable[str]) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for p in paths:
        root = pathlib.Path(p)
        if root.is_dir():
            out.extend(sorted(root.rglob("*.py")))
        elif root.suffix == ".py":
            out.append(root)
    return [p for p in out if "__pycache__" not in p.parts]


def analyze_paths(paths: Iterable[str]) -> list[Finding]:
    """Run navilint over files/directories; findings sorted by location.
    All files form ONE project, so the flow passes see cross-file call
    edges (a core entry point jitted in api/, donated state consumed in
    serving/)."""
    specs = []
    seen_registry_files = set()
    for f in iter_python_files(paths):
        specs.append((str(f), f.read_text(encoding="utf-8"),
                      registry.normalize_path(str(f))))
        seen_registry_files.add(registry.normalize_path(str(f)))
    findings = _analyze_project(specs)
    # registry entries pointing at files the sweep never saw are stale
    # only when the sweep actually covered the repro package
    if any(p.startswith("repro/") for p in seen_registry_files):
        for rel in sorted(set(registry.HOT_PATHS) - seen_registry_files):
            if any(p.endswith(rel.split("/")[-1])
                   for p in seen_registry_files):
                continue
            findings.append(Finding(
                STALE_REGISTRY, rel, 1,
                f"hot-path registry lists '{rel}' but the sweep found no "
                f"such file"))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))
