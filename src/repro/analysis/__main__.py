"""One entry point for all repo linting: navilint + (optional) ruff.

Usage::

    python -m repro.analysis [--strict] [--github] [paths ...]

Default paths are ``src`` and ``tests`` (resolved relative to the repo
root, found by walking up from this file). ``--strict`` exits non-zero
on any finding; ``--github`` additionally renders findings as GitHub
Actions ``::error`` annotations so they land on the PR diff.

ruff is invoked when it's on PATH and skipped (with a note) when it
isn't -- the container image doesn't ship it, CI installs it. navilint's
own NX4xx hygiene rules keep pyflakes-grade coverage either way.
"""

from __future__ import annotations

import argparse
import pathlib
import shutil
import subprocess
import sys

from repro.analysis import navilint


def repo_root() -> pathlib.Path:
    here = pathlib.Path(__file__).resolve()
    for parent in here.parents:
        if (parent / "ROADMAP.md").exists() or (parent / ".git").exists():
            return parent
    return here.parents[3]


def run_ruff(paths: list[str], github: bool) -> int:
    exe = shutil.which("ruff")
    if exe is None:
        print("[analysis] ruff not installed; skipping "
              "(navilint NX4xx hygiene rules still ran)")
        return 0
    fmt = ["--output-format", "github" if github else "concise"]
    proc = subprocess.run([exe, "check", *fmt, *paths],
                          capture_output=True, text=True)
    if proc.stdout.strip():
        print(proc.stdout.strip())
    if proc.stderr.strip():
        print(proc.stderr.strip(), file=sys.stderr)
    return proc.returncode


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="navilint + ruff over the repo tree")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to analyze (default: src tests)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on any finding")
    ap.add_argument("--github", action="store_true",
                    help="emit GitHub Actions ::error annotations")
    ap.add_argument("--no-ruff", action="store_true",
                    help="run only navilint")
    args = ap.parse_args(argv)

    paths = args.paths
    if not paths:
        root = repo_root()
        paths = [str(root / "src"), str(root / "tests")]
        paths = [p for p in paths if pathlib.Path(p).exists()]

    findings = navilint.analyze_paths(paths)
    for f in findings:
        print(f.render())
        if args.github:
            print(f.github())
    n_files = len(navilint.iter_python_files(paths))
    print(f"[analysis] navilint: {len(findings)} finding(s) "
          f"across {n_files} file(s)")

    ruff_rc = 0 if args.no_ruff else run_ruff(paths, args.github)

    if findings and args.strict:
        return 1
    if ruff_rc != 0 and args.strict:
        return ruff_rc
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
