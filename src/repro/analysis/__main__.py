"""One entry point for all repo linting: navilint + (optional) ruff.

Usage::

    python -m repro.analysis [--strict] [--github] [paths ...]
    python -m repro.analysis --write-baseline
    python -m repro.analysis --changed-only --strict

Default paths are ``src``, ``tests``, ``benchmarks`` and ``examples``
(resolved relative to the repo root, found by walking up from this
file). ``--strict`` exits non-zero on any finding; ``--github``
additionally renders findings as GitHub Actions ``::error`` annotations
so they land on the PR diff.

``ANALYSIS_baseline.json`` (committed at the repo root) records a
content hash per analyzed file from the last clean full run.
``--changed-only`` still runs the *whole-project* analysis -- the
interprocedural passes (NX2xx lock discipline, NX5xx tracer flow,
NX6xx key coverage, NX7xx donation) need every module's call graph --
but only reports findings in files whose hash differs from the
baseline, so a focused edit gets a focused report.

``--budget SECONDS`` enforces the analyzer's own runtime contract: the
full-tree run must stay fast enough to sit in the inner loop (CI pins
30s). Overrunning the budget is itself a failure under ``--strict``.

ruff is invoked when it's on PATH and skipped (with a note) when it
isn't -- the container image doesn't ship it, CI installs it. navilint's
own NX4xx hygiene rules keep pyflakes-grade coverage either way.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import shutil
import subprocess
import sys
import time

from repro.analysis import navilint

BASELINE_NAME = "ANALYSIS_baseline.json"
DEFAULT_TREES = ("src", "tests", "benchmarks", "examples")


def repo_root() -> pathlib.Path:
    here = pathlib.Path(__file__).resolve()
    for parent in here.parents:
        if (parent / "ROADMAP.md").exists() or (parent / ".git").exists():
            return parent
    return here.parents[3]


def run_ruff(paths: list[str], github: bool) -> int:
    exe = shutil.which("ruff")
    if exe is None:
        print("[analysis] ruff not installed; skipping "
              "(navilint NX4xx hygiene rules still ran)")
        return 0
    fmt = ["--output-format", "github" if github else "concise"]
    proc = subprocess.run([exe, "check", *fmt, *paths],
                          capture_output=True, text=True)
    if proc.stdout.strip():
        print(proc.stdout.strip())
    if proc.stderr.strip():
        print(proc.stderr.strip(), file=sys.stderr)
    return proc.returncode


def _file_hashes(paths: list[str]) -> dict[str, str]:
    root = repo_root()
    out: dict[str, str] = {}
    for path in navilint.iter_python_files(paths):
        p = pathlib.Path(path).resolve()
        try:
            rel = str(p.relative_to(root))
        except ValueError:
            rel = str(p)
        out[rel] = hashlib.sha256(p.read_bytes()).hexdigest()
    return out


def write_baseline(paths: list[str]) -> pathlib.Path:
    target = repo_root() / BASELINE_NAME
    payload = {"version": 1, "files": _file_hashes(paths)}
    target.write_text(json.dumps(payload, indent=1, sort_keys=True)
                      + "\n")
    return target


def changed_files(paths: list[str]) -> set[str] | None:
    """Repo-relative paths whose content differs from the committed
    baseline (new files count as changed). None when no baseline."""
    target = repo_root() / BASELINE_NAME
    if not target.exists():
        return None
    try:
        base = json.loads(target.read_text()).get("files", {})
    except (json.JSONDecodeError, OSError):
        return None
    current = _file_hashes(paths)
    return {rel for rel, digest in current.items()
            if base.get(rel) != digest}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="navilint + ruff over the repo tree")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to analyze "
                         "(default: src tests benchmarks examples)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on any finding")
    ap.add_argument("--github", action="store_true",
                    help="emit GitHub Actions ::error annotations")
    ap.add_argument("--no-ruff", action="store_true",
                    help="run only navilint")
    ap.add_argument("--changed-only", action="store_true",
                    help="analyze the whole project but report only "
                         "findings in files changed vs "
                         + BASELINE_NAME)
    ap.add_argument("--write-baseline", action="store_true",
                    help="record per-file content hashes to "
                         + BASELINE_NAME + " and exit")
    ap.add_argument("--budget", type=float, default=None, metavar="SEC",
                    help="fail (under --strict) when the navilint run "
                         "itself exceeds SEC seconds")
    args = ap.parse_args(argv)

    paths = args.paths
    if not paths:
        root = repo_root()
        paths = [str(root / t) for t in DEFAULT_TREES]
        paths = [p for p in paths if pathlib.Path(p).exists()]

    if args.write_baseline:
        target = write_baseline(paths)
        n = len(json.loads(target.read_text())["files"])
        print(f"[analysis] baseline written: {target.name} "
              f"({n} files)")
        return 0

    t0 = time.monotonic()
    findings = navilint.analyze_paths(paths)
    elapsed = time.monotonic() - t0

    if args.changed_only:
        changed = changed_files(paths)
        if changed is None:
            print(f"[analysis] no {BASELINE_NAME}; --changed-only "
                  f"falls back to a full report")
        else:
            root = repo_root()

            def _rel(f):
                try:
                    return str(pathlib.Path(
                        f.path).resolve().relative_to(root))
                except ValueError:
                    return f.path

            total = len(findings)
            findings = [f for f in findings if _rel(f) in changed]
            print(f"[analysis] --changed-only: {len(changed)} changed "
                  f"file(s); reporting {len(findings)}/{total} "
                  f"finding(s)")

    for f in findings:
        print(f.render())
        if args.github:
            print(f.github())
    n_files = len(navilint.iter_python_files(paths))
    print(f"[analysis] navilint: {len(findings)} finding(s) "
          f"across {n_files} file(s) in {elapsed:.1f}s")

    over_budget = args.budget is not None and elapsed > args.budget
    if over_budget:
        print(f"[analysis] BUDGET EXCEEDED: navilint took "
              f"{elapsed:.1f}s > {args.budget:.0f}s -- the analyzer "
              f"must stay fast enough for the inner loop",
              file=sys.stderr)

    ruff_rc = 0 if args.no_ruff else run_ruff(paths, args.github)

    if args.strict and (findings or over_budget):
        return 1
    if ruff_rc != 0 and args.strict:
        return ruff_rc
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
