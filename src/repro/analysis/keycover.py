"""ProgramKey coverage proofs (NX6xx): static zero-steady-compile.

``COMPILE_baseline.json`` regression-tests the zero-steady-compile
property dynamically: after warmup, the open-loop smoke must perform no
further XLA compiles. That only proves the property for the plan shapes
the smoke happened to exercise. This pass proves it structurally, for
every AOT program cache in the tree (any module defining a
``*Key(NamedTuple)`` class alongside a program store):

* **NX601 uncovered static field** -- a cache arm lowers its program
  with a ``static_argnames`` parameter whose NamedTuple type has fields
  the :class:`ProgramKey` construction never hashes. A call site varying
  such a field would silently reuse a program compiled for a different
  value (or retrace per value, breaking the compile baseline). The check
  follows ``self._key(...)`` helper indirection: a field read on the
  helper's parameter covers the caller's corresponding argument.
* **NX602 uncovered program input** -- a value that determines the
  *identity* of the stored program (the jitted function object, a
  static argument, the sharded receiver) does not reach the key: two
  call sites differing only in that value would collide on one cache
  entry. Roots are traced through local assignment chains
  (``bb = _bucket(b); b = Q.shape[0]`` covers ``Q``) and through
  ``functools.partial`` pre-binding (the ``batch(engine)`` pattern:
  the bound ``fn`` co-varies with the key-covered ``engine`` arm).
* **NX603 unknown key field** -- the key construction reads a field
  that the parameter's NamedTuple type does not define: rename drift
  between the params type and the cache key (the key arm silently
  hashes ``None``-ish garbage or raises at first use).

Suppression kind: ``# navilint: key-ok <reason>``.
"""

from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.callgraph import (
    FuncInfo, ModuleInfo, Project, attr_chain)

UNCOVERED_STATIC = "NX601"
UNCOVERED_INPUT = "NX602"
UNKNOWN_KEY_FIELD = "NX603"


def _namedtuple_fields(cls: ast.ClassDef) -> Optional[tuple]:
    is_nt = any(
        (isinstance(b, ast.Name) and b.id == "NamedTuple")
        or (isinstance(b, ast.Attribute) and b.attr == "NamedTuple")
        for b in cls.bases)
    if not is_nt:
        return None
    fields = []
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(
                node.target, ast.Name):
            fields.append(node.target.id)
    return tuple(fields)


class _ModuleTypes:
    """NamedTuple definitions reachable from one module (local classes
    plus ``from m import T`` targets in other swept modules)."""

    def __init__(self, project: Project, mod: ModuleInfo):
        self.project = project
        self.mod = mod
        self._cache: dict[str, Optional[tuple]] = {}
        self._cls_cache: dict[str, Optional[ast.ClassDef]] = {}

    def _lookup(self, type_name: str) -> Optional[ast.ClassDef]:
        if type_name in self._cls_cache:
            return self._cls_cache[type_name]
        out: Optional[ast.ClassDef] = None
        for cls in self.mod.classes.values():
            if cls.name == type_name:
                out = cls
                break
        if out is None and type_name in self.mod.from_names:
            src_mod, src_name = self.mod.from_names[type_name]
            target = self.project.by_name.get(src_mod)
            if target is not None:
                for cls in target.classes.values():
                    if cls.name == src_name:
                        out = cls
                        break
        self._cls_cache[type_name] = out
        return out

    def fields_of(self, type_name: str) -> Optional[tuple]:
        if type_name not in self._cache:
            cls = self._lookup(type_name)
            self._cache[type_name] = None if cls is None \
                else _namedtuple_fields(cls)
        return self._cache[type_name]

    def readable_of(self, type_name: str) -> Optional[frozenset]:
        """Every attribute legitimately readable on the type: tuple
        fields plus properties/methods (``graph.n`` is a property
        derived from field shapes, not a field)."""
        fields = self.fields_of(type_name)
        if fields is None:
            return None
        cls = self._lookup(type_name)
        extra = {n.name for n in cls.body
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        return frozenset(fields) | extra

    def annotation_fields(self, fi: FuncInfo, param: str
                          ) -> Optional[tuple]:
        name = self.annotation_name(fi, param)
        return None if name is None else self.fields_of(name)

    def annotation_name(self, fi: FuncInfo, param: str) -> Optional[str]:
        a = fi.node.args
        for p in a.posonlyargs + a.args + a.kwonlyargs:
            if p.arg == param and isinstance(p.annotation, ast.Name):
                return p.annotation.id
        return None


def _names_in(node: ast.AST) -> set:
    """Free names under ``node`` (lambda parameters are bound, not
    inputs: ``jax.jit(lambda q: q)`` depends on nothing)."""
    out: set = set()

    def visit(n: ast.AST, bound: frozenset) -> None:
        if isinstance(n, ast.Lambda):
            a = n.args
            params = {p.arg for p in a.posonlyargs + a.args
                      + a.kwonlyargs}
            for v in (a.vararg, a.kwarg):
                if v is not None:
                    params.add(v.arg)
            for d in list(a.defaults) + [d for d in a.kw_defaults
                                         if d is not None]:
                visit(d, bound)
            visit(n.body, bound | params)
            return
        if isinstance(n, ast.Name):
            if n.id not in bound:
                out.add(n.id)
        for c in ast.iter_child_nodes(n):
            visit(c, bound)

    visit(node, frozenset())
    return out


def _attr_reads(node: ast.AST) -> dict:
    """name -> set of fields read as ``name.field`` under ``node``."""
    out: dict[str, set] = {}
    for n in ast.walk(node):
        if (isinstance(n, ast.Attribute)
                and isinstance(n.value, ast.Name)):
            out.setdefault(n.value.id, set()).add(n.attr)
    return out


def _local_chains(fn: ast.AST) -> dict:
    """Transitive local-assignment roots: ``bb -> {Q}`` when
    ``bb = _bucket(b)`` and ``b = Q.shape[0]``."""
    direct: dict[str, set] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            names = _names_in(node.value)
            for t in node.targets:
                for sub in ast.walk(t):
                    if isinstance(sub, ast.Name) and isinstance(
                            sub.ctx, ast.Store):
                        direct.setdefault(sub.id, set()).update(names)
    # small transitive closure
    for _ in range(len(direct) + 1):
        changed = False
        for name, roots in direct.items():
            extra = set()
            for r in list(roots):
                extra |= direct.get(r, set())
            if not extra <= roots:
                roots |= extra
                changed = True
        if not changed:
            break
    return direct


class _CacheModule:
    """One module owning a ``*Key(NamedTuple)`` program cache."""

    def __init__(self, project: Project, mod: ModuleInfo,
                 key_classes: list, emit):
        self.project = project
        self.mod = mod
        self.key_names = {cls.name for cls, _ in key_classes}
        self.key_fields = {cls.name: f for cls, f in key_classes}
        self.types = _ModuleTypes(project, mod)
        self.emit = emit
        #: params of each function passed into a jit() call inside it
        self.jit_targets: dict[str, set] = {}
        self.static_names: set = set()
        self._checked_key_calls: set = set()
        self._collect_jit_surface()

    # -- jit surface ----------------------------------------------------
    def _collect_jit_surface(self) -> None:
        for fi in self.mod.funcs.values():
            for call in ast.walk(fi.node):
                if not isinstance(call, ast.Call):
                    continue
                chain = attr_chain(call.func)
                if not (chain and chain[-1] == "jit"):
                    continue
                for kw in call.keywords:
                    if kw.arg == "static_argnames":
                        for n in ast.walk(kw.value):
                            if isinstance(n, ast.Constant) and isinstance(
                                    n.value, str):
                                self.static_names.add(n.value)
                if call.args and isinstance(call.args[0], ast.Name):
                    name = call.args[0].id
                    if name in fi.params + fi.kwonly:
                        self.jit_targets.setdefault(
                            fi.qualname, set()).add(name)

    # -- key constructions ----------------------------------------------
    def _key_calls(self, fi: FuncInfo) -> list:
        """(call, covered-fields-per-name, key-root-names) for every key
        construction in ``fi`` -- direct ``ProgramKey(...)`` or through
        a local ``self._key(...)``-style builder."""
        out = []
        chains = _local_chains(fi.node)

        def expand(names: set) -> set:
            roots = set(names)
            for n in names:
                roots |= chains.get(n, set())
            return roots

        for call in ast.walk(fi.node):
            if not isinstance(call, ast.Call):
                continue
            # direct Key(...) construction
            if isinstance(call.func, ast.Name) \
                    and call.func.id in self.key_names:
                covered = _attr_reads(call)
                self._check_unknown_fields(fi, call, covered)
                out.append((call, covered, expand(_names_in(call))))
                continue
            # helper indirection: self._key(...) / _key(...)
            builder = self.project.resolve(
                self.mod, fi.qualname, call.func)
            if builder is None or builder.module is not self.mod:
                continue
            bcall = self._builder_key_call(builder)
            if bcall is None:
                continue
            bcov = _attr_reads(bcall)
            self._check_unknown_fields(builder, bcall, bcov)
            binding = builder.bind(call)
            covered: dict[str, set] = {}
            roots = set()
            for bparam, expr in binding.items():
                fields = bcov.get(bparam)
                for name in _names_in(expr):
                    roots.add(name)
                    if fields:
                        covered.setdefault(name, set()).update(fields)
            out.append((call, covered, expand(roots)))
        return out

    def _builder_key_call(self, builder: FuncInfo) -> Optional[ast.Call]:
        for node in ast.walk(builder.node):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in self.key_names):
                return node
        return None

    def _check_unknown_fields(self, fi: FuncInfo, key_call: ast.Call,
                              covered: dict) -> None:
        if key_call in self._checked_key_calls:
            return          # a builder's key call is bound once per
        self._checked_key_calls.add(key_call)       # caller: check once
        span = (key_call.lineno, key_call.end_lineno or key_call.lineno)
        for name, fields in covered.items():
            tname = self.types.annotation_name(fi, name)
            readable = None if tname is None \
                else self.types.readable_of(tname)
            if readable is None:
                continue
            for f in sorted(fields - readable):
                self.emit(
                    UNKNOWN_KEY_FIELD, self.mod, key_call, span,
                    f"key construction reads '{name}.{f}' but "
                    f"'{name}' has no such field -- rename drift "
                    f"between the params type and the cache key")

    # -- store sites ----------------------------------------------------
    def _store_exprs(self, fi: FuncInfo) -> list:
        """Expressions whose value is stored in the program cache:
        ``self._programs[k] = expr`` plus the program-identity args of
        calls into jit-forwarding helpers (``self._get(key, fn, ...)``).
        """
        out = []
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if (isinstance(t, ast.Subscript)
                            and isinstance(t.value, ast.Attribute)
                            and t.value.attr.startswith("_program")):
                        out.append(node.value)
            elif isinstance(node, ast.Call):
                callee = self.project.resolve(
                    self.mod, fi.qualname, node.func)
                if callee is None:
                    continue
                fwd = self.jit_targets.get(callee.qualname)
                if not fwd:
                    continue
                binding = callee.bind(node)
                for p in fwd:
                    if p in binding:
                        out.append(binding[p])
        return out

    # -- partial pre-binding --------------------------------------------
    def _partial_origins(self, fi: FuncInfo) -> dict:
        """param name -> origin root names, from ``functools.partial(
        self.<fi>, a, b)`` sites anywhere in the module."""
        out: dict[str, set] = {}
        for other in self.mod.funcs.values():
            for call in ast.walk(other.node):
                if not isinstance(call, ast.Call):
                    continue
                chain = attr_chain(call.func)
                if not (chain and chain[-1] == "partial" and call.args):
                    continue
                target = self.project.resolve(
                    self.mod, other.qualname, call.args[0])
                if target is not fi:
                    continue
                params = [p for p in fi.params if p != "self"]
                for i, arg in enumerate(call.args[1:]):
                    if i < len(params):
                        out.setdefault(params[i], set()).update(
                            _names_in(arg))
        return out

    # -- the arm check --------------------------------------------------
    def check_arms(self) -> None:
        for fi in self.mod.funcs.values():
            key_calls = self._key_calls(fi)
            if not key_calls:
                continue
            stores = self._store_exprs(fi)
            if not stores:
                continue        # pure key builder (e.g. `_key` itself)
            for key_call, covered, key_roots in key_calls:
                self._check_static_coverage(fi, key_call, covered)
                for expr in stores:
                    self._check_store_roots(
                        fi, key_call, key_roots, expr)

    def _check_static_coverage(self, fi: FuncInfo, key_call: ast.Call,
                               covered: dict) -> None:
        span = (key_call.lineno, key_call.end_lineno or key_call.lineno)
        for param in fi.params + fi.kwonly:
            if param not in self.static_names:
                continue
            tfields = self.types.annotation_fields(fi, param)
            if tfields is None:
                continue
            missing = [f for f in tfields
                       if f not in covered.get(param, set())]
            if missing:
                self.emit(
                    UNCOVERED_STATIC, self.mod, key_call, span,
                    f"program key never hashes {param} field(s) "
                    f"{', '.join(repr(m) for m in missing)}: a call "
                    f"site varying them reuses a program compiled for "
                    f"a different value (or retraces per value) -- "
                    f"add them to the key, or annotate "
                    f"'# navilint: key-ok <reason>'")

    def _is_module_level(self, name: str) -> bool:
        return (name in self.mod.funcs
                or name in {c.name for c in self.mod.classes.values()}
                or name in self.mod.import_alias
                or name in self.mod.from_names
                or name in ("self", "cls", "None", "True", "False"))

    def _check_store_roots(self, fi: FuncInfo, key_call: ast.Call,
                           key_roots: set, expr: ast.AST) -> None:
        chains = _local_chains(fi.node)
        origins = None
        # a local is covered when everything it was derived from is
        # (bb <- _bucket(b) <- Q.shape[0]: Q in the key covers bb)
        covered = {n for n in set(chains) | key_roots
                   if n in key_roots or self._is_module_level(n)}
        for _ in range(len(chains) + 1):
            grew = False
            for name, srcs in chains.items():
                if name not in covered and srcs and all(
                        s in covered or self._is_module_level(s)
                        for s in srcs):
                    covered.add(name)
                    grew = True
            if not grew:
                break
        uncovered = []
        for name in sorted(_names_in(expr)):
            if name in covered or self._is_module_level(name):
                continue
            if name in fi.params or name in fi.kwonly:
                if origins is None:
                    origins = self._partial_origins(fi)
                # partial pre-binding: the param's origin expression
                # shares its roots with a key-covered parameter
                mine = {n for n in origins.get(name, set())
                        if not self._is_module_level(n)}
                if origins.get(name) is not None:
                    covered_origin = set()
                    for p in fi.params + fi.kwonly:
                        if p in key_roots:
                            covered_origin |= origins.get(p, {p})
                    if mine <= covered_origin:
                        continue
            uncovered.append(name)
        if uncovered:
            span = (expr.lineno, expr.end_lineno or expr.lineno)
            self.emit(
                UNCOVERED_INPUT, self.mod, expr, span,
                f"stored program depends on "
                f"{', '.join(repr(u) for u in uncovered)} which never "
                f"reach(es) the cache key: call sites differing only "
                f"there would collide on one cache entry -- hash an "
                f"arm for it, or annotate '# navilint: key-ok <reason>'")


def check(project: Project, emit) -> None:
    """Run the key-coverage pass; findings go through ``emit``."""
    for mod in project.modules:
        key_classes = []
        for cls in mod.classes.values():
            if cls.name.endswith("Key"):
                fields = _namedtuple_fields(cls)
                if fields is not None:
                    key_classes.append((cls, fields))
        if key_classes:
            _CacheModule(project, mod, key_classes, emit).check_arms()
