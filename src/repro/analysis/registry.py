"""Hot-path registry: which functions navilint holds to device-loop purity.

The purity rules (no host syncs, no CPU-hostile device ops) are only
meaningful on code that runs inside -- or directly drives -- the engine's
step loop. Enumerating those functions here, by module path and qualified
name, makes the contract explicit and reviewable: adding a new hot path
is a one-line diff, and a registry entry whose function disappears in a
refactor is itself a finding (NX303), so the registry can never silently
rot.

Two ways a function becomes hot:

* listed in :data:`HOT_PATHS` under its file's repo-relative path
  (``repro/...``) and its dotted qualname (nested functions use the
  ``<locals>`` spelling, matching ``__qualname__``);
* marked inline with ``# navilint: hot`` on its ``def`` line (used by
  test fixtures and one-off scripts outside the repo layout).

Everything lexically inside a hot function -- including nested closures
like the engine's loop ``body`` or a ``shard_map`` ``local`` -- inherits
hotness.
"""

from __future__ import annotations

#: repo-relative file path -> qualnames held to hot-loop purity
HOT_PATHS: dict[str, tuple[str, ...]] = {
    # the batched-frontier engine: the while_loop body and every entry
    # point of the resumable stepping API (PR 3's scatter/top_k purge
    # lives here -- the two surviving fused top_k merges are annotated)
    "repro/core/search_batch.py": (
        "greedy_upper_batch",
        "_init_state",
        "_loop_fns",
        # the residency dispatch the loop body's every distance call runs
        # through (f32 rows vs int8 codes+scales) -- a host sync here
        # would serialize every beam iteration
        "batch_gather_dist",
        "_take_first_batch",
        "_frontier_min",
        "_r_max",
        "_resolve_branching",
        "_extract_results",
        "beam_search_lower_batch",
        "search_lanes",
        "step_lanes",
        "refill_lanes",
        "finalize_lanes",
        "evict_lanes",
        "parked_state",
        # the donated (buffer-donating, async-dispatch) variants the
        # serving tier's overlapped stepping runs on
        "engine_steps_overlap",
        "engine_refill_overlap",
        "engine_evict_overlap",
    ),
    # the shared distance layer: every engine's per-candidate gather
    # (including the dequantizing int8 gather) flows through these
    "repro/core/distances.py": (
        "gather_rows",
        "gathered_dist",
        "gathered_dist_batch",
        "point_dist",
    ),
    # the shard_map bodies: everything that runs per shard inside the
    # sharded programs, plus the one-op merge they feed
    "repro/core/distributed.py": (
        "merge_shard_topk",
        "_masked_stats_sum",
        "ShardedNavix._guard",
        "ShardedNavix._build_search.<locals>.local",
        "ShardedNavix._build_refill.<locals>.local",
        "ShardedNavix._build_steps.<locals>.run.<locals>.local",
        "ShardedNavix._build_finalize.<locals>.local",
    ),
    # the shared device-lane core: step_async dispatches the device loop
    # (donated buffers, no sync), step_wait is the ONE liveness sync per
    # chunk, and finalize is THE declared host boundary (results cross
    # exactly once)
    "repro/serving/lanes.py": (
        "LaneBatch.step",
        "LaneBatch.step_async",
        "LaneBatch.step_wait",
        "LaneBatch.finalize",
    ),
    # the serving drivers' device loops
    "repro/serving/service.py": (
        "SearchService._tick",
    ),
    "repro/serving/engine.py": (
        "SearchEngine._serve_fused",
    ),
}


def hot_names_for(rel_path: str) -> tuple[str, ...]:
    """Registered hot qualnames for a repo-relative file path."""
    return HOT_PATHS.get(rel_path, ())


def normalize_path(path: str) -> str:
    """Map any path to its repo-relative ``repro/...`` registry key.

    Files outside the ``repro`` package (tests, fixtures, scripts) have
    no registry entries; they can still opt in via ``# navilint: hot``.
    """
    parts = path.replace("\\", "/").split("/")
    if "repro" in parts:
        return "/".join(parts[parts.index("repro"):])
    return parts[-1]
