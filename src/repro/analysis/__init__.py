"""Repo-native static analysis (navilint) + runtime verification guards.

Static side (stdlib-only, no jax import)::

    from repro.analysis import analyze_paths, analyze_source

Runtime side (imports jax lazily, on first use)::

    from repro.analysis.runtime import CompileCounter, instrument_locks

CLI: ``python -m repro.analysis --strict src tests`` (see __main__).
"""

from repro.analysis.navilint import (  # noqa: F401
    Finding,
    analyze_file,
    analyze_paths,
    analyze_source,
)
from repro.analysis.registry import HOT_PATHS  # noqa: F401

__all__ = [
    "Finding",
    "analyze_file",
    "analyze_paths",
    "analyze_source",
    "HOT_PATHS",
]
