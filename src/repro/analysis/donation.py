"""Donation safety (NX7xx): def-use analysis of donated buffers.

The serving tier's overlapped stepping donates its state buffers to the
device (``engine_steps_overlap`` / ``steps_program(donate=True)``): the
callee may write the result *in place* of the argument, so the
argument's buffer is dead the moment the call is dispatched. On CPU,
JAX silently ignores donation -- which is exactly why this bug class
never shows up in the CI suites and detonates only on real TPU/GPU
hardware. This pass makes the lifecycle a static contract:

* **donating callables** are discovered from the call graph: direct
  ``donate_argnums`` decorations, the conditional
  ``donate_argnums=(3,) if donate else ()`` program builders behind
  ``steps_program(params, donate=True)``-style constructors, instance
  attributes bound to such constructor calls, and *wrapper methods*
  that pass their own parameter straight into a donated position
  (``_FlatLanes.steps`` donates its ``st`` because
  ``engine_steps_overlap`` does);
* **NX701 use-after-donate** -- a read of a donated name (or
  ``self.attr`` chain) after the donating call, before it is rebound.
  Rebinding in the same statement (``self.st, live = f(..., self.st)``)
  is the sanctioned pattern and passes.
* **NX702 discarded donation** -- a donating call whose result is
  thrown away (a bare expression statement): the result holds the only
  live buffers; dropping it leaves every donated argument dead with
  nothing to rebind from.
* **NX703 donation alias** -- the same value passed at a donated
  position *and* anywhere else in one call: the other use reads a
  buffer the callee is free to overwrite.

Suppression kind: ``# navilint: donate-ok <reason>``.
"""

from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.callgraph import FuncInfo, Project, attr_chain

USE_AFTER_DONATE = "NX701"
DISCARDED_DONATION = "NX702"
DONATION_ALIAS = "NX703"


def _render(node: ast.AST) -> Optional[str]:
    chain = attr_chain(node)
    return ".".join(chain) if chain else None


class DonationTables:
    """Project-wide donation facts, computed before the def-use walk."""

    def __init__(self, project: Project):
        self.project = project
        #: constructor method name -> donated positions of the callable
        #: it returns when called with donate=True
        self.constructors: dict[str, tuple] = {}
        #: (module path, class qualname, attr) -> donated positions for
        #: instance attributes bound to donate=True constructor calls
        self.attr_programs: dict[tuple, tuple] = {}
        #: method name -> donated DEF positions, when every analyzed
        #: class defining that method agrees (duck-typed backends)
        self.duck_methods: dict[str, tuple] = {}
        #: FuncInfo -> donated DEF positions (direct + wrapper-propagated)
        self.func_donates: dict[FuncInfo, tuple] = {}
        self._build()

    # -- construction ---------------------------------------------------
    def _build(self) -> None:
        for fi in self.project.iter_funcs():
            if fi.donate_idx and fi.donate_cond is None:
                self.func_donates[fi] = fi.donate_idx
        self._find_constructors()
        self._find_attr_programs()
        self._propagate_wrappers()
        self._build_duck_table()

    def _find_constructors(self) -> None:
        """Methods with a ``donate`` parameter returning either a
        conditionally-donating nested jit or ``self._program("<kind>",
        ...)`` whose ``_build_<kind>`` sibling holds one."""
        for mod in self.project.modules:
            for fi in mod.funcs.values():
                if fi.cls is None or "donate" not in (
                        fi.params + fi.kwonly):
                    continue
                pos = self._constructor_positions(mod, fi)
                if pos:
                    prev = self.constructors.get(fi.node.name)
                    if prev is None or prev == pos:
                        self.constructors[fi.node.name] = pos

    def _constructor_positions(self, mod, fi: FuncInfo
                               ) -> Optional[tuple]:
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Return) or node.value is None:
                continue
            val = node.value
            if isinstance(val, ast.IfExp):
                target = self.project.resolve(mod, fi.qualname, val.body)
                if target is not None and target.donate_idx:
                    return target.donate_idx
            if (isinstance(val, ast.Call) and val.args
                    and isinstance(val.func, ast.Attribute)
                    and val.func.attr == "_program"
                    and isinstance(val.args[0], ast.Constant)):
                kind = val.args[0].value
                builder = mod.funcs.get(f"{fi.cls}._build_{kind}")
                if builder is not None:
                    prefix = f"{builder.qualname}.<locals>."
                    for qual, sub in mod.funcs.items():
                        if qual.startswith(prefix) and sub.donate_cond:
                            return sub.donate_idx
        return None

    def _find_attr_programs(self) -> None:
        """``self.X = obj.steps_program(params, donate=True)``: the
        attribute holds a donating compiled callable."""
        for mod in self.project.modules:
            for fi in mod.funcs.values():
                if fi.cls is None:
                    continue
                for node in ast.walk(fi.node):
                    if not isinstance(node, ast.Assign):
                        continue
                    pos = self._donating_constructor_call(node.value)
                    if pos is None:
                        continue
                    for t in node.targets:
                        if (isinstance(t, ast.Attribute)
                                and isinstance(t.value, ast.Name)
                                and t.value.id == "self"):
                            self.attr_programs[
                                (mod.path, fi.cls, t.attr)] = pos

    def _donating_constructor_call(self, expr: ast.AST
                                   ) -> Optional[tuple]:
        """Positions when ``expr`` is ``<x>.<ctor>(..., donate=True)``."""
        if not (isinstance(expr, ast.Call)
                and isinstance(expr.func, ast.Attribute)):
            return None
        pos = self.constructors.get(expr.func.attr)
        if pos is None:
            return None
        for kw in expr.keywords:
            if (kw.arg == "donate" and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True):
                return pos
        return None

    def _propagate_wrappers(self) -> None:
        """A method passing its own (unrebound) parameter into a donated
        position donates that parameter itself."""
        for _ in range(3):
            changed = False
            for mod in self.project.modules:
                for fi in mod.funcs.values():
                    if fi in self.func_donates:
                        continue
                    pos = self._wrapper_positions(mod, fi)
                    if pos:
                        self.func_donates[fi] = pos
                        changed = True
            if not changed:
                break

    def _wrapper_positions(self, mod, fi: FuncInfo) -> tuple:
        params = fi.params
        rebound: set = set()
        donated: set = set()
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        rebound.add(t.id)
            if not isinstance(node, ast.Call):
                continue
            pos = self.call_donated_args(mod, fi, node)
            for i in pos:
                if i < len(node.args):
                    arg = node.args[i]
                    if (isinstance(arg, ast.Name)
                            and arg.id in params
                            and arg.id not in rebound):
                        donated.add(params.index(arg.id))
        return tuple(sorted(donated))

    def _build_duck_table(self) -> None:
        """Method names whose every class-level definition donates the
        same DEF positions -- applied to unresolvable ``x.m(...)``.
        A same-name method that merely *forwards* to another ``.m(...)``
        call (the ``LaneBatch.evict`` -> ``backend.evict`` dispatcher
        pattern) is not counted as disagreement."""
        by_name: dict[str, set] = {}
        for fi, pos in self.func_donates.items():
            if fi.cls is not None:
                by_name.setdefault(fi.node.name, set()).add(pos)
        for mod in self.project.modules:
            for fi in mod.funcs.values():
                if fi.cls is None or fi.node.name not in by_name \
                        or fi in self.func_donates:
                    continue
                forwards = any(
                    isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr == fi.node.name
                    for n in ast.walk(fi.node))
                if not forwards:
                    by_name[fi.node.name].add(())
        for name, variants in by_name.items():
            if len(variants) == 1:
                pos = next(iter(variants))
                if pos:
                    self.duck_methods[name] = pos

    # -- per-call-site donation ----------------------------------------
    def call_donated_args(self, mod, fi: FuncInfo, call: ast.Call,
                          local_programs: Optional[dict] = None) -> tuple:
        """Donated CALL-ARGUMENT indices for one call expression (method
        receiver offset already applied)."""
        callee = self.project.resolve(mod, fi.qualname, call.func)
        if callee is not None:
            pos = self.func_donates.get(callee, ())
            if not pos:
                return ()
            if callee.cls is not None and isinstance(
                    call.func, ast.Attribute):
                # bound method: def position i surfaces at call arg i-1
                return tuple(i - 1 for i in pos if i >= 1)
            return pos
        if isinstance(call.func, ast.Attribute):
            key = _render(call.func)
            if key is not None and key.startswith("self.") \
                    and fi.cls is not None:
                hit = self.attr_programs.get(
                    (mod.path, fi.cls, call.func.attr))
                if hit is not None:
                    return hit
            pos = self.duck_methods.get(call.func.attr)
            if pos is not None:
                out = tuple(i - 1 for i in pos if i >= 1)
                # arity guard: a same-named method taking fewer args is
                # a different signature (``LaneBatch.evict(lane_ids)``
                # vs ``_FlatLanes.evict(st, udc, mask)``), not a
                # donating duck match
                if out and all(i < len(call.args) for i in out):
                    return out
                return ()
        elif isinstance(call.func, ast.Name) and local_programs:
            hit = local_programs.get(call.func.id)
            if hit is not None:
                return hit
        return ()


class _DefUse:
    """Linear def-use walk of one function body: donated keys die at
    the donating call and revive at rebinding."""

    def __init__(self, tables: DonationTables, mod, fi: FuncInfo, emit):
        self.tables = tables
        self.mod = mod
        self.fi = fi
        self.emit = emit
        self.dead: dict[str, int] = {}      # key -> donation line
        self.reported: set = set()
        self.local_programs: dict[str, tuple] = {}
        self.span = (fi.node.lineno, fi.node.lineno)

    # -- statement processing ------------------------------------------
    def run(self) -> None:
        self.walk(self.fi.node.body)

    def walk(self, body: list) -> None:
        for stmt in body:
            self.span = (stmt.lineno, stmt.end_lineno or stmt.lineno)
            self.stmt(stmt)

    def stmt(self, node: ast.stmt) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        if isinstance(node, (ast.If, ast.While)):
            self.check_reads(node.test)
            self.walk(node.body)
            self.walk(node.orelse)
            return
        if isinstance(node, ast.For):
            self.check_reads(node.iter)
            self.walk(node.body)
            self.walk(node.orelse)
            return
        if isinstance(node, ast.With):
            for item in node.items:
                self.process_expr(item.context_expr)
            self.walk(node.body)
            return
        if isinstance(node, ast.Try):
            self.walk(node.body)
            for h in node.handlers:
                self.walk(h.body)
            self.walk(node.orelse)
            self.walk(node.finalbody)
            return
        targets: list = []
        value = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            targets = [node.target]
            value = node.value
        elif isinstance(node, ast.Return):
            value = node.value
        elif isinstance(node, ast.Expr):
            value = node.value
            if isinstance(value, ast.Call):
                pos = self.tables.call_donated_args(
                    self.mod, self.fi, value, self.local_programs)
                if pos:
                    self.emit(
                        DISCARDED_DONATION, self.mod, value, self.span,
                        "result of a donating call discarded: the "
                        "donated arguments are dead and the only live "
                        "buffers are in the dropped result -- bind it "
                        "(e.g. 'st, ... = ...') or use the non-donating "
                        "variant")
        else:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.expr):
                    self.check_reads(child)
            return
        # 1) reads + donations in the value expression
        if value is not None:
            self.process_expr(value, skip_targets=targets)
        # 2) rebinding revives keys; track program-constructor locals
        if isinstance(node, ast.Assign) and value is not None:
            ctor = self.tables._donating_constructor_call(value)
            if ctor is not None:
                for t in targets:
                    if isinstance(t, ast.Name):
                        self.local_programs[t.id] = ctor
        for t in targets:
            self.rebind(t)

    # -- expression processing -----------------------------------------
    def process_expr(self, expr: ast.AST, skip_targets=()) -> None:
        """Check reads of dead keys, then apply this expression's
        donations (reads happen at dispatch; death is after)."""
        self.check_reads(expr)
        for call in ast.walk(expr):
            if isinstance(call, ast.Call):
                self.apply_donation(call)

    def apply_donation(self, call: ast.Call) -> None:
        pos = self.tables.call_donated_args(
            self.mod, self.fi, call, self.local_programs)
        if not pos:
            return
        donated_keys = []
        for i in pos:
            if i < len(call.args):
                key = _render(call.args[i])
                if key is not None:
                    donated_keys.append((key, call.args[i]))
        # NX703: donated value aliased elsewhere in the same call
        all_renders = []
        for j, a in enumerate(call.args):
            all_renders.append((_render(a), j))
        for kw in call.keywords:
            all_renders.append((_render(kw.value), None))
        for key, node in donated_keys:
            uses = [r for r, j in all_renders if r == key]
            if len(uses) > 1:
                self.emit(
                    DONATION_ALIAS, self.mod, node, self.span,
                    f"'{key}' passed at a donated position and again in "
                    f"the same call: the callee may overwrite the "
                    f"donated buffer the other argument still reads")
        for key, _node in donated_keys:
            self.dead[key] = getattr(call, "lineno", self.span[0])

    def check_reads(self, expr: ast.AST) -> None:
        if expr is None or not self.dead:
            return
        for node in ast.walk(expr):
            if not isinstance(node, (ast.Name, ast.Attribute)):
                continue
            if isinstance(getattr(node, "ctx", None), ast.Store):
                continue
            key = _render(node)
            if key is None:
                continue
            hit = self._dead_hit(key)
            if hit is not None and (key, self.span[0]) not in \
                    self.reported:
                self.reported.add((key, self.span[0]))
                self.emit(
                    USE_AFTER_DONATE, self.mod, node, self.span,
                    f"'{key}' was donated on line {self.dead[hit]} and "
                    f"not rebound since: its buffer may already be "
                    f"overwritten by the callee (JAX ignores donation "
                    f"on CPU, so tests pass and TPU/GPU corrupts) -- "
                    f"rebind it from the call result, or annotate "
                    f"'# navilint: donate-ok <reason>'")

    def _dead_hit(self, key: str) -> Optional[str]:
        if key in self.dead:
            return key
        # a read of a donated chain's prefix-extension (self.st.d) or
        # of a dead leaf through its parent is also a use
        for dead in self.dead:
            if key.startswith(dead + "."):
                return dead
        return None

    def rebind(self, target: ast.AST) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for t in target.elts:
                self.rebind(t)
            return
        if isinstance(target, ast.Starred):
            self.rebind(target.value)
            return
        key = _render(target)
        if key is not None:
            for dead in [d for d in self.dead
                         if d == key or d.startswith(key + ".")]:
                del self.dead[dead]


def check(project: Project, emit) -> None:
    """Run the donation-safety pass; findings go through ``emit``."""
    tables = DonationTables(project)
    for mod in project.modules:
        for fi in mod.funcs.values():
            has_donation = any(
                isinstance(n, ast.Call)
                and (tables.call_donated_args(mod, fi, n)
                     or tables._donating_constructor_call(n) is not None)
                for n in ast.walk(fi.node))
            if has_donation:
                _DefUse(tables, mod, fi, emit).run()
