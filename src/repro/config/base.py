"""Typed configuration system.

Every assigned architecture is a module in ``repro.configs`` that builds an
:class:`ArchDef` (full-size config + its shape set + a reduced smoke config)
and registers it under its ``--arch <id>``.  The launcher, dry-run, roofline
and tests all resolve architectures exclusively through this registry.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping, Optional

# --------------------------------------------------------------------------
# Shapes
# --------------------------------------------------------------------------

#: shape kinds determine which step function the dry-run lowers:
#:   train      -> train_step          (LM training)
#:   prefill    -> prefill_step        (inference prefill, serve path)
#:   decode     -> decode_step         (one token, KV cache of seq_len)
#:   graph_*    -> gnn train_step variants
#:   recsys_*   -> recsys train/serve/retrieval steps
VALID_KINDS = (
    "train",
    "prefill",
    "decode",
    "graph_full",
    "graph_minibatch",
    "graph_batched",
    "recsys_train",
    "recsys_serve",
    "recsys_retrieval",
)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str
    params: Mapping[str, int] = dataclasses.field(default_factory=dict)
    #: set for shapes that are documented skips (e.g. long_500k on pure
    #: full-attention archs). The dry-run records them as SKIP, not FAIL.
    skip_reason: Optional[str] = None

    def __post_init__(self):
        if self.kind not in VALID_KINDS:
            raise ValueError(f"unknown shape kind {self.kind!r} (valid: {VALID_KINDS})")

    def __getitem__(self, key: str) -> int:
        return self.params[key]

    def get(self, key: str, default: int | None = None):
        return self.params.get(key, default)


# --------------------------------------------------------------------------
# Model configs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class LMConfig:
    arch_id: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    activation: str = "swiglu"          # "swiglu" | "geglu"
    qkv_bias: bool = False               # qwen1.5
    attn_pattern: str = "global"         # "global" | "local_global" (gemma2)
    local_window: int = 4096             # sliding window for local layers
    attn_logit_softcap: float = 0.0      # gemma2 (50.0); 0 disables
    final_logit_softcap: float = 0.0     # gemma2 (30.0); 0 disables
    post_norms: bool = False             # gemma2 sandwich norms
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    norm_eps: float = 1e-6
    embedding_scale: bool = True         # gemma-style sqrt(d_model) scaling
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    optimizer: str = "adafactor"         # default for large-scale dry-runs

    family: str = "lm"

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def n_params(self) -> int:
        """Analytic parameter count (embedding + per-layer + head)."""
        d, h, kv, hd, ff, v, L = (self.d_model, self.n_heads, self.n_kv_heads,
                                  self.head_dim, self.d_ff, self.vocab_size,
                                  self.n_layers)
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.moe is None:
            mlp = 3 * d * ff  # gated: up, gate, down
        else:
            e = self.moe
            mlp = (e.n_experts + e.n_shared_experts) * 3 * d * e.d_ff_expert + d * e.n_experts
        norms = 2 * d
        emb = v * d if self.tie_embeddings else 2 * v * d
        return emb + L * (attn + mlp + norms) + d

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared experts)."""
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        e = self.moe
        dense_like = dataclasses.replace(self, moe=None, d_ff=1)
        base = dense_like.n_params() - L * 3 * d  # strip placeholder mlp
        active_mlp = (e.top_k + e.n_shared_experts) * 3 * d * e.d_ff_expert + d * e.n_experts
        return base + L * active_mlp


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    arch_id: str
    n_layers: int
    d_hidden: int
    aggregator: str = "sum"             # segment_sum
    mlp_layers: int = 2
    in_node_dim: int = 16               # overridden per-shape (d_feat)
    in_edge_dim: int = 4
    out_dim: int = 3                    # meshgraphnet predicts accelerations
    layer_norm: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    optimizer: str = "adamw"

    family: str = "gnn"


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    arch_id: str
    model: str                           # wide_deep | deepfm | dien | bst
    n_sparse: int
    embed_dim: int
    mlp_dims: tuple[int, ...]
    interaction: str                     # concat | fm | augru | transformer-seq
    field_vocabs: tuple[int, ...] = ()
    multi_hot_sizes: tuple[int, ...] = ()  # >1 => EmbeddingBag field
    n_dense: int = 13
    seq_len: int = 0                     # dien / bst behavior sequence
    gru_dim: int = 0                     # dien
    n_blocks: int = 0                    # bst
    n_heads: int = 0                     # bst
    item_vocab: int = 1_000_000          # behavior-sequence item table
    param_dtype: str = "float32"
    compute_dtype: str = "float32"       # CTR models are precision-sensitive
    remat: bool = False
    optimizer: str = "adamw"

    family: str = "recsys"

    def total_rows(self) -> int:
        return sum(self.field_vocabs) + (self.item_vocab if self.seq_len else 0)


AnyConfig = Any  # LMConfig | GNNConfig | RecsysConfig


# --------------------------------------------------------------------------
# Arch registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchDef:
    arch_id: str
    config: AnyConfig
    shapes: tuple[ShapeSpec, ...]
    smoke_config: AnyConfig
    description: str = ""
    source: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name!r}; have {[s.name for s in self.shapes]}")


_REGISTRY: dict[str, ArchDef] = {}


def register_arch(arch: ArchDef) -> ArchDef:
    if arch.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch id {arch.arch_id}")
    _REGISTRY[arch.arch_id] = arch
    return arch


def get_arch(arch_id: str) -> ArchDef:
    # importing repro.configs populates the registry
    import repro.configs  # noqa: F401
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def config_to_json(cfg: AnyConfig) -> str:
    return json.dumps(dataclasses.asdict(cfg), indent=2, default=str)
