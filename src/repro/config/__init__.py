"""Base experiment configuration."""
