"""Activation sharding constraints (context-scoped, no-op by default).

XLA SPMD propagation from params+inputs alone can lose the batch sharding
of activations inside scanned layers and fall back to gathering -- observed
in the dry-run baseline as ~80GB/chip of per-layer all-gathers
(experiments/perf_log.md it-2). Model code pins the canonical layouts via
``constrain(x, "dp", None, "tp")`` using *role* names:

  dp -> ("pod", "data") (whichever exist on the mesh)   batch-ish dims
  tp -> "model"                                          tensor-parallel dims

Outside an ``activation_sharding(mesh)`` context (unit tests, single-CPU
benches) ``constrain`` is the identity, so models stay mesh-agnostic.
Constraints on dims not divisible by the axis size are skipped.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_POLICY: Optional["Policy"] = None


@dataclasses.dataclass(frozen=True)
class Policy:
    mesh: Mesh
    seq_parallel: bool = True

    def resolve(self, role):
        if role is None:
            return None, 1
        if role == "dp":
            axes = tuple(a for a in self.mesh.axis_names
                         if a in ("pod", "data"))
            size = math.prod(self.mesh.shape[a] for a in axes)
            return (axes if len(axes) > 1 else axes[0]), size
        if role == "tp":
            return "model", self.mesh.shape["model"]
        if role == "sp":
            # sequence-parallel residual stream (Megatron-SP style): the
            # scan carry (and its saved-activation stack) shards its
            # sequence dim over the model axis; each layer re-gathers.
            if self.seq_parallel:
                return "model", self.mesh.shape["model"]
            return None, 1
        if role == "all":
            axes = tuple(self.mesh.axis_names)
            return axes, math.prod(self.mesh.shape[a] for a in axes)
        raise ValueError(role)


def axis_size(role: str) -> int:
    """Size of a role's axis group under the active policy (1 if none)."""
    if _POLICY is None:
        return 1
    return _POLICY.resolve(role)[1]


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, seq_parallel: bool = True):
    global _POLICY
    prev = _POLICY
    _POLICY = Policy(mesh, seq_parallel=seq_parallel)
    try:
        yield
    finally:
        _POLICY = prev


def constrain(x: jax.Array, *roles):
    """Apply a sharding constraint by role names; identity when no policy
    is active or when any constrained dim is not divisible."""
    if _POLICY is None:
        return x
    assert len(roles) == x.ndim, (roles, x.shape)
    spec = []
    for dim, role in zip(x.shape, roles):
        axes, size = _POLICY.resolve(role)
        if role is not None and dim % size != 0:
            axes = None   # skip non-divisible constraints (e.g. 24 heads/16)
        spec.append(axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_POLICY.mesh, P(*spec)))
