"""Mesh construction and autosharding helpers."""
