"""Per-architecture sharding policies for the production mesh.

Mesh axes: ("pod", "data", "model") multi-pod / ("data", "model") single
pod. Policies (DESIGN.md Section 4):

  LM train    FSDP over (pod x data) on the d_model dim of every weight,
              TP over model on heads / d_ff / vocab, EP over model for MoE
              experts; batch over (pod x data); vocab-parallel logits.
  LM decode   batch over (pod x data); KV cache sharded by kv-head over
              model when divisible (kv=16 archs) else by sequence
              (flash-decode-style distributed softmax falls out of XLA's
              sharded-reduction handling); long_500k (batch=1) shards the
              sequence over every axis.
  GNN         node tensors sharded over (pod x data); edge tensors over all
              axes (edge-parallel message passing); params replicated.
  RecSys      embedding tables row-sharded over model (the distributed
              embedding engine); batch over (pod x data); retrieval
              candidates sharded over model with distributed top-k.

Only params + step inputs are annotated; XLA SPMD propagates the rest.
Non-divisible dims (e.g. granite's 24 heads on a 16-way model axis, odd
vocab sizes) rely on GSPMD's padded uneven sharding.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config.base import GNNConfig, LMConfig, RecsysConfig, ShapeSpec


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def sanitize(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop sharding from dims the mesh axes don't divide (jit in_shardings
    require exact divisibility; padding non-divisible payloads is the data
    layer's job -- e.g. granite's 49155 vocab stays replicated)."""
    out = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            out.append(None)
            continue
        group = axes if isinstance(axes, tuple) else (axes,)
        size = int(np.prod([mesh.shape[a] for a in group]))
        out.append(axes if dim % size == 0 else None)
    return P(*out)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def lm_param_spec(path: str, shape: tuple, dp, model_size: int = 16) -> P:
    if "embed" in path:                       # [V, D]
        return P("model", dp)
    if "lm_head" in path:                     # [D, V]
        return P(dp, "model")
    if "router" in path:                      # [L, D, E]
        return P(None, dp, None)
    if "shared" in path or ("mlp" in path and len(shape) == 3):
        if path.endswith("wi"):               # [L, D, 2F]
            return P(None, dp, "model")
        if path.endswith("wo"):               # [L, F, D]
            return P(None, "model", dp)
    if "mlp" in path and len(shape) == 4:     # MoE experts
        if shape[1] % model_size:             # E doesn't divide the model
            # axis (granite: 40/16): shard the matmul dims over both axes
            # instead -- replicated experts would cost params+grads x16
            if path.endswith("wi"):           # [L, E, D, 2Fe]
                return P(None, None, dp, "model")
            if path.endswith("wo"):           # [L, E, Fe, D]
                return P(None, None, "model", dp)
        if path.endswith("wi"):               # [L, E, D, 2Fe]
            return P(None, "model", dp, None)
        if path.endswith("wo"):               # [L, E, Fe, D]
            return P(None, "model", None, dp)
    if "attn" in path and len(shape) == 3:
        if path.endswith("wo"):               # [L, H*hd, D]
            return P(None, "model", dp)
        return P(None, dp, "model")           # wq/wk/wv [L, D, X]
    if "attn" in path and len(shape) == 2 and not path.endswith("scale"):
        return P(None, "model")               # biases [L, X]
    return P(*([None] * len(shape)))          # norms etc: replicated


def recsys_param_spec(path: str, shape: tuple, dp) -> P:
    # row-shard every big [V, D] embedding table over model; the dense
    # towers/GRU/transformer params are small and replicate
    if len(shape) == 2 and shape[0] >= 4096:
        return P("model", None)
    return P(*([None] * len(shape)))


def param_specs(cfg, params_spec: Any, mesh: Mesh) -> Any:
    dp = dp_axes(mesh)

    def rule(path, leaf):
        p = _path_str(path)
        if isinstance(cfg, LMConfig):
            spec = lm_param_spec(p, leaf.shape, dp, mesh.shape["model"])
        elif isinstance(cfg, RecsysConfig):
            spec = recsys_param_spec(p, leaf.shape, dp)
        else:
            spec = P(*([None] * len(leaf.shape)))  # GNN: replicate
        return sanitize(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, params_spec)


def opt_specs(param_spec_tree: Any, opt_state_spec: Any) -> Any:
    """Optimizer-state specs derived from param specs.

    AdamW m/v mirror the param; Adafactor vr drops the last dim's axis and
    vc drops the second-to-last (factored stats follow their dims)."""
    flat_params, _ = jax.tree_util.tree_flatten(param_spec_tree)

    def build(sub, pspec_tree):
        # m / v / per_param subtrees share the params' structure
        def per_leaf(path, leaf):
            p = _path_str(path)
            # find matching param spec by aligning tree structures below
            return leaf
        return sub

    # walk the opt-state pytree; anything whose shape matches a param gets
    # that param's spec; vr/vc get reduced specs; scalars are replicated.
    params_by_struct = {}

    def assign(opt_leaf_path, opt_leaf):
        p = _path_str(opt_leaf_path)
        return opt_leaf

    # simpler: structural recursion below
    def mirror(opt_tree, param_tree):
        if isinstance(opt_tree, dict):
            if set(opt_tree) == {"vr", "vc"}:
                ps = param_tree  # a P for the param
                return {"vr": P(*ps[:-1]), "vc": P(*(ps[:-2] + ps[-1:]))}
            if set(opt_tree) == {"v"} and isinstance(param_tree, P):
                return {"v": param_tree}
            return {k: mirror(v, param_tree[k] if isinstance(param_tree, dict)
                              and k in param_tree else param_tree)
                    for k, v in opt_tree.items()}
        if isinstance(opt_tree, (tuple, list)):
            t = type(opt_tree)
            if isinstance(param_tree, (tuple, list)):
                return t(mirror(o, q) for o, q in zip(opt_tree, param_tree))
            return t(mirror(o, param_tree) for o in opt_tree)
        if isinstance(param_tree, P):
            if hasattr(opt_tree, "shape") and len(opt_tree.shape) == 0:
                return P()
            return param_tree
        return P()

    def top(opt_state_spec, param_spec_tree):
        out = {}
        for k, v in opt_state_spec.items():
            if k == "count":
                out[k] = P()
            elif k in ("m", "v", "per_param"):
                out[k] = mirror(v, param_spec_tree)
            else:
                out[k] = jax.tree.map(lambda _: P(), v)
        return out

    return top(opt_state_spec, param_spec_tree)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(cfg, shape: ShapeSpec, specs: dict, mesh: Mesh) -> dict:
    raw = _batch_specs_raw(cfg, shape, specs, mesh)
    return jax.tree.map(
        lambda p, s: sanitize(p, s.shape, mesh), raw, dict(specs),
        is_leaf=lambda x: isinstance(x, P))


def _batch_specs_raw(cfg, shape: ShapeSpec, specs: dict, mesh: Mesh) -> dict:
    dp = dp_axes(mesh)
    model_size = mesh.shape["model"]

    if isinstance(cfg, LMConfig):
        if shape.kind in ("train", "prefill"):
            return {"tokens": P(dp, None)}
        # decode: cache [L, B, S, KV, hd] + token [B]
        b = shape["global_batch"]
        if b == 1:
            cache_kv = P(None, None, dp + ("model",), None, None)
            token = P(None)
        elif cfg.n_kv_heads % model_size == 0:
            cache_kv = P(None, dp, None, "model", None)
            token = P(dp)
        else:
            cache_kv = P(None, dp, "model", None, None)
            token = P(dp)
        from repro.models.transformer import KVCache
        return {"cache": KVCache(k=cache_kv, v=cache_kv, length=P()),
                "token": token}

    if isinstance(cfg, GNNConfig):
        all_axes = dp + ("model",)
        out = {}
        for name, s in specs.items():
            if name.startswith("edge"):
                out[name] = P(all_axes, *([None] * (len(s.shape) - 1)))
            else:
                out[name] = P(dp, *([None] * (len(s.shape) - 1)))
        return out

    if isinstance(cfg, RecsysConfig):
        out = {}
        for name, s in specs.items():
            if name == "candidates":
                out[name] = P("model")
            else:
                out[name] = P(dp, *([None] * (len(s.shape) - 1)))
        return out

    raise TypeError(type(cfg))


def to_named(tree_of_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda p: NamedSharding(mesh, p), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
