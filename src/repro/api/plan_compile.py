"""Compiled-program cache for the kNN plan operator.

``jax.jit`` already memoizes traces, but the seed code paid the full
retrace cost whenever a new (batch shape, SearchParams) combination first
arrived -- and gave callers no way to *observe* compilation, so the
serving engine could not distinguish a warm path from a cold one. This
layer makes compilation explicit:

* programs are ahead-of-time lowered + compiled (``jit(...).lower(...)
  .compile()``) and stored under a :class:`ProgramKey` --
  ``(n, dim, k, efs, heuristic, metric, batch_shape, engine)`` plus the
  minor search knobs -- so executing a cached program can never retrace;
  the ``engine`` arm keeps the batched-frontier engine ("batched") and
  the vmap reference oracle ("vmap") as distinct programs, and the
  ``sharded`` arm does the same for ShardedNavix shard_map programs
  (:meth:`ProgramCache.search_sharded`);
* batch shapes are bucketed to the next power of two (queries are padded
  with their first row and the result sliced back), so a serving engine
  draining groups of 17, then 19, then 23 requests compiles once, not
  three times;
* hits/misses are counted; tests assert that the second execution of a
  same-shape plan performs zero new compilations.

The cache is owned by :class:`repro.api.db.NavixDB` and shared with every
index in its catalog (``NavixIndex.program_cache``), so the compatibility
API ``NavixIndex.search(...)`` benefits too.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import HnswGraph
from repro.core.search import SearchParams, SearchResult
from repro.core.search import search as _search
from repro.core.search_batch import resolve_engine


class ProgramKey(NamedTuple):
    """Identity of one compiled search program (the plan's *shape*)."""
    n: int
    dim: int
    k: int
    efs: int
    heuristic: int
    metric: str
    batch_shape: Optional[int]     # None = single-query program
    knobs: tuple = ()              # (ub, lf, two_hop_cap, max_iters,
                                   #  m_l, n_upper, m_u)
    engine: str = "single"         # "single" | "vmap" | "batched" -- the
                                   # two batch engines are distinct programs
    per_lane_sel: bool = False     # [B, W] per-lane semimasks (mixed-plan
                                   # batches) vs one shared [W] mask
    sharded: int = 0               # shard count S of a ShardedNavix
                                   # program (0 = unsharded) -- the MODEL
                                   # axis: every shard searches its own
                                   # subgraph and the results merge
    lane_shards: int = 1           # DATA-axis size of the mesh: the lane
                                   # (batch) dim is split this many ways,
                                   # each device stepping B/lane_shards
                                   # lanes; batch buckets are rounded up
                                   # to a multiple of it
    resident: str = "f32"          # device residency of the vector store:
                                   # "f32" (dense rows) | "int8" (codes +
                                   # per-vector scales; quantized-resident
                                   # engine) -- distinct programs, since
                                   # the gather primitive differs


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0

    @property
    def compiles(self) -> int:
        return self.misses

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "compiles": self.compiles}


def _bucket(b: int) -> int:
    """Round a batch size up to the next power of two (min 1)."""
    out = 1
    while out < b:
        out <<= 1
    return out


class ProgramCache:
    """AOT program cache for single-query and batched filtered search."""

    def __init__(self):
        self._programs: dict[ProgramKey, jax.stages.Compiled] = {}
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._programs)

    def info(self) -> dict:
        return {**self.stats.as_dict(), "programs": len(self._programs)}

    # -- internals ----------------------------------------------------------
    def _key(self, graph: HnswGraph, params: SearchParams,
             batch_shape: Optional[int], engine: str = "single",
             per_lane_sel: bool = False) -> ProgramKey:
        from repro.core.quantize import QuantizedStore
        resident = ("int8" if isinstance(graph.vectors, QuantizedStore)
                    else "f32")
        return ProgramKey(
            n=graph.n, dim=graph.dim, k=params.k, efs=params.efs,
            heuristic=params.heuristic, metric=params.metric,
            batch_shape=batch_shape,
            knobs=(params.ub, params.lf, params.two_hop_cap,
                   params.max_iters, graph.m_l, graph.n_upper,
                   graph.m_u),
            engine=engine, per_lane_sel=per_lane_sel, resident=resident)

    def _get(self, key: ProgramKey, fn, graph, q, sel_bits, params, sigma_g):
        prog = self._programs.get(key)
        if prog is None:
            self.stats.misses += 1
            jitted = jax.jit(fn, static_argnames=("params",))
            prog = jitted.lower(graph, q, sel_bits, params=params,
                                sigma_g=sigma_g).compile()
            self._programs[key] = prog
        else:
            self.stats.hits += 1
        return prog

    # -- execution ----------------------------------------------------------
    def search(self, graph: HnswGraph, q: jax.Array, sel_bits: jax.Array,
               params: SearchParams, sigma_g) -> SearchResult:
        """Single-query filtered search through a cached program."""
        sigma_g = jnp.asarray(sigma_g, dtype=jnp.float32)
        key = self._key(graph, params, None)
        prog = self._get(key, _search, graph, q, sel_bits, params, sigma_g)
        return prog(graph, q, sel_bits, sigma_g=sigma_g)

    def search_batch(self, graph: HnswGraph, Q: jax.Array,
                     sel_bits: jax.Array, params: SearchParams,
                     sigma_g) -> SearchResult:
        """vmap-engine batch search (the reference oracle path)."""
        return self.batch("vmap")(graph, Q, sel_bits, params, sigma_g)

    def search_many(self, graph: HnswGraph, Q: jax.Array,
                    sel_bits: jax.Array, params: SearchParams,
                    sigma_g) -> SearchResult:
        """Batched-frontier engine search (the serving throughput path).

        Compiled under its own cache-key arm (``engine="batched"``) so the
        two batch engines never collide even at identical plan shapes.
        """
        return self.batch("batched")(graph, Q, sel_bits, params, sigma_g)

    def batch(self, engine: str):
        """The cached batch entry point for a (validated) engine name."""
        return functools.partial(self._run_batched, resolve_engine(engine),
                                 engine)

    def _run_batched(self, fn, engine: str, graph: HnswGraph, Q: jax.Array,
                     sel_bits: jax.Array, params: SearchParams,
                     sigma_g) -> SearchResult:
        """Shared batch-program path: the batch is padded to its
        power-of-two bucket so nearby batch sizes share one program, and
        results are sliced back to the true size.

        ``sel_bits`` may be one shared ``[W]`` semimask or a per-lane
        ``[B, W]`` stack (the mixed-plan serving path); per-lane masks
        (and a per-lane ``sigma_g`` vector) are padded alongside the
        query rows and compile under a distinct ``per_lane_sel`` key arm.

        Padding and result slicing run in host numpy: eager jnp ops here
        would each compile a throwaway XLA program keyed on the UNpadded
        batch size, re-introducing per-size compiles the bucket exists
        to avoid (caught by the CompileCounter runtime guard).
        """
        sigma_g = np.asarray(sigma_g, dtype=np.float32)
        per_lane = sel_bits.ndim == 2
        b = Q.shape[0]
        bb = _bucket(b)
        if bb != b:
            pad = (bb - b,)
            Qh = np.asarray(Q)
            Q = np.concatenate(
                [Qh, np.broadcast_to(Qh[:1], pad + Qh.shape[1:])])
            if per_lane:
                sh = np.asarray(sel_bits)
                sel_bits = np.concatenate(
                    [sh, np.broadcast_to(sh[:1], pad + sh.shape[1:])])
            if sigma_g.ndim == 1:
                sigma_g = np.concatenate(
                    [sigma_g, np.broadcast_to(sigma_g[:1], pad)])
        key = self._key(graph, params, bb, engine=engine,
                        per_lane_sel=per_lane)
        prog = self._get(key, fn, graph, Q, sel_bits, params, sigma_g)
        res = prog(graph, Q, sel_bits, sigma_g=sigma_g)
        if bb != b:
            res = jax.tree_util.tree_map(lambda a: np.asarray(a)[:b], res)
        return res

    def search_sharded(self, sn, Q: jax.Array, sel_bits: jax.Array,
                       alive: jax.Array, params: SearchParams
                       ) -> SearchResult:
        """Sharded batched search through the cache (the ``sharded`` key
        arm). The program is the ShardedNavix's shard_map search: the
        batched-frontier engine on every shard + one global merge.

        ``sel_bits`` is shared ``[S, W]`` or per-lane ``[S, B, W]``
        (padded along the lane axis with the batch bucket). Stored as
        the memoized jitted callable rather than an AOT ``Compiled`` --
        shard_map programs re-dispatch safely when input shardings vary,
        and the jit cache still guarantees zero retraces at a fixed plan
        shape (asserted via the hit/miss stats).
        """
        per_lane = sel_bits.ndim == 3
        b = Q.shape[0]
        bb = _bucket(b)
        ls = sn.lane_shards
        if bb % ls:
            # the data axis splits the lane dim; the padded bucket must
            # divide evenly (a power-of-two bucket already does for a
            # power-of-two data axis)
            bb = -(-bb // ls) * ls
        if bb != b:
            # host-side padding for the same reason as _run_batched:
            # eager jnp pads compile per unpadded batch size
            pad = bb - b
            Qh = np.asarray(Q)
            Q = np.concatenate(
                [Qh, np.broadcast_to(Qh[:1], (pad,) + Qh.shape[1:])])
            if per_lane:
                sh = np.asarray(sel_bits)
                sel_bits = np.concatenate(
                    [sh, np.broadcast_to(sh[:, :1],
                                         (sh.shape[0], pad, sh.shape[2]))],
                    axis=1)
        key = ProgramKey(
            n=sn.n_total, dim=sn.dim, k=params.k, efs=params.efs,
            heuristic=params.heuristic, metric=params.metric,
            batch_shape=bb,
            knobs=(params.ub, params.lf, params.two_hop_cap,
                   params.max_iters, sn.n_local,
                   int(sn.graphs.lower.shape[-1]),
                   int(sn.graphs.upper_ids.shape[-1]),
                   int(sn.graphs.upper.shape[-1]),
                   sn.model_axis, sn.data_axis,
                   # mesh/device identity: the cached program closes over
                   # the mesh, so two same-shape indexes on different
                   # device groups must never share an entry
                   tuple(d.id for d in sn.mesh.devices.flat)),
            engine="batched", per_lane_sel=per_lane, sharded=sn.n_shards,
            lane_shards=ls)
        prog = self._programs.get(key)
        if prog is None:
            self.stats.misses += 1
            prog = sn._program("search", params, per_lane=per_lane)
            self._programs[key] = prog
        else:
            self.stats.hits += 1
        res = prog(sn.graphs, Q, sel_bits, alive)
        if bb != b:
            res = jax.tree_util.tree_map(lambda a: np.asarray(a)[:b], res)
        return res
