"""Unified NavixDB query API.

The paper's native-integration claim, as a Python surface: one ``NavixDB``
owns the graph store, an index catalog (CREATE_HNSW_INDEX), and query
execution (QUERY_HNSW_INDEX as a plan operator), with a fluent builder and
a compiled-program cache underneath.
"""

from repro.api.builder import Q  # noqa: F401
from repro.api.db import (IndexEntry, NavixDB, ResultSet,  # noqa: F401
                          StageTimings)
from repro.api.plan_compile import ProgramCache, ProgramKey  # noqa: F401
