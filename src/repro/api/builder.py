"""Fluent query builder -- the Cypher-analogue surface of NavixDB.

    Q.match("Chunk").where("year", ">=", 2020).knn(qvec, k=10)
    Q.match("Person").where("birth_date", "range", lo=0, hi=18250)
     .hop("PersonChunk", "fwd").knn(qvec, k=100).project("cID")

Each call returns a new immutable builder; ``.plan()`` compiles to the
exact ``repro.query.operators`` tree a user could hand-build (the two are
``==``-equal, which the tests assert). The query *vector* passed to
``.knn`` is bound on the builder, not in the plan node, so the same plan
shape can be re-executed with any vector (or a batch) and reuses one
compiled program; ``.knn()`` without a vector produces a plan template for
the serving engine.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.query.operators import (And, Filter, HopJoin, KnnSearch, Limit,
                                   NodeScan, Not, Or, Plan, Project)


@dataclasses.dataclass(frozen=True)
class Q:
    """Immutable builder wrapping a partially-constructed plan tree."""
    _plan: Plan
    bound_query: Optional[np.ndarray] = None

    # -- entry point --------------------------------------------------------
    @classmethod
    def match(cls, table: str) -> "Q":
        """MATCH (x:table) -- start a selection over one node table."""
        return cls(NodeScan(table))

    # -- selection subquery (Q_S) ------------------------------------------
    def where(self, column: str, op: str, value=None, *, lo=None,
              hi=None) -> "Q":
        """WHERE column <op> value; op in {<, <=, >, >=, ==, range, isin}."""
        return self._wrap(Filter(self._plan, column, op, value=value,
                                 lo=lo, hi=hi))

    def hop(self, rel: str, direction: str = "fwd") -> "Q":
        """Semi-join one relationship hop; chain twice for 2-hop RAG."""
        return self._wrap(HopJoin(self._plan, rel, direction))

    def union(self, other: "Q") -> "Q":
        return self._wrap(Or(self._plan, other._plan))

    def intersect(self, other: "Q") -> "Q":
        return self._wrap(And(self._plan, other._plan))

    def negate(self) -> "Q":
        return self._wrap(Not(self._plan))

    # -- the kNN operator ---------------------------------------------------
    def knn(self, query: Optional[np.ndarray] = None, k: int = 10,
            index: Optional[str] = None, efs: int = 0,
            heuristic: str = "adaptive_local") -> "Q":
        """QUERY_HNSW_INDEX over the current selection.

        ``query`` ([d] or [b, d]) is bound for execute(); omit it to build
        a reusable plan template (the vector is then supplied per request,
        e.g. by the serving engine).
        """
        node = KnnSearch(child=self._plan, k=k, index=index, efs=efs,
                         heuristic=heuristic)
        bound = None if query is None else np.asarray(query, np.float32)
        return Q(node, bound)

    # -- row operators ------------------------------------------------------
    def project(self, *columns: str) -> "Q":
        return self._wrap(Project(self._plan, tuple(columns)))

    def limit(self, n: int) -> "Q":
        return self._wrap(Limit(self._plan, n))

    # -- compile ------------------------------------------------------------
    def plan(self) -> Plan:
        return self._plan

    def _wrap(self, node: Plan) -> "Q":
        return Q(node, self.bound_query)
