"""NavixDB -- the unified query facade (the paper's "native" claim as API).

The paper's point (Sections 2.3, 4) is that QUERY_HNSW_INDEX is just
another operator inside the GDBMS query processor: the selection subquery
runs first, its selected set S reaches the kNN operator as a node semimask
via sideways information passing, and everything composes with joins,
projections and limits. ``NavixDB`` is that processor:

    db = NavixDB(store)
    db.create_index("chunk_emb", "Chunk", column="embedding",
                    config=NavixConfig(metric="cos"))      # CREATE_HNSW_INDEX
    rs = db.execute(
        Q.match("Person").where("birth_date", "range", lo=0, hi=18250)
         .hop("PersonChunk", "fwd")
         .knn(qvec, k=10).project("cID"))                  # QUERY_HNSW_INDEX
    rs.ids, rs.dists, rs.columns["cID"], rs.timings.prefilter_ms

One ``execute`` runs the whole pipeline -- prefilter -> semimask packing ->
adaptive-local search (through the compiled-program cache) -> projection --
and returns a typed :class:`ResultSet` with the paper's Table 7 per-stage
timing split. The legacy path ``NavixIndex.search(..., semimask=...)``
remains as a thin compatibility layer and shares the same program cache
once the index is registered in a catalog.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterator, Optional

import numpy as np

from repro.api.plan_compile import ProgramCache
from repro.core.build import BuildStats
from repro.core.distributed import ShardedNavix
from repro.core.navix import NavixConfig, NavixIndex
from repro.query.operators import (KnnSearch, Plan, QueryResult,
                                   evaluate, output_table, split_pipeline)
from repro.storage.columnar import GraphStore


@dataclasses.dataclass
class StageTimings:
    """Per-stage wall times of one execute() (Table 7 accounting)."""
    prefilter_ms: float = 0.0      # Q_S evaluation (host, numpy)
    pack_ms: float = 0.0           # mask -> device bitset (SIP handoff)
    search_ms: float = 0.0         # kNN operator (device)
    rerank_ms: float = 0.0         # exact-tier re-rank (host; quantized
                                   # residency only)
    project_ms: float = 0.0        # projection / row materialization

    @property
    def total_ms(self) -> float:
        return (self.prefilter_ms + self.pack_ms + self.search_ms
                + self.rerank_ms + self.project_ms)

    def as_dict(self) -> dict:
        return {"prefilter_ms": self.prefilter_ms, "pack_ms": self.pack_ms,
                "search_ms": self.search_ms, "rerank_ms": self.rerank_ms,
                "project_ms": self.project_ms, "total_ms": self.total_ms}


@dataclasses.dataclass
class ResultSet:
    """Typed result of ``NavixDB.execute``.

    ``ids``/``dists`` are [k] for a single bound query or [b, k] for a
    batch; -1 ids are padding (fewer than k reachable selected nodes).
    ``columns`` holds the projected property columns gathered at ``ids``.
    """
    table: str
    ids: np.ndarray
    dists: Optional[np.ndarray]
    columns: dict[str, np.ndarray]
    sigma: float                   # selectivity |S| / |V| of the prefilter
                                   # (mean over lanes for per-lane masks)
    timings: StageTimings
    stats: Optional[object] = None          # SearchStats (kNN plans only)
    mask: Optional[np.ndarray] = None       # the Q_S semimask (host bool[n])
    sigmas: Optional[np.ndarray] = None     # per-lane selectivities (f32[b],
                                            # execute(masks=[...]) only)

    def __len__(self) -> int:
        return int(self.ids.shape[0])

    def rows(self) -> Iterator[dict]:
        """Iterate result rows as dicts (single-query plans only)."""
        if self.ids.ndim != 1:
            raise ValueError("rows() is for single-query results; "
                             "index batch results directly")
        for j, i in enumerate(self.ids):
            if i < 0:
                continue
            row = {"id": int(i)}
            if self.dists is not None:
                row["dist"] = float(self.dists[j])
            for c, v in self.columns.items():
                row[c] = v[j]
            yield row


@dataclasses.dataclass
class IndexEntry:
    """One catalog entry: a named HNSW index over (table, vector column).
    ``index`` is a NavixIndex or a ShardedNavix (shard-and-merge)."""
    name: str
    table: str
    column: str
    index: object


class NavixDB:
    """GraphStore + index catalog + query execution, behind one handle."""

    def __init__(self, store: Optional[GraphStore] = None):
        self.store = store if store is not None else GraphStore()
        self.catalog: dict[str, IndexEntry] = {}
        self.programs = ProgramCache()

    # -- catalog (CREATE_HNSW_INDEX) ---------------------------------------
    def create_index(self, name: str, table: str, column: str = "embedding",
                     vectors: Optional[np.ndarray] = None,
                     config: NavixConfig = NavixConfig()
                     ) -> tuple[NavixIndex, BuildStats]:
        """Build + register an HNSW index over ``table.column``.

        ``vectors`` (f32[n, d]) may be passed to materialize the column
        first (creating the node table if absent) -- the common path when
        embeddings come from a model rather than the store.
        """
        if name in self.catalog:
            raise ValueError(f"index {name!r} already exists")
        if vectors is not None:
            vectors = np.asarray(vectors, dtype=np.float32)
            if table not in self.store.nodes:
                self.store.add_node_table(table, vectors.shape[0])
            self.store.add_vector_column(table, column, vectors)
        payload = self.store.node(table).column(column)
        index, stats = NavixIndex.create(payload, config)
        self._register(IndexEntry(name, table, column, index))
        return index, stats

    def register_index(self, name: str, index,
                       table: Optional[str] = None,
                       column: str = "embedding") -> IndexEntry:
        """Adopt an already-built index (checkpoint restore, bench cache).

        ``index`` may be a :class:`NavixIndex` or a
        :class:`~repro.core.distributed.ShardedNavix` (sharded entries
        route ``execute`` through the sharded batched engine). When
        ``table`` is omitted, the catalog binds to the unique node table
        with a matching row count, creating a bare one if needed.
        """
        if name in self.catalog:
            raise ValueError(f"index {name!r} already exists")
        n = (index.n_total if isinstance(index, ShardedNavix)
             else index.graph.n)
        if table is None:
            matches = [t for t, nt in self.store.nodes.items() if nt.n == n]
            if len(matches) > 1:
                raise ValueError(f"ambiguous table for index {name!r}: "
                                 f"{matches}; pass table= explicitly")
            table = matches[0] if matches else name
        if table not in self.store.nodes:
            self.store.add_node_table(table, n)
        entry = IndexEntry(name, table, column, index)
        self._register(entry)
        return entry

    def _register(self, entry: IndexEntry) -> None:
        entry.index.program_cache = self.programs
        self.catalog[entry.name] = entry

    def index(self, name: str) -> NavixIndex:
        return self.catalog[name].index

    def quantize_index(self, name: str, mmap_path=None) -> NavixIndex:
        """Switch a catalog entry to int8 device residency.

        The entry's index is replaced by its quantized-resident sibling
        (``NavixIndex.quantize_resident``): the device holds codes +
        per-vector scales + graph only, full-precision rows live in a
        host-side exact tier (``mmap_path`` spills them to disk), and
        every ``execute`` over this entry finishes with an exact re-rank
        (timed separately as ``StageTimings.rerank_ms``). Programs key on
        residency, so the swap never invalidates cached f32 programs.
        """
        entry = self.catalog[name]
        if isinstance(entry.index, ShardedNavix):
            raise ValueError(f"index {name!r} is sharded; quantized "
                             f"residency applies to single-device indexes")
        entry.index = entry.index.quantize_resident(mmap_path=mmap_path)
        entry.index.program_cache = self.programs
        return entry.index

    def _resolve(self, knn: KnnSearch, table: str) -> IndexEntry:
        if knn.index is not None:
            return self.catalog[knn.index]
        matches = [e for e in self.catalog.values() if e.table == table]
        if not matches:
            raise ValueError(f"no index on table {table!r}; create one with "
                             f"db.create_index(...)")
        if len(matches) > 1:
            raise ValueError(f"multiple indexes on table {table!r}: "
                             f"{[e.name for e in matches]}; name one in "
                             f"KnnSearch(index=...)")
        return matches[0]

    # -- serving -------------------------------------------------------------
    def serve(self, index: Optional[str] = None, **kw):
        """Construct a live :class:`~repro.serving.service.SearchService`
        over one catalog entry (default: the first registered index).
        Keyword args pass through -- k/efs caps, batch size, deadlines,
        backpressure policy, heartbeat monitor; see ``SearchService``.
        Call ``.start()`` (or use as a context manager) to spawn the
        device loop."""
        from repro.serving.service import SearchService
        return SearchService(self, index=index, **kw)

    # -- execution ----------------------------------------------------------
    def prefilter(self, plan: Plan) -> QueryResult:
        """Run a selection subquery alone (mask + wall time)."""
        return evaluate(plan, self.store)

    def execute(self, plan, query: Optional[np.ndarray] = None,
                max_batch: int = 0, engine: str = "batched",
                masks=None, alive=None) -> ResultSet:
        """Run a full plan. ``plan`` is a Plan tree or a ``Q`` builder.

        ``query`` binds the vector(s) for the KnnSearch operator: [d] for
        one query, [b, d] for a batch (overrides a vector bound on the
        builder). ``max_batch`` chunks device execution of large batches;
        the prefilter still runs exactly once. ``engine`` picks the
        multi-row execution engine: "batched" (default, the
        batched-frontier engine) or "vmap" (the reference oracle);
        single-row queries ignore it.

        ``masks`` runs a **mixed-plan batch**: a list of per-query
        selection masks (bool[n]; ``None`` entries mean unfiltered), one
        per row of a [b, d] ``query``. Each lane then searches its own
        selected set in one device batch (the paper's per-query ad-hoc S,
        batched); ``ResultSet.sigmas`` carries the per-lane
        selectivities. The plan must not also carry a selection subquery
        -- the caller has already run the per-request Q_S's.

        When the resolved catalog entry is a ShardedNavix, the kNN
        operator runs the sharded batched engine (every shard searched
        at once, one global merge); ``alive`` (bool[S], default all
        alive) quorum-masks the merge so dead shards contribute nothing.
        """
        # builders carry their own bound query vector
        bound = getattr(plan, "bound_query", None)
        as_plan = getattr(plan, "plan", None)
        if callable(as_plan):
            plan = as_plan()
        if query is None:
            query = bound
        parts = split_pipeline(plan)
        table = output_table(plan, self.store)

        # stage 1: prefilter (Q_S on the host)
        timings = StageTimings()
        mask = None
        sigma = 1.0
        if parts.selection is not None:
            if masks is not None:
                raise ValueError(
                    "execute(masks=...) replaces the prefilter stage; the "
                    "plan must not also carry a selection subquery")
            qres = evaluate(parts.selection, self.store)
            mask, sigma = qres.mask, qres.selectivity
            timings.prefilter_ms = qres.seconds * 1e3

        if parts.knn is None:
            return self._finish_selection(parts, table, mask, sigma, timings)
        if query is None:
            raise ValueError("plan has a KnnSearch but no query vector was "
                             "bound; pass execute(plan, query=...)")
        query = np.asarray(query)
        if masks is not None:
            if query.ndim != 2 or len(masks) != query.shape[0]:
                raise ValueError(
                    f"masks needs one entry per query row; got "
                    f"{len(masks)} masks for query shape {query.shape}")
            n = self.store.node(table).n
            mask = np.stack([np.ones(n, bool) if m is None
                             else np.asarray(m, bool) for m in masks])
        return self._execute_knn(parts, table, query, mask,
                                 sigma, timings, max_batch, engine, alive)

    def _execute_knn(self, parts, table, query, mask, sigma, timings,
                     max_batch, engine="batched", alive=None) -> ResultSet:
        knn = parts.knn
        entry = self._resolve(knn, table)
        idx = entry.index
        sharded = isinstance(idx, ShardedNavix)
        n_rows = idx.n_total if sharded else idx.graph.n
        if n_rows != self.store.node(table).n:
            raise ValueError(f"index {entry.name!r} covers {n_rows} "
                             f"rows but table {table!r} has "
                             f"{self.store.node(table).n}")
        if sharded and engine != "batched":
            raise ValueError(f"sharded index {entry.name!r} runs the "
                             f"batched engine only, not {engine!r}")
        if alive is not None and not sharded:
            raise ValueError(f"alive= quorum-masks sharded indexes; "
                             f"{entry.name!r} is unsharded")

        # stage 2: semimask packing (the SIP handoff to the device)
        t0 = time.perf_counter()
        if sharded:
            sel = (idx.full_semimask() if mask is None
                   else idx.shard_semimask(mask))
        else:
            sel = (idx.full_semimask() if mask is None
                   else idx.pack_semimask(mask))
        sel.block_until_ready()
        timings.pack_ms = (time.perf_counter() - t0) * 1e3

        # per-lane masks carry per-lane selectivities
        sigmas = None
        if sel.ndim == (3 if sharded else 2):
            sigmas = np.asarray(idx.sigma(sel))
            sigma = float(sigmas.mean())

        # stage 3: the kNN operator through the compiled-program cache
        k = knn.k
        quantized = (not sharded) and getattr(idx, "is_quantized", False)
        if quantized:
            # int8 residency: the beam runs on codes at FULL width (k ==
            # efs); the exact tier does the final cut to k in stage 3b
            efs_eff = max(knn.efs or 2 * k, k)
            params = idx._params(efs_eff, efs_eff, knn.heuristic)
        else:
            params = idx._params(k, knn.efs or 2 * k, knn.heuristic)
        t0 = time.perf_counter()
        single = query.ndim == 1
        if sharded:
            res = self._run_sharded(idx, query, sel, params, max_batch,
                                    alive)
        elif single:
            res = self.programs.search(idx.graph, idx._prep_query(query),
                                       sel, params, sigma)
        else:
            res = self._run_batch(idx, query, sel, params,
                                  sigma if sigmas is None else sigmas,
                                  max_batch, engine)
        ids = np.asarray(res.ids)
        dists = np.asarray(res.dists)
        timings.search_ms = (time.perf_counter() - t0) * 1e3

        # stage 3b: exact-tier re-rank (quantized residency only)
        if quantized:
            t0 = time.perf_counter()
            Qp = np.asarray(idx._prep_query(query))
            if single:
                dists, ids = idx.exact.rerank(Qp, ids, k)
            else:
                dists, ids = idx.exact.rerank_many(Qp, ids, k)
            timings.rerank_ms = (time.perf_counter() - t0) * 1e3

        # stage 4: projection + limit
        t0 = time.perf_counter()
        if parts.limit is not None:
            ids = ids[..., :parts.limit]
            dists = dists[..., :parts.limit]
        columns = (self.store.node(table).rows(ids, parts.projections)
                   if parts.projections else {})
        timings.project_ms = (time.perf_counter() - t0) * 1e3
        return ResultSet(table=table, ids=ids, dists=dists, columns=columns,
                         sigma=sigma, timings=timings, stats=res.stats,
                         mask=mask, sigmas=sigmas)

    def _run_sharded(self, sn, query, sel, params, max_batch, alive):
        """Sharded kNN through the program cache's ``sharded`` arm; a
        single query is lifted to a one-lane batch and sliced back."""
        import jax
        import jax.numpy as jnp

        single = query.ndim == 1
        Q = jnp.atleast_2d(sn._prep_query(query))
        alive = (np.ones(sn.n_shards, bool) if alive is None
                 else np.asarray(alive, bool))
        if alive.shape != (sn.n_shards,):
            raise ValueError(f"alive mask has shape {alive.shape}; index "
                             f"has {sn.n_shards} shards")
        alive_j = jnp.asarray(alive)

        def run(Qc, selc):
            return self.programs.search_sharded(sn, Qc, selc, alive_j,
                                                params)

        if not max_batch or Q.shape[0] <= max_batch:
            res = run(Q, sel)
        else:
            chunks = [run(Q[i:i + max_batch],
                          sel[:, i:i + max_batch] if sel.ndim == 3 else sel)
                      for i in range(0, Q.shape[0], max_batch)]
            res = jax.tree_util.tree_map(
                lambda *xs: np.concatenate([np.asarray(x) for x in xs]),
                *chunks)
        return (jax.tree_util.tree_map(lambda a: a[0], res) if single
                else res)

    def _run_batch(self, idx, query, sel, params, sigma, max_batch,
                   engine="batched"):
        import jax

        run = self.programs.batch(engine)
        Q = idx._prep_query(query)
        if not max_batch or Q.shape[0] <= max_batch:
            return run(idx.graph, Q, sel, params, sigma)

        def chunk_of(x, i):
            """Per-lane operands (2-D sel, [b] sigma) chunk with the
            query rows; shared operands pass through whole."""
            return x[i:i + max_batch] if np.ndim(x) >= 1 else x

        chunks = [run(idx.graph, Q[i:i + max_batch],
                      chunk_of(sel, i) if sel.ndim == 2 else sel,
                      params, chunk_of(sigma, i))
                  for i in range(0, Q.shape[0], max_batch)]
        return jax.tree_util.tree_map(
            lambda *xs: np.concatenate([np.asarray(x) for x in xs]), *chunks)

    def _finish_selection(self, parts, table, mask, sigma,
                          timings) -> ResultSet:
        """Pure Q_S plan (no kNN): rows are the selected node ids."""
        ids = (np.flatnonzero(mask) if mask is not None
               else np.arange(self.store.node(table).n))
        t0 = time.perf_counter()
        if parts.limit is not None:
            ids = ids[:parts.limit]
        columns = (self.store.node(table).rows(ids, parts.projections)
                   if parts.projections else {})
        timings.project_ms = (time.perf_counter() - t0) * 1e3
        return ResultSet(table=table, ids=ids, dists=None, columns=columns,
                         sigma=sigma, timings=timings, mask=mask)

    # -- introspection -------------------------------------------------------
    def explain(self, plan) -> str:
        """Compact textual plan tree (top-down), Kuzu-EXPLAIN style."""
        as_plan = getattr(plan, "plan", None)
        if callable(as_plan):
            plan = as_plan()

        lines: list[str] = []

        def walk(node, depth):
            pad = "  " * depth
            name = type(node).__name__
            fields = {f.name: getattr(node, f.name)
                      for f in dataclasses.fields(node)
                      if f.name not in ("child", "left", "right")}
            args = ", ".join(f"{k}={v!r}" for k, v in fields.items()
                             if v is not None and v != ())
            lines.append(f"{pad}{name}({args})")
            for attr in ("child", "left", "right"):
                sub = getattr(node, attr, None)
                if sub is not None:
                    walk(sub, depth + 1)

        walk(plan, 0)
        return "\n".join(lines)
