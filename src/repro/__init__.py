"""NaviX-JAX: a native vector index + unified training/serving framework.

Reproduction (and beyond-paper optimization) of:
  "NaviX: A Native Vector Index Design for Graph DBMSs With Robust
   Predicate-Agnostic Search Performance" (Sehgal & Salihoglu, 2025).

Public API entry points:
  repro.core.navix      -- NavixIndex: build / (filtered) search
  repro.query           -- selection subqueries -> semimasks
  repro.configs         -- assigned architecture registry (--arch <id>)
  repro.launch          -- mesh / dryrun / train / serve
"""

__version__ = "0.1.0"
