"""NaviX-JAX: a native vector index + unified training/serving framework.

Reproduction (and beyond-paper optimization) of:
  "NaviX: A Native Vector Index Design for Graph DBMSs With Robust
   Predicate-Agnostic Search Performance" (Sehgal & Salihoglu, 2025).

Public API entry points:
  repro.api             -- NavixDB: store + index catalog + plan execution
  repro.core.navix      -- NavixIndex: per-index build / search (compat)
  repro.query           -- plan algebra (selection subqueries + KnnSearch)
  repro.configs         -- assigned architecture registry (--arch <id>)
  repro.launch          -- mesh / dryrun / train / serve
"""

__version__ = "0.1.0"
