"""Host-side neighbor sampling for minibatch GNN training (GraphSAGE-style).

The ``minibatch_lg`` shape requires a real sampler: uniform fanout
sampling over a CSR graph, producing fixed-size padded blocks (seeds
first, then hop-1, hop-2 frontiers) whose layout matches
``repro.models.api.input_specs`` for kind "graph_minibatch". Edges are
(src, dst) pairs in *block-local* indices with -1 padding; the GNN model
masks padding (tested in test_models_gnn.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.storage.columnar import CSR, csr_from_edges


def random_power_law_graph(n: int, avg_degree: int, d_feat: int,
                           seed: int = 0, alpha: float = 1.5):
    """Synthetic power-law graph (degree skew like social/product graphs)."""
    rng = np.random.default_rng(seed)
    m = n * avg_degree
    # preferential-attachment-ish target distribution
    w = (1.0 / np.arange(1, n + 1) ** (alpha / 2))
    w /= w.sum()
    src = rng.integers(0, n, size=m)
    dst = rng.choice(n, size=m, p=w)
    csr = csr_from_edges(src.astype(np.int64), dst.astype(np.int64), n)
    feats = rng.normal(size=(n, d_feat)).astype(np.float32)
    return csr, feats


def random_mesh_graph(n: int, d_feat: int, seed: int = 0):
    """Bounded-degree mesh-like graph (grid + jitter) -- MeshGraphNet's
    native regime."""
    rng = np.random.default_rng(seed)
    side = int(np.sqrt(n))
    n = side * side
    idx = np.arange(n).reshape(side, side)
    src, dst = [], []
    for sh in ((0, 1), (1, 0), (1, 1)):
        a = idx[: side - sh[0] or None, : side - sh[1] or None].ravel()
        b = idx[sh[0]:, sh[1]:].ravel()
        src += [a, b]
        dst += [b, a]
    src = np.concatenate(src)
    dst = np.concatenate(dst)
    csr = csr_from_edges(src, dst, n)
    feats = rng.normal(size=(n, d_feat)).astype(np.float32)
    return csr, feats


@dataclasses.dataclass
class NeighborSampler:
    csr: CSR
    fanouts: tuple[int, ...] = (15, 10)
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def block_sizes(self, n_seeds: int) -> tuple[int, int]:
        n = n_seeds
        nodes = n_seeds
        edges = 0
        for f in self.fanouts:
            e = n * f
            edges += e
            nodes += e
            n = e
        return nodes, edges

    def sample_block(self, seeds: np.ndarray) -> dict[str, np.ndarray]:
        """Returns padded arrays:
        node_ids[int64, n_pad] (-1 pad), edge_src/edge_dst[int64, e_pad]
        (block-local, -1 pad). Seeds occupy positions [0, len(seeds))."""
        seeds = np.asarray(seeds, dtype=np.int64)
        n_pad, e_pad = self.block_sizes(len(seeds))
        node_ids = np.full(n_pad, -1, np.int64)
        edge_src = np.full(e_pad, -1, np.int64)
        edge_dst = np.full(e_pad, -1, np.int64)
        node_ids[: len(seeds)] = seeds

        frontier = np.arange(len(seeds))           # block-local positions
        write_n = len(seeds)
        write_e = 0
        for f in self.fanouts:
            next_frontier = []
            for pos in frontier:
                u = node_ids[pos]
                if u < 0:
                    continue
                nbrs = self.csr.neighbors(int(u))
                if len(nbrs) == 0:
                    continue
                take = self._rng.choice(nbrs, size=min(f, len(nbrs)),
                                        replace=len(nbrs) < f)
                for v in take:
                    node_ids[write_n] = v
                    # message flows sampled-neighbor -> center
                    edge_src[write_e] = write_n
                    edge_dst[write_e] = pos
                    next_frontier.append(write_n)
                    write_n += 1
                    write_e += 1
            frontier = np.asarray(next_frontier, dtype=np.int64)
        return {"node_ids": node_ids, "edge_src": edge_src,
                "edge_dst": edge_dst, "n_real_nodes": write_n,
                "n_real_edges": write_e}

    def block_batch(self, seeds: np.ndarray, feats: np.ndarray,
                    targets: np.ndarray, d_edge: int = 4) -> dict:
        """Assemble a model-ready batch (gather features, synth edge feats)."""
        blk = self.sample_block(seeds)
        ids = blk["node_ids"]
        ok = ids >= 0
        nf = np.zeros((len(ids), feats.shape[1]), np.float32)
        nf[ok] = feats[ids[ok]]
        tg = np.zeros((len(ids), targets.shape[1]), np.float32)
        tg[ok] = targets[ids[ok]]
        ef = np.zeros((len(blk["edge_src"]), d_edge), np.float32)
        mask = np.zeros(len(ids), bool)
        mask[: len(seeds)] = True                  # loss on seeds only
        return {"node_feats": nf,
                "edge_src": blk["edge_src"].astype(np.int32),
                "edge_dst": blk["edge_dst"].astype(np.int32),
                "edge_feats": ef, "node_targets": tg, "node_mask": mask}
