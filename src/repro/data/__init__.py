"""Dataset synthesis and graph sampling."""
