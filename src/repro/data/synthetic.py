"""Synthetic datasets + correlation-controlled workloads (paper Section 5.1).

The paper evaluates on GIST/Tiny/Arxiv (objects, uncorrelated filters) and a
Wiki graph dataset (Person/Resource/Chunk with PersonChunk/ResourceChunk/
WikiLink relationships) whose 1- and 2-hop selection subqueries produce
positively / negatively correlated selected sets. We reproduce the *shape*
of these datasets synthetically at laptop scale:

* embeddings are a Gaussian mixture (cluster structure is what makes the
  directed heuristic and correlations meaningful);
* Person chunks live in a dedicated region of the mixture so that
  person-ish queries correlate positively with person-chunk filters and
  non-person queries correlate negatively -- exactly the mechanism of the
  paper's Wiki workloads;
* the correlation metric ce = sigma_vq / sigma (paper Section 5.1.3) is
  computed for every generated workload and asserted in the benchmarks
  (Tables 4/5 analogue).
"""

from __future__ import annotations

import dataclasses
import numpy as np

from repro.query.operators import Filter, HopJoin, NodeScan, Plan
from repro.storage.columnar import GraphStore


def gaussian_mixture(n: int, d: int, n_clusters: int, seed: int = 0,
                     cluster_std: float = 0.35,
                     centers: np.ndarray | None = None):
    rng = np.random.default_rng(seed)
    if centers is None:
        centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
    labels = rng.integers(0, n_clusters, size=n)
    X = centers[labels] + cluster_std * rng.normal(size=(n, d)).astype(np.float32)
    return X.astype(np.float32), labels, centers


@dataclasses.dataclass
class WikiLike:
    store: GraphStore
    embeddings: np.ndarray          # f32[n_chunks, d]
    chunk_is_person: np.ndarray     # bool[n_chunks]
    person_centers: np.ndarray
    resource_centers: np.ndarray
    seed: int

    @property
    def n_chunks(self) -> int:
        return self.embeddings.shape[0]


def make_wiki_like(n_person: int = 600, n_resource: int = 2000,
                   chunks_per_person: int = 6, chunks_per_resource: int = 3,
                   d: int = 64, n_person_clusters: int = 12,
                   n_resource_clusters: int = 40, seed: int = 0) -> WikiLike:
    """Build the Wiki-analogue property graph (Figure 7a schema)."""
    rng = np.random.default_rng(seed)
    pc = rng.normal(size=(n_person_clusters, d)).astype(np.float32)
    rc = rng.normal(size=(n_resource_clusters, d)).astype(np.float32)

    # --- chunks ----------------------------------------------------------
    p_chunk_src, p_chunk_dst, embs, is_person = [], [], [], []
    r_chunk_src, r_chunk_dst = [], []
    person_cluster = rng.integers(0, n_person_clusters, size=n_person)
    resource_cluster = rng.integers(0, n_resource_clusters, size=n_resource)

    cid = 0
    for p in range(n_person):
        for _ in range(chunks_per_person):
            embs.append(pc[person_cluster[p]] +
                        0.35 * rng.normal(size=d).astype(np.float32))
            is_person.append(True)
            p_chunk_src.append(p)
            p_chunk_dst.append(cid)
            cid += 1
    for r in range(n_resource):
        for _ in range(chunks_per_resource):
            embs.append(rc[resource_cluster[r]] +
                        0.35 * rng.normal(size=d).astype(np.float32))
            is_person.append(False)
            r_chunk_src.append(r)
            r_chunk_dst.append(cid)
            cid += 1

    embeddings = np.stack(embs).astype(np.float32)
    is_person = np.asarray(is_person)
    n_chunks = cid

    # --- shuffle chunk ids so id-range filters are uncorrelated -----------
    perm = rng.permutation(n_chunks)
    inv = np.argsort(perm)
    embeddings = embeddings[inv]
    is_person = is_person[inv]
    p_chunk_dst = perm[np.asarray(p_chunk_dst)]
    r_chunk_dst = perm[np.asarray(r_chunk_dst)]

    store = GraphStore()
    store.add_node_table("Person", n_person, {
        "pID": np.arange(n_person),
        # birth dates as integer days; range filters control selectivity
        "birth_date": rng.integers(0, 36500, size=n_person),
    })
    store.add_node_table("Resource", n_resource, {"rID": np.arange(n_resource)})
    store.add_node_table("Chunk", n_chunks, {
        "cID": np.arange(n_chunks),
        "is_person": is_person,
    })
    store.add_rel_table("PersonChunk", "Person", "Chunk",
                        np.asarray(p_chunk_src), np.asarray(p_chunk_dst))
    store.add_rel_table("ResourceChunk", "Resource", "Chunk",
                        np.asarray(r_chunk_src), np.asarray(r_chunk_dst))
    # WikiLink: each person links to a few resources
    wl_src = np.repeat(np.arange(n_person), 4)
    wl_dst = rng.integers(0, n_resource, size=n_person * 4)
    store.add_rel_table("WikiLink", "Person", "Resource", wl_src, wl_dst)

    return WikiLike(store=store, embeddings=embeddings,
                    chunk_is_person=is_person, person_centers=pc,
                    resource_centers=rc, seed=seed)


# ---------------------------------------------------------------------------
# workloads
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Workload:
    name: str
    queries: np.ndarray            # f32[n_q, d]
    plan: Plan                     # the selection subquery Q_S
    target_sigma: float


def uncorrelated_plan(sigma: float, n_chunks: int) -> Plan:
    """MATCH (c:Chunk) WHERE c.cID < MAX_CHUNK_ID * sigma (paper 5.1.3)."""
    return Filter(NodeScan("Chunk"), "cID", "<", value=int(n_chunks * sigma))


def person_chunk_plan(store: GraphStore, sigma_of_person: float,
                      date_lo: int = 0) -> Plan:
    """MATCH (p:Person)-[:PersonChunk]->(c:Chunk)
    WHERE p.birth_date in [lo, hi)  (paper's correlated Q_S)."""
    hi = date_lo + int(36500 * sigma_of_person)
    return HopJoin(Filter(NodeScan("Person"), "birth_date", "range",
                          lo=date_lo, hi=hi), "PersonChunk", "fwd")


def two_hop_plan(store: GraphStore, sigma_of_person: float) -> Plan:
    """(p:Person)-[:WikiLink]->(r:Resource)-[:ResourceChunk]->(c:Chunk)
    -- the graph-RAG 2-hop workload (paper Section 5.7.1)."""
    hi = int(36500 * sigma_of_person)
    persons = Filter(NodeScan("Person"), "birth_date", "range", lo=0, hi=hi)
    resources = HopJoin(persons, "WikiLink", "fwd")
    return HopJoin(resources, "ResourceChunk", "fwd")


def make_queries(data: WikiLike, n_q: int, mode: str, seed: int = 1) -> np.ndarray:
    """Query vectors, generated the way the paper generates them
    (Section 5.1.3): 'person' queries are questions ABOUT persons, i.e.
    they live next to actual person chunks (positive correlation with
    person-chunk filters, ce ~ 3); 'nonperson' queries live next to
    resource chunks (negative, ce ~ 0.03); 'uncorrelated' samples the
    global mixture."""
    rng = np.random.default_rng(seed)
    d = data.embeddings.shape[1]
    if mode == "uncorrelated":
        ids = rng.integers(0, data.n_chunks, size=n_q)
        base = data.embeddings[ids]
    elif mode == "person":
        pids = np.flatnonzero(data.chunk_is_person)
        base = data.embeddings[rng.choice(pids, size=n_q)]
    elif mode == "nonperson":
        rids = np.flatnonzero(~data.chunk_is_person)
        base = data.embeddings[rng.choice(rids, size=n_q)]
    else:
        raise ValueError(mode)
    noise = 0.15 if mode != "uncorrelated" else 0.25
    return (base + noise * rng.normal(size=(n_q, d))).astype(np.float32)


def correlation_ratio(X: np.ndarray, queries: np.ndarray, mask: np.ndarray,
                      k: int = 100, metric: str = "l2") -> float:
    """ce = sigma_vq / sigma (paper Section 5.1.3): the fraction of v_Q's
    global kNNs that fall in S, normalized by |S|/|V|."""
    import jax.numpy as jnp

    from repro.core.distances import brute_force_topk
    sigma = float(mask.mean())
    if sigma == 0.0:
        return float("nan")
    _, ids = brute_force_topk(jnp.asarray(queries), jnp.asarray(X), k, metric)
    ids = np.asarray(ids)
    in_s = mask[np.maximum(ids, 0)] & (ids >= 0)
    sigma_vq = in_s.mean(axis=1)
    return float(np.mean(sigma_vq) / sigma)
