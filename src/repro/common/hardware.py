"""Target-hardware constants and roofline helpers.

The deployment target is a TPU v5e pod (16x16 = 256 chips per pod); the
multi-pod configuration is 2 pods = 512 chips. This container runs on CPU,
so these constants parameterize the *analytical* roofline derived from
compiled HLO (see repro.launch.roofline), never wall-clock measurement.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_bf16_flops: float   # FLOP/s per chip
    hbm_bandwidth: float     # bytes/s per chip
    ici_link_bandwidth: float  # bytes/s per link (one direction)
    ici_links: int           # ICI links per chip (2D torus: 4)
    hbm_bytes: int           # HBM capacity per chip
    vmem_bytes: int          # VMEM per core


# Values given by the assignment: 197 TFLOP/s bf16; 819 GB/s HBM; ~50 GB/s/link ICI.
TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    ici_links=4,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
)

TARGET = TPU_V5E

# MXU-native tile sizes (used to align Pallas BlockSpecs).
MXU_DIM = 128
VPU_LANES = 128
VPU_SUBLANES = 8


def compute_time_s(flops: float, chips: int, chip: ChipSpec = TARGET) -> float:
    """Roofline compute term: HLO_FLOPs / (chips * peak)."""
    return flops / (chips * chip.peak_bf16_flops)


def memory_time_s(hbm_bytes: float, chips: int, chip: ChipSpec = TARGET) -> float:
    """Roofline memory term: HLO bytes-accessed / (chips * HBM bw)."""
    return hbm_bytes / (chips * chip.hbm_bandwidth)


def collective_time_s(coll_bytes: float, chips: int, chip: ChipSpec = TARGET) -> float:
    """Roofline collective term: collective bytes / (chips * link bw).

    Per the assignment's convention this uses a single-link denominator per
    chip, i.e. it is conservative for multi-link torus routing.
    """
    return coll_bytes / (chips * chip.ici_link_bandwidth)
