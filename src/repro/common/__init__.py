"""Shared host-side utilities (hardware probing, small helpers)."""
