"""Small shared utilities: timing, rounding, PRNG fan-out, pytree sizing."""

from __future__ import annotations

import contextlib
import math
import time
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def next_pow2(x: int) -> int:
    return 1 if x <= 1 else 2 ** math.ceil(math.log2(x))


@contextlib.contextmanager
def timer(sink: dict, key: str) -> Iterator[None]:
    t0 = time.perf_counter()
    yield
    sink[key] = sink.get(key, 0.0) + (time.perf_counter() - t0)


def tree_bytes(tree: Any) -> int:
    """Total bytes of all arrays in a pytree (concrete or ShapeDtypeStruct)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_params(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) for l in leaves if hasattr(l, "shape"))


def split_key(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.3g}{unit}"
        n /= 1000.0
    return f"{n:.3g}Q"


def assert_no_nans(tree: Any, where: str = "") -> None:
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            if bool(jnp.any(~jnp.isfinite(leaf))):
                raise AssertionError(f"non-finite values at {where}{jax.tree_util.keystr(path)}")
