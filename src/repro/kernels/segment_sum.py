"""CSR-sorted segment sum as one-hot MXU matmuls.

The message-passing / EmbeddingBag hot path: given per-edge messages sorted
by destination and the sorted destination ids, produce per-node sums.
JAX has no native EmbeddingBag or CSR SpMM -- this kernel IS that substrate
on TPU (taxonomy B.3/B.6).

Schedule: grid = (node blocks, edge tiles per block). A host-side
preprocessing step (ops.py) computes, per node block, the first edge tile
that can touch it; the kernel visits ``t_max`` consecutive edge tiles from
there, builds the (bn, be) one-hot dst matrix with broadcasted_iota and
accumulates ``onehot @ messages`` on the MXU. Because edges are sorted by
destination, each node block's edges occupy a contiguous tile range, so
``t_max = max over blocks of (range length)``; tiles outside a block's true
range contribute zero via the one-hot mask (masked, not branched).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: padding destination id: sorts after every real node id and can never
#: alias into a node block (callers replace -1 with this before sorting)
PAD_SENTINEL = 0x3FFFFFFF


def _kernel(first_tile_ref, msg_ref, dst_ref, out_ref, acc_ref,
            *, bn: int, t_max: int):
    i = pl.program_id(0)          # node block
    t = pl.program_id(1)          # relative edge tile

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    base = i * bn
    dst = dst_ref[...].reshape(-1)          # [be] sorted dst ids (padding =
    local = dst - base                      #  PAD_SENTINEL, sorts last and
    be = dst.shape[0]                       #  never matches a local row)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bn, be), 0)
    onehot = jnp.where((local[None, :] == rows) & (dst[None, :] >= 0),
                       1.0, 0.0)
    acc_ref[...] += jax.lax.dot_general(
        onehot, msg_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(t == t_max - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("n", "bn", "be", "t_max", "interpret"))
def csr_segment_sum_pallas(messages: jax.Array, dst_sorted: jax.Array,
                           first_tile: jax.Array, n: int, bn: int = 128,
                           be: int = 256, t_max: int = 4,
                           interpret: bool = False) -> jax.Array:
    """messages[E,d] (dst-sorted, -1 padded), dst_sorted[E] int32,
    first_tile[n_blocks] int32 -> f32[n_pad, d] with n_pad = blocks * bn.

    ``first_tile[i]`` = index of the first edge tile containing an edge for
    node block i (clamped so first_tile + t_max covers the block's range).
    """
    e, d = messages.shape
    assert e % be == 0, (e, be)
    n_blocks = -(-n // bn)
    grid = (n_blocks, t_max)
    out = pl.pallas_call(
        functools.partial(_kernel, bn=bn, t_max=t_max),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((be, d), lambda i, t, ft: (ft[i] + t, 0)),
                pl.BlockSpec((1, be), lambda i, t, ft: (0, ft[i] + t)),
            ],
            out_specs=pl.BlockSpec((bn, d), lambda i, t, ft: (i, 0)),
            scratch_shapes=[pltpu.VMEM((bn, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_blocks * bn, d), jnp.float32),
        interpret=interpret,
    )(first_tile, messages, dst_sorted[None, :])
    return out


def plan_tiles(dst_sorted, n: int, bn: int, be: int, e_pad: int):
    """Host-side tile plan: per node block, the first edge tile index and
    the global t_max. Returns (first_tile int32[n_blocks], t_max int).

    dst_sorted: int32[E], sorted ascending; padding encoded as PAD_SENTINEL
    (NOT -1 -- -1 would sort first and break the contiguous-range property).
    """
    import numpy as np
    dst = np.asarray(dst_sorted)
    n_blocks = -(-n // bn)
    n_tiles = e_pad // be
    # first/last edge index per node block via searchsorted
    starts = np.searchsorted(dst, np.arange(n_blocks) * bn, side="left")
    ends = np.searchsorted(dst, np.minimum((np.arange(n_blocks) + 1) * bn,
                                           n) - 1, side="right")
    first = np.minimum(starts // be, n_tiles - 1)
    last = np.maximum(np.ceil(ends / be).astype(np.int64) - 1, first)
    t_max = int((last - first + 1).max()) if n_blocks else 1
    # clamp so first + t_max stays in range
    first = np.minimum(first, n_tiles - t_max)
    first = np.maximum(first, 0)
    return first.astype(np.int32), t_max
