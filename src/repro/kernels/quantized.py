"""Int8 quantized distance kernel (DiskANN-regime search, Section 5.8).

Vectors are stored as int8 codes with a per-vector symmetric scale
(x_i ~= scale_i * codes_i). The kernel streams 4x less HBM traffic than
the f32 distance matrix -- on a memory-bound shard (big n, small batch)
that is a ~4x roofline win; search quality is recovered by exact re-ranking
(repro.core.quantize.rerank), exactly like DiskANN's in-memory quantized
search + re-rank design that the paper benchmarks against.

Same schedule as distance_matrix: d innermost, f32 VMEM accumulator; scale
and the codes' self-dot are applied on the last d-step:

  ||q - s*c||^2 = ||q||^2 - 2 s (q.c) + s^2 (c.c)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# older jax releases (< 0.5) name the struct TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(q_ref, c_ref, s_ref, out_ref, dot_acc, cc_acc, qq_acc,
            *, metric: str, n_d: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        dot_acc[...] = jnp.zeros_like(dot_acc)
        cc_acc[...] = jnp.zeros_like(cc_acc)
        qq_acc[...] = jnp.zeros_like(qq_acc)

    q = q_ref[...].astype(jnp.float32)                   # [bq, bd]
    c = c_ref[...].astype(jnp.float32)                   # [bn, bd] int8 codes
    dot_acc[...] += jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    cc_acc[...] += jnp.sum(c * c, axis=1, keepdims=True)  # [bn, 1]
    qq_acc[...] += jnp.sum(q * q, axis=1, keepdims=True)  # [bq, 1]

    @pl.when(k == n_d - 1)
    def _done():
        s = s_ref[...].astype(jnp.float32)               # [1, bn]
        sdot = dot_acc[...] * s                          # [bq, bn]
        if metric == "l2":
            out_ref[...] = qq_acc[...] + (s * s) * cc_acc[...].T - 2.0 * sdot
        elif metric == "cos":
            out_ref[...] = 1.0 - sdot
        else:  # dot
            out_ref[...] = -sdot


@functools.partial(jax.jit,
                   static_argnames=("metric", "bq", "bn", "bd", "interpret"))
def quantized_distance_pallas(Q: jax.Array, codes: jax.Array,
                              scale: jax.Array, metric: str = "l2",
                              bq: int = 128, bn: int = 128, bd: int = 128,
                              interpret: bool = False) -> jax.Array:
    """Q[b,d] f32/bf16, codes[n,d] int8, scale[n] f32 -> f32[b,n]."""
    b, d = Q.shape
    n, d2 = codes.shape
    assert d == d2 and scale.shape == (n,)
    assert b % bq == 0 and n % bn == 0 and d % bd == 0
    n_d = d // bd
    grid = (b // bq, n // bn, n_d)
    return pl.pallas_call(
        functools.partial(_kernel, metric=metric, n_d=n_d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, bn), jnp.float32),
                        pltpu.VMEM((bn, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(Q, codes, scale[None, :])
