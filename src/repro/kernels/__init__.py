"""Pallas TPU kernels for the paper's compute hot spots.

The paper's dominant search cost is distance computation (Section 2.1) and
its flagship system optimization is computing distances *where the data
already lives* (in buffer-manager frames, Section 4.2.1). The TPU analogue:

  distance_matrix   -- MXU-tiled all-pairs distances (brute force /
                       retrieval_cand / construction pruning)
  gather_distance   -- fused gather+distance via scalar-prefetch BlockSpecs:
                       candidate rows stream HBM->VMEM and the distance is
                       computed in VMEM without materializing the gather
                       (the in-buffer-manager zero-copy optimization)
  quantized         -- int8-code distance with per-vector scales
                       (DiskANN-regime search, Section 5.8)
  segment_sum       -- CSR-sorted segment sum as one-hot MXU matmuls
                       (GNN message passing / EmbeddingBag hot path)

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd public wrapper
in ``ops.py`` (which also routes to the oracle on hosts without a TPU,
keeping the kernels validated in interpret mode by the tests).
"""
