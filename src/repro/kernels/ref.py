"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

These are small, obviously-correct implementations; the kernel tests sweep
shapes/dtypes and assert_allclose kernels (interpret mode) against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def distance_matrix(Q: jax.Array, X: jax.Array, metric: str) -> jax.Array:
    """f32[b,n] distances; see repro.core.distances.dist_matrix."""
    Qf = Q.astype(jnp.float32)
    Xf = X.astype(jnp.float32)
    dots = Qf @ Xf.T
    if metric == "l2":
        return (jnp.sum(Qf * Qf, -1)[:, None] + jnp.sum(Xf * Xf, -1)[None, :]
                - 2.0 * dots)
    if metric == "cos":
        return 1.0 - dots
    if metric == "dot":
        return -dots
    raise ValueError(metric)


def gather_distance(q: jax.Array, vectors: jax.Array, ids: jax.Array,
                    metric: str) -> jax.Array:
    """f32[k]: dist(q, vectors[ids]); ids < 0 -> +inf.

    Elementwise forms match ``repro.core.distances`` exactly, so the
    engines stay bitwise-identical when routed through this fallback.
    """
    rows = vectors[jnp.maximum(ids, 0)].astype(jnp.float32)
    qf = q.astype(jnp.float32)
    if metric == "l2":
        diff = rows - qf
        d = jnp.sum(diff * diff, axis=-1)
    elif metric == "cos":
        d = 1.0 - jnp.sum(rows * qf, axis=-1)
    elif metric == "dot":
        d = -jnp.sum(rows * qf, axis=-1)
    else:
        raise ValueError(metric)
    return jnp.where(ids >= 0, d, jnp.inf)


def gather_distance_batch(Q: jax.Array, vectors: jax.Array, ids: jax.Array,
                          metric: str) -> jax.Array:
    """f32[b,k]: dist(Q[b], vectors[ids[b]]); ids < 0 -> +inf.

    Same elementwise forms as ``distances.gathered_dist_batch`` (see
    :func:`gather_distance`).
    """
    rows = vectors[jnp.maximum(ids, 0)].astype(jnp.float32)  # [b, k, d]
    Qf = Q.astype(jnp.float32)[:, None, :]
    if metric == "l2":
        diff = rows - Qf
        d = jnp.sum(diff * diff, axis=-1)
    elif metric == "cos":
        d = 1.0 - jnp.sum(rows * Qf, axis=-1)
    elif metric == "dot":
        d = -jnp.sum(rows * Qf, axis=-1)
    else:
        raise ValueError(metric)
    return jnp.where(ids >= 0, d, jnp.inf)


def quantized_distance_matrix(Q: jax.Array, codes: jax.Array,
                              scale: jax.Array, metric: str) -> jax.Array:
    """Distances against int8-quantized vectors x_i ~= scale_i * codes_i."""
    X = codes.astype(jnp.float32) * scale[:, None].astype(jnp.float32)
    return distance_matrix(Q, X, metric)


def quantized_gather_distance(q: jax.Array, codes: jax.Array,
                              scale: jax.Array, ids: jax.Array,
                              metric: str) -> jax.Array:
    """f32[k]: dist(q, scale[ids] * codes[ids]); ids < 0 -> +inf.

    Rows dequantize per gathered id -- bitwise what
    ``gather_distance(q, dequantize(store), ids)`` computes (a gather of
    an elementwise product is the product of the gathers), with no
    ``[n, d]`` f32 buffer live.
    """
    safe = jnp.maximum(ids, 0)
    rows = codes[safe].astype(jnp.float32) * \
        scale[safe].astype(jnp.float32)[..., None]
    qf = q.astype(jnp.float32)
    if metric == "l2":
        diff = rows - qf
        d = jnp.sum(diff * diff, axis=-1)
    elif metric == "cos":
        d = 1.0 - jnp.sum(rows * qf, axis=-1)
    elif metric == "dot":
        d = -jnp.sum(rows * qf, axis=-1)
    else:
        raise ValueError(metric)
    return jnp.where(ids >= 0, d, jnp.inf)


def quantized_gather_distance_batch(Q: jax.Array, codes: jax.Array,
                                    scale: jax.Array, ids: jax.Array,
                                    metric: str) -> jax.Array:
    """f32[b,k]: dist(Q[b], scale[ids[b]] * codes[ids[b]]); ids < 0 -> +inf.

    The int8-resident engine's distance primitive; same elementwise forms
    as :func:`quantized_gather_distance` (and as
    ``distances.gathered_dist_batch`` over a QuantizedStore), so the
    batched and single-query paths stay bitwise-identical.
    """
    safe = jnp.maximum(ids, 0)
    rows = codes[safe].astype(jnp.float32) * \
        scale[safe].astype(jnp.float32)[..., None]       # [b, k, d]
    Qf = Q.astype(jnp.float32)[:, None, :]
    if metric == "l2":
        diff = rows - Qf
        d = jnp.sum(diff * diff, axis=-1)
    elif metric == "cos":
        d = 1.0 - jnp.sum(rows * Qf, axis=-1)
    elif metric == "dot":
        d = -jnp.sum(rows * Qf, axis=-1)
    else:
        raise ValueError(metric)
    return jnp.where(ids >= 0, d, jnp.inf)


def csr_segment_sum(messages: jax.Array, dst_sorted: jax.Array,
                    n: int) -> jax.Array:
    """out[v] = sum of messages whose (sorted, padded=-1) destination is v."""
    safe = jnp.where(dst_sorted >= 0, dst_sorted, n)
    contrib = jnp.where((dst_sorted >= 0)[:, None], messages, 0)
    return jax.ops.segment_sum(contrib.astype(jnp.float32), safe,
                               num_segments=n + 1)[:n]
