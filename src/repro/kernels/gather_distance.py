"""Fused gather + distance kernel (the in-buffer-manager optimization).

Paper Section 4.2.1: NaviX passes the distance function *into* the buffer
manager so it runs directly on pinned frames, skipping the copy into an
operator-local buffer (up to 1.6x). The TPU analogue: candidate vector rows
are streamed HBM->VMEM by the Pallas pipeline via a scalar-prefetch
BlockSpec whose index_map reads the candidate id list, and the distance is
computed on the VMEM-resident row -- the gathered matrix is never
materialized in HBM and never round-trips through an intermediate buffer.

Grid = one step per candidate id; each step gathers one (1, d) row.
Out-of-range / negative ids are clamped to row 0 and the wrapper masks
their outputs to +inf (padding contract shared with repro.core).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, row_ref, q_ref, out_ref, *, metric: str):
    row = row_ref[...].astype(jnp.float32)       # [1, d]
    q = q_ref[...].astype(jnp.float32)           # [1, d]
    if metric == "l2":
        diff = row - q
        out_ref[...] = jnp.sum(diff * diff, axis=1)
    elif metric == "cos":
        out_ref[...] = 1.0 - jnp.sum(row * q, axis=1)
    else:  # dot
        out_ref[...] = -jnp.sum(row * q, axis=1)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def gather_distance_pallas(q: jax.Array, vectors: jax.Array, ids: jax.Array,
                           metric: str = "l2",
                           interpret: bool = False) -> jax.Array:
    """q[d], vectors[n,d], ids[k] (int32; <0 = padding) -> f32[k]."""
    n, d = vectors.shape
    k = ids.shape[0]
    safe = jnp.clip(ids, 0, n - 1).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_kernel, metric=metric),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(k,),
            in_specs=[
                pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0)),
                pl.BlockSpec((1, d), lambda i, ids_ref: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1,), lambda i, ids_ref: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=interpret,
    )(safe, vectors, q[None, :])
    return jnp.where(ids >= 0, out, jnp.inf)


def _batch_kernel(ids_ref, row_ref, q_ref, out_ref, *, metric: str):
    row = row_ref[...].astype(jnp.float32)       # [1, d]
    q = q_ref[...].astype(jnp.float32)           # [1, d]
    if metric == "l2":
        diff = row - q
        out_ref[...] = jnp.sum(diff * diff, axis=1, keepdims=True)
    elif metric == "cos":
        out_ref[...] = 1.0 - jnp.sum(row * q, axis=1, keepdims=True)
    else:  # dot
        out_ref[...] = -jnp.sum(row * q, axis=1, keepdims=True)


def _quantized_kernel(ids_ref, code_ref, s_ref, q_ref, out_ref,
                      *, metric: str):
    # dequantize the one gathered row in VMEM: the f32 store never exists
    row = code_ref[...].astype(jnp.float32) * \
        s_ref[...].astype(jnp.float32)               # [1, d] * [1, 1]
    q = q_ref[...].astype(jnp.float32)               # [1, d]
    if metric == "l2":
        diff = row - q
        out_ref[...] = jnp.sum(diff * diff, axis=1)
    elif metric == "cos":
        out_ref[...] = 1.0 - jnp.sum(row * q, axis=1)
    else:  # dot
        out_ref[...] = -jnp.sum(row * q, axis=1)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def quantized_gather_distance_pallas(q: jax.Array, codes: jax.Array,
                                     scale: jax.Array, ids: jax.Array,
                                     metric: str = "l2",
                                     interpret: bool = False) -> jax.Array:
    """q[d], codes[n,d] int8, scale[n] f32, ids[k] (<0 = padding) -> f32[k].

    The int8-resident variant of :func:`gather_distance_pallas`: the
    scalar-prefetch index_map gathers the (1, d) int8 code row AND its
    (1, 1) scale, the row dequantizes in VMEM, and the distance forms
    match the f32 kernel -- so HBM streams d + 4 bytes per candidate
    instead of 4d.
    """
    n, d = codes.shape
    k = ids.shape[0]
    safe = jnp.clip(ids, 0, n - 1).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_quantized_kernel, metric=metric),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(k,),
            in_specs=[
                pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0)),
                pl.BlockSpec((1, 1), lambda i, ids_ref: (ids_ref[i], 0)),
                pl.BlockSpec((1, d), lambda i, ids_ref: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1,), lambda i, ids_ref: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=interpret,
    )(safe, codes, scale[:, None], q[None, :])
    return jnp.where(ids >= 0, out, jnp.inf)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def gather_distance_batch_pallas(Q: jax.Array, vectors: jax.Array,
                                 ids: jax.Array, metric: str = "l2",
                                 interpret: bool = False) -> jax.Array:
    """Q[b,d], vectors[n,d], ids[b,k] (int32; <0 = padding) -> f32[b,k].

    The batched-engine variant of the fused gather+distance kernel: all B
    id lists stream through ONE pallas_call with a (B, K) grid -- the
    scalar-prefetch index_map reads ``ids[b, k]`` to pick the HBM row and
    ``b`` to pick the query row, so the multi-query engine pays a single
    trace/launch instead of B separate ones. Retired lanes pass ids == -1
    (clamped to row 0, masked to +inf here), matching the engine's
    active-query masking contract.
    """
    n, d = vectors.shape
    b, k = ids.shape
    safe = jnp.clip(ids, 0, n - 1).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_batch_kernel, metric=metric),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, k),
            in_specs=[
                pl.BlockSpec((1, d), lambda i, j, ids_ref: (ids_ref[i, j], 0)),
                pl.BlockSpec((1, d), lambda i, j, ids_ref: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1), lambda i, j, ids_ref: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(safe, vectors, Q)
    return jnp.where(ids >= 0, out, jnp.inf)


def _quantized_batch_kernel(ids_ref, code_ref, s_ref, q_ref, out_ref,
                            *, metric: str):
    row = code_ref[...].astype(jnp.float32) * \
        s_ref[...].astype(jnp.float32)               # [1, d] * [1, 1]
    q = q_ref[...].astype(jnp.float32)               # [1, d]
    if metric == "l2":
        diff = row - q
        out_ref[...] = jnp.sum(diff * diff, axis=1, keepdims=True)
    elif metric == "cos":
        out_ref[...] = 1.0 - jnp.sum(row * q, axis=1, keepdims=True)
    else:  # dot
        out_ref[...] = -jnp.sum(row * q, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def quantized_gather_distance_batch_pallas(Q: jax.Array, codes: jax.Array,
                                           scale: jax.Array, ids: jax.Array,
                                           metric: str = "l2",
                                           interpret: bool = False
                                           ) -> jax.Array:
    """Q[b,d], codes[n,d] int8, scale[n] f32, ids[b,k] -> f32[b,k].

    The batched int8-resident gather+distance kernel: one (B, K) grid
    streams every lane's candidate codes + scales through VMEM (the
    batched-frontier engine's distance primitive when the index is
    quantized-resident). ids < 0 are clamped to row 0 and masked to +inf
    here, matching the engine's retired-lane contract.
    """
    n, d = codes.shape
    b, k = ids.shape
    safe = jnp.clip(ids, 0, n - 1).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_quantized_batch_kernel, metric=metric),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, k),
            in_specs=[
                pl.BlockSpec((1, d),
                             lambda i, j, ids_ref: (ids_ref[i, j], 0)),
                pl.BlockSpec((1, 1),
                             lambda i, j, ids_ref: (ids_ref[i, j], 0)),
                pl.BlockSpec((1, d), lambda i, j, ids_ref: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1), lambda i, j, ids_ref: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(safe, codes, scale[:, None], Q)
    return jnp.where(ids >= 0, out, jnp.inf)
