"""Public jit'd wrappers around the Pallas kernels.

Pad-and-dispatch layer: arbitrary shapes are padded up to MXU-aligned tile
multiples, the kernel runs, and results are sliced back. On hosts without
a TPU the wrappers route to the pure-jnp oracles (``ref.py``) so the whole
framework runs anywhere; the kernels themselves stay validated in
interpret mode by tests/test_kernels_*.py. Set ``REPRO_FORCE_PALLAS=1`` to
force interpret-mode kernels on CPU (slow; used by the kernel benchmarks).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.distance_matrix import distance_matrix_pallas
from repro.kernels.gather_distance import (
    gather_distance_batch_pallas, gather_distance_pallas,
    quantized_gather_distance_batch_pallas, quantized_gather_distance_pallas)
from repro.kernels.quantized import quantized_distance_pallas
from repro.kernels.segment_sum import csr_segment_sum_pallas, plan_tiles


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _force_pallas() -> bool:
    return os.environ.get("REPRO_FORCE_PALLAS", "0") == "1"


def _use_pallas() -> bool:
    return _on_tpu() or _force_pallas()


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------


def distance_matrix(Q, X, metric: str = "l2", bq: int = 128, bn: int = 128,
                    bd: int = 128):
    """All-pairs distances with automatic padding. f32[b, n]."""
    if not _use_pallas():
        return ref.distance_matrix(Q, X, metric)
    b, n = Q.shape[0], X.shape[0]
    bq = min(bq, max(8, 1 << (b - 1).bit_length()))
    Qp = _pad_to(_pad_to(Q, 1, bd), 0, bq)
    Xp = _pad_to(_pad_to(X, 1, bd), 0, bn)
    out = distance_matrix_pallas(Qp, Xp, metric, bq=bq, bn=bn,
                                 bd=min(bd, Qp.shape[1]),
                                 interpret=not _on_tpu())
    return out[:b, :n]


def gather_distance(q, vectors, ids, metric: str = "l2"):
    """Fused gather+distance: dist(q, vectors[ids]); ids<0 -> inf. f32[k]."""
    if not _use_pallas():
        return ref.gather_distance(q, vectors, ids, metric)
    vp = _pad_to(vectors, 1, 128)
    qp = _pad_to(q, 0, 128)
    return gather_distance_pallas(qp, vp, ids, metric,
                                  interpret=not _on_tpu())


def gather_distance_batch(Q, vectors, ids, metric: str = "l2"):
    """Batched fused gather+distance: dist(Q[b], vectors[ids[b]]). f32[b,k].

    One pallas_call grid streams all B id lists (the batched engine's
    distance primitive); ids<0 -> inf.
    """
    if not _use_pallas():
        return ref.gather_distance_batch(Q, vectors, ids, metric)
    vp = _pad_to(vectors, 1, 128)
    qp = _pad_to(Q, 1, 128)
    return gather_distance_batch_pallas(qp, vp, ids, metric,
                                        interpret=not _on_tpu())


def quantized_gather_distance(q, codes, scale, ids, metric: str = "l2"):
    """Fused int8 gather+distance: dist(q, scale[ids] * codes[ids]);
    ids<0 -> inf. f32[k]. The single-query primitive of the
    quantized-resident engine; d is zero-padded to the lane multiple
    (padded dims contribute 0 under every metric)."""
    if not _use_pallas():
        return ref.quantized_gather_distance(q, codes, scale, ids, metric)
    cp = _pad_to(codes, 1, 128)
    qp = _pad_to(q, 0, 128)
    return quantized_gather_distance_pallas(qp, cp, scale, ids, metric,
                                            interpret=not _on_tpu())


def quantized_gather_distance_batch(Q, codes, scale, ids, metric: str = "l2"):
    """Batched fused int8 gather+distance:
    dist(Q[b], scale[ids[b]] * codes[ids[b]]); ids<0 -> inf. f32[b,k].

    One pallas_call grid streams all B id lists over the int8 store --
    the batched-frontier engine's distance primitive when the index is
    quantized-resident (d + 4 HBM bytes per candidate instead of 4d).
    """
    if not _use_pallas():
        return ref.quantized_gather_distance_batch(Q, codes, scale, ids,
                                                   metric)
    cp = _pad_to(codes, 1, 128)
    qp = _pad_to(Q, 1, 128)
    return quantized_gather_distance_batch_pallas(qp, cp, scale, ids, metric,
                                                  interpret=not _on_tpu())


def quantized_distance_matrix(Q, codes, scale, metric: str = "l2",
                              bq: int = 128, bn: int = 128, bd: int = 128):
    """Distances against int8 codes with per-vector scales. f32[b, n]."""
    if not _use_pallas():
        return ref.quantized_distance_matrix(Q, codes, scale, metric)
    b, n = Q.shape[0], codes.shape[0]
    bq = min(bq, max(8, 1 << (b - 1).bit_length()))
    Qp = _pad_to(_pad_to(Q, 1, bd), 0, bq)
    Cp = _pad_to(_pad_to(codes, 1, bd), 0, bn)
    Sp = _pad_to(scale, 0, bn)
    out = quantized_distance_pallas(Qp, Cp, Sp, metric, bq=bq, bn=bn,
                                    bd=min(bd, Qp.shape[1]),
                                    interpret=not _on_tpu())
    return out[:b, :n]


def csr_segment_sum(messages, dst_sorted, n: int, bn: int = 128,
                    be: int = 256):
    """Sorted segment sum -> f32[n, d]. messages[E,d], dst_sorted[E]
    ascending; -1 padding allowed anywhere only if pre-sorted as if it were
    +inf (callers usually produce it at the end)."""
    if not _use_pallas():
        return ref.csr_segment_sum(messages, dst_sorted, n)
    from repro.kernels.segment_sum import PAD_SENTINEL
    mp = _pad_to(messages, 0, be)
    dp = _pad_to(dst_sorted, 0, be, value=PAD_SENTINEL)
    dp = jnp.where(dp < 0, PAD_SENTINEL, dp)
    first, t_max = plan_tiles(np.asarray(dp), n, bn, be, mp.shape[0])
    out = csr_segment_sum_pallas(mp, dp, jnp.asarray(first), n, bn=bn, be=be,
                                 t_max=t_max, interpret=not _on_tpu())
    return out[:n]
