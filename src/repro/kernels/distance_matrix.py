"""MXU-tiled all-pairs distance kernel.

Computes D[b, n] = dist(Q[b, d], X[n, d]) with the matmul decomposition
``||q - x||^2 = ||q||^2 + ||x||^2 - 2 q.x`` so the dominant term runs on
the MXU. The d (contraction) axis is the innermost grid dimension; partial
products accumulate in a f32 VMEM scratch and the (transformed) result is
written on the last d-step -- the canonical Pallas matmul schedule.

Block shapes are (bq, bd) x (bn, bd) -> (bq, bn), all multiples of the
MXU/VPU native tiling (128 lanes, 8 sublanes); the wrapper in ops.py pads
arbitrary shapes up to tile multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# older jax releases (< 0.5) name the struct TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(q_ref, x_ref, out_ref, acc_ref, *, metric: str, n_d: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)          # [bq, bd]
    x = x_ref[...].astype(jnp.float32)          # [bn, bd]
    dot = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    if metric == "l2":
        qq = jnp.sum(q * q, axis=1, keepdims=True)      # [bq, 1]
        xx = jnp.sum(x * x, axis=1, keepdims=True).T    # [1, bn]
        acc_ref[...] += qq + xx - 2.0 * dot
    else:
        acc_ref[...] += dot

    @pl.when(k == n_d - 1)
    def _done():
        acc = acc_ref[...]
        if metric == "l2":
            out_ref[...] = acc
        elif metric == "cos":
            out_ref[...] = 1.0 - acc
        else:  # dot
            out_ref[...] = -acc


@functools.partial(jax.jit,
                   static_argnames=("metric", "bq", "bn", "bd", "interpret"))
def distance_matrix_pallas(Q: jax.Array, X: jax.Array, metric: str = "l2",
                           bq: int = 128, bn: int = 128, bd: int = 128,
                           interpret: bool = False) -> jax.Array:
    """Q[b,d], X[n,d] -> f32[b,n]. b, n, d must be multiples of the blocks."""
    b, d = Q.shape
    n, d2 = X.shape
    assert d == d2, (d, d2)
    assert b % bq == 0 and n % bn == 0 and d % bd == 0, (Q.shape, X.shape)
    n_d = d // bd
    grid = (b // bq, n // bn, n_d)
    return pl.pallas_call(
        functools.partial(_kernel, metric=metric, n_d=n_d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(Q, X)
