"""Declarative plan operators (selection subqueries, kNN, projection)."""
