"""Query-plan operators: selection subqueries + first-class kNN rows.

The paper evaluates predicate-agnostic queries by running an arbitrary
selection subquery Q_S first (filters, joins) and passing the resulting
selected set S to the kNN operator as a node semimask via sideways
information passing. This module holds the whole plan algebra: the Q_S
evaluator (a small typed operator tree over the columnar GraphStore
producing a boolean mask over one node table) plus the row-producing
operators the unified NavixDB pipeline executes on top of it.

Selection (mask) operators mirror the paper's workloads:
  NodeScan          MATCH (c:Chunk)                    -> all true
  Filter            WHERE c.cid < X / range / eq / isin
  HopJoin           MATCH (p)-[:R]->(c) WHERE mask(p)  -> semi-join (1 hop)
  (chain HopJoin twice for the 2-hop graph-RAG workload of Section 5.7.1)
  And / Or / Not    boolean combinators

Row operators (executed by ``repro.api.db.NavixDB``, not by ``evaluate``):
  KnnSearch         QUERY_HNSW_INDEX: child = Q_S, produces scored rows
  Project           keep named property columns of the result rows
  Limit             truncate to the first n rows

All nodes are frozen dataclasses: plans are hashable values, which is what
lets the serving engine group requests by plan and the compile layer key
cached programs by plan shape. The query *vector* is deliberately not part
of ``KnnSearch`` -- it is bound at execution time, so one plan shape serves
any number of queries (and batches) through one compiled program.

``evaluate`` runs on the host (numpy) -- this is the prefiltering phase
whose cost Table 7 accounts separately -- and the resulting mask is packed
to a device bitset for the search operator.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Union

import numpy as np

from repro.storage.columnar import GraphStore

SelectionPlan = Union["NodeScan", "Filter", "HopJoin", "And", "Or", "Not"]
Plan = Union[SelectionPlan, "KnnSearch", "Project", "Limit"]


@dataclasses.dataclass(frozen=True)
class NodeScan:
    table: str


@dataclasses.dataclass(frozen=True)
class Filter:
    child: Plan
    column: str
    op: str                    # "<", "<=", ">", ">=", "==", "range", "isin"
    value: object = None
    lo: object = None
    hi: object = None


@dataclasses.dataclass(frozen=True)
class HopJoin:
    """Semi-join: select dst-table nodes reachable from selected src nodes
    via rel (direction 'fwd': src->dst edges; 'bwd' follows edges backwards)."""
    child: Plan                # plan over the rel's source side
    rel: str
    direction: str = "fwd"


@dataclasses.dataclass(frozen=True)
class And:
    left: Plan
    right: Plan


@dataclasses.dataclass(frozen=True)
class Or:
    left: Plan
    right: Plan


@dataclasses.dataclass(frozen=True)
class Not:
    child: Plan


@dataclasses.dataclass(frozen=True)
class KnnSearch:
    """The paper's QUERY_HNSW_INDEX as a plan operator.

    ``child`` is the selection subquery Q_S (None = unfiltered search);
    ``index`` names a catalog entry (None = resolve by the child's output
    table); ``table`` is only needed when ``child`` is None. The query
    vector is bound at execution time (see module docstring).
    """
    child: Optional[Plan] = None
    k: int = 10
    index: Optional[str] = None
    table: Optional[str] = None
    efs: int = 0                   # 0 -> 2*k at execution
    heuristic: str = "adaptive_local"


@dataclasses.dataclass(frozen=True)
class Project:
    child: Plan
    columns: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Limit:
    child: Plan
    n: int


@dataclasses.dataclass(frozen=True)
class PipelineParts:
    """A root plan split into its three execution stages (top-down)."""
    selection: Optional[Plan]      # Q_S subtree (mask-producing), or None
    knn: Optional[KnnSearch]       # the kNN operator, or None (pure Q_S)
    projections: tuple[str, ...]   # union of Project columns above the knn
    limit: Optional[int]           # smallest Limit above the knn, or None


def split_pipeline(plan: Plan) -> PipelineParts:
    """Walk Project/Limit wrappers down to the KnnSearch (if any) and its
    selection subtree. Row operators below a KnnSearch are rejected."""
    projections: tuple[str, ...] = ()
    limit: Optional[int] = None
    node = plan
    while isinstance(node, (Project, Limit)):
        if isinstance(node, Project):
            projections = tuple(c for c in node.columns
                                if c not in projections) + projections
        else:
            limit = node.n if limit is None else min(limit, node.n)
        node = node.child
    if isinstance(node, KnnSearch):
        sel = node.child
        if sel is not None and not is_selection(sel):
            raise TypeError(f"KnnSearch child must be a selection subquery, "
                            f"got {type(sel).__name__}")
        return PipelineParts(selection=sel, knn=node,
                             projections=projections, limit=limit)
    if not is_selection(node):
        raise TypeError(f"unsupported plan node {type(node).__name__}")
    return PipelineParts(selection=node, knn=None,
                         projections=projections, limit=limit)


def is_selection(plan: Plan) -> bool:
    return isinstance(plan, (NodeScan, Filter, HopJoin, And, Or, Not))


@dataclasses.dataclass
class QueryResult:
    table: str
    mask: np.ndarray           # bool[n]
    seconds: float             # prefiltering time (Table 7)

    @property
    def selectivity(self) -> float:
        return float(self.mask.mean())


def output_table(plan: Plan, store: GraphStore) -> str:
    if isinstance(plan, NodeScan):
        return plan.table
    if isinstance(plan, Filter):
        return output_table(plan.child, store)
    if isinstance(plan, HopJoin):
        rel = store.rel(plan.rel)
        return rel.dst_table if plan.direction == "fwd" else rel.src_table
    if isinstance(plan, (And, Or)):
        lt = output_table(plan.left, store)
        rt = output_table(plan.right, store)
        if lt != rt:
            raise ValueError(f"boolean combinator over different tables: {lt} vs {rt}")
        return lt
    if isinstance(plan, Not):
        return output_table(plan.child, store)
    if isinstance(plan, KnnSearch):
        if plan.child is not None:
            return output_table(plan.child, store)
        if plan.table is None:
            raise ValueError("unfiltered KnnSearch needs an explicit table")
        return plan.table
    if isinstance(plan, (Project, Limit)):
        return output_table(plan.child, store)
    raise TypeError(plan)


def _eval(plan: Plan, store: GraphStore) -> np.ndarray:
    if isinstance(plan, NodeScan):
        return np.ones(store.node(plan.table).n, dtype=bool)
    if isinstance(plan, Filter):
        mask = _eval(plan.child, store)
        col = store.node(output_table(plan.child, store)).column(plan.column)
        if plan.op == "<":
            pred = col < plan.value
        elif plan.op == "<=":
            pred = col <= plan.value
        elif plan.op == ">":
            pred = col > plan.value
        elif plan.op == ">=":
            pred = col >= plan.value
        elif plan.op == "==":
            pred = col == plan.value
        elif plan.op == "range":
            pred = (col >= plan.lo) & (col < plan.hi)
        elif plan.op == "isin":
            pred = np.isin(col, np.asarray(plan.value))
        else:
            raise ValueError(f"unknown filter op {plan.op!r}")
        return mask & pred
    if isinstance(plan, HopJoin):
        rel = store.rel(plan.rel)
        src_mask = _eval(plan.child, store)
        csr = rel.fwd if plan.direction == "fwd" else rel.bwd
        n_out = store.node(rel.dst_table if plan.direction == "fwd"
                           else rel.src_table).n
        out = np.zeros(n_out, dtype=bool)
        sel = np.flatnonzero(src_mask)
        # expand CSR ranges of the selected sources (vectorized)
        starts, ends = csr.offsets[sel], csr.offsets[sel + 1]
        total = int((ends - starts).sum())
        if total:
            idx = np.repeat(starts, ends - starts) + _ranges(ends - starts)
            out[csr.targets[idx]] = True
        return out
    if isinstance(plan, And):
        return _eval(plan.left, store) & _eval(plan.right, store)
    if isinstance(plan, Or):
        return _eval(plan.left, store) | _eval(plan.right, store)
    if isinstance(plan, Not):
        return ~_eval(plan.child, store)
    raise TypeError(plan)


def _ranges(lengths: np.ndarray) -> np.ndarray:
    """[0..l0-1, 0..l1-1, ...] for per-source offsets into CSR ranges."""
    csum = np.cumsum(lengths)
    out = np.arange(csum[-1])
    out -= np.repeat(csum - lengths, lengths)
    return out


def evaluate(plan: Plan, store: GraphStore) -> QueryResult:
    """Run Q_S; returns the node semimask + prefiltering wall time."""
    if not is_selection(plan):
        raise TypeError(
            f"evaluate() runs selection subqueries only; execute "
            f"{type(plan).__name__} plans through repro.api.NavixDB")
    t0 = time.perf_counter()
    table = output_table(plan, store)
    mask = _eval(plan, store)
    return QueryResult(table=table, mask=mask,
                       seconds=time.perf_counter() - t0)
