"""Selection-subquery operators -> node semimasks.

The paper evaluates predicate-agnostic queries by running an arbitrary
selection subquery Q_S first (filters, joins) and passing the resulting
selected set S to the kNN operator as a node semimask via sideways
information passing. This module is the Q_S evaluator: a small typed
operator tree over the columnar GraphStore producing a boolean mask over
one node table.

Operators mirror the paper's workloads:
  NodeScan          MATCH (c:Chunk)                    -> all true
  Filter            WHERE c.cid < X / range / eq / isin
  HopJoin           MATCH (p)-[:R]->(c) WHERE mask(p)  -> semi-join (1 hop)
  (chain HopJoin twice for the 2-hop graph-RAG workload of Section 5.7.1)
  And / Or / Not    boolean combinators

``evaluate`` runs on the host (numpy) -- this is the prefiltering phase
whose cost Table 7 accounts separately -- and the resulting mask is packed
to a device bitset for the search operator.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.storage.columnar import GraphStore

Plan = Union["NodeScan", "Filter", "HopJoin", "And", "Or", "Not"]


@dataclasses.dataclass(frozen=True)
class NodeScan:
    table: str


@dataclasses.dataclass(frozen=True)
class Filter:
    child: Plan
    column: str
    op: str                    # "<", "<=", ">", ">=", "==", "range", "isin"
    value: object = None
    lo: object = None
    hi: object = None


@dataclasses.dataclass(frozen=True)
class HopJoin:
    """Semi-join: select dst-table nodes reachable from selected src nodes
    via rel (direction 'fwd': src->dst edges; 'bwd' follows edges backwards)."""
    child: Plan                # plan over the rel's source side
    rel: str
    direction: str = "fwd"


@dataclasses.dataclass(frozen=True)
class And:
    left: Plan
    right: Plan


@dataclasses.dataclass(frozen=True)
class Or:
    left: Plan
    right: Plan


@dataclasses.dataclass(frozen=True)
class Not:
    child: Plan


@dataclasses.dataclass
class QueryResult:
    table: str
    mask: np.ndarray           # bool[n]
    seconds: float             # prefiltering time (Table 7)

    @property
    def selectivity(self) -> float:
        return float(self.mask.mean())


def output_table(plan: Plan, store: GraphStore) -> str:
    if isinstance(plan, NodeScan):
        return plan.table
    if isinstance(plan, Filter):
        return output_table(plan.child, store)
    if isinstance(plan, HopJoin):
        rel = store.rel(plan.rel)
        return rel.dst_table if plan.direction == "fwd" else rel.src_table
    if isinstance(plan, (And, Or)):
        lt = output_table(plan.left, store)
        rt = output_table(plan.right, store)
        if lt != rt:
            raise ValueError(f"boolean combinator over different tables: {lt} vs {rt}")
        return lt
    if isinstance(plan, Not):
        return output_table(plan.child, store)
    raise TypeError(plan)


def _eval(plan: Plan, store: GraphStore) -> np.ndarray:
    if isinstance(plan, NodeScan):
        return np.ones(store.node(plan.table).n, dtype=bool)
    if isinstance(plan, Filter):
        mask = _eval(plan.child, store)
        col = store.node(output_table(plan.child, store)).column(plan.column)
        if plan.op == "<":
            pred = col < plan.value
        elif plan.op == "<=":
            pred = col <= plan.value
        elif plan.op == ">":
            pred = col > plan.value
        elif plan.op == ">=":
            pred = col >= plan.value
        elif plan.op == "==":
            pred = col == plan.value
        elif plan.op == "range":
            pred = (col >= plan.lo) & (col < plan.hi)
        elif plan.op == "isin":
            pred = np.isin(col, np.asarray(plan.value))
        else:
            raise ValueError(f"unknown filter op {plan.op!r}")
        return mask & pred
    if isinstance(plan, HopJoin):
        rel = store.rel(plan.rel)
        src_mask = _eval(plan.child, store)
        csr = rel.fwd if plan.direction == "fwd" else rel.bwd
        n_out = store.node(rel.dst_table if plan.direction == "fwd"
                           else rel.src_table).n
        out = np.zeros(n_out, dtype=bool)
        sel = np.flatnonzero(src_mask)
        # expand CSR ranges of the selected sources (vectorized)
        starts, ends = csr.offsets[sel], csr.offsets[sel + 1]
        total = int((ends - starts).sum())
        if total:
            idx = np.repeat(starts, ends - starts) + _ranges(ends - starts)
            out[csr.targets[idx]] = True
        return out
    if isinstance(plan, And):
        return _eval(plan.left, store) & _eval(plan.right, store)
    if isinstance(plan, Or):
        return _eval(plan.left, store) | _eval(plan.right, store)
    if isinstance(plan, Not):
        return ~_eval(plan.child, store)
    raise TypeError(plan)


def _ranges(lengths: np.ndarray) -> np.ndarray:
    """[0..l0-1, 0..l1-1, ...] for per-source offsets into CSR ranges."""
    csum = np.cumsum(lengths)
    out = np.arange(csum[-1])
    out -= np.repeat(csum - lengths, lengths)
    return out


def evaluate(plan: Plan, store: GraphStore) -> QueryResult:
    """Run Q_S; returns the node semimask + prefiltering wall time."""
    t0 = time.perf_counter()
    table = output_table(plan, store)
    mask = _eval(plan, store)
    return QueryResult(table=table, mask=mask,
                       seconds=time.perf_counter() - t0)
