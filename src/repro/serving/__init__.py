"""The serving tier: closed-queue engine + live front door.

* :class:`SearchEngine` -- closed-queue drains (submit everything, then
  ``drain()``); the continuous-batching scheduler's reference driver.
* :class:`SearchService` -- the live loop: ``submit() -> Future`` while
  the device steps, deadlines, backpressure, heartbeat shard liveness.
* Both run the same :class:`~repro.serving.lanes.LaneBatch` device core,
  so their per-lane answers stay in bitwise lockstep.
"""

from repro.serving.engine import (Request, Response, SearchEngine,
                                  canonical_plan, greedy_generate,
                                  resolve_alive)
from repro.serving.heartbeat import HeartbeatMonitor
from repro.serving.lanes import LaneBatch
from repro.serving.queues import (QueueFull, QueueItem, ServiceClosed,
                                  SubmissionQueue, sigma_bin)
from repro.serving.service import SearchService

__all__ = [
    "HeartbeatMonitor", "LaneBatch", "QueueFull", "QueueItem", "Request",
    "Response", "SearchEngine", "SearchService", "ServiceClosed",
    "SubmissionQueue", "canonical_plan", "greedy_generate",
    "resolve_alive", "sigma_bin",
]
