"""The live serving front door: :class:`SearchService`.

Where ``SearchEngine.drain()`` serves a *closed* queue (everything
submitted up front, loop until empty), ``SearchService`` runs the same
:class:`~repro.serving.lanes.LaneBatch` machine *forever*: clients
``submit()`` from any thread (or ``await asubmit()``), the device loop
advances in ``step_iters``-sized chunks, and between chunks it

1. expires queue items whose deadline already passed (they never get a
   lane; their futures resolve to a ``timeout`` response) -- host-only
   work that OVERLAPS the chunk still in flight from the previous tick,
2. waits on that chunk, then finalizes converged lanes (``ok``) and
   evicts in-flight lanes past their deadline -- finalizing FIRST so a
   beam that already covers k valid candidates is salvaged as a
   ``"partial"`` best-effort answer; otherwise the response is
   ``"timeout"`` with all ids ``-1`` (never a truncated id list),
3. admits new requests from the :class:`SubmissionQueue` into freed
   lanes (deadline-ordered, selectivity-binned; see ``queues.py``),
4. dispatches the next chunk asynchronously on donated state buffers
   and resolves the finalized futures while it runs.

Shard liveness is heartbeat-derived (:class:`HeartbeatMonitor`): the
alive mask is recomputed from per-shard heartbeat staleness at every
finalize, so a straggler shard flips responses to ``degraded``
automatically -- no caller-set mask. Because ShardedNavix masks shards
only at the finalize merge, answers under a stale shard equal the
alive-restricted reference exactly.

Drive it with the background thread (``start()`` / ``shutdown()``) or
tick it by hand (``_tick()``) for deterministic tests. ``shutdown``
with ``drain=True`` answers every submitted rid exactly once before
returning; ``drain=False`` cancels outstanding futures.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Callable, Optional

import numpy as np

from repro.api.db import NavixDB
from repro.api.plan_compile import _bucket
from repro.query.operators import output_table, split_pipeline
from repro.serving.engine import Response, canonical_plan, resolve_alive
from repro.serving.lanes import LaneBatch
from repro.serving.queues import ServiceClosed, SubmissionQueue

try:                                    # stdlib; import guarded only so the
    from concurrent.futures import Future  # module surface is explicit
except ImportError:                     # pragma: no cover
    raise


@dataclasses.dataclass
class _Pending:
    """Everything the device loop needs about one in-flight submission.
    Lives as ``QueueItem.meta`` while queued, then as ``LaneBatch.meta``
    while occupying a lane."""
    rid: int
    fut: Future
    k: int
    efs: int                     # this request's OWN efs (<= the service
                                 # cap): its lane's beam tail beyond efs
                                 # is masked, so small-efs requests skip
                                 # cap-wide beam maintenance
    sigma: float
    pf_ms: float                 # this submission's prefilter charge (the
                                 # first carrier of a Q_S pays its wall
                                 # time; later cache hits pay 0)
    deadline: Optional[float]
    t_enqueue: float
    t_start: float = 0.0         # set at lane admission
    qrow: Optional[np.ndarray] = None
    sel_row: Optional[np.ndarray] = None


class SearchService:
    """Async front door over one catalog index entry.

    The device program is fixed at construction (``k_cap`` / ``efs_cap``
    / ``heuristic`` / batch size): a live loop cannot re-derive caps per
    drain, so submissions exceeding them are rejected at ``submit``.
    ``clock`` is injectable -- deadlines, queue timestamps, and latency
    accounting all run on it, so tests drive a fake clock.
    """

    def __init__(self, db: NavixDB, index: Optional[str] = None,
                 heuristic: str = "adaptive_local", k_cap: int = 10,
                 efs_cap: int = 0, max_batch: int = 16,
                 step_iters: int = 32,
                 default_deadline_s: Optional[float] = None,
                 queue: Optional[SubmissionQueue] = None,
                 queue_size: int = 256, policy: str = "reject",
                 high_watermark: Optional[int] = None,
                 low_watermark: Optional[int] = None,
                 alive: Optional[np.ndarray] = None,
                 heartbeats: Optional[object] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 window: int = 1024, sel_cache_size: int = 128):
        self.db = db
        name = index if index is not None else next(iter(db.catalog), None)
        if name is None or name not in db.catalog:
            raise ValueError(f"no catalog index {name!r}; create one with "
                             "db.create_index(...)")
        self.entry = db.catalog[name]
        self.heuristic = heuristic
        self.k_cap = k_cap
        self.efs_cap = max(efs_cap or 2 * k_cap, k_cap)
        self.step_iters = step_iters
        self.default_deadline_s = default_deadline_s
        self.clock = clock
        self.alive = alive
        self.heartbeats = heartbeats
        # fail fast on an inconsistent liveness config instead of at the
        # first finalize (mid-service, inside the device loop)
        resolve_alive(0 if not hasattr(self.entry.index, "n_shards")
                      else self.entry.index.n_shards, alive, heartbeats)
        self.lanes = LaneBatch(self.entry.index, heuristic, k_cap,
                               self.efs_cap, _bucket(max(1, max_batch)))
        self.queue = queue if queue is not None else SubmissionQueue(
            maxsize=queue_size, policy=policy,
            high_watermark=high_watermark, low_watermark=low_watermark)
        # Q_S -> (row, sigma, ms), LRU-bounded: each packed row is
        # ~n/32 words (per shard), so an unbounded cache leaks memory on
        # a long-running service with many distinct selections. An
        # evicted Q_S is simply re-prefiltered on its next submission
        # (whose carrier then pays the wall time again).
        if sel_cache_size < 1:
            raise ValueError("sel_cache_size must be >= 1")
        self.sel_cache_size = sel_cache_size
        self._sel_cache: OrderedDict[Any, tuple] = OrderedDict()  # guarded-by: _submit_lock
        self._submit_lock = threading.Lock()
        self._lat_lock = threading.Lock()
        self._next_rid = 0                       # guarded-by: _submit_lock
        self.n_submitted = 0                     # guarded-by: _lat_lock
        self.n_done = 0                          # guarded-by: _lat_lock
        self.n_timeout = 0                       # guarded-by: _lat_lock
        self.n_partial = 0                       # guarded-by: _lat_lock
        self._lat = deque(maxlen=window)         # guarded-by: _lat_lock  (total ms, rolling)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._draining = False
        self.closed = False

    # -- client side --------------------------------------------------------
    def submit(self, query, plan=None, k: Optional[int] = None,
               deadline_s: Optional[float] = None,
               block_timeout: Optional[float] = None) -> Future:
        """Enqueue one request; returns a Future resolving to a
        :class:`Response` (status ``ok`` / ``partial`` / ``timeout``).
        Raises :class:`QueueFull` under ``reject`` backpressure (or after
        ``block_timeout`` seconds under ``block``), :class:`ServiceClosed`
        after shutdown, ``ValueError`` if the plan exceeds the service's
        fixed program (k/efs caps, heuristic, target index)."""
        if self.closed or self.queue.closed:
            raise ServiceClosed("service is shut down")
        k = k if k is not None else self.k_cap
        plan = canonical_plan(self.db, self.entry.name, plan, k, 0,
                              self.heuristic)
        parts = split_pipeline(plan)
        entry = self.db._resolve(parts.knn,
                                 output_table(plan, self.db.store))
        if entry.name != self.entry.name:
            raise ValueError(f"plan targets index {entry.name!r}; this "
                             f"service serves {self.entry.name!r}")
        if parts.knn.heuristic != self.heuristic:
            raise ValueError(f"plan heuristic {parts.knn.heuristic!r} != "
                             f"service program {self.heuristic!r}")
        k_r = parts.knn.k
        efs_r = max(parts.knn.efs or 2 * k_r, k_r)
        if k_r > self.k_cap or efs_r > self.efs_cap:
            raise ValueError(f"k={k_r}/efs={efs_r} exceed the service "
                             f"program caps (k_cap={self.k_cap}, "
                             f"efs_cap={self.efs_cap})")
        # ragged per-lane efs: a plan that names its efs gets exactly
        # that beam width (its lane skips cap-wide beam maintenance); an
        # unset efs keeps the historical cap-wide beam
        efs_lane = (min(max(parts.knn.efs, k_r), self.efs_cap)
                    if parts.knn.efs else self.efs_cap)
        # prefilter + query prep in the SUBMITTER's thread (jit dispatch
        # is thread-safe): the device loop never blocks on a prefilter,
        # and the queue can bin by the resulting sigma. One prefilter per
        # distinct Q_S for the service's lifetime; the first carrier pays.
        with self._submit_lock:
            s = parts.selection
            if s not in self._sel_cache:
                if s is None:
                    self._sel_cache[s] = (self.lanes.backend.full_row(),
                                          1.0, 0.0)
                else:
                    qres = self.db.prefilter(s)
                    self._sel_cache[s] = (
                        self.lanes.backend.pack_row(qres.mask),
                        qres.selectivity, qres.seconds * 1e3)
                row, sigma, pf_ms = self._sel_cache[s]
                while len(self._sel_cache) > self.sel_cache_size:
                    self._sel_cache.popitem(last=False)
            else:
                self._sel_cache.move_to_end(s)
                row, sigma, _ = self._sel_cache[s]
                pf_ms = 0.0
            rid = self._next_rid
            self._next_rid += 1
        qrow = np.asarray(self.entry.index._prep_query(
            np.asarray(query, np.float32)[None]), np.float32)[0]
        now = self.clock()
        ddl_s = deadline_s if deadline_s is not None \
            else self.default_deadline_s
        pend = _Pending(rid=rid, fut=Future(), k=k_r, efs=efs_lane,
                        sigma=float(sigma), pf_ms=pf_ms,
                        deadline=None if ddl_s is None else now + ddl_s,
                        t_enqueue=now, qrow=qrow, sel_row=row)
        self.queue.put(sigma, pend.deadline, pend,
                       timeout=block_timeout, now=now)
        with self._lat_lock:
            self.n_submitted += 1
        return pend.fut

    async def asubmit(self, query, plan=None, k: Optional[int] = None,
                      deadline_s: Optional[float] = None) -> Response:
        """Asyncio driver: awaits the response. ``submit`` may block
        under ``block`` backpressure, so it runs in the default
        executor."""
        import asyncio
        loop = asyncio.get_running_loop()
        fut = await loop.run_in_executor(
            None, lambda: self.submit(query, plan, k, deadline_s))
        return await asyncio.wrap_future(fut)

    # -- device loop --------------------------------------------------------
    def _alive(self) -> np.ndarray:
        return resolve_alive(self.lanes.n_shards, self.alive,
                             self.heartbeats)

    def _resolve(self, pend: _Pending, resp: Response) -> None:
        if not pend.fut.done():
            pend.fut.set_result(resp)
            # gauges() reads the counters and iterates this deque from
            # other threads; an unguarded update can tear that poll
            with self._lat_lock:
                self.n_done += 1
                self._lat.append(resp.queue_ms + resp.exec_ms
                                 + resp.prefilter_ms)
                if resp.status == "timeout":
                    self.n_timeout += 1
                elif resp.status == "partial":
                    self.n_partial += 1

    def _emit_timeout(self, pend: _Pending, now: float) -> None:
        self._resolve(pend, Response(
            rid=pend.rid, ids=np.full(pend.k, -1, np.int64),
            dists=np.full(pend.k, np.inf, np.float32),
            queue_ms=(now - pend.t_enqueue) * 1e3, exec_ms=0.0,
            prefilter_ms=pend.pf_ms, sigma=pend.sigma,
            degraded=False, status="timeout"))

    def _tick(self, now: Optional[float] = None) -> bool:
        """One service-loop iteration: expire -> wait on the previous
        chunk -> finalize (converged + overdue) -> admit -> dispatch the
        next chunk -> resolve futures. Returns False when there was
        nothing to do (the thread driver then parks on the queue). Call
        directly for deterministic single-threaded tests.

        Overlapped stepping: the chunk dispatched at the END of each tick
        (donated state, async) is waited on at the TOP of the next, so
        queue expiry overlaps the in-flight chunk and future resolution
        overlaps the next one. A lane that both converged in the chunk
        and passed its deadline while in flight resolves ``ok`` --
        convergence takes precedence, matching the synchronous order
        where the step emitted it before the deadline check could run.
        """
        now = self.clock() if now is None else now
        worked = False

        # 1. queue-side expiry: deadline passed before a lane freed up
        # (host-only -- runs while the previous chunk is still in flight)
        for it in self.queue.expire(now):
            self._emit_timeout(it.meta, now)
            worked = True

        # 2. synchronize on the chunk dispatched last tick (the ONE
        # device sync per tick)
        live = self.lanes.step_wait() if self.lanes.step_pending else None
        t_done = self.clock()

        # 3. one finalize covers both converged and overdue lanes.
        # Finalize FIRST for overdue lanes: a beam that already holds k
        # valid candidates is a usable best-effort answer ("partial");
        # anything less resolves to "timeout" with ALL ids -1 -- a
        # truncated list would silently read as a full top-k. Evicted
        # lanes park on device (live=False) so the next admit reuses
        # them. Responses are built here but resolved AFTER the next
        # chunk is dispatched (step 6).
        conv = ([] if live is None else
                [i for i in self.lanes.occupied() if not live[i]])
        overdue = [i for i in self.lanes.occupied()
                   if i not in conv
                   and self.lanes.meta[i].deadline is not None
                   and self.lanes.meta[i].deadline < now]
        rows: list[tuple] = []
        if conv or overdue:
            alive = self._alive()
            degraded = self.lanes.n_shards > 0 and not alive.all()
            ids, dists = self.lanes.finalize(alive)
            for i in conv:
                pend = self.lanes.meta[i]
                rows.append((pend, Response(
                    rid=pend.rid, ids=ids[i, :pend.k],
                    dists=dists[i, :pend.k],
                    queue_ms=(pend.t_start - pend.t_enqueue) * 1e3,
                    exec_ms=(t_done - pend.t_start) * 1e3,
                    prefilter_ms=pend.pf_ms, sigma=pend.sigma,
                    degraded=degraded, status="ok")))
                self.lanes.release(i)
            for i in overdue:
                pend = self.lanes.meta[i]
                got = ids[i, :pend.k]
                if (got >= 0).all():
                    rows.append((pend, Response(
                        rid=pend.rid, ids=got, dists=dists[i, :pend.k],
                        queue_ms=(pend.t_start - pend.t_enqueue) * 1e3,
                        exec_ms=(now - pend.t_start) * 1e3,
                        prefilter_ms=pend.pf_ms, sigma=pend.sigma,
                        degraded=degraded, status="partial")))
                else:
                    rows.append((pend, None))    # timeout, built in step 6
            self.lanes.evict(overdue)
            worked = True

        # 4. admit from the queue into free lanes (the running lanes'
        # median sigma anchors the selectivity bin, keeping the fused
        # batch regime-coherent)
        n_free = self.lanes.free_count()
        if n_free:
            occ = self.lanes.occupied()
            # navilint: sync-ok sigh is host-side scheduler state (sigma history), never a traced value
            prefer = (float(np.median(self.lanes.sigh[occ]))
                      if occ else None)
            batch = self.queue.pop_batch(n_free, prefer)
            if batch:
                entries = []
                for it in batch:
                    pend = it.meta
                    pend.t_start = now
                    entries.append((pend, pend.qrow, pend.sel_row,
                                    pend.sigma, pend.efs))
                self.lanes.admit(entries)
                worked = True

        # 5. dispatch the next chunk (async, donated state). Always
        # chunked (never run-to-convergence): a live loop must return to
        # the queue between chunks.
        if self.lanes.occupied_count():
            self.lanes.step_async(self.step_iters)
            worked = True

        # 6. resolve futures -- host-only, overlapped with the chunk
        # dispatched above (Future callbacks run in this thread)
        for pend, resp in rows:
            if resp is None:
                self._emit_timeout(pend, now)
            else:
                self._resolve(pend, resp)
        return worked

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "SearchService":
        """Spawn the background device-loop thread (idempotent)."""
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._run,
                                            name="navix-serve",
                                            daemon=True)
            self._thread.start()
        return self

    def _run(self) -> None:
        while True:
            worked = self._tick()
            if self._stop.is_set():
                if not self._draining:
                    break
                if (not worked and not len(self.queue)
                        and not self.lanes.occupied_count()):
                    break
            elif not worked:
                self.queue.wait_nonempty(0.01)

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> bool:
        """Close the front door. ``drain=True`` first answers every
        submitted rid exactly once (blocked putters wake with
        :class:`ServiceClosed`); ``drain=False`` cancels every
        outstanding future. Returns True once fully shut down; False if
        the background thread is still draining when ``timeout`` expires
        -- the thread keeps sole ownership of the lane state (ticking it
        inline here would race it), so call ``shutdown`` again to keep
        waiting. Idempotent."""
        if self.closed:
            return True
        self.queue.close()
        self._draining = drain
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                return False
            self._thread = None
        if drain:
            # manual-driver path (no thread ever ran, or it exited
            # before finishing a non-drain stop): finish inline
            while len(self.queue) or self.lanes.occupied_count():
                self._tick()
        else:
            for it in self.queue.drain_remaining():
                self._cancel(it.meta)
            # the loop thread exits right after dispatching a chunk
            # (tick step 5), so a non-drain stop usually lands here
            # with that chunk still in flight -- synchronize before
            # touching the donated lane state
            if self.lanes.step_pending:
                self.lanes.step_wait()
            occ = self.lanes.occupied()
            for i in occ:
                self._cancel(self.lanes.meta[i])
            self.lanes.evict(occ)
        self.closed = True
        return True

    @staticmethod
    def _cancel(pend: _Pending) -> None:
        if not pend.fut.done() and not pend.fut.cancel():
            pend.fut.set_exception(
                ServiceClosed("service shut down without drain"))

    def __enter__(self) -> "SearchService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=exc[0] is None)

    # -- observability ------------------------------------------------------
    def gauges(self) -> dict:
        """Live service gauges: queue depth/backpressure state, in-flight
        lanes, completion counters, rolling p50/p99 latency, and the
        cumulative host-vs-device chunk split (``chunks``: host work the
        device waited for vs host work hidden behind in-flight chunks vs
        time blocked on the device)."""
        g = {"queue": self.queue.gauges(),
             "in_flight": self.lanes.occupied_count(),
             "lanes": self.lanes.bsz,
             "chunks": self.lanes.timing()}
        with self._lat_lock:
            g.update(submitted=self.n_submitted, done=self.n_done,
                     timeouts=self.n_timeout, partials=self.n_partial)
            lat = list(self._lat)
        if lat:
            arr = np.asarray(lat)
            g["p50_ms"] = float(np.percentile(arr, 50))
            g["p99_ms"] = float(np.percentile(arr, 99))
        return g
