"""The device-side lane core shared by every serving driver.

Both serving drivers -- the closed-queue ``SearchEngine.drain()`` and the
live :class:`~repro.serving.service.SearchService` loop -- run the same
machine: a fixed ``[B]``-lane batch over the resumable stepping API of
``repro.core.search_batch`` (``parked_state`` / ``engine_refill`` /
``engine_steps`` / ``engine_finalize`` / ``engine_evict``), shard-aware
through the mirrored ``*_program`` surface of
:class:`~repro.core.distributed.ShardedNavix`. This module holds that
machine so the two drivers stay in bitwise lockstep:

* ``_FlatLanes`` / ``_ShardLanes`` -- the backend split: identical lane
  operations over an unsharded :class:`NavixIndex` or a
  :class:`ShardedNavix` (whose buffers gain a leading shard dim and whose
  ``finalize`` merges per-shard beams under an ``alive`` quorum mask);
* :class:`LaneBatch` -- host-side buffer management + the device calls:
  ``admit`` (compact free lanes, refill them with new requests), ``step``
  (advance ``n_steps`` loop iterations, report per-lane liveness),
  ``finalize`` (extract every lane's current beam), ``evict`` (park
  overdue lanes so they stop burning device work and become refillable).

Scheduling policy -- what to admit, when to flush, which lanes are past
deadline -- stays in the drivers; ``LaneBatch`` owns no policy beyond
"fill free lanes in ascending order", which both drivers rely on.

Overlapped stepping: ``step_async`` dispatches the next device chunk on
DONATED state buffers (``engine_steps_overlap`` / the sharded
``steps_program(donate=True)``) and returns immediately -- the host then
runs finalize/expire/refill/response work concurrently with the in-flight
chunk, and ``step_wait`` synchronizes on the per-lane liveness exactly
once per chunk. ``step`` (dispatch + wait back-to-back) remains the
synchronous spelling. Because the state buffer is donated, callers must
never retain references to a pre-step ``st``; ``LaneBatch`` owns the only
reference and swaps it at dispatch. Finalize/evict/admit issued while a
chunk is in flight simply queue behind it on the device stream -- results
are bitwise identical to the synchronous order.
"""

from __future__ import annotations

import time
from typing import Any, Optional

import numpy as np

from repro.core.distributed import ShardedNavix
from repro.core.navix import NavixIndex
from repro.storage.columnar import GraphStore  # noqa: F401  (re-export site)


class _FlatLanes:
    """Device-side lane operations of the continuous scheduler over an
    unsharded :class:`NavixIndex` (the ``search_batch`` stepping API)."""

    n_shards = 0
    lane_multiple = 1
    exact = None

    def __init__(self, idx: NavixIndex, params):
        from repro.core import bitset

        self.idx, self.graph, self.params = idx, idx.graph, params
        self._words = bitset.n_words(idx.graph.n)
        # int8-resident indexes carry an exact f32 tier; LaneBatch
        # re-ranks finalized beams against it (the serving-side re-rank)
        self.exact = (idx.exact if getattr(idx, "is_quantized", False)
                      else None)

    def full_row(self) -> np.ndarray:
        return np.asarray(self.idx.full_semimask())            # [W]

    def pack_row(self, mask) -> np.ndarray:
        # host-side pack: one numpy pass per distinct plan instead of an
        # eager jnp dispatch chain (it dominated the drain wall)
        from repro.core import bitset

        m = np.asarray(mask)
        if m.dtype == np.uint32:
            return m                                           # [W]
        return bitset.pack_np(m)                               # [W]

    def sel_buffer(self, bsz: int) -> np.ndarray:
        return np.zeros((bsz, self._words), np.uint32)

    def set_lane(self, selh: np.ndarray, i: int, row: np.ndarray) -> None:
        selh[i] = row

    def place_lanes(self, arr):
        """Host [B, ...] lane buffer -> device array."""
        import jax.numpy as jnp
        return jnp.asarray(arr)

    place_sel = place_lanes

    def place_admit(self, Qh, selh, sigh, efsh, refill):
        """All five admit-time lane buffers in ONE device transfer."""
        import jax
        return jax.device_put((Qh, selh, sigh, efsh, refill))

    def parked(self, bsz: int):
        import jax.numpy as jnp

        from repro.core import search_batch as sb
        return (sb.parked_state(self.graph.n, bsz, self.params),
                jnp.zeros((bsz,), jnp.int32))

    def refill(self, Qj, selj, st, udc, refill):
        # donated st/udc: LaneBatch drops its references on return
        from repro.core import search_batch as sb
        return sb.engine_refill_overlap(self.graph, Qj, selj, st, udc,
                                        refill, self.params)

    def steps(self, Qj, selj, st, n_steps, sigj, efsj):
        # donated st; dispatch is async -- the caller syncs on `live`
        from repro.core import search_batch as sb
        return sb.engine_steps_overlap(self.graph, Qj, selj, st,
                                       self.params, n_steps, sigma_g=sigj,
                                       efs_lanes=efsj)

    def finalize(self, st, udc, alive):
        from repro.core import search_batch as sb
        fin = sb.engine_finalize(st, udc, self.params)
        return fin.ids, fin.dists

    def evict(self, st, udc, evict):
        import jax.numpy as jnp

        from repro.core import search_batch as sb
        return sb.engine_evict_overlap(st, udc, jnp.asarray(evict))


class _ShardLanes:
    """The same lane operations over a :class:`ShardedNavix`: every
    buffer gains a leading shard dim ([S, B, W] semimasks, [S, B]
    upper_dc, shard-stacked beam state) and ``finalize`` merges the
    per-shard beams into global top-k under the current ``alive`` mask.
    Per-lane k/efs capping and lane refill are untouched."""

    exact = None    # sharded indexes stay f32-resident (no quantized tier)

    def __init__(self, sn: ShardedNavix, params):
        self.sn, self.params = sn, params
        self.n_shards = sn.n_shards
        self.lane_multiple = sn.lane_shards
        # donate=True throughout: LaneBatch owns the only reference to
        # st/udc and swaps it at every call, so the device writes in place
        self._refill = sn.refill_program(params, donate=True)
        self._steps = sn.steps_program(params, donate=True)
        # beams-only finalize: bitwise-identical merged ids/dists to
        # finalize_program, minus the stats reduction the drivers discard
        self._finalize = sn.finalize_beams_program(params)
        self._evict = sn.evict_program(params, donate=True)
        # cached NamedShardings: building one per place_* call shows up
        # in the admit path (mesh-shape lookups per transfer)
        self._lane_ns: dict = {}
        self._sel_ns = None

    def full_row(self) -> np.ndarray:
        return np.asarray(self.sn.full_semimask())             # [S, W]

    def pack_row(self, mask) -> np.ndarray:
        m = np.asarray(mask)
        if m.dtype == np.uint32:
            return m                                           # [S, W]
        return self.sn.shard_semimask_np(m)                    # [S, W]

    def sel_buffer(self, bsz: int) -> np.ndarray:
        return np.zeros((self.n_shards, bsz, self.sn.n_words_local),
                        np.uint32)

    def set_lane(self, selh: np.ndarray, i: int, row: np.ndarray) -> None:
        selh[:, i] = row

    def _lane_sharding(self, ndim: int):
        ns = self._lane_ns.get(ndim)
        if ns is None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            ns = NamedSharding(self.sn.mesh, P(
                self.sn.data_axis, *([None] * (ndim - 1))))
            self._lane_ns[ndim] = ns
        return ns

    def _sel_sharding(self):
        if self._sel_ns is None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            self._sel_ns = NamedSharding(self.sn.mesh, P(
                self.sn.model_axis, self.sn.data_axis, None))
        return self._sel_ns

    def place_lanes(self, arr):
        """Host [B, ...] lane buffer -> device array split over the data
        axis -- matching the program in_specs, so steady-state calls
        never reshard their operands."""
        import jax
        return jax.device_put(arr, self._lane_sharding(np.ndim(arr)))

    def place_sel(self, arr):
        """Host [S, B, W] semimask buffer -> device array on the
        (model, data) layout the programs expect."""
        import jax
        return jax.device_put(arr, self._sel_sharding())

    def place_admit(self, Qh, selh, sigh, efsh, refill):
        """All five admit-time lane buffers in ONE mesh transfer."""
        import jax
        lane1, lane2 = self._lane_sharding(1), self._lane_sharding(2)
        return jax.device_put(
            (Qh, selh, sigh, efsh, refill),
            (lane2, self._sel_sharding(), lane1, lane1, lane1))

    def parked(self, bsz: int):
        return self.sn.parked_state(bsz, self.params)

    def refill(self, Qj, selj, st, udc, refill):
        return self._refill(self.sn.graphs, Qj, selj, st, udc, refill)

    def steps(self, Qj, selj, st, n_steps, sigj, efsj):
        # sigj unused: each shard's lanes estimate selectivity against
        # their own slice of S (lane-local, shard-local)
        return self._steps(self.sn.graphs, Qj, selj, st, n_steps,
                           efs_lanes=efsj)

    def finalize(self, st, udc, alive):
        import jax.numpy as jnp
        d, ids = self._finalize(st, udc, jnp.asarray(alive))
        return ids, d

    def evict(self, st, udc, evict):
        return self._evict(st, udc, self.place_lanes(np.asarray(evict)))


def make_backend(idx, params):
    """The backend split: ShardedNavix -> _ShardLanes, else _FlatLanes."""
    return (_ShardLanes(idx, params) if isinstance(idx, ShardedNavix)
            else _FlatLanes(idx, params))


class LaneBatch:
    """A resumable ``[B]``-lane device batch with host-side bookkeeping.

    Each lane is free (``meta[i] is None``) or carries one in-flight
    request's opaque driver payload. Device state (`st`, `udc`) and the
    host mirrors of the lane buffers (query rows, packed per-lane
    semimasks, per-lane sigma) live here; drivers decide *when* to call
    ``admit`` / ``step`` / ``finalize`` / ``evict`` and what the payloads
    mean. Admission fills free lanes in ascending index order.
    """

    def __init__(self, idx, heuristic: str, k_cap: int, efs_cap: int,
                 bsz: int):
        self.params = idx._params(k_cap, efs_cap, heuristic)
        self.backend = make_backend(idx, self.params)
        # data-axis backends split the lane dim over lane_multiple
        # devices; round the batch up so it divides evenly
        lm = self.backend.lane_multiple
        bsz = -(-bsz // lm) * lm
        self.bsz = bsz
        self.k_cap, self.efs_cap = k_cap, efs_cap
        dim = (idx.dim if isinstance(idx, ShardedNavix)
               else int(idx.graph.vectors.shape[-1]))
        self.Qh = np.zeros((bsz, dim), np.float32)
        self.selh = self.backend.sel_buffer(bsz)
        self.sigh = np.ones((bsz,), np.float32)
        # per-lane efs: free/uniform lanes sit at the cap (the masked
        # beam tail is then empty, bitwise-identical to no masking)
        self.efsh = np.full((bsz,), efs_cap, np.int32)
        self.meta: list[Optional[Any]] = [None] * bsz
        self.st, self.udc = self.backend.parked(bsz)
        self.Qj = self.backend.place_lanes(self.Qh)
        self.selj = self.backend.place_sel(self.selh)
        self.sigj = self.backend.place_lanes(self.sigh)
        self.efsj = self.backend.place_lanes(self.efsh)
        # overlapped-stepping bookkeeping (host-vs-device observability)
        self._live_pending = None          # in-flight chunk's live[B]
        self._t_dispatched = 0.0
        self._t_wait_end = time.perf_counter()
        self.n_chunks = 0
        self.host_gap_ms = 0.0      # host work NOT overlapped (wait->dispatch)
        self.host_overlap_ms = 0.0  # host work overlapped (dispatch->wait)
        self.device_wait_ms = 0.0   # blocked on the device inside step_wait

    @property
    def n_shards(self) -> int:
        return self.backend.n_shards

    def occupied(self) -> list[int]:
        return [i for i in range(self.bsz) if self.meta[i] is not None]

    def occupied_count(self) -> int:
        return sum(1 for m in self.meta if m is not None)

    def free_count(self) -> int:
        return self.bsz - self.occupied_count()

    def release(self, i: int) -> None:
        """Free a lane host-side. Its frozen device state is inert (a
        converged/parked lane never advances) and the next ``admit``
        overwrites it."""
        self.meta[i] = None

    # -- device calls ---------------------------------------------------
    def admit(self, entries) -> list[int]:
        """Fill free lanes (ascending) from ``entries`` -- an iterable of
        ``(meta, qrow, sel_row, sigma, efs)`` -- and run ONE device refill
        for all of them (``efs`` is clamped to ``[1, efs_cap]``; lanes
        below the cap skip the cap-wide beam-tail maintenance). Returns
        the lane indices used; raises if more entries arrive than there
        are free lanes."""
        refill = np.zeros(self.bsz, bool)
        used: list[int] = []
        it = iter(entries)
        entry = next(it, None)
        for i in range(self.bsz):
            if entry is None:
                break
            if self.meta[i] is not None:
                continue
            meta, qrow, row, sigma, efs = entry
            self.Qh[i] = qrow
            self.backend.set_lane(self.selh, i, row)
            self.sigh[i] = sigma
            self.efsh[i] = min(max(int(efs), 1), self.efs_cap)
            self.meta[i] = meta
            refill[i] = True
            used.append(i)
            entry = next(it, None)
        if entry is not None:
            raise ValueError("more entries than free lanes; size the "
                             "admission to LaneBatch.free_count()")
        if not used:
            return used
        (self.Qj, self.selj, self.sigj, self.efsj,
         refill_j) = self.backend.place_admit(
            self.Qh, self.selh, self.sigh, self.efsh, refill)
        self.st, self.udc = self.backend.refill(
            self.Qj, self.selj, self.st, self.udc, refill_j)
        return used

    @property
    def step_pending(self) -> bool:
        """True while a dispatched device chunk has not been waited on."""
        return self._live_pending is not None

    def step_async(self, n_steps: int) -> None:
        """Dispatch the next device chunk (at most ``n_steps`` loop
        iterations; 0 = run to whole-batch convergence) WITHOUT blocking.
        The state buffers are donated to the chunk, so the pre-dispatch
        ``st`` is dead the moment this returns; host-side work between
        this call and :meth:`step_wait` overlaps the device."""
        if self._live_pending is not None:
            raise RuntimeError("a device chunk is already in flight; "
                               "step_wait() it first")
        t0 = time.perf_counter()
        self.host_gap_ms += (t0 - self._t_wait_end) * 1e3
        self.st, self._live_pending = self.backend.steps(
            self.Qj, self.selj, self.st, n_steps, self.sigj, self.efsj)
        self._t_dispatched = time.perf_counter()

    def step_wait(self) -> np.ndarray:
        """Synchronize on the in-flight chunk; returns live bool[B].
        The ONE host sync per chunk lives here."""
        if self._live_pending is None:
            raise RuntimeError("no device chunk in flight; step_async() "
                               "first")
        t1 = time.perf_counter()
        self.host_overlap_ms += (t1 - self._t_dispatched) * 1e3
        # navilint: sync-ok chunk boundary -- the host scheduler branches on liveness between device chunks (one sync per chunk by design)
        live = np.asarray(self._live_pending)
        self._live_pending = None
        t2 = time.perf_counter()
        self.device_wait_ms += (t2 - t1) * 1e3
        self._t_wait_end = t2
        self.n_chunks += 1
        return live

    def step(self, n_steps: int) -> np.ndarray:
        """Advance every lane by at most ``n_steps`` loop iterations
        (0 = run to whole-batch convergence); returns live bool[B]. The
        synchronous spelling of ``step_async`` + ``step_wait``."""
        self.step_async(n_steps)
        return self.step_wait()

    def timing(self) -> dict:
        """Cumulative host-vs-device split over every stepped chunk."""
        return {"n_chunks": self.n_chunks,
                "host_gap_ms": self.host_gap_ms,
                "host_overlap_ms": self.host_overlap_ms,
                "device_wait_ms": self.device_wait_ms}

    def reset_timing(self) -> None:
        """Zero the chunk counters and re-anchor the gap clock. A reused
        batch (the closed-queue engine keeps LaneBatches across drains --
        parked-state allocation + mesh placement is the dominant per-drain
        setup cost on sharded backends) would otherwise charge the idle
        time between drains as host_gap."""
        self.n_chunks = 0
        self.host_gap_ms = self.host_overlap_ms = self.device_wait_ms = 0.0
        self._t_wait_end = time.perf_counter()

    def finalize(self, alive) -> tuple[np.ndarray, np.ndarray]:
        """Extract every lane's current beam under ``alive`` (sharded
        backends merge across shards; a flat backend ignores it).
        Returns host ``(ids[B, efs], dists[B, efs])``.

        Quantized-resident backends finish here: the full-width beam
        (searched on int8 codes) is exactly re-ranked against the host
        f32 tier, lane-vectorized, so every driver's ``[:k]`` slice of a
        finalized lane is already exact-ordered. Parked/free lanes are
        all ``-1`` and stay all ``-1`` through the re-rank."""
        ids, dists = self.backend.finalize(self.st, self.udc, alive)
        # navilint: sync-ok THE declared finalize boundary -- results cross to host exactly once per finalize
        ids, dists = np.asarray(ids), np.asarray(dists)
        exact = self.backend.exact
        if exact is not None:
            # exact-tier re-rank: host-side numpy at the same finalize
            # boundary (prepped queries already mirrored in Qh)
            dists, ids = exact.rerank_many(self.Qh, ids, ids.shape[1])
        return ids, dists

    def evict(self, lane_ids) -> None:
        """Park the given lanes (one device call) and free them. Parked
        lanes report live=False and finalize to all ``-1`` ids until the
        next admit overwrites them -- finalize BEFORE evicting to salvage
        a partial beam."""
        lane_ids = list(lane_ids)
        if not lane_ids:
            return
        mask = np.zeros(self.bsz, bool)
        mask[lane_ids] = True
        self.st, self.udc = self.backend.evict(self.st, self.udc, mask)
        for i in lane_ids:
            self.meta[i] = None
