"""The device-side lane core shared by every serving driver.

Both serving drivers -- the closed-queue ``SearchEngine.drain()`` and the
live :class:`~repro.serving.service.SearchService` loop -- run the same
machine: a fixed ``[B]``-lane batch over the resumable stepping API of
``repro.core.search_batch`` (``parked_state`` / ``engine_refill`` /
``engine_steps`` / ``engine_finalize`` / ``engine_evict``), shard-aware
through the mirrored ``*_program`` surface of
:class:`~repro.core.distributed.ShardedNavix`. This module holds that
machine so the two drivers stay in bitwise lockstep:

* ``_FlatLanes`` / ``_ShardLanes`` -- the backend split: identical lane
  operations over an unsharded :class:`NavixIndex` or a
  :class:`ShardedNavix` (whose buffers gain a leading shard dim and whose
  ``finalize`` merges per-shard beams under an ``alive`` quorum mask);
* :class:`LaneBatch` -- host-side buffer management + the device calls:
  ``admit`` (compact free lanes, refill them with new requests), ``step``
  (advance ``n_steps`` loop iterations, report per-lane liveness),
  ``finalize`` (extract every lane's current beam), ``evict`` (park
  overdue lanes so they stop burning device work and become refillable).

Scheduling policy -- what to admit, when to flush, which lanes are past
deadline -- stays in the drivers; ``LaneBatch`` owns no policy beyond
"fill free lanes in ascending order", which both drivers rely on.
"""

from __future__ import annotations

from typing import Any, Optional

import numpy as np

from repro.core.distributed import ShardedNavix
from repro.core.navix import NavixIndex
from repro.storage.columnar import GraphStore  # noqa: F401  (re-export site)


class _FlatLanes:
    """Device-side lane operations of the continuous scheduler over an
    unsharded :class:`NavixIndex` (the ``search_batch`` stepping API)."""

    n_shards = 0

    def __init__(self, idx: NavixIndex, params):
        from repro.core import bitset

        self.idx, self.graph, self.params = idx, idx.graph, params
        self._words = bitset.n_words(idx.graph.n)

    def full_row(self) -> np.ndarray:
        return np.asarray(self.idx.full_semimask())            # [W]

    def pack_row(self, mask) -> np.ndarray:
        return np.asarray(self.idx.pack_semimask(mask))        # [W]

    def sel_buffer(self, bsz: int) -> np.ndarray:
        return np.zeros((bsz, self._words), np.uint32)

    def set_lane(self, selh: np.ndarray, i: int, row: np.ndarray) -> None:
        selh[i] = row

    def parked(self, bsz: int):
        import jax.numpy as jnp

        from repro.core import search_batch as sb
        return (sb.parked_state(self.graph.n, bsz, self.params),
                jnp.zeros((bsz,), jnp.int32))

    def refill(self, Qj, selj, st, udc, refill):
        from repro.core import search_batch as sb
        return sb.engine_refill(self.graph, Qj, selj, st, udc, refill,
                                self.params)

    def steps(self, Qj, selj, st, n_steps, sigj):
        from repro.core import search_batch as sb
        return sb.engine_steps(self.graph, Qj, selj, st, self.params,
                               n_steps, sigma_g=sigj)

    def finalize(self, st, udc, alive):
        from repro.core import search_batch as sb
        return sb.engine_finalize(st, udc, self.params)

    def evict(self, st, udc, evict):
        import jax.numpy as jnp

        from repro.core import search_batch as sb
        return sb.engine_evict(st, udc, jnp.asarray(evict))


class _ShardLanes:
    """The same lane operations over a :class:`ShardedNavix`: every
    buffer gains a leading shard dim ([S, B, W] semimasks, [S, B]
    upper_dc, shard-stacked beam state) and ``finalize`` merges the
    per-shard beams into global top-k under the current ``alive`` mask.
    Per-lane k/efs capping and lane refill are untouched."""

    def __init__(self, sn: ShardedNavix, params):
        self.sn, self.params = sn, params
        self.n_shards = sn.n_shards
        self._refill = sn.refill_program(params)
        self._steps = sn.steps_program(params)
        self._finalize = sn.finalize_program(params)
        self._evict = sn.evict_program(params)

    def full_row(self) -> np.ndarray:
        return np.asarray(self.sn.full_semimask())             # [S, W]

    def pack_row(self, mask) -> np.ndarray:
        return np.asarray(self.sn.shard_semimask(mask))        # [S, W]

    def sel_buffer(self, bsz: int) -> np.ndarray:
        return np.zeros((self.n_shards, bsz, self.sn.n_words_local),
                        np.uint32)

    def set_lane(self, selh: np.ndarray, i: int, row: np.ndarray) -> None:
        selh[:, i] = row

    def parked(self, bsz: int):
        return self.sn.parked_state(bsz, self.params)

    def refill(self, Qj, selj, st, udc, refill):
        return self._refill(self.sn.graphs, Qj, selj, st, udc, refill)

    def steps(self, Qj, selj, st, n_steps, sigj):
        # sigj unused: each shard's lanes estimate selectivity against
        # their own slice of S (lane-local, shard-local)
        return self._steps(self.sn.graphs, Qj, selj, st, n_steps)

    def finalize(self, st, udc, alive):
        import jax.numpy as jnp
        return self._finalize(st, udc, jnp.asarray(alive))

    def evict(self, st, udc, evict):
        import jax.numpy as jnp
        return self._evict(st, udc, jnp.asarray(evict))


def make_backend(idx, params):
    """The backend split: ShardedNavix -> _ShardLanes, else _FlatLanes."""
    return (_ShardLanes(idx, params) if isinstance(idx, ShardedNavix)
            else _FlatLanes(idx, params))


class LaneBatch:
    """A resumable ``[B]``-lane device batch with host-side bookkeeping.

    Each lane is free (``meta[i] is None``) or carries one in-flight
    request's opaque driver payload. Device state (`st`, `udc`) and the
    host mirrors of the lane buffers (query rows, packed per-lane
    semimasks, per-lane sigma) live here; drivers decide *when* to call
    ``admit`` / ``step`` / ``finalize`` / ``evict`` and what the payloads
    mean. Admission fills free lanes in ascending index order.
    """

    def __init__(self, idx, heuristic: str, k_cap: int, efs_cap: int,
                 bsz: int):
        import jax.numpy as jnp

        self.params = idx._params(k_cap, efs_cap, heuristic)
        self.backend = make_backend(idx, self.params)
        self.bsz = bsz
        self.k_cap, self.efs_cap = k_cap, efs_cap
        dim = (idx.dim if isinstance(idx, ShardedNavix)
               else int(idx.graph.vectors.shape[-1]))
        self.Qh = np.zeros((bsz, dim), np.float32)
        self.selh = self.backend.sel_buffer(bsz)
        self.sigh = np.ones((bsz,), np.float32)
        self.meta: list[Optional[Any]] = [None] * bsz
        self.st, self.udc = self.backend.parked(bsz)
        self.Qj = jnp.asarray(self.Qh)
        self.selj = jnp.asarray(self.selh)
        self.sigj = jnp.asarray(self.sigh)

    @property
    def n_shards(self) -> int:
        return self.backend.n_shards

    def occupied(self) -> list[int]:
        return [i for i in range(self.bsz) if self.meta[i] is not None]

    def occupied_count(self) -> int:
        return sum(1 for m in self.meta if m is not None)

    def free_count(self) -> int:
        return self.bsz - self.occupied_count()

    def release(self, i: int) -> None:
        """Free a lane host-side. Its frozen device state is inert (a
        converged/parked lane never advances) and the next ``admit``
        overwrites it."""
        self.meta[i] = None

    # -- device calls ---------------------------------------------------
    def admit(self, entries) -> list[int]:
        """Fill free lanes (ascending) from ``entries`` -- an iterable of
        ``(meta, qrow, sel_row, sigma)`` -- and run ONE device refill for
        all of them. Returns the lane indices used; raises if more
        entries arrive than there are free lanes."""
        import jax.numpy as jnp

        refill = np.zeros(self.bsz, bool)
        used: list[int] = []
        it = iter(entries)
        entry = next(it, None)
        for i in range(self.bsz):
            if entry is None:
                break
            if self.meta[i] is not None:
                continue
            meta, qrow, row, sigma = entry
            self.Qh[i] = qrow
            self.backend.set_lane(self.selh, i, row)
            self.sigh[i] = sigma
            self.meta[i] = meta
            refill[i] = True
            used.append(i)
            entry = next(it, None)
        if entry is not None:
            raise ValueError("more entries than free lanes; size the "
                             "admission to LaneBatch.free_count()")
        if not used:
            return used
        self.Qj = jnp.asarray(self.Qh)
        self.selj = jnp.asarray(self.selh)
        self.sigj = jnp.asarray(self.sigh)
        self.st, self.udc = self.backend.refill(
            self.Qj, self.selj, self.st, self.udc, jnp.asarray(refill))
        return used

    def step(self, n_steps: int) -> np.ndarray:
        """Advance every lane by at most ``n_steps`` loop iterations
        (0 = run to whole-batch convergence); returns live bool[B]."""
        self.st, live = self.backend.steps(self.Qj, self.selj, self.st,
                                           n_steps, self.sigj)
        # navilint: sync-ok chunk boundary -- the host scheduler branches on liveness between device chunks (one sync per chunk by design)
        return np.asarray(live)

    def finalize(self, alive) -> tuple[np.ndarray, np.ndarray]:
        """Extract every lane's current beam under ``alive`` (sharded
        backends merge across shards; a flat backend ignores it).
        Returns host ``(ids[B, efs], dists[B, efs])``."""
        fin = self.backend.finalize(self.st, self.udc, alive)
        # navilint: sync-ok THE declared finalize boundary -- results cross to host exactly once per finalize
        return np.asarray(fin.ids), np.asarray(fin.dists)

    def evict(self, lane_ids) -> None:
        """Park the given lanes (one device call) and free them. Parked
        lanes report live=False and finalize to all ``-1`` ids until the
        next admit overwrites them -- finalize BEFORE evicting to salvage
        a partial beam."""
        lane_ids = list(lane_ids)
        if not lane_ids:
            return
        mask = np.zeros(self.bsz, bool)
        mask[lane_ids] = True
        self.st, self.udc = self.backend.evict(self.st, self.udc, mask)
        for i in lane_ids:
            self.meta[i] = None
