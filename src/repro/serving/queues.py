"""Bounded, thread-safe submission queue for the live serving tier.

The queue sits between ``SearchService.submit`` (any number of client
threads) and the single device loop. Three policies live here, and only
here -- the service just calls ``pop_batch``:

* **deadline-ordered admission** -- ``pop_batch`` serves the most urgent
  request first (earliest absolute deadline; deadline-free requests rank
  after every deadlined one, FIFO among themselves);
* **selectivity-binned batching** -- requests are binned by their
  prefiltered selectivity (geometric bins: ``(1/2, 1]``, ``(1/4, 1/2]``,
  ...), and a batch is filled from the urgent request's bin outward.
  Lanes running together then carry similar-sigma subqueries, which keeps
  the engine's two-hop ``lax.cond`` stage off for whole step chunks (see
  ``SearchEngine._serve_fused``) -- the live-queue analogue of the
  closed drain's selectivity-sorted admission;
* **backpressure with watermark hysteresis** -- once depth reaches the
  high watermark the queue *gates*: ``policy="reject"`` makes ``put``
  raise :class:`QueueFull` immediately, ``policy="block"`` makes it wait.
  The gate stays closed until depth falls back to the low watermark, so
  a queue oscillating around the high mark doesn't flap admission.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Any, Optional


class QueueFull(RuntimeError):
    """Submission rejected (or timed out) under backpressure."""


class ServiceClosed(RuntimeError):
    """Submission after ``close()``/``shutdown()``."""


def sigma_bin(sigma: float, n_bins: int) -> int:
    """Geometric selectivity bin: 0 = (1/2, 1], 1 = (1/4, 1/2], ...
    clamped to ``n_bins`` bins. Matches the selectivity regimes the
    adaptive heuristic switches on (low sigma = sparse S = different
    search behavior), so same-bin lanes batch cheaply."""
    s = min(max(float(sigma), 1e-9), 1.0)
    return min(n_bins - 1, max(0, int(math.floor(-math.log2(s) + 1e-12))))


@dataclasses.dataclass
class QueueItem:
    """One queued submission. ``deadline`` is absolute (same clock as the
    service; ``None`` = no deadline). ``meta`` is the service's opaque
    payload (future, prepped query row, packed semimask, ...)."""
    seq: int
    sigma: float
    deadline: Optional[float]
    t_enqueue: float
    meta: Any = None

    def sort_key(self, prefer_bin: Optional[int], n_bins: int):
        d = (0 if prefer_bin is None
             else abs(sigma_bin(self.sigma, n_bins) - prefer_bin))
        return (d, self.deadline if self.deadline is not None else math.inf,
                self.seq)


class SubmissionQueue:
    """Bounded thread-safe queue with EDF + selectivity-bin pop order and
    watermark-hysteresis backpressure. All methods are safe to call from
    any thread; ``pop_batch``/``expire`` are meant for the single device
    loop, ``put`` for submitters."""

    def __init__(self, maxsize: int = 256, policy: str = "reject",
                 high_watermark: Optional[int] = None,
                 low_watermark: Optional[int] = None, n_bins: int = 4):
        if policy not in ("reject", "block"):
            raise ValueError(f"unknown backpressure policy {policy!r}; "
                             f"valid: ('reject', 'block')")
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.maxsize = maxsize
        self.policy = policy
        self.high = high_watermark if high_watermark is not None else maxsize
        self.low = (low_watermark if low_watermark is not None
                    else max(1, self.high // 2))
        if not (1 <= self.low <= self.high <= maxsize):
            raise ValueError(f"need 1 <= low ({self.low}) <= high "
                             f"({self.high}) <= maxsize ({maxsize})")
        self.n_bins = n_bins
        self._items: list[QueueItem] = []               # guarded-by: _lock
        self._lock = threading.Lock()
        self._space = threading.Condition(self._lock)   # putters wait here
        self._data = threading.Condition(self._lock)    # the loop waits here
        self._gated = False                             # guarded-by: _lock
        self._closed = False                            # guarded-by: _lock
        self._seq = 0                                   # guarded-by: _lock
        self.n_rejected = 0                             # guarded-by: _lock

    # -- submitter side -----------------------------------------------------
    def put(self, sigma: float, deadline: Optional[float], meta: Any,
            timeout: Optional[float] = None,
            now: Optional[float] = None) -> QueueItem:
        """Enqueue one submission. Under backpressure (depth at the high
        watermark, not yet drained to the low one): ``reject`` raises
        :class:`QueueFull` immediately; ``block`` waits for the gate to
        reopen (``timeout`` seconds, then :class:`QueueFull`). Raises
        :class:`ServiceClosed` after ``close()`` -- including for blocked
        putters, which wake immediately."""
        with self._lock:
            if self._closed:
                raise ServiceClosed("queue is closed")
            t_end = None
            # re-evaluate the gate each time a blocked putter wakes: N
            # putters woken together would otherwise all append after
            # one ungate, pushing depth to low + N past the high
            # watermark (and potentially past maxsize)
            while True:
                if len(self._items) >= self.high:
                    self._gated = True
                if not self._gated:
                    break
                if self.policy == "reject":
                    self.n_rejected += 1
                    raise QueueFull(
                        f"queue gated at depth {len(self._items)} "
                        f"(high={self.high}; reopens at low={self.low})")
                if timeout is not None and t_end is None:
                    t_end = time.monotonic() + timeout
                remaining = (None if t_end is None
                             else t_end - time.monotonic())
                if remaining is not None and remaining <= 0:
                    self.n_rejected += 1
                    raise QueueFull("blocked put timed out under "
                                    "backpressure")
                self._space.wait(remaining)
                if self._closed:
                    raise ServiceClosed("queue closed while blocked on "
                                        "backpressure")
            item = QueueItem(
                seq=self._seq, sigma=float(sigma), deadline=deadline,
                t_enqueue=now if now is not None else time.perf_counter(),
                meta=meta)
            self._seq += 1
            self._items.append(item)
            self._data.notify_all()
            return item

    # -- device-loop side ---------------------------------------------------
    def pop_batch(self, n: int,
                  prefer_sigma: Optional[float] = None) -> list[QueueItem]:
        """Pop up to ``n`` items: the earliest-deadline item anchors the
        batch's selectivity bin (unless ``prefer_sigma`` -- e.g. the
        running lanes' sigma -- anchors it instead), then the batch fills
        bin-distance-first, deadline-second, FIFO-third."""
        with self._lock:
            if n <= 0 or not self._items:
                return []
            if prefer_sigma is not None:
                prefer = sigma_bin(prefer_sigma, self.n_bins)
            else:
                urgent = min(self._items,
                             key=lambda it: it.sort_key(None, self.n_bins))
                prefer = sigma_bin(urgent.sigma, self.n_bins)
            order = sorted(self._items,
                           key=lambda it: it.sort_key(prefer, self.n_bins))
            taken = order[:n]
            picked = {id(it) for it in taken}
            self._items = [it for it in self._items
                           if id(it) not in picked]
            self._maybe_ungate()
            return taken

    def expire(self, now: float) -> list[QueueItem]:
        """Remove and return every item whose deadline already passed --
        they will never get device time; the service resolves them as
        ``timeout`` without occupying a lane."""
        with self._lock:
            dead = [it for it in self._items
                    if it.deadline is not None and it.deadline < now]
            if dead:
                gone = {id(it) for it in dead}
                self._items = [it for it in self._items
                               if id(it) not in gone]
                self._maybe_ungate()
            return dead

    def drain_remaining(self) -> list[QueueItem]:
        """Pop everything (shutdown path)."""
        with self._lock:
            items, self._items = self._items, []
            self._maybe_ungate()
            return items

    def wait_nonempty(self, timeout: Optional[float] = None) -> bool:
        """Park the device loop until an item arrives or the queue closes.
        Returns True iff items are present."""
        with self._lock:
            if not self._items and not self._closed:
                self._data.wait(timeout)
            return bool(self._items)

    # -- lifecycle / gauges -------------------------------------------------
    def close(self) -> None:
        """Refuse further ``put``s (blocked putters wake with
        :class:`ServiceClosed`); queued items stay poppable for drain."""
        with self._lock:
            self._closed = True
            self._space.notify_all()
            self._data.notify_all()

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def gauges(self) -> dict:
        with self._lock:
            return {"depth": len(self._items), "gated": self._gated,
                    "rejected": self.n_rejected, "closed": self._closed}

    def _maybe_ungate(self) -> None:
        # no lock-held annotation needed: navilint's interprocedural
        # NX201 proves every call site already holds self._lock
        if self._gated and len(self._items) <= self.low:
            self._gated = False
            self._space.notify_all()
