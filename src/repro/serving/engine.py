"""Batched request serving for the vector index (+ LM generation helper).

The search engine mirrors a production vector-serving tier:
  * requests (query vector + selection subquery + k) accumulate in a queue;
  * a scheduler drains up to ``max_batch`` compatible requests (same
    semimask => same compiled program) into one batched search;
  * per-request latency is recorded (queue + execution) and summarized as
    p50/p95/p99 -- the paper's latency protocol (warm-up + repeats) is
    implemented in the benchmark harness on top of this engine.

Straggler-robust distributed mode: when constructed over a ShardedNavix,
the engine searches with a shard-liveness mask and a quorum (DESIGN.md
Section 4); dead shards degrade recall, not availability.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Any, Optional

import numpy as np

from repro.core.navix import NavixIndex
from repro.query.operators import Plan, evaluate
from repro.storage.columnar import GraphStore


@dataclasses.dataclass
class Request:
    rid: int
    query: np.ndarray
    plan: Optional[Plan]          # selection subquery (None = unfiltered)
    k: int = 10
    t_enqueue: float = 0.0


@dataclasses.dataclass
class Response:
    rid: int
    ids: np.ndarray
    dists: np.ndarray
    queue_ms: float
    exec_ms: float
    prefilter_ms: float
    sigma: float


@dataclasses.dataclass
class SearchEngine:
    index: NavixIndex
    store: Optional[GraphStore] = None
    heuristic: str = "adaptive_local"
    efs: int = 0
    max_batch: int = 32

    def __post_init__(self):
        self._queue: deque[Request] = deque()
        self._next_rid = 0
        self.latencies_ms: list[float] = []

    # -- client API ---------------------------------------------------------
    def submit(self, query, plan: Optional[Plan] = None, k: int = 10) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid=rid, query=np.asarray(query),
                                   plan=plan, k=k,
                                   t_enqueue=time.perf_counter()))
        return rid

    def drain(self) -> list[Response]:
        """Serve everything queued; batches requests with identical plans."""
        groups: dict[Any, list[Request]] = defaultdict(list)
        while self._queue:
            r = self._queue.popleft()
            groups[(r.plan, r.k)].append(r)
        out: list[Response] = []
        for (plan, k), reqs in groups.items():
            out.extend(self._serve_group(plan, k, reqs))
        return out

    # -- internals ------------------------------------------------------------
    def _serve_group(self, plan, k, reqs: list[Request]) -> list[Response]:
        t0 = time.perf_counter()
        if plan is not None:
            if self.store is None:
                raise ValueError("filtered request but engine has no store")
            qres = evaluate(plan, self.store)
            mask, pf_ms = qres.mask, qres.seconds * 1e3
            sigma = qres.selectivity
        else:
            mask, pf_ms, sigma = None, 0.0, 1.0

        responses = []
        for i in range(0, len(reqs), self.max_batch):
            chunk = reqs[i:i + self.max_batch]
            Q = np.stack([r.query for r in chunk])
            t1 = time.perf_counter()
            res = self.index.search_many(Q, k=k, efs=self.efs or 2 * k,
                                         semimask=mask,
                                         heuristic=self.heuristic)
            ids = np.asarray(res.ids)
            dists = np.asarray(res.dists)
            exec_ms = (time.perf_counter() - t1) * 1e3 / len(chunk)
            for j, r in enumerate(chunk):
                queue_ms = (t1 - r.t_enqueue) * 1e3
                self.latencies_ms.append(queue_ms + exec_ms + pf_ms)
                responses.append(Response(
                    rid=r.rid, ids=ids[j], dists=dists[j],
                    queue_ms=queue_ms, exec_ms=exec_ms,
                    prefilter_ms=pf_ms, sigma=sigma))
        return responses

    def latency_summary(self) -> dict:
        if not self.latencies_ms:
            return {}
        arr = np.asarray(self.latencies_ms)
        return {"n": len(arr), "p50_ms": float(np.percentile(arr, 50)),
                "p95_ms": float(np.percentile(arr, 95)),
                "p99_ms": float(np.percentile(arr, 99)),
                "mean_ms": float(arr.mean())}


def greedy_generate(cfg, params, prompt_tokens: np.ndarray, n_new: int,
                    max_len: Optional[int] = None):
    """Tiny LM generation helper (prefill + greedy decode) for the RAG
    example; batch-first tokens int32[B, S]."""
    import jax.numpy as jnp

    from repro.models.transformer import decode_step, prefill
    tokens = jnp.asarray(prompt_tokens, jnp.int32)
    b, s = tokens.shape
    cache, logits = prefill(cfg, params, tokens,
                            max_len=max_len or s + n_new)
    out = []
    for _ in range(n_new):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(np.asarray(nxt))
        cache, logits = decode_step(cfg, params, cache, nxt)
    return np.stack(out, axis=1)
