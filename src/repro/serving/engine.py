"""Batched request serving for the vector index (+ LM generation helper).

The search engine mirrors a production vector-serving tier, rebased on the
unified :class:`repro.api.NavixDB` pipeline:
  * requests (query vector + declarative plan + k) accumulate in a queue;
    plans may be full ``KnnSearch`` trees (built with ``repro.api.Q``) or
    bare selection subqueries (legacy form, wrapped automatically);
  * the default scheduler is **continuous batching** (the LLM-serving
    pattern applied to beam search): requests with *different* plans fuse
    into one device batch via per-lane ``[B, W]`` semimasks -- each lane
    searches its own selection subquery's S at its own selectivity, with
    per-lane k/efs capped to the batch max -- and a host-side step loop
    (``repro.core.search_batch.engine_steps``) periodically compacts
    converged lanes out and refills them from the queue, so long-tail
    convergence gaps never strand SIMD lanes. Every distinct selection
    subquery is prefiltered exactly once per drain; its cost is shared by
    the requests that carry it (never amortized across unrelated plans);
  * ``scheduler="grouped"`` keeps the PR-2 reference path: requests
    grouped by identical plan into ``NavixDB.execute`` calls (one shared
    semimask per group batch, whole-batch convergence);
  * per-request latency is recorded (queue + execution + own-plan
    prefilter share) and summarized as p50/p95/p99 -- the paper's latency
    protocol (warm-up + repeats) is implemented in the benchmark harness
    on top of this engine.

Straggler-robust distributed mode: the engine serves a
:class:`~repro.core.distributed.ShardedNavix` through the same
schedulers -- the continuous scheduler's lane state simply gains a shard
dimension (per-lane semimasks become ``[S, B, W_local]``, refill masks
apply to every shard's copy of a lane) and converged lanes are merged
across shards at finalize time under the engine's ``alive`` mask. A
shard marked dead mid-drain degrades recall, not availability: responses
finalized under a partial quorum are flagged ``degraded`` and contain no
ids from dead shards.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, defaultdict, deque
from typing import Any, Callable, Optional

import numpy as np

from repro.api.db import NavixDB
from repro.api.plan_compile import _bucket
from repro.core.distributed import ShardedNavix
from repro.query.operators import (KnnSearch, Plan, is_selection,
                                   output_table, split_pipeline)
from repro.serving.lanes import LaneBatch, _FlatLanes, _ShardLanes  # noqa: F401
from repro.storage.columnar import GraphStore


@dataclasses.dataclass
class Request:
    rid: int
    query: np.ndarray
    plan: Optional[Plan]          # KnnSearch tree or bare Q_S (None = unfiltered)
    k: int = 10
    t_enqueue: float = 0.0


@dataclasses.dataclass
class Response:
    rid: int
    ids: np.ndarray
    dists: np.ndarray
    queue_ms: float
    exec_ms: float
    prefilter_ms: float           # this request's share of its OWN plan's
                                  # prefilter wall time (shared only with
                                  # requests carrying the same Q_S)
    sigma: float                  # this request's own |S| / |V|
    degraded: bool = False        # finalized under a partial shard quorum
                                  # (sharded indexes only): some shards
                                  # were dead, so recall may be reduced
    status: str = "ok"            # terminal state: "ok" (converged),
                                  # "partial" (deadline hit but the beam
                                  # already covered k candidates -- a
                                  # best-effort answer), "timeout"
                                  # (deadline hit first; ids are all -1,
                                  # NEVER a truncated id list)

    @property
    def timeout(self) -> bool:
        return self.status == "timeout"


def canonical_plan(db: NavixDB, default_index: Optional[str],
                   plan: Optional[Plan], k: int, efs: int,
                   heuristic: str) -> Plan:
    """Normalize a submission to a hashable KnnSearch-rooted plan -- the
    fuse/group key: same plan => one prefilter + one compiled program.
    Shared by the closed-queue engine and the live SearchService."""
    builder_plan = getattr(plan, "plan", None)
    if callable(builder_plan):
        plan = builder_plan()
    if plan is None:
        # resolve lazily: the catalog may be populated after __init__
        name = default_index or next(iter(db.catalog), None)
        if name is None or name not in db.catalog:
            raise ValueError("unfiltered request but the NavixDB "
                             "catalog has no index; create one with "
                             "db.create_index(...)")
        entry = db.catalog[name]
        return KnnSearch(child=None, table=entry.table, k=k,
                         index=name, efs=efs, heuristic=heuristic)
    if is_selection(plan):
        return KnnSearch(child=plan, k=k, efs=efs, heuristic=heuristic)
    return plan                    # already declarative


def resolve_alive(n_shards: int, alive, heartbeats,
                  now: Optional[float] = None) -> np.ndarray:
    """The serving tier's single source of shard liveness.

    ``heartbeats`` (a :class:`repro.serving.heartbeat.HeartbeatMonitor`)
    takes the place of a caller-set ``alive`` mask: the mask is DERIVED
    from per-shard heartbeat staleness at the moment of each finalize,
    so straggler shards degrade responses automatically. Setting both is
    ambiguous and raises; either on an unsharded index raises (same
    contract as ``NavixDB.execute(alive=...)``).
    """
    if heartbeats is not None:
        if alive is not None:
            raise ValueError("set either a heartbeat monitor or a static "
                             "alive mask, not both")
        if not n_shards:
            raise ValueError("heartbeat liveness quorum-masks sharded "
                             "indexes; this index is unsharded")
        mask = np.asarray(heartbeats.alive(now), bool)
        if mask.shape != (n_shards,):
            raise ValueError(f"heartbeat monitor tracks {mask.shape[0]} "
                             f"shards; the index has {n_shards}")
        return mask
    if alive is None:
        return np.ones(max(n_shards, 1), bool)
    if not n_shards:
        # mirror NavixDB.execute: silently ignoring a quorum mask on
        # an unsharded index would hide the caller's intent
        raise ValueError("alive quorum-masks sharded indexes; "
                         "this drain targets an unsharded index")
    mask = np.asarray(alive, bool)
    if mask.shape != (n_shards,):
        raise ValueError(f"alive has shape {mask.shape}; the "
                         f"index has {n_shards} shards")
    return mask


@dataclasses.dataclass
class SearchEngine:
    """Serving tier over a :class:`NavixDB`.

    Construct either from a ``db`` (preferred; serves declarative plans
    against its catalog) or from a bare ``index`` (+ optional ``store``),
    which is wrapped into a single-index NavixDB automatically. ``index``
    may also be a :class:`ShardedNavix`: both schedulers then run the
    sharded batched engine, honoring the engine's ``alive`` shard mask.
    """
    index: Optional[object] = None
    store: Optional[GraphStore] = None
    heuristic: str = "adaptive_local"
    efs: int = 0
    max_batch: int = 32
    db: Optional[NavixDB] = None
    default_index: Optional[str] = None    # catalog name for unfiltered kNN
    engine: str = "batched"                # grouped drains run the
                                           # batched-frontier engine;
                                           # "vmap" = reference oracle
    scheduler: str = "continuous"          # "continuous": mixed-plan fusing
                                           # with per-lane semimasks + lane
                                           # refill; "grouped": the PR-2
                                           # per-plan reference path
    step_iters: int = 32                   # device loop iterations per
                                           # continuous-batching step call
                                           # while requests are still queued
                                           # (an empty queue runs each step
                                           # to whole-batch convergence)
    refill_threshold: int = 0              # min free lanes before a refill
                                           # (compaction) is worth a device
                                           # call; 0 = auto (batch size / 2)
    alive: Optional[np.ndarray] = None     # shard liveness (sharded indexes
                                           # only): bool[S], None = all
                                           # alive; may flip mid-drain --
                                           # lanes finalized under a partial
                                           # quorum come back degraded
    heartbeats: Optional[object] = None    # a HeartbeatMonitor: shard
                                           # liveness DERIVED from per-shard
                                           # heartbeat staleness at every
                                           # finalize instead of a caller-
                                           # set mask (mutually exclusive
                                           # with ``alive``)
    step_hook: Optional[Callable] = None   # called after every continuous-
                                           # scheduler device step with a
                                           # progress dict (telemetry /
                                           # liveness probes can flip
                                           # ``alive`` here mid-drain)

    def __post_init__(self):
        if self.db is None:
            if self.index is None:
                raise ValueError("SearchEngine needs a db= or an index=")
            self.db = NavixDB(self.store)
            self.db.register_index("default", self.index)
            self.default_index = "default"
        else:
            if self.default_index is None:
                self.default_index = next(iter(self.db.catalog), None)
            if self.index is None and self.default_index is not None:
                self.index = self.db.index(self.default_index)
        self.store = self.db.store
        self._queue: deque[Request] = deque()
        self._next_rid = 0
        self.latencies_ms: list[float] = []
        # queue-wait vs service-time split of the same requests, recorded
        # in lockstep with latencies_ms (service = exec + prefilter share)
        self.queue_waits_ms: list[float] = []
        self.service_ms: list[float] = []
        # host-vs-device split of every stepped chunk, summed over drains
        # (see LaneBatch.timing): host_gap = host work the device waited
        # for; host_overlap = host work hidden behind an in-flight chunk
        self.chunk_timing = {"n_chunks": 0, "host_gap_ms": 0.0,
                             "host_overlap_ms": 0.0, "device_wait_ms": 0.0}
        # LaneBatch reuse across drains, keyed by the fused program shape:
        # building one per drain pays parked-state allocation + mesh
        # placement every time (the dominant per-drain setup cost on
        # sharded backends). A batch is only reusable when the previous
        # drain left it clean (all lanes free, no chunk in flight).
        self._lane_cache: "OrderedDict[Any, LaneBatch]" = OrderedDict()

    def _record_latency(self, queue_ms: float, service_ms: float) -> None:
        self.latencies_ms.append(queue_ms + service_ms)
        self.queue_waits_ms.append(queue_ms)
        self.service_ms.append(service_ms)

    # -- client API ---------------------------------------------------------
    def submit(self, query, plan: Optional[Plan] = None, k: int = 10) -> int:
        """Enqueue one request. ``plan`` may be a full declarative plan
        (``Q...knn(...)`` tree, in which case its own k/efs/heuristic
        apply), a bare selection subquery, or None (unfiltered)."""
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid=rid, query=np.asarray(query),
                                   plan=self._canonical(plan, k), k=k,
                                   t_enqueue=time.perf_counter()))
        return rid

    def drain(self) -> list[Response]:
        """Serve everything queued.

        ``scheduler="continuous"`` (default) fuses requests with
        *different* plans into shared device batches (per-lane semimasks,
        continuous lane refill); ``scheduler="grouped"`` batches only
        identical plans (the reference path). Every submitted rid is
        answered exactly once either way.
        """
        if self.scheduler not in ("continuous", "grouped"):
            # validate BEFORE popping the queue: a bad config must not
            # silently discard every queued request
            raise ValueError(f"unknown scheduler {self.scheduler!r}; "
                             f"valid: ('continuous', 'grouped')")
        reqs: list[Request] = []
        while self._queue:
            reqs.append(self._queue.popleft())
        if self.scheduler == "continuous":
            return self._drain_continuous(reqs)
        groups: dict[Any, list[Request]] = defaultdict(list)
        for r in reqs:
            groups[r.plan].append(r)
        out: list[Response] = []
        for plan, group in groups.items():
            out.extend(self._serve_group(plan, group))
        return out

    # -- internals ------------------------------------------------------------
    def _canonical(self, plan: Optional[Plan], k: int) -> Plan:
        """Normalize every submit to a hashable KnnSearch-rooted plan --
        the group key: same plan => one prefilter + one compiled program."""
        return canonical_plan(self.db, self.default_index, plan, k,
                              self.efs, self.heuristic)

    # -- continuous batching (mixed-plan fusing + lane refill) ---------------
    def _drain_continuous(self, reqs: list[Request]) -> list[Response]:
        """Fuse mixed-plan requests into shared device batches.

        Requests fuse when they target the same index with the same
        heuristic -- their selection subqueries (and k/efs) may all
        differ: each lane carries its own packed semimask, k/efs are
        capped to the batch max, and every distinct Q_S is prefiltered
        once. Per fuse group, a host step loop advances the batch in
        ``step_iters``-iteration chunks, finalizes converged lanes, and
        refills freed lanes from the queue (``refill_threshold`` sets how
        many free lanes make a compaction worth the device call).
        """
        fuse: dict[Any, list[tuple[Request, Any]]] = defaultdict(list)
        for r in reqs:
            parts = split_pipeline(r.plan)
            table = output_table(r.plan, self.db.store)
            entry = self.db._resolve(parts.knn, table)
            fuse[(entry.name, parts.knn.heuristic)].append((r, parts))
        out: list[Response] = []
        for (name, heuristic), items in fuse.items():
            out.extend(self._serve_fused(self.db.catalog[name].index,
                                         heuristic, items))
        return out

    def _current_alive(self, backend) -> np.ndarray:
        return resolve_alive(backend.n_shards, self.alive, self.heartbeats)

    def _lanes(self, idx, heuristic: str, k_cap: int, efs_cap: int,
               bsz: int) -> LaneBatch:
        """A clean LaneBatch for this fused program shape, reused across
        drains when possible. A dirty cache entry (a previous drain died
        with lanes occupied or a chunk in flight) is discarded rather
        than repaired -- its donated device state is unrecoverable."""
        key = (id(idx), heuristic, k_cap, efs_cap, bsz)
        lanes = self._lane_cache.get(key)
        if lanes is not None and not lanes.step_pending \
                and not lanes.occupied_count():
            self._lane_cache.move_to_end(key)
            lanes.reset_timing()
            return lanes
        lanes = LaneBatch(idx, heuristic, k_cap, efs_cap, bsz)
        self._lane_cache[key] = lanes
        self._lane_cache.move_to_end(key)
        while len(self._lane_cache) > 8:     # bound device-state residency
            self._lane_cache.popitem(last=False)
        return lanes

    def _serve_fused(self, idx, heuristic: str,
                     items: list[tuple[Request, Any]]) -> list[Response]:
        # per-lane k/efs, capped to the batch max: one static program
        # serves every fused request; lanes slice their own k at the end
        k_cap = max(p.knn.k for _, p in items)
        efs_cap = max(max(p.knn.efs or 2 * p.knn.k for _, p in items), k_cap)
        bsz = _bucket(max(1, min(self.max_batch, len(items))))
        lanes = self._lanes(idx, heuristic, k_cap, efs_cap, bsz)

        # one prefilter per DISTINCT selection subquery; its wall time is
        # shared only by the requests that carry it
        sel_info: dict[Any, list] = {}   # Q_S -> [packed_row, sigma, ms, cnt]
        full_row = lanes.backend.full_row()
        for r, parts in items:
            s = parts.selection
            if s not in sel_info:
                if s is None:
                    sel_info[s] = [full_row, 1.0, 0.0, 0]
                else:
                    qres = self.db.prefilter(s)
                    sel_info[s] = [lanes.backend.pack_row(qres.mask),
                                   qres.selectivity, qres.seconds * 1e3, 0]
            sel_info[s][3] += 1

        # selectivity-sorted admission: lanes running together then carry
        # similar-sigma subqueries, so whole step chunks pass in which no
        # live lane picks a two-hop branch and the engine's lax.cond
        # skips the [B, M, M] second-degree stage entirely -- mixing one
        # low-sigma lane into a high-sigma batch would re-enable it for
        # everyone. Lane-for-lane results are order-independent.
        items = sorted(items,
                       key=lambda rp: -sel_info[rp[1].selection][1])

        # prep every query in ONE vectorized device call (a per-request
        # _prep_query inside the refill loop costs a dispatch each)
        # navilint: sync-ok admission boundary -- queries are host data; prep is one vectorized call before the device loop starts
        prepped = np.asarray(idx._prep_query(
            np.stack([r.query for r, _ in items])), np.float32)

        pending = deque((r, parts, prepped[j])
                        for j, (r, parts) in enumerate(items))

        bsz = lanes.bsz            # data-axis backends round the batch up
        refill_thr = self.refill_threshold or max(1, bsz // 2)
        responses: list[Response] = []
        done: dict[int, float] = {}    # converged lane -> t_done (state
                                       # stays frozen until flushed)
        n_devsteps = 0

        def collect():
            """Finalize every converged-but-unemitted lane (one device
            call for any number of them), free the lanes, and return the
            raw rows for ``emit``. Sharded backends merge across shards
            under the CURRENT alive mask; a partial quorum flags the
            responses degraded. The device sync lives HERE; ``emit`` is
            pure host work that the driver overlaps with the next
            in-flight chunk."""
            if not done:
                return []
            alive = self._current_alive(lanes.backend)
            degraded = lanes.n_shards > 0 and not alive.all()
            ids, dists = lanes.finalize(alive)
            rows = []
            for i, t_done in done.items():
                r, parts, t0 = lanes.meta[i]
                k_r = parts.knn.k
                rows.append((r, parts, t0, t_done,
                             ids[i, :k_r], dists[i, :k_r], degraded))
                lanes.release(i)
            done.clear()
            return rows

        def emit(rows):
            """Build + record the responses for ``collect``'s rows --
            host-only, safe to run while a device chunk is in flight."""
            for r, parts, t0, t_done, ids_i, dists_i, degraded in rows:
                _, sigma, pf_ms, cnt = sel_info[parts.selection]
                pf_share = pf_ms / cnt
                queue_ms = (t0 - r.t_enqueue) * 1e3
                exec_ms = (t_done - t0) * 1e3
                self._record_latency(queue_ms, exec_ms + pf_share)
                responses.append(Response(
                    rid=r.rid, ids=ids_i, dists=dists_i,
                    queue_ms=queue_ms, exec_ms=exec_ms,
                    prefilter_ms=pf_share, sigma=float(sigma),
                    degraded=degraded))

        while pending or lanes.occupied_count():
            n_running = lanes.occupied_count() - len(done)
            # free_count() already excludes converged-but-unflushed lanes
            # (their meta stays set until flush), so the reclaimable lane
            # count is free + done -- subtracting done here would reduce
            # the admission test to free >= thr, which never passes while
            # the batch is full, silently degrading continuous scheduling
            # to whole-batch convergence
            n_free = lanes.free_count()
            rows = []
            if pending and (n_free + len(done) >= refill_thr
                            or n_running == 0):
                rows = collect()        # compact converged lanes out ...
                entries = []            # ... and refill from the queue
                now = time.perf_counter()
                while pending and len(entries) < lanes.free_count():
                    r, parts, qrow = pending.popleft()
                    row, sigma, _, _ = sel_info[parts.selection]
                    # ragged per-lane efs only when the plan NAMES its
                    # efs; an unset efs keeps the cap-wide beam
                    efs_r = (min(max(parts.knn.efs, parts.knn.k), efs_cap)
                             if parts.knn.efs else efs_cap)
                    entries.append(((r, parts, now), qrow, row, sigma,
                                    efs_r))
                lanes.admit(entries)
            elif n_running == 0:
                # queue empty (a non-empty queue with zero running lanes
                # always takes the refill branch): only frozen converged
                # lanes remain
                break

            # with an empty queue there is nothing to refill between
            # chunks: run the remaining lanes straight to convergence.
            # Dispatch FIRST (donated state, async), then do the host-side
            # response building for the lanes collected above while the
            # chunk is in flight; sync only on the chunk's liveness.
            n_steps = self.step_iters if pending else 0
            lanes.step_async(n_steps)
            emit(rows)
            live_np = lanes.step_wait()
            n_devsteps += 1
            if self.step_hook is not None:
                self.step_hook({"step": n_devsteps,
                                # navilint: sync-ok live_np is host-side
                                # numpy; step() already crossed the boundary
                                "live": int(live_np.sum()),
                                "pending": len(pending),
                                "done": len(done)})
            now = time.perf_counter()
            for i in range(bsz):
                if (lanes.meta[i] is not None and i not in done
                        and not live_np[i]):
                    done[i] = now
        emit(collect())
        for key, v in lanes.timing().items():
            self.chunk_timing[key] += v
        return responses

    def _serve_group(self, plan: Plan, reqs: list[Request]) -> list[Response]:
        Q = np.stack([r.query for r in reqs])
        parts = split_pipeline(plan)
        entry = self.db._resolve(parts.knn,
                                 output_table(plan, self.db.store))
        sharded = isinstance(entry.index, ShardedNavix)
        if self.alive is not None and not sharded:
            raise ValueError("engine.alive quorum-masks sharded indexes; "
                             f"index {entry.name!r} is unsharded")
        alive = self.alive if sharded else None
        degraded = bool(sharded and alive is not None
                        and not np.asarray(alive, bool).all())
        t1 = time.perf_counter()
        # engine passes through: db.execute rejects "vmap" on a sharded
        # index rather than this layer silently overriding it
        rs = self.db.execute(plan, query=Q, max_batch=self.max_batch,
                             engine=self.engine, alive=alive)
        # the prefilter ran once for the whole group: amortize its cost
        # (and the semimask pack) across the group's requests so the
        # latency summary reflects what each request actually paid
        pf_share = rs.timings.prefilter_ms / len(reqs)
        exec_ms = (rs.timings.pack_ms + rs.timings.search_ms
                   + rs.timings.project_ms) / len(reqs)
        responses = []
        for j, r in enumerate(reqs):
            queue_ms = (t1 - r.t_enqueue) * 1e3
            self._record_latency(queue_ms, exec_ms + pf_share)
            responses.append(Response(
                rid=r.rid, ids=rs.ids[j], dists=rs.dists[j],
                queue_ms=queue_ms, exec_ms=exec_ms,
                prefilter_ms=pf_share, sigma=rs.sigma,
                degraded=degraded))
        return responses

    def latency_summary(self) -> dict:
        """End-to-end p50/p95/p99 plus the queue-wait vs service-time
        split of the same requests (service = exec + prefilter share;
        queue = t_dequeue - Request.t_enqueue). ``chunks`` breaks every
        continuous-scheduler step chunk into host time the device waited
        for (``host_gap_ms``), host time hidden behind an in-flight chunk
        (``host_overlap_ms``), and time blocked on the device
        (``device_wait_ms``) -- the overlap win made observable."""
        if not self.latencies_ms:
            return {}
        arr = np.asarray(self.latencies_ms)
        qarr = np.asarray(self.queue_waits_ms)
        sarr = np.asarray(self.service_ms)
        out = {"n": len(arr), "p50_ms": float(np.percentile(arr, 50)),
               "p95_ms": float(np.percentile(arr, 95)),
               "p99_ms": float(np.percentile(arr, 99)),
               "mean_ms": float(arr.mean()),
               "queue_p50_ms": float(np.percentile(qarr, 50)),
               "queue_p99_ms": float(np.percentile(qarr, 99)),
               "service_p50_ms": float(np.percentile(sarr, 50)),
               "service_p95_ms": float(np.percentile(sarr, 95)),
               "service_p99_ms": float(np.percentile(sarr, 99))}
        if self.chunk_timing["n_chunks"]:
            out["chunks"] = dict(self.chunk_timing)
        return out


def greedy_generate(cfg, params, prompt_tokens: np.ndarray, n_new: int,
                    max_len: Optional[int] = None):
    """Tiny LM generation helper (prefill + greedy decode) for the RAG
    example; batch-first tokens int32[B, S]."""
    import jax.numpy as jnp

    from repro.models.transformer import decode_step, prefill
    tokens = jnp.asarray(prompt_tokens, jnp.int32)
    b, s = tokens.shape
    cache, logits = prefill(cfg, params, tokens,
                            max_len=max_len or s + n_new)
    out = []
    for _ in range(n_new):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(np.asarray(nxt))
        cache, logits = decode_step(cfg, params, cache, nxt)
    return np.stack(out, axis=1)
