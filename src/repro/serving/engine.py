"""Batched request serving for the vector index (+ LM generation helper).

The search engine mirrors a production vector-serving tier, rebased on the
unified :class:`repro.api.NavixDB` pipeline:
  * requests (query vector + declarative plan + k) accumulate in a queue;
    plans may be full ``KnnSearch`` trees (built with ``repro.api.Q``) or
    bare selection subqueries (legacy form, wrapped automatically);
  * a scheduler drains requests grouped by plan (same plan => same
    prefilter AND same compiled program) into batched ``NavixDB.execute``
    calls served by the batched-frontier engine
    (``repro.core.search_batch``): one while-loop per group batch,
    converged queries masked out, one shared expansion per iteration;
    the shared AOT program cache means repeated plan shapes never
    retrace, and the group's prefilter runs exactly once, its cost
    amortized across the group's requests;
  * per-request latency is recorded (queue + execution + amortized
    prefilter share) and summarized as p50/p95/p99 -- the paper's latency
    protocol (warm-up + repeats) is implemented in the benchmark harness
    on top of this engine.

Straggler-robust distributed mode: when constructed over a ShardedNavix,
the engine searches with a shard-liveness mask and a quorum (DESIGN.md
Section 4); dead shards degrade recall, not availability.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Any, Optional

import numpy as np

from repro.api.db import NavixDB
from repro.core.navix import NavixIndex
from repro.query.operators import KnnSearch, Plan, is_selection
from repro.storage.columnar import GraphStore


@dataclasses.dataclass
class Request:
    rid: int
    query: np.ndarray
    plan: Optional[Plan]          # KnnSearch tree or bare Q_S (None = unfiltered)
    k: int = 10
    t_enqueue: float = 0.0


@dataclasses.dataclass
class Response:
    rid: int
    ids: np.ndarray
    dists: np.ndarray
    queue_ms: float
    exec_ms: float
    prefilter_ms: float           # this request's amortized share of the
                                  # group's (shared) prefilter wall time
    sigma: float


@dataclasses.dataclass
class SearchEngine:
    """Serving tier over a :class:`NavixDB`.

    Construct either from a ``db`` (preferred; serves declarative plans
    against its catalog) or from a bare ``index`` (+ optional ``store``),
    which is wrapped into a single-index NavixDB automatically.
    """
    index: Optional[NavixIndex] = None
    store: Optional[GraphStore] = None
    heuristic: str = "adaptive_local"
    efs: int = 0
    max_batch: int = 32
    db: Optional[NavixDB] = None
    default_index: Optional[str] = None    # catalog name for unfiltered kNN
    engine: str = "batched"                # grouped drains run the
                                           # batched-frontier engine;
                                           # "vmap" = reference oracle

    def __post_init__(self):
        if self.db is None:
            if self.index is None:
                raise ValueError("SearchEngine needs a db= or an index=")
            self.db = NavixDB(self.store)
            self.db.register_index("default", self.index)
            self.default_index = "default"
        else:
            if self.default_index is None:
                self.default_index = next(iter(self.db.catalog), None)
            if self.index is None and self.default_index is not None:
                self.index = self.db.index(self.default_index)
        self.store = self.db.store
        self._queue: deque[Request] = deque()
        self._next_rid = 0
        self.latencies_ms: list[float] = []

    # -- client API ---------------------------------------------------------
    def submit(self, query, plan: Optional[Plan] = None, k: int = 10) -> int:
        """Enqueue one request. ``plan`` may be a full declarative plan
        (``Q...knn(...)`` tree, in which case its own k/efs/heuristic
        apply), a bare selection subquery, or None (unfiltered)."""
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid=rid, query=np.asarray(query),
                                   plan=self._canonical(plan, k), k=k,
                                   t_enqueue=time.perf_counter()))
        return rid

    def drain(self) -> list[Response]:
        """Serve everything queued; batches requests with identical plans."""
        groups: dict[Any, list[Request]] = defaultdict(list)
        while self._queue:
            r = self._queue.popleft()
            groups[r.plan].append(r)
        out: list[Response] = []
        for plan, reqs in groups.items():
            out.extend(self._serve_group(plan, reqs))
        return out

    # -- internals ------------------------------------------------------------
    def _canonical(self, plan: Optional[Plan], k: int) -> Plan:
        """Normalize every submit to a hashable KnnSearch-rooted plan --
        the group key: same plan => one prefilter + one compiled program."""
        builder_plan = getattr(plan, "plan", None)
        if callable(builder_plan):
            plan = builder_plan()
        if plan is None:
            # resolve lazily: the catalog may be populated after __init__
            name = self.default_index or next(iter(self.db.catalog), None)
            if name is None or name not in self.db.catalog:
                raise ValueError("unfiltered request but the NavixDB "
                                 "catalog has no index; create one with "
                                 "db.create_index(...)")
            entry = self.db.catalog[name]
            return KnnSearch(child=None, table=entry.table, k=k,
                             index=name, efs=self.efs,
                             heuristic=self.heuristic)
        if is_selection(plan):
            return KnnSearch(child=plan, k=k, efs=self.efs,
                             heuristic=self.heuristic)
        return plan                # already declarative

    def _serve_group(self, plan: Plan, reqs: list[Request]) -> list[Response]:
        Q = np.stack([r.query for r in reqs])
        t1 = time.perf_counter()
        rs = self.db.execute(plan, query=Q, max_batch=self.max_batch,
                             engine=self.engine)
        # the prefilter ran once for the whole group: amortize its cost
        # (and the semimask pack) across the group's requests so the
        # latency summary reflects what each request actually paid
        pf_share = rs.timings.prefilter_ms / len(reqs)
        exec_ms = (rs.timings.pack_ms + rs.timings.search_ms
                   + rs.timings.project_ms) / len(reqs)
        responses = []
        for j, r in enumerate(reqs):
            queue_ms = (t1 - r.t_enqueue) * 1e3
            self.latencies_ms.append(queue_ms + exec_ms + pf_share)
            responses.append(Response(
                rid=r.rid, ids=rs.ids[j], dists=rs.dists[j],
                queue_ms=queue_ms, exec_ms=exec_ms,
                prefilter_ms=pf_share, sigma=rs.sigma))
        return responses

    def latency_summary(self) -> dict:
        if not self.latencies_ms:
            return {}
        arr = np.asarray(self.latencies_ms)
        return {"n": len(arr), "p50_ms": float(np.percentile(arr, 50)),
                "p95_ms": float(np.percentile(arr, 95)),
                "p99_ms": float(np.percentile(arr, 99)),
                "mean_ms": float(arr.mean())}


def greedy_generate(cfg, params, prompt_tokens: np.ndarray, n_new: int,
                    max_len: Optional[int] = None):
    """Tiny LM generation helper (prefill + greedy decode) for the RAG
    example; batch-first tokens int32[B, S]."""
    import jax.numpy as jnp

    from repro.models.transformer import decode_step, prefill
    tokens = jnp.asarray(prompt_tokens, jnp.int32)
    b, s = tokens.shape
    cache, logits = prefill(cfg, params, tokens,
                            max_len=max_len or s + n_new)
    out = []
    for _ in range(n_new):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(np.asarray(nxt))
        cache, logits = decode_step(cfg, params, cache, nxt)
    return np.stack(out, axis=1)
