"""Heartbeat-based shard liveness for the serving tier.

The closed-queue engine takes a caller-set ``alive`` bool[S] mask; a live
service can't -- nobody is there to set it. :class:`HeartbeatMonitor`
derives the mask instead: each shard worker calls ``beat(shard)``
periodically, and a shard whose last beat is older than ``stale_after``
seconds is considered dead at the moment of each finalize. Because
ShardedNavix applies ``alive`` only at the finalize merge (per-shard
beams are independent), a shard going stale MID-search yields exactly
the alive-restricted reference answer -- no partial contamination.

The monitor is clock-injectable (tests drive a fake clock) and exposes
``suppress(shard)`` to simulate a straggler: beats from a suppressed
shard are dropped, so it goes stale on schedule rather than instantly --
the same observable behavior as a worker that silently hangs.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional

import numpy as np


class HeartbeatMonitor:
    """Tracks per-shard heartbeat timestamps; ``alive(now)`` is the
    derived liveness mask. Thread-safe: workers beat from their own
    threads while the device loop reads the mask."""

    def __init__(self, n_shards: int, stale_after: float = 2.0,
                 clock: Callable[[], float] = time.monotonic):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if stale_after <= 0:
            raise ValueError("stale_after must be positive")
        self.n_shards = n_shards
        self.stale_after = float(stale_after)
        self.clock = clock
        self._lock = threading.Lock()
        now = clock()
        # every shard starts freshly beaten: a service that finalizes
        # before the first beat round should not mark the world dead
        self._last = np.full(n_shards, now, np.float64)  # guarded-by: _lock
        self._suppressed = np.zeros(n_shards, bool)      # guarded-by: _lock

    def _check(self, shard: int) -> None:
        if not (0 <= shard < self.n_shards):
            raise IndexError(f"shard {shard} out of range "
                             f"[0, {self.n_shards})")

    def beat(self, shard: int, now: Optional[float] = None) -> None:
        """Record a heartbeat. Beats from a suppressed shard are dropped
        (it goes stale exactly as a hung worker would)."""
        self._check(shard)
        with self._lock:
            if not self._suppressed[shard]:
                self._last[shard] = now if now is not None else self.clock()

    def beat_all(self, now: Optional[float] = None) -> None:
        for s in range(self.n_shards):
            self.beat(s, now)

    def suppress(self, shard: int) -> None:
        """Drop this shard's future beats (straggler injection)."""
        self._check(shard)
        with self._lock:
            self._suppressed[shard] = True

    def restore(self, shard: int, now: Optional[float] = None) -> None:
        """Lift a suppression and beat once, so the shard is instantly
        alive again (a recovered worker's first heartbeat)."""
        self._check(shard)
        with self._lock:
            self._suppressed[shard] = False
            self._last[shard] = now if now is not None else self.clock()

    def alive(self, now: Optional[float] = None) -> np.ndarray:
        """bool[S]: shards whose last beat is within ``stale_after``."""
        with self._lock:
            t = now if now is not None else self.clock()
            return (t - self._last) <= self.stale_after

    def snapshot(self, now: Optional[float] = None) -> dict:
        with self._lock:
            t = now if now is not None else self.clock()
            age = t - self._last
            suppressed = self._suppressed.tolist()
        return {"age_s": age.tolist(),
                "alive": (age <= self.stale_after).tolist(),
                "suppressed": suppressed,
                "stale_after": self.stale_after}
