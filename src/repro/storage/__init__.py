"""Columnar graph store and the exact f32 re-rank tier."""
