"""Columnar graph store -- the GDBMS substrate the index is native to.

Mirrors the parts of Kuzu that NaviX leverages (paper Section 2.3):
node tables are columnar property vectors; relationship tables are CSR
structures (forward + backward); the vector index's lower level is itself
stored as a relationship table (fixed-degree adjacency in device memory +
a CSR view here). Selection subqueries (repro.query) run against this store
and emit node semimasks.

Host-side state is numpy (this is the "disk" side); device payloads
(vector columns) are materialized to jax arrays on demand.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np


@dataclasses.dataclass
class NodeTable:
    name: str
    n: int
    columns: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    def add_column(self, name: str, values: np.ndarray) -> None:
        values = np.asarray(values)
        if values.shape[0] != self.n:
            raise ValueError(f"column {name}: {values.shape[0]} rows != {self.n}")
        self.columns[name] = values

    def column(self, name: str) -> np.ndarray:
        return self.columns[name]

    def rows(self, ids: np.ndarray,
             columns: Sequence[str] | None = None) -> dict[str, np.ndarray]:
        """Gather property values at ``ids`` (projection after a kNN).

        ``ids`` may carry -1 padding (unreachable result slots); padded
        positions return the row-0 value -- callers mask on ``ids >= 0``.
        """
        ids = np.asarray(ids)
        take = np.maximum(ids, 0)
        names = list(columns) if columns is not None else list(self.columns)
        return {c: self.columns[c][take] for c in names}


@dataclasses.dataclass
class CSR:
    offsets: np.ndarray      # int64[n_src + 1]
    targets: np.ndarray      # int64[n_edges]

    @property
    def n_src(self) -> int:
        return len(self.offsets) - 1

    @property
    def n_edges(self) -> int:
        return len(self.targets)

    def neighbors(self, u: int) -> np.ndarray:
        return self.targets[self.offsets[u]:self.offsets[u + 1]]

    def degrees(self) -> np.ndarray:
        return np.diff(self.offsets)


def csr_from_edges(src: np.ndarray, dst: np.ndarray, n_src: int) -> CSR:
    order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst[order]
    counts = np.bincount(src_s, minlength=n_src)
    offsets = np.zeros(n_src + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return CSR(offsets=offsets, targets=dst_s.astype(np.int64))


@dataclasses.dataclass
class RelTable:
    name: str
    src_table: str
    dst_table: str
    fwd: CSR                 # src -> dst
    bwd: CSR                 # dst -> src

    @property
    def n_edges(self) -> int:
        return self.fwd.n_edges


@dataclasses.dataclass
class GraphStore:
    nodes: dict[str, NodeTable] = dataclasses.field(default_factory=dict)
    rels: dict[str, RelTable] = dataclasses.field(default_factory=dict)

    def add_node_table(self, name: str, n: int,
                       columns: Mapping[str, np.ndarray] | None = None) -> NodeTable:
        t = NodeTable(name=name, n=n)
        for cname, col in (columns or {}).items():
            t.add_column(cname, col)
        self.nodes[name] = t
        return t

    def add_rel_table(self, name: str, src_table: str, dst_table: str,
                      src: np.ndarray, dst: np.ndarray) -> RelTable:
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        n_src = self.nodes[src_table].n
        n_dst = self.nodes[dst_table].n
        if src.size and (src.max() >= n_src or dst.max() >= n_dst):
            raise ValueError(f"rel {name}: edge endpoint out of range")
        rel = RelTable(name=name, src_table=src_table, dst_table=dst_table,
                       fwd=csr_from_edges(src, dst, n_src),
                       bwd=csr_from_edges(dst, src, n_dst))
        self.rels[name] = rel
        return rel

    def add_vector_column(self, table: str, name: str,
                          vectors: np.ndarray) -> None:
        """Register an embedding column (f32[n, d]) on a node table; the
        index catalog builds HNSW indexes over these (CREATE_HNSW_INDEX's
        first argument pair)."""
        vectors = np.asarray(vectors, dtype=np.float32)
        if vectors.ndim != 2:
            raise ValueError(f"vector column {name}: expected [n, d], "
                             f"got shape {vectors.shape}")
        self.nodes[table].add_column(name, vectors)

    def node(self, name: str) -> NodeTable:
        return self.nodes[name]

    def rel(self, name: str) -> RelTable:
        return self.rels[name]
