"""Columnar graph store -- the GDBMS substrate the index is native to.

Mirrors the parts of Kuzu that NaviX leverages (paper Section 2.3):
node tables are columnar property vectors; relationship tables are CSR
structures (forward + backward); the vector index's lower level is itself
stored as a relationship table (fixed-degree adjacency in device memory +
a CSR view here). Selection subqueries (repro.query) run against this store
and emit node semimasks.

Host-side state is numpy (this is the "disk" side); device payloads
(vector columns) are materialized to jax arrays on demand.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np


@dataclasses.dataclass
class NodeTable:
    name: str
    n: int
    columns: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    def add_column(self, name: str, values: np.ndarray) -> None:
        values = np.asarray(values)
        if values.shape[0] != self.n:
            raise ValueError(f"column {name}: {values.shape[0]} rows != {self.n}")
        self.columns[name] = values

    def column(self, name: str) -> np.ndarray:
        return self.columns[name]

    def rows(self, ids: np.ndarray,
             columns: Sequence[str] | None = None) -> dict[str, np.ndarray]:
        """Gather property values at ``ids`` (projection after a kNN).

        ``ids`` may carry -1 padding (unreachable result slots); padded
        positions return the row-0 value -- callers mask on ``ids >= 0``.
        """
        ids = np.asarray(ids)
        take = np.maximum(ids, 0)
        names = list(columns) if columns is not None else list(self.columns)
        return {c: self.columns[c][take] for c in names}


@dataclasses.dataclass
class ExactTier:
    """Host-side float32 exact re-rank tier over a vector column.

    The memory-hierarchy counterpart of the int8-resident engine
    (``repro.core.quantize.QuantizedStore``): device HBM holds codes +
    scales + graph only, and the full-precision rows live here -- a plain
    ndarray or an ``np.memmap`` (the paper's disk-resident regime; DiskANN
    keeps compressed vectors in memory and exact vectors on disk the same
    way). ``rerank_many`` gathers only the final beam's rows, so a search
    touches O(B * efs) f32 rows host-side, never the whole store.

    Distance forms mirror ``repro.core.distances.point_dist``
    (smaller-is-closer; cos assumes rows were normalized at ingest).
    """

    vectors: np.ndarray      # f32[n, d]; ndarray or np.memmap
    metric: str = "l2"

    @classmethod
    def build(cls, vectors: np.ndarray, metric: str = "l2",
              mmap_path=None) -> "ExactTier":
        """Materialize a tier from f32 rows; ``mmap_path`` spills them to
        a file and reopens the map read-only (the "disk" side)."""
        vectors = np.ascontiguousarray(vectors, dtype=np.float32)
        if mmap_path is None:
            return cls(vectors=vectors, metric=metric)
        mm = np.memmap(mmap_path, dtype=np.float32, mode="w+",
                       shape=vectors.shape)
        mm[:] = vectors
        mm.flush()
        ro = np.memmap(mmap_path, dtype=np.float32, mode="r",
                       shape=vectors.shape)
        return cls(vectors=ro, metric=metric)

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def is_mmapped(self) -> bool:
        return isinstance(self.vectors, np.memmap)

    def nbytes(self) -> int:
        """Host/disk bytes of the tier (NOT device-resident)."""
        return int(self.vectors.size) * 4

    def rerank_many(self, Q: np.ndarray, ids: np.ndarray, k: int
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Exact re-rank of per-lane candidate beams, entirely host-side.

        ``Q`` f32[b, d] (prepped queries), ``ids`` int[b, w] with ``-1``
        padding -> ``(dists[b, k], ids[b, k])`` ascending by exact
        distance. Padded ids never surface (-1 in, -1 out) and duplicate
        ids count once (repeats after the first occurrence are dropped
        before ranking). Ties keep beam order (stable sort), so lane b of
        a batch is exactly :meth:`rerank` on row b.
        """
        Q = np.asarray(Q, dtype=np.float32)
        ids = np.asarray(ids)
        b, w = ids.shape
        # dedupe keep-first: id equal to an EARLIER slot's id -> -1
        earlier = np.tril(np.ones((w, w), dtype=bool), -1)
        dup = ((ids[:, :, None] == ids[:, None, :]) & earlier).any(-1) \
            & (ids >= 0)
        ids = np.where(dup, -1, ids)
        rows = self.vectors[np.maximum(ids, 0)]          # [b, w, d] gather
        if self.metric == "l2":
            diff = rows - Q[:, None, :]
            d = np.sum(diff * diff, axis=-1)
        elif self.metric == "cos":
            d = 1.0 - np.sum(rows * Q[:, None, :], axis=-1)
        elif self.metric == "dot":
            d = -np.sum(rows * Q[:, None, :], axis=-1)
        else:
            raise ValueError(self.metric)
        d = np.where(ids >= 0, d, np.inf).astype(np.float32)
        order = np.argsort(d, axis=1, kind="stable")[:, :k]
        out_d = np.take_along_axis(d, order, axis=1)
        out_i = np.where(np.isfinite(out_d),
                         np.take_along_axis(ids, order, axis=1), -1)
        if k > w:                                        # pad short beams
            pad = k - w
            out_d = np.concatenate(
                [out_d, np.full((b, pad), np.inf, np.float32)], axis=1)
            out_i = np.concatenate(
                [out_i, np.full((b, pad), -1, out_i.dtype)], axis=1)
        return out_d, out_i.astype(np.int32)

    def rerank(self, q: np.ndarray, ids: np.ndarray, k: int
               ) -> tuple[np.ndarray, np.ndarray]:
        """Single-query exact re-rank: trivially lane 0 of
        :meth:`rerank_many` (the single/batched equivalence is by
        construction, not by parallel implementations)."""
        d, i = self.rerank_many(np.asarray(q)[None], np.asarray(ids)[None],
                                k)
        return d[0], i[0]


@dataclasses.dataclass
class CSR:
    offsets: np.ndarray      # int64[n_src + 1]
    targets: np.ndarray      # int64[n_edges]

    @property
    def n_src(self) -> int:
        return len(self.offsets) - 1

    @property
    def n_edges(self) -> int:
        return len(self.targets)

    def neighbors(self, u: int) -> np.ndarray:
        return self.targets[self.offsets[u]:self.offsets[u + 1]]

    def degrees(self) -> np.ndarray:
        return np.diff(self.offsets)


def csr_from_edges(src: np.ndarray, dst: np.ndarray, n_src: int) -> CSR:
    order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst[order]
    counts = np.bincount(src_s, minlength=n_src)
    offsets = np.zeros(n_src + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return CSR(offsets=offsets, targets=dst_s.astype(np.int64))


@dataclasses.dataclass
class RelTable:
    name: str
    src_table: str
    dst_table: str
    fwd: CSR                 # src -> dst
    bwd: CSR                 # dst -> src

    @property
    def n_edges(self) -> int:
        return self.fwd.n_edges


@dataclasses.dataclass
class GraphStore:
    nodes: dict[str, NodeTable] = dataclasses.field(default_factory=dict)
    rels: dict[str, RelTable] = dataclasses.field(default_factory=dict)

    def add_node_table(self, name: str, n: int,
                       columns: Mapping[str, np.ndarray] | None = None) -> NodeTable:
        t = NodeTable(name=name, n=n)
        for cname, col in (columns or {}).items():
            t.add_column(cname, col)
        self.nodes[name] = t
        return t

    def add_rel_table(self, name: str, src_table: str, dst_table: str,
                      src: np.ndarray, dst: np.ndarray) -> RelTable:
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        n_src = self.nodes[src_table].n
        n_dst = self.nodes[dst_table].n
        if src.size and (src.max() >= n_src or dst.max() >= n_dst):
            raise ValueError(f"rel {name}: edge endpoint out of range")
        rel = RelTable(name=name, src_table=src_table, dst_table=dst_table,
                       fwd=csr_from_edges(src, dst, n_src),
                       bwd=csr_from_edges(dst, src, n_dst))
        self.rels[name] = rel
        return rel

    def add_vector_column(self, table: str, name: str,
                          vectors: np.ndarray) -> None:
        """Register an embedding column (f32[n, d]) on a node table; the
        index catalog builds HNSW indexes over these (CREATE_HNSW_INDEX's
        first argument pair)."""
        vectors = np.asarray(vectors, dtype=np.float32)
        if vectors.ndim != 2:
            raise ValueError(f"vector column {name}: expected [n, d], "
                             f"got shape {vectors.shape}")
        self.nodes[table].add_column(name, vectors)

    def node(self, name: str) -> NodeTable:
        return self.nodes[name]

    def rel(self, name: str) -> RelTable:
        return self.rels[name]
