"""Family-dispatch model API: init / loss / step functions + input specs.

Everything the launcher, dry-run and tests need to drive any of the 10
assigned architectures uniformly:

    api = model_api(arch.config)
    params = api.init(key)                       (or jax.eval_shape for dry-run)
    step = make_train_step(cfg)                  (params, opt, batch) -> ...
    specs = input_specs(cfg, shape)              ShapeDtypeStructs per cell
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.config.base import (GNNConfig, LMConfig, RecsysConfig,
                               ShapeSpec)
from repro.models import gnn, recsys, transformer
from repro.training.optimizer import make_optimizer


class ModelAPI(NamedTuple):
    init: Callable
    loss: Callable                       # (params, batch) -> (loss, metrics)
    family: str


def model_api(cfg) -> ModelAPI:
    if isinstance(cfg, LMConfig):
        return ModelAPI(init=functools.partial(transformer.init_lm, cfg),
                        loss=functools.partial(transformer.lm_loss, cfg),
                        family="lm")
    if isinstance(cfg, GNNConfig):
        return ModelAPI(init=functools.partial(gnn.init_gnn, cfg),
                        loss=functools.partial(gnn.gnn_loss, cfg),
                        family="gnn")
    if isinstance(cfg, RecsysConfig):
        return ModelAPI(init=functools.partial(recsys.init_recsys, cfg),
                        loss=functools.partial(recsys.recsys_loss, cfg),
                        family="recsys")
    raise TypeError(type(cfg))


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg, lr: float | None = None):
    api = model_api(cfg)
    opt = make_optimizer(getattr(cfg, "optimizer", "adamw"), lr)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            api.loss, has_aux=True)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, metrics

    return train_step, opt


def make_eval_step(cfg):
    api = model_api(cfg)

    def eval_step(params, batch):
        return api.loss(params, batch)[1]

    return eval_step


def make_decode_step(cfg: LMConfig):
    def decode(params, cache, token):
        return transformer.decode_step(cfg, params, cache, token)
    return decode


def make_prefill_step(cfg: LMConfig):
    def pre(params, tokens):
        return transformer.prefill(cfg, params, tokens)
    return pre


def make_serve_step(cfg: RecsysConfig):
    def serve(params, batch):
        return recsys.recsys_forward(cfg, params, batch)
    return serve


def make_retrieval_step(cfg: RecsysConfig, k: int = 100):
    def retrieve(params, batch):
        scores = recsys.retrieval_scores(cfg, params, batch)
        vals, idx = jax.lax.top_k(scores, k)
        return vals, jnp.take(batch["candidates"], idx)
    return retrieve


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

_i32 = jnp.int32
_f32 = jnp.float32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _pad512(x: int) -> int:
    """Pad flat node/edge counts to a multiple of 512 so every mesh axis
    combination divides them (padding is -1-masked in the model)."""
    return -(-x // 512) * 512


def _gnn_block_sizes(shape: ShapeSpec) -> tuple[int, int]:
    """(n_nodes_pad, n_edges_pad) for each GNN shape kind."""
    if shape.kind == "graph_full":
        return _pad512(shape["n_nodes"]), _pad512(shape["n_edges"])
    if shape.kind == "graph_minibatch":
        b = shape["batch_nodes"]
        f1, f2 = shape.get("fanout1", 15), shape.get("fanout2", 10)
        n = b * (1 + f1 + f1 * f2)
        e = b * (f1 + f1 * f2)
        return _pad512(n), _pad512(e)
    if shape.kind == "graph_batched":
        g = shape["batch"]
        return _pad512(g * shape["n_nodes"]), _pad512(g * shape["n_edges"])
    raise ValueError(shape.kind)


def resolve_config(cfg, shape: ShapeSpec):
    """Shape-dependent config fields (GNN input feature width comes from
    the dataset, i.e. the shape)."""
    import dataclasses
    if isinstance(cfg, GNNConfig):
        return dataclasses.replace(
            cfg, in_node_dim=shape.get("d_feat", cfg.in_node_dim))
    return cfg


def input_specs(cfg, shape: ShapeSpec) -> dict[str, Any]:
    """Step-input ShapeDtypeStructs for one (arch, shape) cell.

    For decode shapes the dict includes the KV cache spec; the dry-run
    treats every entry as a step input.
    """
    if isinstance(cfg, LMConfig):
        if shape.kind == "train":
            return {"tokens": _sds((shape["global_batch"], shape["seq_len"]),
                                   _i32)}
        if shape.kind == "prefill":
            return {"tokens": _sds((shape["global_batch"], shape["seq_len"]),
                                   _i32)}
        if shape.kind == "decode":
            b, s = shape["global_batch"], shape["seq_len"]
            cdt = jnp.dtype(cfg.compute_dtype)
            kv_shape = (cfg.n_layers, b, s, cfg.n_kv_heads, cfg.head_dim)
            return {
                "cache": transformer.KVCache(
                    k=_sds(kv_shape, cdt), v=_sds(kv_shape, cdt),
                    length=_sds((), _i32)),
                "token": _sds((b,), _i32),
            }
        raise ValueError(f"LM has no shape kind {shape.kind}")

    if isinstance(cfg, GNNConfig):
        n, e = _gnn_block_sizes(shape)
        d_feat = shape.get("d_feat", cfg.in_node_dim)
        return {
            "node_feats": _sds((n, d_feat), _f32),
            "edge_src": _sds((e,), _i32),
            "edge_dst": _sds((e,), _i32),
            "edge_feats": _sds((e, cfg.in_edge_dim), _f32),
            "node_targets": _sds((n, cfg.out_dim), _f32),
            "node_mask": _sds((n,), jnp.bool_),
        }

    if isinstance(cfg, RecsysConfig):
        hot = max(cfg.multi_hot_sizes) if cfg.multi_hot_sizes else 1
        b = shape.get("batch", 1)
        base = {
            "dense": _sds((b, cfg.n_dense), _f32),
            "sparse": _sds((b, cfg.n_sparse, hot), _i32),
        }
        if cfg.seq_len:
            base["seq"] = _sds((b, cfg.seq_len), _i32)
            base["target_item"] = _sds((b,), _i32)
        if shape.kind == "recsys_train":
            base["labels"] = _sds((b,), _f32)
        if shape.kind == "recsys_retrieval":
            base["candidates"] = _sds((shape["n_candidates"],), _i32)
        return base

    raise TypeError(type(cfg))


def abstract_params(cfg) -> Any:
    """Parameter ShapeDtypeStructs without allocating (for lowering)."""
    api = model_api(cfg)
    return jax.eval_shape(api.init, jax.random.key(0))


def abstract_opt_state(cfg, params_spec) -> Any:
    opt = make_optimizer(getattr(cfg, "optimizer", "adamw"))
    return jax.eval_shape(opt.init, params_spec)
