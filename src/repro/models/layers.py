"""Shared neural-net layers (functional, param-pytree style).

No external NN library: params are plain dict pytrees, layers are
(init, apply) function pairs. Layer params for transformer stacks carry a
leading ``L`` axis and are consumed with ``lax.scan`` so the lowered HLO
stays compact even for 61-layer trillion-parameter configs.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

Params = Any


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * s).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(dim, dtype, layers=None):
    shape = (dim,) if layers is None else (layers, dim)
    return {"scale": jnp.zeros(shape, dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    """RMSNorm with (1 + scale) parameterization (gemma convention)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def layernorm_init(dim, dtype, layers=None):
    shape = (dim,) if layers is None else (layers, dim)
    return {"scale": jnp.ones(shape, dtype=dtype),
            "bias": jnp.zeros(shape, dtype=dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    ang = ang[..., :, None, :]                                # [..., S, 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_mask(q_pos: jax.Array, kv_pos: jax.Array, *, causal: bool,
                   window) -> jax.Array:
    """[..., Sq, Skv] boolean. ``window`` may be a traced scalar (layers
    with unrestricted attention pass a huge sentinel)."""
    diff = q_pos[..., :, None] - kv_pos[..., None, :]
    mask = diff < window
    if causal:
        mask &= diff >= 0
    return mask


def mha(q, k, v, mask, *, logit_cap: float = 0.0, scale: float | None = None):
    """q: [B,Sq,H,hd], k/v: [B,Skv,KV,hd] (GQA: H = KV * groups).
    mask: bool [B, Sq, Skv] (broadcast over heads)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    qg = q.reshape(b, sq, kvh, groups, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, logit_cap)
    logits = jnp.where(mask[:, None, None, :, :],
                       logits.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(b, sq, h, hd)


def chunked_mha(q, k, v, q_pos, kv_pos, *, causal: bool, window,
                logit_cap: float = 0.0, chunk: int = 512,
                scale: float | None = None):
    """Online-softmax (flash-style) attention over KV chunks.

    Memory: O(Sq * chunk) scores instead of O(Sq * Skv); used for long
    prefill where materializing [Sq, Skv] would not fit. Pure JAX (the TPU
    kernel schedule is the same loop; XLA pipelines the chunk scan).
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    groups = h // kvh
    scale_ = scale if scale is not None else 1.0 / np.sqrt(hd)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    qg = (q.reshape(b, sq, kvh, groups, hd) * scale_)

    def step(carry, xs):
        m, num, den = carry
        kc, vc, pc = xs                       # [b,chunk,kvh,hd], [b,chunk]
        logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, kc,
                            preferred_element_type=jnp.float32)
        logits = softcap(logits, logit_cap)
        valid = pc[:, None, None, None, :] >= 0
        diff = q_pos[:, None, None, :, None] - pc[:, None, None, None, :]
        mask = valid & (diff < window)
        if causal:
            mask &= diff >= 0
        logits = jnp.where(mask, logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(kc.dtype), vc,
                        preferred_element_type=jnp.float32)
        num = num * alpha[..., None] + pv.astype(jnp.float32)
        den = den * alpha + p.sum(axis=-1)
        return (m_new, num, den), None

    kc = k.reshape(b, n_chunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    m0 = jnp.full((b, kvh, groups, sq), -jnp.inf, jnp.float32)
    num0 = jnp.zeros((b, kvh, groups, sq, hd), jnp.float32)
    den0 = jnp.zeros((b, kvh, groups, sq), jnp.float32)
    # checkpoint each chunk step: the backward recomputes the [.., sq, ck]
    # probability matrices instead of the scan-transpose stacking them for
    # every chunk (the flash-attention backward; perf_log it-7)
    step_ckpt = jax.checkpoint(
        step, policy=jax.checkpoint_policies.nothing_saveable)
    (m, num, den), _ = lax.scan(step_ckpt, (m0, num0, den0), (kc, vc, pc))
    out = (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def gated_mlp_init(key, d, f, dtype, layers=None, activation="swiglu"):
    pre = () if layers is None else (layers,)
    k1, k2 = jax.random.split(key)
    return {
        "wi": dense_init(k1, pre + (d, 2 * f), dtype),
        "wo": dense_init(k2, pre + (f, d), dtype),
    }


def gated_mlp(params, x, activation: str = "swiglu"):
    from repro.distributed.autoshard import constrain
    gate_up = jnp.einsum("...d,df->...f", x, params["wi"])
    if gate_up.ndim == 3:
        gate_up = constrain(gate_up, "dp", None, "tp")
    gate, up = jnp.split(gate_up, 2, axis=-1)
    if activation == "swiglu":
        act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    elif activation == "geglu":
        act = jax.nn.gelu(gate.astype(jnp.float32), approximate=True).astype(x.dtype)
    else:
        raise ValueError(activation)
    return jnp.einsum("...f,fd->...d", act * up, params["wo"])


def mlp_stack_init(key, dims, dtype, bias=True):
    """Plain MLP: dims = [in, h1, ..., out]."""
    ks = jax.random.split(key, len(dims) - 1)
    layers = []
    for i, k in enumerate(ks):
        p = {"w": dense_init(k, (dims[i], dims[i + 1]), dtype)}
        if bias:
            p["b"] = jnp.zeros((dims[i + 1],), dtype)
        layers.append(p)
    return {"layers": tuple(layers)}


def mlp_stack(params, x, act=jax.nn.relu, final_act=False):
    n = len(params["layers"])
    for i, p in enumerate(params["layers"]):
        x = x @ p["w"]
        if "b" in p:
            x = x + p["b"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# embedding bags (RecSys substrate: JAX has no nn.EmbeddingBag)
# ---------------------------------------------------------------------------


def embedding_bag(table: jax.Array, ids: jax.Array, mode: str = "sum"):
    """table [V, D]; ids [B, hot] with -1 padding -> [B, D].

    Implemented as gather + segment-reduce (taxonomy B.6): the flattened
    lookup reduces by bag id. On TPU the same contract is served by the
    csr_segment_sum kernel for large bags.
    """
    b, hot = ids.shape
    flat = ids.reshape(-1)
    rows = jnp.where((flat >= 0)[:, None],
                     jnp.take(table, jnp.maximum(flat, 0), axis=0), 0)
    seg = jnp.repeat(jnp.arange(b), hot)
    out = jax.ops.segment_sum(rows, seg, num_segments=b)
    if mode == "mean":
        cnt = jax.ops.segment_sum((flat >= 0).astype(rows.dtype), seg,
                                  num_segments=b)
        out = out / jnp.maximum(cnt, 1)[:, None]
    return out


def embedding_lookup(table: jax.Array, ids: jax.Array):
    """Single-hot lookup with -1 -> zeros."""
    safe = jnp.maximum(ids, 0)
    out = jnp.take(table, safe, axis=0)
    return jnp.where((ids >= 0)[..., None], out, 0)
