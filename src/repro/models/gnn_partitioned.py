"""Halo-partitioned message passing (hillclimb #2, beyond-paper).

Baseline edge-parallel message passing replicates node states and
all-reduces the full [N, d_hidden] aggregate every layer -- collective
bytes scale with N regardless of partition quality. Mesh-like graphs
(MeshGraphNet's native domain) partition with small boundaries, so the
production layout is owner-computes:

  * nodes are split into P partitions (one per chip across every mesh
    axis); each chip owns its nodes' states and all edges whose dst it
    owns;
  * per layer, each chip sends only the boundary ("halo") rows its
    neighbors need: send buffer [P, S, d] -> all_to_all -> received halo;
    comm per layer = P*S*d per chip instead of N*d.

Shapes are uniform (S = halo slots per partition pair, -1 padded), so the
same program serves any partitioning; partition quality only changes S.
For a 2D mesh graph S/n_local ~ 4/sqrt(n_local) (boundary/area); the
dry-run uses halo_per_pair from the config. Host-side partitioning for
real runs lives in repro/data/graph_partition.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.config.base import GNNConfig
from repro.models.gnn import _mlp


def partitioned_input_specs(cfg: GNNConfig, shape, n_parts: int,
                            halo_per_pair: int = 16) -> dict:
    """ShapeDtypeStructs for the partitioned layout (leading P dim)."""
    from repro.models.api import _gnn_block_sizes
    n, e = _gnn_block_sizes(shape)
    nl = -(-n // n_parts)
    el = -(-e // n_parts)
    d_feat = shape.get("d_feat", cfg.in_node_dim)
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    return {
        "node_feats": sds((n_parts, nl, d_feat), f32),
        "edge_src": sds((n_parts, el), i32),     # 0..nl+P*S-1 (ext index)
        "edge_dst": sds((n_parts, el), i32),     # 0..nl-1, -1 pad
        "edge_feats": sds((n_parts, el, cfg.in_edge_dim), f32),
        "send_idx": sds((n_parts, n_parts, halo_per_pair), i32),
        "node_targets": sds((n_parts, nl, cfg.out_dim), f32),
        "node_mask": sds((n_parts, nl), jnp.bool_),
    }


def partitioned_loss(cfg: GNNConfig, mesh: Mesh):
    """Returns loss_fn(params, batch) running owner-computes message
    passing under shard_map over every mesh axis."""
    axes = tuple(mesh.axis_names)

    def local(params, nf, es, ed, ef, send_idx, targets, mask):
        # local views: [1, nl, ...] -> squeeze the partition dim
        nf, es, ed, ef = nf[0], es[0], ed[0], ef[0]
        send_idx, targets, mask = send_idx[0], targets[0], mask[0]
        nl = nf.shape[0]
        cdt = jnp.dtype(cfg.compute_dtype)
        e_ok = (ed >= 0)
        d_safe = jnp.where(e_ok, ed, nl)

        h = _mlp(params["node_enc"], nf.astype(cdt))
        e = _mlp(params["edge_enc"], ef.astype(cdt))

        def block(carry, p):
            h, e = carry
            # ---- halo exchange: send my boundary rows to each peer ----
            send = jnp.where((send_idx >= 0)[..., None],
                             h[jnp.maximum(send_idx, 0)], 0)  # [P, S, dh]
            recv = lax.all_to_all(send, axes, split_axis=0, concat_axis=0,
                                  tiled=False)                # [P, S, dh]
            h_ext = jnp.concatenate([h, recv.reshape(-1, h.shape[-1])], 0)
            msg_in = jnp.concatenate(
                [e, h_ext[jnp.maximum(es, 0)],
                 h[jnp.maximum(ed, 0)]], axis=-1)
            e = e + _mlp(p["edge_mlp"], msg_in)
            agg = jax.ops.segment_sum(jnp.where(e_ok[:, None], e, 0),
                                      d_safe, num_segments=nl + 1)[:nl]
            h = h + _mlp(p["node_mlp"],
                         jnp.concatenate([h, agg.astype(cdt)], axis=-1))
            return (h, e), None

        blocks = {"edge_mlp": params["edge_mlp"],
                  "node_mlp": params["node_mlp"]}
        step = block
        if cfg.remat:
            step = jax.checkpoint(
                block, policy=jax.checkpoint_policies.nothing_saveable)
        (h, e), _ = lax.scan(step, (h, e), blocks)
        pred = _mlp(params["decoder"], h).astype(jnp.float32)
        w = mask.astype(jnp.float32)[:, None]
        se = ((pred - targets.astype(jnp.float32)) ** 2 * w).sum()
        cnt = w.sum() * pred.shape[-1]
        # global mean across partitions
        se = lax.psum(se, axes)
        cnt = lax.psum(cnt, axes)
        return se / jnp.maximum(cnt, 1.0)

    pd = P(axes)

    def loss_fn(params, batch):
        loss = jax.shard_map(
            local, mesh=mesh,
            in_specs=(P(),
                      P(axes, None, None), P(axes, None), P(axes, None),
                      P(axes, None, None), P(axes, None, None),
                      P(axes, None, None), P(axes, None)),
            out_specs=P(),
            check_vma=False,
        )(params, batch["node_feats"], batch["edge_src"], batch["edge_dst"],
          batch["edge_feats"], batch["send_idx"], batch["node_targets"],
          batch["node_mask"])
        return loss, {"loss": loss}

    return loss_fn
