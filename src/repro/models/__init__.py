"""Embedding models (GNN, transformer, recsys) producing vectors."""
