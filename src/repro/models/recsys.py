"""RecSys ranking models: Wide&Deep, DeepFM, DIEN, BST.

Shared substrate: huge per-field embedding tables (row-sharded over the
mesh in production, see repro.distributed.sharding) + EmbeddingBag for
multi-hot fields (gather + segment-sum -- JAX has no nn.EmbeddingBag; this
is the same primitive as the csr_segment_sum kernel), a feature-interaction
op per model, and a small MLP tower:

  wide-deep  interaction = concat  (+ linear "wide" path over sparse ids)
  deepfm     interaction = FM: 0.5 * ((sum v)^2 - sum v^2)
  dien       interaction = GRU over behavior seq + AUGRU attention to target
  bst        interaction = transformer block over [behavior seq; target]

Batches: {"dense": f32[B, n_dense], "sparse": int32[B, n_sparse, hot]
(-1 pad), "seq": int32[B, T] (dien/bst), "target_item": int32[B],
"labels": f32[B]} -- CTR binary target.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.config.base import RecsysConfig
from repro.distributed.autoshard import constrain
from repro.models import layers as L


def _field_tables(cfg: RecsysConfig, key, dim) -> tuple:
    ks = jax.random.split(key, cfg.n_sparse)
    dt = jnp.dtype(cfg.param_dtype)
    return tuple(L.embed_init(ks[i], (cfg.field_vocabs[i], dim), dt)
                 for i in range(cfg.n_sparse))


def init_recsys(cfg: RecsysConfig, key: jax.Array) -> Any:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.embed_dim
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {"tables": _field_tables(cfg, keys[0], d)}

    mlp_in = cfg.n_sparse * d + cfg.n_dense
    if cfg.model == "wide_deep":
        params["wide"] = _field_tables(cfg, keys[1], 1)
        params["wide_dense"] = L.dense_init(keys[2], (cfg.n_dense, 1), dt)
    elif cfg.model == "deepfm":
        params["fm_linear"] = _field_tables(cfg, keys[1], 1)
    elif cfg.model == "dien":
        params["item_table"] = L.embed_init(keys[1], (cfg.item_vocab, d), dt)
        g = cfg.gru_dim
        params["gru"] = _gru_init(keys[2], d, g, dt)
        params["augru"] = _gru_init(keys[3], g, g, dt)
        params["attn"] = L.dense_init(keys[4], (g + d, 1), dt)
        mlp_in += g + d
    elif cfg.model == "bst":
        params["item_table"] = L.embed_init(keys[1], (cfg.item_vocab, d), dt)
        params["pos_embed"] = L.embed_init(keys[2], (cfg.seq_len + 1, d), dt)
        hd = d // cfg.n_heads
        k = jax.random.split(keys[3], 4)
        params["blocks"] = {
            "wq": L.dense_init(k[0], (cfg.n_blocks, d, d), dt),
            "wk": L.dense_init(k[1], (cfg.n_blocks, d, d), dt),
            "wv": L.dense_init(k[2], (cfg.n_blocks, d, d), dt),
            "wo": L.dense_init(k[3], (cfg.n_blocks, d, d), dt),
            "ln1": L.layernorm_init(d, dt, layers=cfg.n_blocks),
            "ffn": L.gated_mlp_init(keys[5], d, 4 * d, dt, layers=cfg.n_blocks),
            "ln2": L.layernorm_init(d, dt, layers=cfg.n_blocks),
        }
        mlp_in += (cfg.seq_len + 1) * d
    else:
        raise ValueError(cfg.model)

    dims = [mlp_in] + list(cfg.mlp_dims) + [1]
    params["mlp"] = L.mlp_stack_init(keys[6], dims, dt)
    return params


def _gru_init(key, d_in, d_h, dt):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wx": L.dense_init(k1, (d_in, 3 * d_h), dt),
            "wh": L.dense_init(k2, (d_h, 3 * d_h), dt),
            "b": jnp.zeros((3 * d_h,), dt)}


def _gru_cell(p, h, x, att=None):
    """Standard GRU; ``att`` (AUGRU) scales the update gate by the
    attention score (DIEN's attentional update gate)."""
    gx = x @ p["wx"] + p["b"]
    gh = h @ p["wh"]
    rx, zx, nx = jnp.split(gx, 3, axis=-1)
    rh, zh, nh = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    if att is not None:
        z = z * att[:, None]
    return (1.0 - z) * n + z * h


def _sparse_embeddings(cfg: RecsysConfig, tables, sparse) -> jax.Array:
    """sparse int32[B, F, hot] -> [B, F, D] via per-field EmbeddingBag."""
    outs = []
    for f in range(cfg.n_sparse):
        hot = cfg.multi_hot_sizes[f] if cfg.multi_hot_sizes else 1
        ids = sparse[:, f, :hot]
        if hot == 1:
            outs.append(L.embedding_lookup(tables[f], ids[:, 0]))
        else:
            outs.append(L.embedding_bag(tables[f], ids, mode="sum"))
    return jnp.stack(outs, axis=1)


def recsys_forward(cfg: RecsysConfig, params, batch) -> jax.Array:
    """-> CTR logits f32[B]."""
    cdt = jnp.dtype(cfg.compute_dtype)
    dense = batch["dense"].astype(cdt)
    sparse = batch["sparse"]
    b = dense.shape[0]
    emb = constrain(_sparse_embeddings(cfg, params["tables"], sparse),
                    "dp", None, None).astype(cdt)
    flat = emb.reshape(b, -1)
    feats = [flat, dense]
    extra_logit = 0.0

    if cfg.model == "wide_deep":
        wide = _sparse_embeddings(cfg, params["wide"], sparse)  # [B, F, 1]
        extra_logit = (wide.sum(axis=(1, 2)) +
                       (dense @ params["wide_dense"].astype(cdt))[:, 0])
    elif cfg.model == "deepfm":
        sum_v = emb.sum(axis=1)
        fm = 0.5 * (sum_v * sum_v - (emb * emb).sum(axis=1)).sum(axis=-1)
        lin = _sparse_embeddings(cfg, params["fm_linear"], sparse)
        extra_logit = fm + lin.sum(axis=(1, 2))
    elif cfg.model == "dien":
        seq = batch["seq"]                                    # [B, T]
        tgt = batch["target_item"]                            # [B]
        xe = L.embedding_lookup(params["item_table"], seq).astype(cdt)
        te = L.embedding_lookup(params["item_table"], tgt).astype(cdt)
        g = cfg.gru_dim

        def step1(h, x):
            h = _gru_cell(params["gru"], h, x).astype(cdt)
            return h, h
        h0 = jnp.zeros((b, g), cdt)
        _, hs = lax.scan(step1, h0, xe.transpose(1, 0, 2))    # [T, B, g]

        att_in = jnp.concatenate(
            [hs, jnp.broadcast_to(te[None], (hs.shape[0], b, te.shape[-1]))],
            axis=-1)
        scores = jax.nn.softmax(
            (att_in @ params["attn"].astype(cdt))[..., 0], axis=0)  # [T, B]

        def step2(h, xs):
            x, a = xs
            h = _gru_cell(params["augru"], h, x, att=a).astype(cdt)
            return h, None
        hT, _ = lax.scan(step2, jnp.zeros((b, g), cdt), (hs, scores))
        feats += [hT, te]
    elif cfg.model == "bst":
        seq = batch["seq"]
        tgt = batch["target_item"]
        xe = L.embedding_lookup(params["item_table"],
                                jnp.concatenate([seq, tgt[:, None]], axis=1))
        t1 = cfg.seq_len + 1
        x = xe.astype(cdt) + params["pos_embed"][None, :t1].astype(cdt)
        hd = cfg.embed_dim // cfg.n_heads
        mask = jnp.ones((b, t1, t1), bool)

        def block(x, p):
            h = L.layernorm(p["ln1"], x)
            q = (h @ p["wq"]).reshape(b, t1, cfg.n_heads, hd)
            k = (h @ p["wk"]).reshape(b, t1, cfg.n_heads, hd)
            v = (h @ p["wv"]).reshape(b, t1, cfg.n_heads, hd)
            a = (L.mha(q, k, v, mask).reshape(b, t1, -1) @ p["wo"]).astype(cdt)
            x = x + a
            h = L.layernorm(p["ln2"], x)
            return x + L.gated_mlp(p["ffn"], h, "swiglu").astype(cdt), None

        x, _ = lax.scan(block, x, params["blocks"])
        feats += [x.reshape(b, -1)]

    z = constrain(jnp.concatenate(feats, axis=-1), "dp", None)
    logit = L.mlp_stack(params["mlp"], z)[:, 0]
    return (logit + extra_logit).astype(jnp.float32)


def recsys_loss(cfg: RecsysConfig, params, batch) -> tuple[jax.Array, dict]:
    logits = recsys_forward(cfg, params, batch)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, {"loss": loss}


def retrieval_scores(cfg: RecsysConfig, params, batch) -> jax.Array:
    """retrieval_cand: score one user query against n_candidates items.

    The query tower reuses the ranking features to produce a query embedding
    in item space; scoring = max-inner-product over the candidate item
    embeddings -- the NaviX brute-force / distance-kernel path
    (repro.kernels.ops.distance_matrix with metric="dot") followed by top-k.
    """
    from repro.kernels import ops
    cdt = jnp.dtype(cfg.compute_dtype)
    cand = batch["candidates"]                     # int32[n_cand]
    table = params.get("item_table", params["tables"][0])
    cand_emb = constrain(L.embedding_lookup(table, cand), "tp",
                         None).astype(cdt)
    dense = batch["dense"].astype(cdt)
    emb = _sparse_embeddings(cfg, params["tables"], batch["sparse"])
    q = emb.mean(axis=1).astype(cdt) + 0.0 * dense.sum(axis=-1, keepdims=True)
    d = constrain(ops.distance_matrix(q, cand_emb, metric="dot"),
                  None, "tp")                              # [B, n_cand]
    return -d                                               # similarity
