"""MeshGraphNet (Pfaff et al., arXiv:2010.03409) -- encode-process-decode.

15 processor blocks; per block: edge update MLP(e, h_src, h_dst) then node
update MLP(h, sum of incoming messages), both residual (+LayerNorm). The
aggregation primitive is segment-sum over the edge list -- the substrate
JAX lacks natively and the csr_segment_sum Pallas kernel provides on TPU
(jax.ops.segment_sum elsewhere). Message passing is edge-parallel: edges
shard over the mesh, node states replicate, and the per-layer aggregate is
an (automatic or explicit) all-reduce -- see repro.distributed.sharding.

Graphs are flat tensors: node_feats [N, Fn], edge src/dst int32[E],
edge_feats [E, Fe], with -1 padding for both nodes and edges (batched
small-graph shapes pack G graphs into one flat padded block with offset
edge ids).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.config.base import GNNConfig
from repro.distributed.autoshard import constrain
from repro.models import layers as L


def _mlp_init(key, dims, dtype, layer_norm=True, layers=None):
    """2-hidden-layer MLP (mlp_layers=2) + optional output LayerNorm."""
    pre = () if layers is None else (layers,)
    ks = jax.random.split(key, len(dims))
    p = {"w": tuple(L.dense_init(ks[i], pre + (dims[i], dims[i + 1]), dtype)
                    for i in range(len(dims) - 1)),
         "b": tuple(jnp.zeros(pre + (dims[i + 1],), dtype)
                    for i in range(len(dims) - 1))}
    if layer_norm:
        p["ln"] = {"scale": jnp.ones(pre + (dims[-1],), dtype),
                   "bias": jnp.zeros(pre + (dims[-1],), dtype)}
    return p


def _mlp(p, x, eps=1e-5):
    n = len(p["w"])
    for i in range(n):
        x = x @ p["w"][i] + p["b"][i]
        if i < n - 1:
            x = jax.nn.relu(x)
    if "ln" in p:
        x = L.layernorm(p["ln"], x, eps)
    return x


def init_gnn(cfg: GNNConfig, key: jax.Array) -> Any:
    dt = jnp.dtype(cfg.param_dtype)
    dh = cfg.d_hidden
    hidden = [dh] * cfg.mlp_layers
    k = jax.random.split(key, 5)
    return {
        "node_enc": _mlp_init(k[0], [cfg.in_node_dim] + hidden + [dh], dt),
        "edge_enc": _mlp_init(k[1], [cfg.in_edge_dim] + hidden + [dh], dt),
        # processor blocks are scanned: leading L axis
        "edge_mlp": _mlp_init(k[2], [3 * dh] + hidden + [dh], dt,
                              layers=cfg.n_layers),
        "node_mlp": _mlp_init(k[3], [2 * dh] + hidden + [dh], dt,
                              layers=cfg.n_layers),
        "decoder": _mlp_init(k[4], [dh] + hidden + [cfg.out_dim], dt,
                             layer_norm=False),
    }


def gnn_forward(cfg: GNNConfig, params, batch) -> jax.Array:
    """batch: node_feats [N,Fn], edge_src/dst int32[E] (-1 pad),
    edge_feats [E,Fe]. Returns per-node predictions [N, out_dim]."""
    cdt = jnp.dtype(cfg.compute_dtype)
    nf = batch["node_feats"].astype(cdt)
    ef = batch["edge_feats"].astype(cdt)
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = nf.shape[0]
    e_ok = (src >= 0) & (dst >= 0)
    s_safe = jnp.maximum(src, 0)
    d_safe = jnp.where(e_ok, dst, n)      # padding scatters to the dump row

    h = _mlp(params["node_enc"], nf)
    e = _mlp(params["edge_enc"], ef)

    def block(carry, p):
        h, e = carry
        # node-state carry shards over dp so the 15-layer saved-activation
        # stack stays sharded (edge states inherit the edge-parallel input
        # sharding through the scan)
        h = constrain(h, "dp", None)
        msg_in = jnp.concatenate([e, h[s_safe], h[jnp.maximum(dst, 0)]],
                                 axis=-1)
        e = e + _mlp(p["edge_mlp"], msg_in)
        agg = jax.ops.segment_sum(
            jnp.where(e_ok[:, None], e, 0), d_safe, num_segments=n + 1)[:n]
        if cfg.aggregator == "mean":
            cnt = jax.ops.segment_sum(e_ok.astype(cdt), d_safe,
                                      num_segments=n + 1)[:n]
            agg = agg / jnp.maximum(cnt, 1)[:, None]
        h = h + _mlp(p["node_mlp"],
                     jnp.concatenate([h, agg.astype(cdt)], axis=-1))
        return (h, e), None

    blocks = {"edge_mlp": params["edge_mlp"], "node_mlp": params["node_mlp"]}
    step = block
    if cfg.remat:
        step = jax.checkpoint(block,
                              policy=jax.checkpoint_policies.nothing_saveable)
    (h, e), _ = lax.scan(step, (h, e), blocks)
    return _mlp(params["decoder"], h).astype(jnp.float32)


def gnn_loss(cfg: GNNConfig, params, batch) -> tuple[jax.Array, dict]:
    """MSE on (optionally masked) node targets."""
    pred = gnn_forward(cfg, params, batch)
    tgt = batch["node_targets"].astype(jnp.float32)
    mask = batch.get("node_mask")
    err = (pred - tgt) ** 2
    if mask is not None:
        w = mask.astype(jnp.float32)[:, None]
        loss = (err * w).sum() / jnp.maximum(w.sum() * err.shape[-1], 1.0)
    else:
        loss = err.mean()
    return loss, {"loss": loss}
