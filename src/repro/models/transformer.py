"""Decoder-only transformer LM family.

Covers all five assigned LM architectures from one config surface:
GQA/MQA + RoPE (+ optional QKV bias: qwen1.5), GeGLU/SwiGLU, tied
embeddings with optional sqrt(d) scaling (gemma), alternating
local(sliding-window)/global attention + attn/final logit soft-capping +
sandwich norms (gemma2), and token-choice top-k MoE with shared experts and
capacity-bounded sort-based dispatch (kimi-k2, granite).

Layers are scanned (stacked params, leading L axis) so the lowered HLO is
layer-count independent. Per-layer heterogeneity (local vs global
attention) rides through the scan as a traced per-layer window array.

Three entry points per the shape kinds:
  lm_loss      -- training forward + next-token cross entropy
  prefill      -- build a KV cache from a prompt (chunked flash-style attn)
  decode_step  -- one token with a KV cache of length S (the decode_* and
                  long_* dry-run cells)
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.config.base import LMConfig, MoEConfig
from repro.distributed.autoshard import axis_size, constrain
from repro.models import layers as L

NEG_INF = -1e30
GLOBAL_WINDOW = 1 << 30   # "no window" sentinel for global-attention layers


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_lm(cfg: LMConfig, key: jax.Array) -> Any:
    dt = jnp.dtype(cfg.param_dtype)
    d, h, kv, hd, nl = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                        cfg.head_dim, cfg.n_layers)
    keys = jax.random.split(key, 12)
    attn = {
        "wq": L.dense_init(keys[0], (nl, d, h * hd), dt),
        "wk": L.dense_init(keys[1], (nl, d, kv * hd), dt),
        "wv": L.dense_init(keys[2], (nl, d, kv * hd), dt),
        "wo": L.dense_init(keys[3], (nl, h * hd, d), dt),
    }
    if cfg.qkv_bias:
        attn["bq"] = jnp.zeros((nl, h * hd), dt)
        attn["bk"] = jnp.zeros((nl, kv * hd), dt)
        attn["bv"] = jnp.zeros((nl, kv * hd), dt)

    if cfg.moe is None:
        mlp = L.gated_mlp_init(keys[4], d, cfg.d_ff, dt, layers=nl)
    else:
        e = cfg.moe
        k1, k2, k3, k4 = jax.random.split(keys[4], 4)
        mlp = {
            "router": L.dense_init(k1, (nl, d, e.n_experts), jnp.float32),
            "wi": L.dense_init(k2, (nl, e.n_experts, d, 2 * e.d_ff_expert), dt),
            "wo": L.dense_init(k3, (nl, e.n_experts, e.d_ff_expert, d), dt),
        }
        if e.n_shared_experts:
            mlp["shared"] = L.gated_mlp_init(
                k4, d, e.n_shared_experts * e.d_ff_expert, dt, layers=nl)

    block = {
        "attn_norm": L.rmsnorm_init(d, dt, layers=nl),
        "mlp_norm": L.rmsnorm_init(d, dt, layers=nl),
        "attn": attn,
        "mlp": mlp,
    }
    if cfg.post_norms:
        block["attn_post_norm"] = L.rmsnorm_init(d, dt, layers=nl)
        block["mlp_post_norm"] = L.rmsnorm_init(d, dt, layers=nl)

    params = {
        "embed": L.embed_init(keys[5], (cfg.vocab_size, d), dt),
        "blocks": block,
        "final_norm": L.rmsnorm_init(d, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[6], (d, cfg.vocab_size), dt)
    return params


def layer_windows(cfg: LMConfig) -> jax.Array:
    """Per-layer sliding-window sizes (GLOBAL_WINDOW = unrestricted).

    gemma2 alternates local (even layers, window 4096) and global."""
    if cfg.attn_pattern == "local_global":
        w = [cfg.local_window if i % 2 == 0 else GLOBAL_WINDOW
             for i in range(cfg.n_layers)]
    else:
        w = [GLOBAL_WINDOW] * cfg.n_layers
    return jnp.asarray(w, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# MoE (token-choice top-k, capacity-bounded sort-based dispatch)
# ---------------------------------------------------------------------------


def moe_apply(p, x: jax.Array, moe: MoEConfig, activation: str) -> jax.Array:
    """x: [T, d] -> [T, d]. Token-choice top-k, sort-based dispatch into
    [E, C] slots; tokens beyond capacity are dropped (GShard, cf=1.25).

    Dispatch is *grouped by data shard* (G = dp size): each group routes
    only its local tokens with a per-group capacity, so every dispatch
    tensor keeps a leading dp-sharded dim and dispatch/combine never leave
    the shard. Without grouping, the combine scatter materializes an
    unshardable global [T+1, d] buffer replicated per chip + per-layer
    all-reduce (28 GiB each on kimi; perf_log it-5). Per-group routing is
    exactly the semantics of per-shard expert parallelism in production
    MoE systems.
    """
    t, d = x.shape
    e, k = moe.n_experts, moe.top_k
    g = axis_size("dp")
    if t % g:
        g = 1
    tl = t // g                                  # tokens per group
    cap = int(np.ceil(tl * k / e * moe.capacity_factor))
    cap = max(8, -(-cap // 8) * 8)
    xg = constrain(x.reshape(g, tl, d), "dp", None, None)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    top_vals, top_idx = lax.top_k(logits, k)                 # [G, Tl, k]
    gates = jax.nn.softmax(top_vals, axis=-1)

    e_flat = top_idx.reshape(g, tl * k)
    t_flat = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tl, dtype=jnp.int32), k)[None], (g, tl * k))
    g_flat = gates.reshape(g, tl * k)
    order = jnp.argsort(e_flat, axis=-1)
    se = jnp.take_along_axis(e_flat, order, -1)
    st = jnp.take_along_axis(t_flat, order, -1)
    sg = jnp.take_along_axis(g_flat, order, -1)
    idx = jnp.broadcast_to(jnp.arange(tl * k, dtype=jnp.int32)[None],
                           (g, tl * k))
    newseg = jnp.concatenate(
        [jnp.ones((g, 1), bool), se[:, 1:] != se[:, :-1]], axis=-1)
    seg_first = lax.cummax(jnp.where(newseg, idx, 0), axis=1)
    rank = idx - seg_first
    keep = rank < cap

    def build_tables(se_g, rank_g, keep_g, st_g, sg_g):
        tok = jnp.full((e, cap), -1, jnp.int32).at[
            jnp.where(keep_g, se_g, e), jnp.where(keep_g, rank_g, 0)
        ].set(jnp.where(keep_g, st_g, -1), mode="drop")
        gate = jnp.zeros((e, cap), jnp.float32).at[
            jnp.where(keep_g, se_g, e), jnp.where(keep_g, rank_g, 0)
        ].set(jnp.where(keep_g, sg_g, 0.0), mode="drop")
        return tok, gate

    slot_tok, slot_gate = jax.vmap(build_tables)(se, rank, keep, st, sg)

    # gather: each (dp-group, expert-shard) chip reads from its replicated
    # local xg slice -- no cross-shard movement
    xe = jax.vmap(lambda xl, tok: jnp.where(
        (tok >= 0)[..., None], xl[jnp.maximum(tok, 0)], 0))(xg, slot_tok)
    # experts over model (EP), groups over data. When E doesn't divide the
    # model axis (granite: 40/16), shard capacity over model instead.
    ec = ("dp", "tp") if e % max(axis_size("tp"), 1) == 0 else ("dp", None, "tp")
    spec = (ec + (None,) * (4 - len(ec)))[:3] + (None,)
    xe = constrain(xe, *spec)                                # [G, E, C, d]
    gate_up = constrain(jnp.einsum("gecd,edf->gecf", xe, p["wi"]), *spec)
    gate, up = jnp.split(gate_up, 2, axis=-1)
    if activation == "swiglu":
        act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    else:
        act = jax.nn.gelu(gate.astype(jnp.float32),
                          approximate=True).astype(x.dtype)
    ye = jnp.einsum("gecf,efd->gecd", act * up, p["wo"])     # [G, E, C, d]
    ye = constrain(ye, *spec)
    ye = ye * slot_gate[..., None].astype(ye.dtype)

    out = jax.vmap(lambda y, tok: jnp.zeros((tl + 1, d), y.dtype).at[
        jnp.where(tok >= 0, tok, tl).reshape(-1)
    ].add(y.reshape(-1, d), mode="drop")[:tl])(ye, slot_tok)
    out = constrain(out, "dp", None, None).reshape(t, d)
    if "shared" in p:
        out = out + L.gated_mlp(p["shared"], x, activation)
    return out


# ---------------------------------------------------------------------------
# transformer blocks (scanned)
# ---------------------------------------------------------------------------


def _qkv(cfg: LMConfig, p, x, positions):
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q.reshape(b, s, h, hd), "dp", None, "tp", None)
    k = constrain(k.reshape(b, s, kv, hd), "dp", None, "tp", None)
    v = constrain(v.reshape(b, s, kv, hd), "dp", None, "tp", None)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    return q, k, v


def _block_train(cfg: LMConfig, p, x, positions, window, chunked: bool):
    """One layer, full-sequence causal attention.

    The residual carry is sequence-sharded over the model axis ("sp") so
    the per-layer saved-activation stack is 1/TP the size; q/k/v/mlp
    anchors re-gather the sequence where needed (Megatron-SP layout)."""
    x = constrain(x, "dp", "sp", None)
    h = L.rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    q, k, v = _qkv(cfg, p["attn"], h, positions)
    if chunked:
        attn = L.chunked_mha(q, k, v, positions, positions, causal=True,
                             window=window, logit_cap=cfg.attn_logit_softcap)
    else:
        diff = positions[:, :, None] - positions[:, None, :]
        mask = (diff >= 0) & (diff < window)
        attn = L.mha(q, k, v, mask, logit_cap=cfg.attn_logit_softcap)
    attn = jnp.einsum("bshe,hed->bsd",
                      attn.reshape(*attn.shape[:2], cfg.n_heads, cfg.head_dim),
                      p["attn"]["wo"].reshape(cfg.n_heads, cfg.head_dim, -1))
    if cfg.post_norms:
        attn = L.rmsnorm(p["attn_post_norm"], attn, cfg.norm_eps)
    x = x + attn

    h = L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    if cfg.moe is None:
        m = L.gated_mlp(p["mlp"], h, cfg.activation)
    else:
        b, s, d = h.shape
        m = moe_apply(p["mlp"], h.reshape(b * s, d), cfg.moe,
                      cfg.activation).reshape(b, s, d)
    if cfg.post_norms:
        m = L.rmsnorm(p["mlp_post_norm"], m, cfg.norm_eps)
    return x + m


def lm_forward(cfg: LMConfig, params, tokens: jax.Array,
               chunked: bool | None = None) -> jax.Array:
    """tokens [B, S] -> logits [B, S, V] (f32)."""
    b, s = tokens.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    # online-softmax chunked attention whenever scores would dominate HBM
    chunked = (s >= 2048) if chunked is None else chunked
    x = constrain(L.embedding_lookup(params["embed"], tokens).astype(cdt),
                  "dp", None, None)
    if cfg.embedding_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cdt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    windows = layer_windows(cfg)

    def body(x, xs):
        p, w = xs
        x = _block_train(cfg, p, x, positions, w, chunked)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = lax.scan(body, x, (params["blocks"], windows))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdt))
    logits = constrain(logits, "dp", None, "tp")
    logits = L.softcap(logits, cfg.final_logit_softcap)
    return logits.astype(jnp.float32)


def lm_loss(cfg: LMConfig, params, batch) -> tuple[jax.Array, dict]:
    """Next-token cross entropy. batch: {"tokens": int32[B, S]}.

    Vocab-parallel-friendly: the target logit is extracted with a masked
    reduction (fuses; psum over vocab shards) instead of take_along_axis
    (which would gather across the sharded vocab dim), and the logsumexp
    reduces the sharded vocab axis directly -- no [B,S,V] log-softmax array
    is ever materialized (perf_log.md it-1)."""
    tokens = batch["tokens"]
    logits = lm_forward(cfg, params, tokens)[:, :-1]          # [B, S-1, V]
    targets = tokens[:, 1:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    onehot = (targets[..., None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, 1, v), 2))
    tgt_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss = (lse - tgt_logit).mean()
    return loss, {"loss": loss, "ppl": jnp.exp(loss)}


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array       # [L, B, S_max, KV, hd]
    v: jax.Array       # [L, B, S_max, KV, hd]
    length: jax.Array  # int32 scalar: tokens already cached


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> KVCache:
    cdt = jnp.dtype(cfg.compute_dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, cdt), v=jnp.zeros(shape, cdt),
                   length=jnp.int32(0))


def decode_step(cfg: LMConfig, params, cache: KVCache,
                token: jax.Array) -> tuple[KVCache, jax.Array]:
    """One-token decode. token: int32[B] -> (cache', logits f32[B, V]).

    Attention runs over the full cached prefix (masked beyond ``length``;
    local layers additionally masked to their sliding window)."""
    b = token.shape[0]
    cdt = jnp.dtype(cfg.compute_dtype)
    pos = cache.length                                      # scalar
    positions = jnp.full((b, 1), pos, jnp.int32)
    x = L.embedding_lookup(params["embed"], token[:, None]).astype(cdt)
    if cfg.embedding_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cdt)
    windows = layer_windows(cfg)
    s_max = cache.k.shape[2]
    kv_pos = jnp.broadcast_to(jnp.arange(s_max, dtype=jnp.int32), (b, s_max))

    def body(x, xs):
        p, w, ck, cv = xs
        h = L.rmsnorm(p["attn_norm"], x, cfg.norm_eps)
        q, k1, v1 = _qkv(cfg, p["attn"], h, positions)
        ck = lax.dynamic_update_slice(ck, k1.astype(ck.dtype), (0, pos, 0, 0))
        cv = lax.dynamic_update_slice(cv, v1.astype(cv.dtype), (0, pos, 0, 0))
        diff = pos - kv_pos                                  # [b, s_max]
        mask = ((kv_pos <= pos) & (diff < w))[:, None, :]    # [b, 1, s_max]
        attn = L.mha(q, ck, cv, mask, logit_cap=cfg.attn_logit_softcap)
        attn = jnp.einsum("bshe,hed->bsd",
                          attn.reshape(b, 1, cfg.n_heads, cfg.head_dim),
                          p["attn"]["wo"].reshape(cfg.n_heads, cfg.head_dim, -1))
        if cfg.post_norms:
            attn = L.rmsnorm(p["attn_post_norm"], attn, cfg.norm_eps)
        x = x + attn
        h = L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        if cfg.moe is None:
            m = L.gated_mlp(p["mlp"], h, cfg.activation)
        else:
            m = moe_apply(p["mlp"], h.reshape(b, -1), cfg.moe,
                          cfg.activation).reshape(b, 1, -1)
        if cfg.post_norms:
            m = L.rmsnorm(p["mlp_post_norm"], m, cfg.norm_eps)
        return x + m, (ck, cv)

    x, (nk, nv) = lax.scan(body, x, (params["blocks"], windows,
                                     cache.k, cache.v))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdt))
    logits = L.softcap(logits, cfg.final_logit_softcap)[:, 0]
    return KVCache(k=nk, v=nv, length=pos + 1), logits.astype(jnp.float32)


def prefill(cfg: LMConfig, params, tokens: jax.Array,
            max_len: int | None = None) -> tuple[KVCache, jax.Array]:
    """Prompt -> KV cache + last-position logits. tokens int32[B, S]."""
    b, s = tokens.shape
    max_len = max_len or s
    cdt = jnp.dtype(cfg.compute_dtype)
    x = L.embedding_lookup(params["embed"], tokens).astype(cdt)
    if cfg.embedding_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cdt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    windows = layer_windows(cfg)
    chunked = s >= 8192

    def body(x, xs):
        p, w = xs
        h = L.rmsnorm(p["attn_norm"], x, cfg.norm_eps)
        q, k1, v1 = _qkv(cfg, p["attn"], h, positions)
        if chunked:
            attn = L.chunked_mha(q, k1, v1, positions, positions, causal=True,
                                 window=w, logit_cap=cfg.attn_logit_softcap)
        else:
            diff = positions[:, :, None] - positions[:, None, :]
            mask = (diff >= 0) & (diff < w)
            attn = L.mha(q, k1, v1, mask, logit_cap=cfg.attn_logit_softcap)
        attn = jnp.einsum("bshe,hed->bsd",
                          attn.reshape(b, s, cfg.n_heads, cfg.head_dim),
                          p["attn"]["wo"].reshape(cfg.n_heads, cfg.head_dim, -1))
        if cfg.post_norms:
            attn = L.rmsnorm(p["attn_post_norm"], attn, cfg.norm_eps)
        x = x + attn
        h = L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        if cfg.moe is None:
            m = L.gated_mlp(p["mlp"], h, cfg.activation)
        else:
            m = moe_apply(p["mlp"], h.reshape(b * s, -1), cfg.moe,
                          cfg.activation).reshape(b, s, -1)
        if cfg.post_norms:
            m = L.rmsnorm(p["mlp_post_norm"], m, cfg.norm_eps)
        x = x + m
        kpad = jnp.zeros((b, max_len - s) + k1.shape[2:], k1.dtype)
        return x, (jnp.concatenate([k1, kpad], axis=1),
                   jnp.concatenate([v1.astype(k1.dtype), kpad], axis=1))

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (ck, cv) = lax.scan(body, x, (params["blocks"], windows))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], head.astype(cdt))
    logits = L.softcap(logits, cfg.final_logit_softcap)
    return (KVCache(k=ck, v=cv, length=jnp.int32(s)),
            logits.astype(jnp.float32))
