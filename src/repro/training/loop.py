"""Fault-tolerant training loop.

Production behaviors, all exercised by tests:
  * periodic sharded checkpoints (atomic COMMIT, checksum-verified),
  * automatic resume from the latest complete checkpoint -- including onto
    a different mesh (elastic restart),
  * per-step wall-time monitoring with a straggler detector (steps slower
    than ``straggler_factor`` x the running median are logged and counted;
    on a real slice this feeds the controller's replace-node policy),
  * optional gradient compression (int8 / topk with error feedback)
    between backward and optimizer,
  * failure injection hook for tests (raise mid-run, resume, bit-identical
    continuation modulo compression state).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Iterator, Optional

import jax

from repro.checkpoint import store
from repro.models.api import model_api
from repro.training.grad_compress import CompressorState, compress_grads, init_state
from repro.training.optimizer import make_optimizer


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "checkpoints"
    lr: float | None = None
    grad_compression: str = "none"        # none | int8 | topk
    topk_frac: float = 0.01
    straggler_factor: float = 3.0
    keep_last: int = 3


@dataclasses.dataclass
class LoopState:
    step: int
    params: Any
    opt_state: Any
    compressor: CompressorState
    metrics_history: list = dataclasses.field(default_factory=list)
    straggler_steps: list = dataclasses.field(default_factory=list)


def make_compressed_train_step(cfg, loop_cfg: LoopConfig):
    api = model_api(cfg)
    opt = make_optimizer(getattr(cfg, "optimizer", "adamw"), loop_cfg.lr)

    def step(params, opt_state, comp_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            api.loss, has_aux=True)(params, batch)
        if loop_cfg.grad_compression != "none":
            grads, comp_state, wire, dense = compress_grads(
                grads, comp_state, loop_cfg.grad_compression,
                loop_cfg.topk_frac)
            metrics = dict(metrics)
            metrics["wire_bytes"] = wire
            metrics["compression_ratio"] = dense / max(wire, 1)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, comp_state, metrics

    return jax.jit(step), opt


def train(cfg, data_iter: Iterator[dict], loop_cfg: LoopConfig,
          init_key=None, fail_at_step: Optional[int] = None,
          shardings: Any = None, verbose: bool = False) -> LoopState:
    """Run (or resume) training. ``fail_at_step`` raises RuntimeError right
    before that step's checkpoint would be cut (tests simulate preemption).
    """
    api = model_api(cfg)
    step_fn, opt = make_compressed_train_step(cfg, loop_cfg)

    # ---- resume or init --------------------------------------------------
    latest = store.latest_complete(loop_cfg.checkpoint_dir)
    if latest is not None:
        like = jax.eval_shape(api.init, jax.random.key(0))
        full_like = {"params": like, "opt": jax.eval_shape(opt.init, like)}
        full = store.load(latest, full_like, shardings)
        params, opt_state = full["params"], full["opt"]
        start = store.load_manifest(latest)["step"]
    else:
        params = api.init(init_key if init_key is not None
                          else jax.random.key(0))
        opt_state = opt.init(params)
        start = 0

    comp_state = init_state(params)
    st = LoopState(step=start, params=params, opt_state=opt_state,
                   compressor=comp_state)

    times: list[float] = []
    for step_idx in range(start, loop_cfg.total_steps):
        if fail_at_step is not None and step_idx == fail_at_step:
            raise RuntimeError(f"injected failure at step {step_idx}")
        batch = next(data_iter)
        t0 = time.perf_counter()
        st.params, st.opt_state, st.compressor, metrics = step_fn(
            st.params, st.opt_state, st.compressor, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        # straggler detection against the running median
        if len(times) >= 5:
            med = statistics.median(times[-20:])
            if dt > loop_cfg.straggler_factor * med:
                st.straggler_steps.append((step_idx, dt, med))
        times.append(dt)
        st.metrics_history.append(
            {k: float(v) for k, v in metrics.items()})
        st.step = step_idx + 1
        if verbose and step_idx % 10 == 0:
            print(f"step {step_idx}: loss={float(metrics['loss']):.4f} "
                  f"({dt*1000:.0f} ms)")
        if st.step % loop_cfg.checkpoint_every == 0 or \
                st.step == loop_cfg.total_steps:
            store.save(loop_cfg.checkpoint_dir, st.step,
                       {"params": st.params, "opt": st.opt_state},
                       extra={"loss": float(metrics["loss"])})
            _gc_checkpoints(loop_cfg)
    return st


def _gc_checkpoints(loop_cfg: LoopConfig) -> None:
    import pathlib
    import shutil
    d = pathlib.Path(loop_cfg.checkpoint_dir)
    steps = sorted(p for p in d.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and (p / "COMMIT").exists())
    for p in steps[:-loop_cfg.keep_last]:
        shutil.rmtree(p)
