"""Optimizers (from scratch -- no optax in this environment).

AdamW for the small/medium archs; Adafactor (factored second moments,
Shazeer & Stern 2018) for the trillion-parameter MoE dry-runs where Adam's
2x fp32 state would not fit 16GB/chip even fully sharded (see DESIGN.md).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)
    name: str


def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01) -> Optimizer:
    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                             params)
        return {"m": zeros,
                "v": jax.tree.map(jnp.copy, zeros),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        cf = c.astype(jnp.float32)

        def upd_one(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** cf)
            vhat = v / (1 - b2 ** cf)
            step = lr * (mhat / (jnp.sqrt(vhat) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return (p.astype(jnp.float32) - step).astype(p.dtype), m, v

        def upd(g, m, v, p):
            # chunk the f32 update math over the leading (layer/expert)
            # axis of big stacked params -- whole-stack temporaries cost
            # several x 8 GiB on the MoE configs (perf_log it-11)
            if p.ndim >= 3 and p.shape[0] > 1 and p.size > (1 << 24):
                return lax.map(lambda a: upd_one(*a), (g, m, v, p))
            return upd_one(g, m, v, p)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        outs = [upd(g, m, v, p)
                for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        return (tdef.unflatten([o[0] for o in outs]),
                {"m": tdef.unflatten([o[1] for o in outs]),
                 "v": tdef.unflatten([o[2] for o in outs]),
                 "count": c})

    return Optimizer(init=init, update=update, name="adamw")


def adafactor(lr: float = 1e-2, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second moments: for a [..., r, c] param keep row/col stats
    only -- O(r + c) state instead of O(r * c)."""

    def _factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2

    def init(params):
        def per_param(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"per_param": jax.tree.map(per_param, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        beta = 1.0 - (c.astype(jnp.float32)) ** (-decay)

        def upd_one(g, st, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * st["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * st["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., :, None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1)[..., None, None], eps))
                u = g * jax.lax.rsqrt(denom + eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_st = {"v": v}
            # update clipping (RMS(u) <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_st

        def upd(g, st, p):
            # big stacked (per-layer/per-expert) params: run the f32 update
            # math one leading slice at a time so its temporaries are
            # 1/L-sized (kimi: 10 GiB f32 temps -> 170 MiB; perf_log it-6)
            if p.ndim >= 3 and p.shape[0] > 1 and _factored(p):
                return lax.map(lambda args: upd_one(*args), (g, st, p))
            return upd_one(g, st, p)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["per_param"])
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_state = tdef.unflatten([o[1] for o in outs])
        return new_params, {"per_param": new_state, "count": c}

    return Optimizer(init=init, update=update, name="adafactor")


def make_optimizer(name: str, lr: float | None = None) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr or 1e-3)
    if name == "adafactor":
        return adafactor(lr=lr or 1e-2)
    raise ValueError(f"unknown optimizer {name!r}")
