"""Training loop, optimizer, gradient compression."""
