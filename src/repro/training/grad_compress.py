"""Gradient compression for DP all-reduce with error feedback.

At 1000+ nodes the cross-pod gradient all-reduce is the dominant collective
(DESIGN.md Section 4). Two standard compressors, both with error-feedback
residual accumulation (Seide et al. 2014 / Karimireddy et al. 2019) so
compression error does not bias convergence:

  int8    per-tensor symmetric int8 quantization (4x bytes reduction vs f32,
          2x vs bf16)
  topk    keep the largest-|g| fraction per tensor (sparsity), the rest is
          carried in the residual

compress(g) -> wire format, decompress restores dense; in training the
pair wraps the gradient between value_and_grad and the optimizer -- on a
real slice the wire format is what crosses the pod interconnect
(all-reduce of int8 partial sums / sparse gathers).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressorState(NamedTuple):
    residual: Any


def init_state(params: Any) -> CompressorState:
    return CompressorState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


# ---------------------------------------------------------------------------


def _int8_compress(g: jax.Array):
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _int8_decompress(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def _topk_compress(g: jax.Array, frac: float):
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def _topk_decompress(vals, idx, shape):
    flat = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), jnp.float32)
    return flat.at[idx].set(vals).reshape(shape)


# ---------------------------------------------------------------------------


def compress_grads(grads: Any, state: CompressorState, method: str = "int8",
                   topk_frac: float = 0.01):
    """Returns (decompressed_grads, new_state, wire_bytes, dense_bytes).

    The decompressed gradients are what the optimizer consumes (exactly
    what every replica would hold after the compressed all-reduce); the
    residual keeps what compression dropped (error feedback)."""
    dense_bytes = 0
    wire_bytes = 0
    new_resid = []
    out = []
    flat, tdef = jax.tree.flatten(grads)
    rflat = tdef.flatten_up_to(state.residual)
    for g, r in zip(flat, rflat):
        gf = g.astype(jnp.float32) + r
        dense_bytes += g.size * 4
        if method == "int8":
            q, scale = _int8_compress(gf)
            dec = _int8_decompress(q, scale)
            wire_bytes += q.size * 1 + 4
        elif method == "topk":
            vals, idx = _topk_compress(gf, topk_frac)
            dec = _topk_decompress(vals, idx, gf.shape)
            wire_bytes += vals.size * 4 + idx.size * 4
        elif method == "none":
            dec = gf
            wire_bytes += g.size * 4
        else:
            raise ValueError(method)
        new_resid.append(gf - dec)
        out.append(dec.astype(g.dtype))
    return (tdef.unflatten(out),
            CompressorState(residual=tdef.unflatten(new_resid)),
            wire_bytes, dense_bytes)
