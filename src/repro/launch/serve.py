"""Serving launcher: stand up the vector-search service on a dataset and
run a request workload against it (the production entry point; the
end-to-end example drives the same engine).

    PYTHONPATH=src python -m repro.launch.serve --n 8000 --requests 100
"""

from __future__ import annotations

import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--d", type=int, default=48)
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--heuristic", default="adaptive_local")
    args = ap.parse_args()

    from repro.core.navix import NavixConfig, NavixIndex
    from repro.data.synthetic import gaussian_mixture
    from repro.query.operators import Filter, NodeScan
    from repro.serving.engine import SearchEngine
    from repro.storage.columnar import GraphStore

    X, _, centers = gaussian_mixture(args.n, args.d, 16, seed=0)
    idx, stats = NavixIndex.create(X, NavixConfig(m_u=8, ef_construction=64))
    print(f"index: n={args.n} build={stats.seconds:.1f}s")

    store = GraphStore()
    store.add_node_table("Chunk", args.n, {"cID": np.arange(args.n)})
    engine = SearchEngine(index=idx, store=store,
                          heuristic=args.heuristic, efs=4 * args.k)

    rng = np.random.default_rng(1)
    for i in range(args.requests):
        q = (centers[rng.integers(0, 16)] +
             0.3 * rng.normal(size=args.d)).astype(np.float32)
        sigma = rng.choice([1.0, 0.5, 0.2, 0.05])
        plan = (None if sigma == 1.0 else
                Filter(NodeScan("Chunk"), "cID", "<",
                       value=int(args.n * sigma)))
        engine.submit(q, plan=plan, k=args.k)
    responses = engine.drain()
    print(f"served {len(responses)} requests")
    print("latency:", engine.latency_summary())


if __name__ == "__main__":
    main()
