"""Entry points: serve/train drivers, dryrun, roofline, reports."""
