"""Dry-run implementation (imported by dryrun.py AFTER XLA_FLAGS is set).

One cell = (architecture x input shape x mesh). For each cell we build the
step function the shape kind dictates, attach the sharding policy, then
``jit(...).lower(**abstract inputs).compile()`` -- success proves the
distribution config is coherent; the compiled artifact feeds the roofline.
"""

from __future__ import annotations

import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config.base import (ArchDef, GNNConfig, LMConfig, RecsysConfig,
                               ShapeSpec, get_arch, list_archs)
from repro.distributed import sharding as shd
from repro.launch import roofline as rl
from repro.models import api as mapi


def _named(tree, mesh):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p), tree,
        is_leaf=lambda x: isinstance(x, P))


def gnn_model_flops(cfg: GNNConfig, shape: ShapeSpec) -> float:
    n, e = mapi._gnn_block_sizes(shape)
    dh = cfg.d_hidden
    mlp2 = lambda din: din * dh + dh * dh  # 2-layer MLP MACs per row
    per_layer = e * mlp2(3 * dh) + n * mlp2(2 * dh)
    enc = n * mlp2(shape.get("d_feat", cfg.in_node_dim)) + e * mlp2(cfg.in_edge_dim)
    dec = n * mlp2(dh)
    macs = cfg.n_layers * per_layer + enc + dec
    return 6.0 * macs  # fwd+bwd ~= 3x fwd, 2 flops/MAC


def recsys_model_flops(cfg: RecsysConfig, shape: ShapeSpec) -> float:
    b = shape.get("batch", 1)
    dims = [cfg.n_sparse * cfg.embed_dim + cfg.n_dense] + list(cfg.mlp_dims) + [1]
    if cfg.model == "dien":
        dims[0] += cfg.gru_dim + cfg.embed_dim
        gru = cfg.seq_len * 2 * 3 * (cfg.embed_dim + cfg.gru_dim) * cfg.gru_dim
    else:
        gru = 0
    if cfg.model == "bst":
        dims[0] += (cfg.seq_len + 1) * cfg.embed_dim
    macs = sum(dims[i] * dims[i + 1] for i in range(len(dims) - 1)) + gru
    mult = 6.0 if shape.kind == "recsys_train" else 2.0
    flops = mult * b * macs
    if shape.kind == "recsys_retrieval":
        flops += 2.0 * b * shape["n_candidates"] * cfg.embed_dim
    return flops


def model_flops(cfg, shape: ShapeSpec) -> float:
    cfg = mapi.resolve_config(cfg, shape)
    if isinstance(cfg, LMConfig):
        return rl.lm_model_flops(cfg, shape)
    if isinstance(cfg, GNNConfig):
        return gnn_model_flops(cfg, shape)
    return recsys_model_flops(cfg, shape)


# ---------------------------------------------------------------------------


def build_cell(arch: ArchDef, shape: ShapeSpec, mesh):
    """Returns (jitted_fn, lower_args: tuple) ready for .lower()."""
    cfg = mapi.resolve_config(arch.config, shape)
    specs = mapi.input_specs(cfg, shape)
    params_spec = mapi.abstract_params(cfg)
    p_sh = _named(shd.param_specs(cfg, params_spec, mesh), mesh)
    b_spec_tree = shd.batch_specs(cfg, shape, specs, mesh)
    b_sh = _named(b_spec_tree, mesh)

    if shape.kind in ("train", "graph_full", "graph_minibatch",
                      "graph_batched", "recsys_train"):
        step, opt = mapi.make_train_step(cfg)
        opt_spec = jax.eval_shape(opt.init, params_spec)
        o_sh = _named(shd.opt_specs(shd.param_specs(cfg, params_spec, mesh),
                                    opt_spec), mesh)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh,
                                    _named(jax.tree.map(lambda _: P(),
                                                        {"loss": 0, "ppl": 0}
                                                        if isinstance(cfg, LMConfig)
                                                        else {"loss": 0}), mesh)),
                     # params/opt buffers are donated (updated in place) --
                     # without donation a full second copy of the params
                     # lives across the update (8 GiB/chip on kimi)
                     donate_argnums=(0, 1))
        return fn, (params_spec, opt_spec, specs)

    if shape.kind == "prefill":
        fn = jax.jit(mapi.make_prefill_step(cfg), in_shardings=(p_sh, b_sh["tokens"]))
        return fn, (params_spec, specs["tokens"])

    if shape.kind == "decode":
        fn = jax.jit(mapi.make_decode_step(cfg),
                     in_shardings=(p_sh, b_sh["cache"], b_sh["token"]),
                     out_shardings=(b_sh["cache"], None),
                     donate_argnums=(1,))   # KV cache updated in place
        return fn, (params_spec, specs["cache"], specs["token"])

    if shape.kind == "recsys_serve":
        fn = jax.jit(mapi.make_serve_step(cfg), in_shardings=(p_sh, b_sh))
        return fn, (params_spec, specs)

    if shape.kind == "recsys_retrieval":
        fn = jax.jit(mapi.make_retrieval_step(cfg), in_shardings=(p_sh, b_sh))
        return fn, (params_spec, specs)

    raise ValueError(shape.kind)


def run_cell(arch_id: str, shape_name: str, mesh, mesh_name: str) -> dict:
    arch = get_arch(arch_id)
    shape = arch.shape(shape_name)
    cell = f"{arch_id}/{shape_name}/{mesh_name}"
    if shape.skip_reason:
        return {"cell": cell, "status": "skip", "reason": shape.skip_reason}
    t0 = time.perf_counter()
    try:
        from repro.distributed.autoshard import activation_sharding
        with activation_sharding(mesh):
            fn, args = build_cell(arch, shape, mesh)
            lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        mem_d = {}
        if mem is not None:
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                mem_d[f] = int(getattr(mem, f, 0))
        chips = int(np.prod(list(mesh.shape.values())))
        roof = rl.analyze(cell, compiled, chips,
                          model_flops=model_flops(arch.config, shape))
        return {
            "cell": cell, "status": "ok",
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "memory_analysis": mem_d,
            "roofline": roof.to_dict(),
        }
    except Exception as e:  # noqa: BLE001 -- dry-run failures are findings
        return {"cell": cell, "status": "fail",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
                "elapsed_s": round(time.perf_counter() - t0, 2)}


def all_cells() -> list[tuple[str, str]]:
    out = []
    for aid in list_archs():
        for s in get_arch(aid).shapes:
            out.append((aid, s.name))
    return out
