"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --smoke --steps 200 --batch 8 --seq 128 [--compress int8]

On a real slice this process runs per-host under the cluster scheduler;
here it drives the fault-tolerant loop (checkpoint/resume, straggler
monitor, optional gradient compression) on whatever devices exist. Data is
the synthetic pipeline (token LM / graph / recsys batches by family).
"""

from __future__ import annotations

import argparse

import numpy as np


def data_iterator(cfg, batch: int, seq: int, seed: int = 0):
    import jax.numpy as jnp

    from repro.config.base import GNNConfig, LMConfig, RecsysConfig
    rng = np.random.default_rng(seed)
    if isinstance(cfg, LMConfig):
        # synthetic in-memory corpus with skewed unigram stats so the loss
        # has structure to learn
        probs = rng.dirichlet(np.full(cfg.vocab_size, 0.05))
        while True:
            yield {"tokens": jnp.asarray(
                rng.choice(cfg.vocab_size, p=probs, size=(batch, seq)),
                jnp.int32)}
    elif isinstance(cfg, GNNConfig):
        from repro.data.graph_sampler import NeighborSampler, random_mesh_graph
        csr, feats = random_mesh_graph(1024, cfg.in_node_dim, seed)
        targets = rng.normal(size=(feats.shape[0], cfg.out_dim)).astype(np.float32)
        sampler = NeighborSampler(csr, fanouts=(6, 4), seed=seed)
        while True:
            seeds = rng.integers(0, feats.shape[0], size=batch)
            b = sampler.block_batch(seeds, feats, targets,
                                    d_edge=cfg.in_edge_dim)
            yield {k: jnp.asarray(v) for k, v in b.items()}
    elif isinstance(cfg, RecsysConfig):
        hot = max(cfg.multi_hot_sizes) if cfg.multi_hot_sizes else 1
        while True:
            b = {"dense": jnp.asarray(rng.normal(size=(batch, cfg.n_dense)),
                                      jnp.float32),
                 "sparse": jnp.asarray(np.stack(
                     [rng.integers(0, cfg.field_vocabs[f], size=(batch, hot))
                      for f in range(cfg.n_sparse)], axis=1), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, 2, size=batch),
                                       jnp.float32)}
            if cfg.seq_len:
                b["seq"] = jnp.asarray(rng.integers(
                    0, cfg.item_vocab, size=(batch, cfg.seq_len)), jnp.int32)
                b["target_item"] = jnp.asarray(
                    rng.integers(0, cfg.item_vocab, size=batch), jnp.int32)
            yield b
    else:
        raise TypeError(type(cfg))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    from repro.config.base import get_arch
    from repro.training.loop import LoopConfig, train

    arch = get_arch(args.arch)
    cfg = arch.smoke_config if args.smoke else arch.config
    lc = LoopConfig(total_steps=args.steps, checkpoint_every=args.ckpt_every,
                    checkpoint_dir=args.ckpt_dir, lr=args.lr,
                    grad_compression=args.compress)
    st = train(cfg, data_iterator(cfg, args.batch, args.seq), lc,
               verbose=True)
    losses = [m["loss"] for m in st.metrics_history]
    print(f"done: {st.step} steps; loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"stragglers={len(st.straggler_steps)}")


if __name__ == "__main__":
    main()
