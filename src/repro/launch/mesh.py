"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state: the dry-run sets XLA_FLAGS before any jax import;
trainers build whatever mesh matches the actual slice.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e pod); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (tests / single-host runs)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))
