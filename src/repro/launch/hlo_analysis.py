"""Trip-count-aware cost analysis of post-SPMD optimized HLO.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop body
ONCE -- for scanned-layer models that underreports flops/bytes by the
layer count (verified: an 8-step scan reports exactly 1/8; see
EXPERIMENTS.md Methodology). This module re-derives per-chip costs from
``compiled.as_text()``:

  * parse computations + instructions (symbol table of result shapes),
  * build the call multigraph: while bodies carry
    backend_config known_trip_count, fusions/calls multiply by call sites,
  * flops  = sum over dot/convolution instructions of
             2 * |result| * contraction_size * multiplicity,
  * bytes  = sum of (operand + result) buffer bytes of materializing
             instructions * multiplicity (an HBM-traffic model: fusion
             internals don't materialize),
  * collective bytes per kind, with multiplicity.

Shapes in post-SPMD HLO are per-partition, so every figure is per-chip.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

#: ops whose results are elementwise-fusable glue; everything else is
#: treated as materializing a buffer for the HBM-traffic model
_NON_MATERIAL = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "after-all", "partition-id", "replica-id"}


def _shape_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    return [(d, [int(x) for x in dims.split(",")] if dims else [])
            for d, dims in _SHAPE_RE.findall(shape_str)]


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dtype, 0)
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: list[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    symbols: dict[str, str]        # instr name -> result shape str


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*{")
_NAME_EQ = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OP_CALL = re.compile(r"\s*([\w\-]+)\(")


def _matched_paren(s: str, start: int) -> int:
    """Index of the ')' matching s[start] == '('."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def _parse_instr(line: str) -> Optional[tuple[str, str, str, list[str]]]:
    """(name, shape, op, operands) -- tolerant of /*index=N*/ comments and
    nested tuple shapes."""
    m = _NAME_EQ.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):                       # tuple-shaped result
        close = _matched_paren(rest, 0)
        shape = rest[:close + 1]
        rest = rest[close + 1:]
    else:
        sm = re.match(r"([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)", rest)
        if not sm:
            return None
        shape = sm.group(1)
        rest = rest[sm.end():]
    om = _OP_CALL.match(rest)
    if not om:
        return None
    op = om.group(1)
    astart = om.end() - 1
    aend = _matched_paren(rest, astart)
    operands = re.findall(r"%([\w\.\-]+)", rest[astart + 1:aend])
    return name, shape, op, operands


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_instr(line)
        if parsed:
            name, shape, op, operands = parsed
            cur.instrs.append(Instr(name, shape, op, operands, line))
            cur.symbols[name] = shape
    return comps


def _trip_count(line: str) -> int:
    m = re.search(r'"known_trip_count":\s*{\s*"n":\s*"?(\d+)"?', line)
    return int(m.group(1)) if m else 1


def _called(line: str) -> list[tuple[str, int]]:
    """(computation, trip_count) pairs invoked by this instruction."""
    out = []
    m = re.search(r"body=%?([\w\.\-]+)", line)
    if m:
        out.append((m.group(1), _trip_count(line)))
    m = re.search(r"condition=%?([\w\.\-]+)", line)
    if m:
        out.append((m.group(1), _trip_count(line)))
    m = re.search(r"calls=%?([\w\.\-]+)", line)
    if m:
        out.append((m.group(1), 1))
    for m in re.finditer(r"to_apply=%?([\w\.\-]+)", line):
        out.append((m.group(1), 1))
    for m in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)%?([\w\.\-]+)", line):
        out.append((m.group(1), 1))
    return out


def multiplicities(comps: dict[str, Computation]) -> dict[str, float]:
    entry = None
    for name in comps:
        if name.startswith("main") or ".main" in name:
            entry = name
    if entry is None:  # fall back: computation not referenced by any other
        referenced = {c for comp in comps.values() for i in comp.instrs
                      for c, _ in _called(i.line)}
        roots = [n for n in comps if n not in referenced]
        entry = roots[-1] if roots else next(iter(comps))
    mult: dict[str, float] = {n: 0.0 for n in comps}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] += m
        for instr in comps[name].instrs:
            for callee, trips in _called(instr.line):
                visit(callee, m * trips)

    visit(entry, 1.0)
    return mult


def _dot_flops(instr: Instr, comp: Computation) -> float:
    result_elems = 0
    for _, dims in _shape_dims(instr.shape):
        n = 1
        for d in dims:
            n *= d
        result_elems += n
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    contract = 1
    if m and instr.operands:
        lhs_shape = comp.symbols.get(instr.operands[0])
        if lhs_shape:
            dims = _shape_dims(lhs_shape)[0][1]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    contract *= dims[idx]
    return 2.0 * result_elems * contract


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    coll_breakdown: dict = dataclasses.field(default_factory=dict)
    n_while: int = 0
    max_trip: int = 1

    def to_dict(self):
        return dataclasses.asdict(self)


def largest_buffers(text: str, top: int = 20) -> list[tuple[int, str, str]]:
    """(bytes, computation, instruction-line-prefix) for the biggest result
    buffers -- the memory-debugging view behind the hillclimb hypotheses."""
    comps = parse_hlo(text)
    mult = multiplicities(comps)
    out = []
    for comp in comps.values():
        if mult.get(comp.name, 0.0) == 0.0:
            continue
        for instr in comp.instrs:
            if instr.op in _NON_MATERIAL:
                continue
            b = _shape_bytes(instr.shape)
            if b > (1 << 20):
                out.append((b, comp.name, instr.line.strip()[:160]))
    out.sort(reverse=True)
    # dedupe identical shapes from the same computation family
    seen = set()
    uniq = []
    for b, c, l in out:
        key = (b, l.split("=")[1][:60] if "=" in l else l[:60])
        if key in seen:
            continue
        seen.add(key)
        uniq.append((b, c, l))
        if len(uniq) >= top:
            break
    return uniq


def analyze_text(text: str) -> HloCost:
    comps = parse_hlo(text)
    mult = multiplicities(comps)
    out = HloCost(coll_breakdown={k: 0.0 for k in COLLECTIVES})
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        for instr in comp.instrs:
            if instr.op == "while":
                out.n_while += 1
                out.max_trip = max(out.max_trip, _trip_count(instr.line))
            if instr.op in ("dot", "convolution"):
                out.flops += m * _dot_flops(instr, comp)
            kind = instr.op
            base = kind.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and not kind.endswith("-done"):
                b = _shape_bytes(instr.shape)
                out.collective_bytes += m * b
                out.coll_breakdown[base] += m * b
            if instr.op not in _NON_MATERIAL and not kind.endswith("-done"):
                rw = _shape_bytes(instr.shape)
                for op_name in instr.operands:
                    rw += _shape_bytes(comp.symbols.get(op_name, ""))
                out.bytes_accessed += m * rw
    return out
