"""Render EXPERIMENTS.md tables from the dry-run JSON records.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import pathlib


def fmt_bytes(b: float) -> str:
    return f"{b/2**30:.2f}"


def load(dirname: str):
    recs = []
    for f in sorted(glob.glob(f"{dirname}/*.json")):
        recs.append(json.loads(pathlib.Path(f).read_text()))
    return recs


def render(recs, mesh_filter: str) -> str:
    rows = []
    head = ("| cell | status | tC (s) | tM (s) | tN (s) | bottleneck | "
            "useful | roofline frac | mem/chip GiB | peak coll op |")
    sep = "|" + "---|" * 10
    rows.append(head)
    rows.append(sep)
    for r in recs:
        if mesh_filter not in r["cell"]:
            continue
        cell = r["cell"].replace(f"/{mesh_filter}", "")
        if r["status"] == "skip":
            rows.append(f"| {cell} | SKIP (documented) | | | | | | | | |")
            continue
        if r["status"] == "fail":
            rows.append(f"| {cell} | FAIL | | | | | | | | |")
            continue
        roof = r["roofline"]
        coll = roof.get("coll_breakdown", {})
        peak_op = max(coll, key=coll.get) if any(coll.values()) else "-"
        rows.append(
            f"| {cell} | ok | {roof['t_compute_s']:.4f} "
            f"| {roof['t_memory_s']:.4f} | {roof['t_collective_s']:.4f} "
            f"| {roof['bottleneck']} | {roof['useful_flops_fraction']:.3f} "
            f"| {roof['roofline_fraction']:.4f} "
            f"| {fmt_bytes(r['memory_analysis'].get('temp_size_in_bytes', 0))} "
            f"| {peak_op} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    for mesh in ("single_pod_16x16", "multi_pod_2x16x16"):
        n_ok = sum(1 for r in recs if mesh in r["cell"] and r["status"] == "ok")
        n_skip = sum(1 for r in recs if mesh in r["cell"]
                     and r["status"] == "skip")
        n_fail = sum(1 for r in recs if mesh in r["cell"]
                     and r["status"] == "fail")
        print(f"\n### {mesh}  (ok={n_ok} skip={n_skip} fail={n_fail})\n")
        print(render(recs, mesh))


if __name__ == "__main__":
    main()
