import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 --xla_disable_hlo_passes=while-loop-expensive-invariant-code-motion,while-loop-invariant-code-motion"

# The two lines above MUST run before any other import (jax locks the device
# count at first init). The placeholder device count builds the production
# mesh; the disabled passes hoist whole-stack bf16->f32 converts out of the
# layer scans, trading tens of GiB of HBM for negligible elementwise
# recompute -- the wrong trade at these sizes (perf_log it-8: kimi train
# 51.1 -> 32.4 GiB/chip). Everything below is ordinary.

"""Multi-pod dry-run driver.

For every (architecture x input shape) cell, lower + compile the cell's
step function on the production mesh -- 16x16 (single pod, 256 chips) and
2x16x16 (multi-pod, 512 chips) -- and record memory/cost/roofline analysis.
Failures (sharding mismatch, compile OOM, unsupported collective) are bugs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out experiments/dryrun
"""

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    import jax
    assert len(jax.devices()) == 512, (
        f"dry-run needs 512 placeholder devices, got {len(jax.devices())}")

    from repro.launch.dryrun_lib import all_cells, run_cell
    from repro.launch.mesh import make_production_mesh

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    if args.all:
        cells = all_cells()
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = 0
    for aid, sname in cells:
        for mname, mesh in meshes:
            path = outdir / f"{aid}__{sname}__{mname}.json"
            if path.exists() and not args.force:
                rec = json.loads(path.read_text())
                print(f"[cached] {rec['cell']}: {rec['status']}")
                if rec["status"] == "fail":
                    failures += 1
                continue
            t0 = time.perf_counter()
            rec = run_cell(aid, sname, mesh, mname)
            rec["wall_s"] = round(time.perf_counter() - t0, 2)
            path.write_text(json.dumps(rec, indent=2))
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f" bottleneck={r['bottleneck']}"
                         f" tC={r['t_compute_s']:.4f}s tM={r['t_memory_s']:.4f}s"
                         f" tN={r['t_collective_s']:.4f}s"
                         f" mem/chip={rec['memory_analysis'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
            elif status == "fail":
                failures += 1
                extra = " " + rec["error"][:200]
            print(f"[{status}] {aid}/{sname}/{mname}"
                  f" ({rec.get('wall_s', 0):.0f}s){extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
