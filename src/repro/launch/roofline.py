"""Three-term roofline analysis from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

``compiled.cost_analysis()`` reports per-partition (per-chip) flops/bytes
for an SPMD module, so HLO_FLOPs = flops x chips. Collective bytes are not
in cost_analysis: we parse the post-SPMD optimized HLO and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute; HLO shapes are per-partition, so the global
collective_bytes = per-chip sum x chips, making the roofline term equal to
per-chip collective bytes / link bandwidth (single-link convention per the
assignment).
"""

from __future__ import annotations

import dataclasses
import re

from repro.common.hardware import TARGET

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string like 'bf16[8,128]' or a tuple."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_per_chip(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective kind (per-partition bytes)."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result shape appears between '=' and the op name
        for kind in _COLLECTIVES:
            # match "= <shape> kind(" with optional -start/-done suffixes
            m = re.search(rf"=\s+(\(.*?\)|\S+)\s+{kind}(?:-start|-done)?\(",
                          ls)
            if m:
                if f"{kind}-done" in ls:
                    break  # counted at -start; -done repeats the shape
                out[kind] += _shape_bytes(m.group(1))
                break
    return out


@dataclasses.dataclass
class Roofline:
    name: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: dict[str, int]
    model_flops: float = 0.0          # 6*N*D (dense) / 6*N_active*D (MoE)
    peak_memory_per_chip: float = 0.0
    xla_cost: dict | None = None      # raw cost_analysis (see analyze())

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / TARGET.peak_bf16_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / TARGET.hbm_bandwidth

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / TARGET.ici_link_bandwidth

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: how much compiled compute is useful."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of compute roofline if the dominant term is
        perfectly overlapped: t_compute / max(all terms)."""
        return self.t_compute / self.bound_time if self.bound_time else 0.0

    def to_dict(self) -> dict:
        return {
            "xla_cost": self.xla_cost,
            "name": self.name, "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "peak_memory_per_chip": self.peak_memory_per_chip,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(name: str, compiled, chips: int, model_flops: float = 0.0
            ) -> Roofline:
    """Headline figures come from the trip-count-aware HLO analyzer
    (repro.launch.hlo_analysis); XLA's cost_analysis is attached as
    ``xla_cost_*`` for reference (it counts while bodies once -- see
    EXPERIMENTS.md Methodology)."""
    from repro.launch import hlo_analysis

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    hc = hlo_analysis.analyze_text(hlo)
    mem = compiled.memory_analysis()
    peak = 0.0
    if mem is not None:
        peak = float(getattr(mem, "temp_size_in_bytes", 0) +
                     getattr(mem, "argument_size_in_bytes", 0) +
                     getattr(mem, "output_size_in_bytes", 0) -
                     getattr(mem, "alias_size_in_bytes", 0))
    r = Roofline(name=name, chips=chips, flops_per_chip=hc.flops,
                 bytes_per_chip=hc.bytes_accessed,
                 coll_bytes_per_chip=hc.collective_bytes,
                 coll_breakdown={k: int(v) for k, v in
                                 hc.coll_breakdown.items()},
                 model_flops=model_flops, peak_memory_per_chip=peak)
    r.xla_cost = {"flops": float(cost.get("flops", 0.0)),
                  "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    return r


def lm_model_flops(cfg, shape) -> float:
    """6*N*D with N = active params; decode counts one token/step, plus
    attention KV dot cost which dominates decode."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape["seq_len"] * shape["global_batch"]
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape["seq_len"] * shape["global_batch"]
        # forward only
        return 2.0 * n_active * tokens
    if shape.kind == "decode":
        b, s = shape["global_batch"], shape["seq_len"]
        dense = 2.0 * n_active * b
        attn = (2.0 * 2.0 * cfg.n_layers * b * s
                * cfg.n_kv_heads * cfg.head_dim * cfg.q_per_kv)
        return dense + attn
    return 0.0
