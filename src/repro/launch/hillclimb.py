import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 --xla_disable_hlo_passes=while-loop-expensive-invariant-code-motion,while-loop-invariant-code-motion"

"""Hillclimb variant runner: lowers the optimized variants of the three
chosen cells next to their baselines and prints roofline deltas.

  gnn:       meshgraphnet/ogb_products  baseline (edge-parallel, replicated
             nodes, per-layer all-reduce) vs halo-partitioned owner-computes
  retrieval: wide-deep/retrieval_cand   baseline f32 scoring vs int8-stored
             candidate scoring (+ sharded top-k merge)

(kimi-k2/train_4k iterates through the standard dry-run driver -- its
optimizations are model/optimizer-level and benefit every LM cell.)

  PYTHONPATH=src python -m repro.launch.hillclimb --which gnn,retrieval
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402


def _report(name, compiled, chips, model_flops):
    from repro.launch import roofline as rl
    r = rl.analyze(name, compiled, chips, model_flops)
    mem = compiled.memory_analysis()
    print(f"{name:42s} tC={r.t_compute:8.4f} tM={r.t_memory:8.4f} "
          f"tN={r.t_collective:8.4f} useful={r.useful_flops_fraction:6.3f} "
          f"mem={mem.temp_size_in_bytes/2**30:7.2f}GiB "
          f"coll/chip={r.coll_bytes_per_chip/2**30:.2f}GiB", flush=True)
    return r


def run_gnn():
    import jax
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.config.base import get_arch
    from repro.distributed.autoshard import activation_sharding
    from repro.launch.dryrun_lib import build_cell, model_flops
    from repro.launch.mesh import make_production_mesh
    from repro.models import api as mapi
    from repro.models.gnn_partitioned import (partitioned_input_specs,
                                              partitioned_loss)
    from repro.training.optimizer import make_optimizer

    mesh = make_production_mesh(multi_pod=False)
    chips = 256
    arch = get_arch("meshgraphnet")
    shape = arch.shape("ogb_products")
    mf = model_flops(arch.config, shape)

    with activation_sharding(mesh):
        fn, args = build_cell(arch, shape, mesh)
        base = fn.lower(*args).compile()
    _report("gnn/ogb_products BASELINE", base, chips, mf)

    # --- halo-partitioned owner-computes variant -------------------------
    cfg = mapi.resolve_config(arch.config, shape)
    n_parts = chips
    specs = partitioned_input_specs(cfg, shape, n_parts, halo_per_pair=16)
    loss_fn = partitioned_loss(cfg, mesh)
    opt = make_optimizer(cfg.optimizer)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, metrics

    params_spec = mapi.abstract_params(cfg)
    opt_spec = jax.eval_shape(opt.init, params_spec)
    rep = lambda t: jax.tree.map(
        lambda x: NamedSharding(mesh, P(*([None] * x.ndim))), t)
    axes = tuple(mesh.axis_names)
    b_sh = {k: NamedSharding(mesh, P(axes, *([None] * (len(v.shape) - 1))))
            for k, v in specs.items()}
    fn2 = jax.jit(train_step,
                  in_shardings=(rep(params_spec), rep(opt_spec), b_sh),
                  donate_argnums=(0, 1))
    opt2 = fn2.lower(params_spec, opt_spec, specs).compile()
    _report("gnn/ogb_products HALO-PARTITIONED", opt2, chips, mf)


def run_retrieval():
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.config.base import get_arch
    from repro.distributed.autoshard import activation_sharding
    from repro.launch.dryrun_lib import build_cell, model_flops
    from repro.launch.mesh import make_production_mesh
    from repro.models import api as mapi

    mesh = make_production_mesh(multi_pod=False)
    chips = 256
    arch = get_arch("wide-deep")
    shape = arch.shape("retrieval_cand")
    mf = model_flops(arch.config, shape)

    with activation_sharding(mesh):
        fn, args = build_cell(arch, shape, mesh)
        base = fn.lower(*args).compile()
    _report("recsys/retrieval_cand BASELINE", base, chips, mf)

    # --- int8-stored candidates + local top-k merge ----------------------
    cfg = arch.config
    d = cfg.embed_dim
    n_cand = shape["n_candidates"]
    k = 100

    def retrieve_q(codes, scale, q, cand_ids):
        # scores = q . (codes * scale) computed from int8-resident rows
        x = codes.astype(jnp.bfloat16) * scale[:, None].astype(jnp.bfloat16)
        scores = jnp.einsum("bd,nd->bn", q.astype(jnp.bfloat16), x,
                            preferred_element_type=jnp.float32)
        vals, idx = jax.lax.top_k(scores, k)
        return vals, jnp.take(cand_ids, idx)

    sds = jax.ShapeDtypeStruct
    qspecs = (sds((n_cand, d), jnp.int8), sds((n_cand,), jnp.float32),
              sds((1, d), jnp.float32), sds((n_cand,), jnp.int32))
    sh = (NamedSharding(mesh, P("model", None)),
          NamedSharding(mesh, P("model")),
          NamedSharding(mesh, P(None, None)),
          NamedSharding(mesh, P("model")))
    fn3 = jax.jit(retrieve_q, in_shardings=sh)
    opt3 = fn3.lower(*qspecs).compile()
    _report("recsys/retrieval_cand INT8-STORED", opt3, chips,
            2.0 * n_cand * d)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--which", default="gnn,retrieval")
    args = ap.parse_args()
    for w in args.which.split(","):
        t0 = time.perf_counter()
        {"gnn": run_gnn, "retrieval": run_retrieval}[w]()
        print(f"[{w} done in {time.perf_counter()-t0:.0f}s]")


if __name__ == "__main__":
    main()
