"""Postfiltering baseline (paper Section 5.7: PGVectorScale / VBase style).

Postfiltering streams vectors from the *unfiltered* index nearest-first and
verifies each against the selection predicate until k survivors are found.
Costs decompose exactly as in the paper: vector-search cost (how far the
stream must run, driven by selectivity/correlation) + verification cost
(one membership check per streamed tuple).

The stream is realized by re-running the unfiltered search with doubling
``efs`` until k selected vectors appear among the results -- the way
Postgres-based systems re-execute the index scan with a larger limit.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import bitset
from repro.core.graph import HnswGraph
from repro.core.heuristics import Heuristic
from repro.core.search import SearchParams, search


class PostfilterStats(NamedTuple):
    restarts: int
    verifications: int     # streamed tuples checked against S
    t_dc: int              # distance computations across all restarts
    final_efs: int


def postfilter_search(graph: HnswGraph, q, sel_bits, k: int,
                      metric: str = "l2", efs0: int = 0,
                      max_efs: int = 4096):
    """Returns (dists[k], ids[k], PostfilterStats). -1 padded when fewer
    than k selected vectors are reachable within max_efs; the cap bounds
    the stream length (real postfiltering systems bail to brute force
    below ~5% selectivity for the same reason, paper 5.1.1)."""
    efs = efs0 or max(2 * k, 64)
    restarts = 0
    verifications = 0
    t_dc = 0
    best = None
    while True:
        params = SearchParams(k=efs, efs=efs, metric=metric,
                              heuristic=int(Heuristic.ONEHOP_A))
        res = search(graph, q, bitset.full_mask(graph.n), params)
        t_dc += int(res.stats.t_dc)
        ids = np.asarray(res.ids)
        dists = np.asarray(res.dists)
        ok = np.asarray(bitset.test(sel_bits, jnp.asarray(ids)))
        streamed = int((ids >= 0).sum())
        verifications += streamed
        sel_ids = ids[ok]
        sel_d = dists[ok]
        best = (sel_d[:k], sel_ids[:k])
        restarts += 1
        if len(sel_ids) >= k or efs >= max_efs:
            break
        efs = min(efs * 2, max_efs)
    out_d = np.full(k, np.inf, np.float32)
    out_i = np.full(k, -1, np.int64)
    out_d[: len(best[0])] = best[0]
    out_i[: len(best[1])] = best[1]
    return out_d, out_i, PostfilterStats(restarts, verifications, t_dc, efs)
