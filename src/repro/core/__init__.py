"""The paper's primary contribution: HNSW index + predicate-agnostic
prefiltered search with fixed and adaptive heuristics."""

from repro.core.navix import NavixIndex, NavixConfig, SearchParams  # noqa: F401
from repro.core.heuristics import Heuristic  # noqa: F401
