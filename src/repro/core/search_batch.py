"""Batched-frontier beam search: the native multi-query engine.

``jax.vmap(search)`` (kept in ``repro.core.search`` as the reference
oracle) is a correct throughput path but a wasteful one: vmap turns the
per-iteration ``lax.switch`` over the three expansion heuristics into a
select over the *branch union*, so every lane pays onehop + directed +
blind work every iteration -- exactly the per-predicate overhead the
paper's adaptive design avoids -- and the whole batch re-traces the
single-query program per lane.

This module is a dedicated engine that runs one ``lax.while_loop`` over a
``[B, efs]`` beam state:

* **per-query live mask** -- each lane carries the single-query
  convergence predicate; a converged lane's state is frozen and its
  candidate ids are masked to ``-1`` *before* the shared gathers, so it
  stops contributing distance computations (and dc accounting) while the
  rest of the batch finishes;
* **masked unified expansion** -- the three heuristics share one
  ``[B, M + K2]`` candidate layout: first-degree candidates are identical
  across branches (selected & unvisited, in neighbor order), so one
  shared ``[B, M]`` gather+distance serves onehop-s distances, blind
  distances, AND directed's ordering pass; branch differences reduce to
  cheap masks (which neighbors get marked visited, which parents seed the
  second hop, what the dc counters charge);
* **per-query adaptive-local branch selection** -- ``sigma_l`` and the
  paper's decision rule evaluate vectorized over lanes, so different
  lanes take different branches in the same iteration at no extra cost;
* **data-dependent second-hop skip** -- when no live lane picked a
  two-hop branch this iteration, a ``lax.cond`` skips the entire
  ``[B, M, M]`` second-degree stage (exclusive under jit, something the
  vmap path structurally cannot do).

Lane-for-lane, the state transition is identical to the single-query
``search``: the equivalence suite asserts exactly equal (ids, dists) and
stats. The distance primitive is ``gathered_dist_batch`` (see
``repro.kernels.gather_distance.gather_distance_batch_pallas`` for the
TPU kernel that streams the same [B] id lists through one pallas_call).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bitset
from repro.core.distances import gathered_dist_batch, point_dist
from repro.core.graph import HnswGraph
from repro.core.heuristics import Heuristic, adaptive_rule
from repro.core.search import (SearchParams, SearchResult, SearchStats,
                               _dedupe_keep_first, _take_first, search_batch)

# batched bitset primitives: visited is per-lane [B, W]; the semimask is
# shared across the batch (one selection subquery serves the whole group)
_test_vis = jax.vmap(bitset.test)                       # [B,W],[B,K] -> [B,K]
_test_sel = jax.vmap(bitset.test, in_axes=(None, 0))    # [W],  [B,K] -> [B,K]
_count_sel = jax.vmap(bitset.count_members, in_axes=(None, 0))
_set_bits = jax.vmap(bitset.set_bits)


class _BatchState(NamedTuple):
    d: jax.Array          # f32[B, efs]
    ids: jax.Array        # i32[B, efs]
    exp: jax.Array        # bool[B, efs]
    sel: jax.Array        # bool[B, efs]
    visited: jax.Array    # u32[B, W]
    it: jax.Array         # i32[B]
    t_dc: jax.Array       # i32[B]
    s_dc: jax.Array       # i32[B]
    picks: jax.Array      # i32[B, 3]


def _frontier_min(st: _BatchState):
    d_un = jnp.where((~st.exp) & (st.ids >= 0), st.d, jnp.inf)
    j = jnp.argmin(d_un, axis=1)
    return j, jnp.take_along_axis(d_un, j[:, None], axis=1)[:, 0]


def _r_max(st: _BatchState, efs: int):
    live = st.sel & (st.ids >= 0) & jnp.isfinite(st.d)
    n_sel = live.sum(axis=1)
    r = jnp.where(live, st.d, -jnp.inf).max(axis=1)
    return jnp.where(n_sel >= efs, r, jnp.inf)


def greedy_upper_batch(graph: HnswGraph, Q: jax.Array, metric: str):
    """Batched greedy walk on G_U with a per-lane improving mask.

    Returns (entry_ids[B], dc[B]); lane-for-lane identical to
    ``search.greedy_upper``.
    """
    upper, upper_ids, vectors = graph.upper, graph.upper_ids, graph.vectors
    bsz = Q.shape[0]
    b_idx = jnp.arange(bsz)

    def cond(c):
        return jnp.any(c[3])

    def body(c):
        pos, d, dc, act = c
        nbr_pos = upper[pos]                               # [B, M_U]
        valid = nbr_pos >= 0
        nbr_ids = jnp.where(valid, upper_ids[jnp.maximum(nbr_pos, 0)], -1)
        nd = gathered_dist_batch(Q, vectors,
                                 jnp.where(act[:, None], nbr_ids, -1), metric)
        jj = jnp.argmin(nd, axis=1)
        best = jnp.take_along_axis(nd, jj[:, None], axis=1)[:, 0]
        upd = act & (best < d)
        return (jnp.where(upd, nbr_pos[b_idx, jj], pos),
                jnp.where(upd, best, d),
                dc + jnp.where(act, valid.sum(axis=1), 0).astype(jnp.int32),
                upd)

    pos0 = jnp.broadcast_to(graph.entry_pos, (bsz,))
    d0 = point_dist(Q, vectors[upper_ids[pos0]], metric)
    init = (pos0, d0, jnp.ones((bsz,), jnp.int32), jnp.ones((bsz,), bool))
    pos, _, dc, _ = lax.while_loop(cond, body, init)
    return upper_ids[pos], dc


def beam_search_lower_batch(
    graph: HnswGraph,
    Q: jax.Array,
    sel_bits: jax.Array,
    seeds: jax.Array,
    params: SearchParams,
    sigma_g=None,
) -> tuple[jax.Array, jax.Array, SearchStats]:
    """Search G_L for B queries at once. Returns the full beams
    (dists[B, efs], ids[B, efs]) ascending, plus per-lane stats.

    ``seeds``: int32[B] entry node ids (one per lane).
    ``sel_bits``: one shared semimask (the group's selection subquery).
    """
    efs = params.efs
    metric = params.metric
    mode = int(params.heuristic)
    m_l = graph.m_l
    k2 = params.two_hop_cap or m_l
    max_iters = params.max_iters or graph.n
    bsz = Q.shape[0]
    b_idx = jnp.arange(bsz)

    vectors, lower = graph.vectors, graph.lower

    if mode == int(Heuristic.ONEHOP_A):
        sel_bits = bitset.full_mask(graph.n)
        mode = int(Heuristic.ONEHOP_S)

    if mode == int(Heuristic.ADAPTIVE_GLOBAL):
        if sigma_g is None:
            sigma_g = bitset.count(sel_bits) / graph.n
        global_branch = adaptive_rule(sigma_g, m_l, params.ub, params.lf)
    else:
        global_branch = jnp.int32(mode if mode <= 2 else 0)

    take_w2 = jax.vmap(lambda e, v: _take_first(e, v, 2 * k2))
    take_cap = jax.vmap(lambda e, v, bud: _take_first(e, v, k2, budget=bud))
    dedupe = jax.vmap(_dedupe_keep_first)

    # --- init beams with the per-lane seed ------------------------------
    seed_d = point_dist(Q, vectors[seeds], metric)
    pad_d = jnp.full((bsz, efs - 1), jnp.inf, seed_d.dtype)
    st = _BatchState(
        d=jnp.concatenate([seed_d[:, None], pad_d], axis=1),
        ids=jnp.concatenate(
            [seeds[:, None], jnp.full((bsz, efs - 1), -1, jnp.int32)], axis=1),
        exp=jnp.zeros((bsz, efs), bool),
        sel=jnp.concatenate(
            [bitset.test(sel_bits, seeds)[:, None],
             jnp.zeros((bsz, efs - 1), bool)], axis=1),
        visited=_set_bits(
            jnp.zeros((bsz, bitset.n_words(graph.n)), jnp.uint32),
            seeds[:, None]),
        it=jnp.zeros((bsz,), jnp.int32),
        t_dc=jnp.zeros((bsz,), jnp.int32),
        s_dc=jnp.zeros((bsz,), jnp.int32),
        picks=jnp.zeros((bsz, 3), jnp.int32),
    )

    def lane_cond(st: _BatchState):
        _, d_min = _frontier_min(st)
        keep = (d_min < jnp.inf) & (d_min <= _r_max(st, efs))
        return keep & (st.it < max_iters)

    def cond(st: _BatchState):
        return jnp.any(lane_cond(st))

    def body(st: _BatchState) -> _BatchState:
        live = lane_cond(st)                               # [B]
        j, _ = _frontier_min(st)
        c_min = st.ids[b_idx, j]
        # retired lanes contribute no candidates to the shared gathers
        nbrs = jnp.where(live[:, None],
                         lower[jnp.maximum(c_min, 0)], -1)  # [B, M_L]

        if mode == int(Heuristic.ADAPTIVE_LOCAL):
            deg = (nbrs >= 0).sum(axis=1)
            sigma_l = _count_sel(sel_bits, nbrs) / jnp.maximum(deg, 1)
            branch = adaptive_rule(sigma_l, m_l, params.ub, params.lf)
        else:
            branch = jnp.broadcast_to(global_branch, (bsz,))
        is_dir = branch == int(Heuristic.DIRECTED)

        # shared first-degree pass: one gather serves every branch
        visited_t = _test_vis(st.visited, nbrs)            # [B, M]
        new1 = (nbrs >= 0) & ~visited_t
        sel1 = _test_sel(sel_bits, nbrs) & ~visited_t      # == cand1 mask
        cand1 = jnp.where(sel1, nbrs, -1)
        d_all = gathered_dist_batch(Q, vectors, nbrs, metric)
        d1 = jnp.where(sel1, d_all, jnp.inf)
        n1 = sel1.sum(axis=1)
        # directed marks every neighbor it ordered; the others only the
        # selected candidates they actually inserted
        mark1 = jnp.where(is_dir[:, None], new1, sel1)
        visited1 = _set_bits(st.visited, jnp.where(mark1, nbrs, -1))

        # second-degree parents: distance-ordered for directed, scan order
        # for blind, none for onehop-s / retired lanes
        order1 = jnp.argsort(jnp.where(nbrs >= 0, d_all, jnp.inf), axis=1)
        parents = jnp.where(is_dir[:, None],
                            jnp.take_along_axis(nbrs, order1, axis=1), nbrs)
        two_hop = live & (branch != int(Heuristic.ONEHOP_S))
        parents = jnp.where(two_hop[:, None], parents, -1)
        budget = jnp.where(two_hop, jnp.maximum(k2 - n1, 0), 0)

        def do_second(args):
            visited1, parents, budget = args
            nb2 = lower[jnp.maximum(parents, 0)]           # [B, M, M]
            flat = jnp.where((parents >= 0)[:, :, None], nb2,
                             -1).reshape(bsz, -1)
            elig = ((flat >= 0) & _test_sel(sel_bits, flat)
                    & ~_test_vis(visited1, flat))
            cand = take_w2(elig, flat)                     # over-take ...
            cand = dedupe(cand)                            # ... dedupe ...
            cand2 = take_cap(cand >= 0, cand, budget)      # ... then cap
            d2 = gathered_dist_batch(Q, vectors, cand2, metric)
            return (cand2, d2, _set_bits(visited1, cand2),
                    (cand2 >= 0).sum(axis=1))

        def skip_second(args):
            visited1, _, _ = args
            return (jnp.full((bsz, k2), -1, jnp.int32),
                    jnp.full((bsz, k2), jnp.inf, jnp.float32),
                    visited1,
                    jnp.zeros((bsz,), jnp.int32))

        cand2, d2, visited2, n2 = lax.cond(
            jnp.any(two_hop), do_second, skip_second,
            (visited1, parents, budget))

        t_add = jnp.where(is_dir, new1.sum(axis=1) + n2, n1 + n2)
        s_add = n1 + n2

        # retire the expanded slot and merge candidates (per lane)
        exp = st.exp.at[b_idx, j].set(True)
        d = st.d.at[b_idx, j].set(
            jnp.where(st.sel[b_idx, j], st.d[b_idx, j], jnp.inf))

        cand_ids = jnp.concatenate([cand1, cand2], axis=1)
        cand_d = jnp.concatenate([d1, d2], axis=1)
        all_d = jnp.concatenate(
            [d, jnp.where(cand_ids >= 0, cand_d, jnp.inf)], axis=1)
        all_id = jnp.concatenate([st.ids, cand_ids], axis=1)
        all_exp = jnp.concatenate(
            [exp, jnp.zeros_like(cand_ids, dtype=bool)], axis=1)
        all_sel = jnp.concatenate([st.sel, cand_ids >= 0], axis=1)

        neg, order2 = lax.top_k(-all_d, efs)
        keep = live[:, None]
        return _BatchState(
            d=jnp.where(keep, -neg, st.d),
            ids=jnp.where(keep, jnp.take_along_axis(all_id, order2, axis=1),
                          st.ids),
            exp=jnp.where(keep, jnp.take_along_axis(all_exp, order2, axis=1),
                          st.exp),
            sel=jnp.where(keep, jnp.take_along_axis(all_sel, order2, axis=1),
                          st.sel),
            visited=jnp.where(keep, visited2, st.visited),
            it=st.it + live.astype(jnp.int32),
            t_dc=st.t_dc + jnp.where(live, t_add, 0).astype(jnp.int32),
            s_dc=st.s_dc + jnp.where(live, s_add, 0).astype(jnp.int32),
            picks=st.picks.at[b_idx, branch].add(live.astype(jnp.int32)),
        )

    st = lax.while_loop(cond, body, st)

    res_d = jnp.where(st.sel & (st.ids >= 0), st.d, jnp.inf)
    neg, order = lax.top_k(-res_d, efs)
    out_d = -neg
    out_id = jnp.where(jnp.isfinite(out_d),
                       jnp.take_along_axis(st.ids, order, axis=1), -1)
    stats = SearchStats(iters=st.it, t_dc=st.t_dc, s_dc=st.s_dc,
                        upper_dc=jnp.zeros((bsz,), jnp.int32),
                        picks=st.picks)
    return out_d, out_id, stats


@functools.partial(jax.jit, static_argnames=("params",))
def search_many(graph: HnswGraph, Q: jax.Array, sel_bits: jax.Array,
                params: SearchParams, sigma_g=None) -> SearchResult:
    """Full 2-level filtered search for a [B, d] query batch.

    Lane-for-lane equivalent to ``search.search`` per query (same ids,
    dists, and stats), at a fraction of the vmap path's per-iteration
    cost. The whole batch shares one semimask.
    """
    entry, upper_dc = greedy_upper_batch(graph, Q, params.metric)
    beam_d, beam_id, stats = beam_search_lower_batch(
        graph, Q, sel_bits, entry, params, sigma_g=sigma_g)
    k = params.k
    return SearchResult(
        dists=beam_d[:, :k],
        ids=beam_id[:, :k],
        # +1: the entry vector's own distance at the lower level
        stats=stats._replace(upper_dc=upper_dc.astype(jnp.int32) + 1),
    )


#: the multi-row execution engines (name -> raw jitted entry point);
#: the single registry behind NavixIndex.search_many, NavixDB.execute,
#: and ProgramCache.batch
BATCH_ENGINES = {"batched": search_many, "vmap": search_batch}


def resolve_engine(engine: str):
    """Validate an engine name and return its raw entry point."""
    try:
        return BATCH_ENGINES[engine]
    except KeyError:
        raise ValueError(f"unknown engine {engine!r}; valid: "
                         f"{tuple(BATCH_ENGINES)}") from None
