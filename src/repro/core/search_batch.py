"""Batched-frontier beam search: the native multi-query engine.

``jax.vmap(search)`` (kept in ``repro.core.search`` as the reference
oracle) is a correct throughput path but a wasteful one: vmap turns the
per-iteration ``lax.switch`` over the three expansion heuristics into a
select over the *branch union*, so every lane pays onehop + directed +
blind work every iteration -- exactly the per-predicate overhead the
paper's adaptive design avoids -- and the whole batch re-traces the
single-query program per lane.

This module is a dedicated engine that runs one ``lax.while_loop`` over a
``[B, efs]`` beam state:

* **per-query live mask** -- each lane carries the single-query
  convergence predicate; a converged lane's state is frozen and its
  candidate ids are masked to ``-1`` *before* the shared gathers, so it
  stops contributing distance computations (and dc accounting) while the
  rest of the batch finishes;
* **per-lane semimasks** -- ``sel_bits`` may be one shared packed bitset
  ``[W]`` or a per-lane ``[B, W]`` stack, so requests carrying *different*
  selection subqueries (each at its own selectivity) fuse into one device
  batch -- the paper's per-query ad-hoc S, batched. All selectivity
  machinery is lane-local: candidate masking, the sigma_l estimate, and
  (for adaptive-global) a per-lane ``sigma_g`` vector;
* **masked unified expansion** -- the three heuristics share one
  ``[B, M + K2]`` candidate layout: first-degree candidates are identical
  across branches (selected & unvisited, in neighbor order), so one
  shared ``[B, M]`` gather+distance serves onehop-s distances, blind
  distances, AND directed's ordering pass; branch differences reduce to
  cheap masks (which neighbors get marked visited, which parents seed the
  second hop, what the dc counters charge);
* **per-query adaptive-local branch selection** -- ``sigma_l`` and the
  paper's decision rule evaluate vectorized over lanes *against each
  lane's own S*, so different lanes take different branches in the same
  iteration at no extra cost;
* **data-dependent second-hop skip** -- when no live lane picked a
  two-hop branch this iteration, a ``lax.cond`` skips the entire
  ``[B, M, M]`` second-degree stage (exclusive under jit, something the
  vmap path structurally cannot do).

Lane-for-lane, the state transition is identical to the single-query
``search`` run with that lane's own semimask: the equivalence suite
asserts exactly equal (ids, dists) and stats.

The distance primitive is :func:`batch_gather_dist`, which routes through
``repro.kernels.ops.gather_distance_batch`` -- the batched Pallas
gather+distance kernel on TPU (interpret mode under
``REPRO_FORCE_PALLAS=1``), the XLA reference elsewhere. Set
``REPRO_ENGINE_GATHER=xla`` to pin the pure-jnp path; the choice is baked
at trace time, so set the env var before the first engine call.

The ``engine_*`` stepping API at the bottom decomposes the same loop into
resumable chunks (park / refill / step / finalize) for the serving tier's
continuous-batching scheduler: converged lanes are compacted out and
refilled from the request queue between device calls, LLM-serving style.
"""

from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bitset
from repro.core.distances import (gather_rows, gathered_dist_batch,
                                  point_dist)
from repro.core.graph import HnswGraph
from repro.core.heuristics import Heuristic, adaptive_rule
from repro.core.search import (SearchParams, SearchResult, SearchStats,
                               _dedupe_keep_first, search_batch)

class _BatchState(NamedTuple):
    d: jax.Array          # f32[B, efs]
    ids: jax.Array        # i32[B, efs]
    exp: jax.Array        # bool[B, efs]
    sel: jax.Array        # bool[B, efs]
    visited: jax.Array    # u32[B, W]
    it: jax.Array         # i32[B]
    t_dc: jax.Array       # i32[B]
    s_dc: jax.Array       # i32[B]
    picks: jax.Array      # i32[B, 3]


# ---------------------------------------------------------------------------
# distance primitive routing (ROADMAP: batched Pallas path in the engine)
# ---------------------------------------------------------------------------

GATHER_ENV = "REPRO_ENGINE_GATHER"
_GATHER_MODES = ("auto", "ops", "pallas", "xla")


def gather_backend() -> str:
    """The engine's gather+distance backend from ``REPRO_ENGINE_GATHER``:
    "auto"/"ops"/"pallas" route through ``repro.kernels.ops`` (Pallas on
    TPU, interpret-mode kernels under REPRO_FORCE_PALLAS=1, the XLA ref
    otherwise); "xla" pins the pure-jnp ``gathered_dist_batch``."""
    mode = os.environ.get(GATHER_ENV, "auto").lower()
    if mode not in _GATHER_MODES:
        raise ValueError(f"{GATHER_ENV}={mode!r}; valid: {_GATHER_MODES}")
    return mode


def batch_gather_dist(Q: jax.Array, vectors: jax.Array, ids: jax.Array,
                      metric: str) -> jax.Array:
    """The engine's distance primitive: dist(Q[b], vectors[ids[b]]).

    Routed through the kernels dispatch layer so the batched Pallas
    gather+distance kernel serves the engine when available; bitwise
    equal to :func:`repro.core.distances.gathered_dist_batch` on the
    fallback path. Backend choice is baked at trace time.

    ``vectors`` may be an int8-resident store (``QuantizedStore``,
    duck-typed on ``codes``): candidates then dequantize per gathered row
    (the quantized gather kernel on TPU, the jnp reference elsewhere) --
    bitwise what ``dequantize``-then-gather computes, with no ``[n, d]``
    f32 buffer live.
    """
    codes = getattr(vectors, "codes", None)
    if gather_backend() == "xla":
        return gathered_dist_batch(Q, vectors, ids, metric)
    from repro.kernels import ops
    if codes is not None:
        return ops.quantized_gather_distance_batch(Q, codes, vectors.scale,
                                                   ids, metric)
    return ops.gather_distance_batch(Q, vectors, ids, metric)


def _take_first_batch(elig: jax.Array, values: jax.Array, width: int,
                      budget=None) -> jax.Array:
    """Lane-wise first-k compaction: ([B, L], [B, L]) -> int32[B, width].

    Bitwise-identical output to ``vmap(search._take_first)`` (the first
    up-to-``budget`` eligible values per lane, in order, -1 padded) but
    scatter- and sort-free: the j-th taken element of a lane sits at the
    first position whose running take-count reaches j+1, found with a
    vmapped binary search over the cumsum -- both per-lane scatters and
    a [B, L] top_k serialize badly on XLA CPU and each dominated the
    engine's second-degree stage.
    """
    cum = jnp.cumsum(elig.astype(jnp.int32), axis=1)
    if budget is not None:
        limit = jnp.minimum(budget, width)[:, None]
    else:
        limit = width
    # running count of TAKEN elements == eligible count clipped at the
    # take limit (an eligible element past the limit is never taken)
    cum_t = jnp.minimum(cum, limit)
    targets = jnp.arange(1, width + 1)
    idx = jax.vmap(
        lambda c: jnp.searchsorted(c, targets, side="left"))(cum_t)
    out = jnp.take_along_axis(
        values, jnp.minimum(idx, values.shape[1] - 1), axis=1)
    return jnp.where(targets[None, :] <= cum_t[:, -1:], out, -1)


def _frontier_min(st: _BatchState):
    d_un = jnp.where((~st.exp) & (st.ids >= 0), st.d, jnp.inf)
    j = jnp.argmin(d_un, axis=1)
    return j, jnp.take_along_axis(d_un, j[:, None], axis=1)[:, 0]


def _r_max(st: _BatchState, efs):
    """Per-lane result-set radius; ``efs`` is the static int cap or a
    per-lane ``int32[B]`` vector (the ragged-efs path -- each lane's
    radius closes once ITS OWN efs slots are selected)."""
    live = st.sel & (st.ids >= 0) & jnp.isfinite(st.d)
    n_sel = live.sum(axis=1)
    r = jnp.where(live, st.d, -jnp.inf).max(axis=1)
    return jnp.where(n_sel >= efs, r, jnp.inf)


def greedy_upper_batch(graph: HnswGraph, Q: jax.Array, metric: str):
    """Batched greedy walk on G_U with a per-lane improving mask.

    Returns (entry_ids[B], dc[B]); lane-for-lane identical to
    ``search.greedy_upper``.
    """
    upper, upper_ids, vectors = graph.upper, graph.upper_ids, graph.vectors
    bsz = Q.shape[0]
    b_idx = jnp.arange(bsz)

    def cond(c):
        return jnp.any(c[3])

    def body(c):
        pos, d, dc, act = c
        nbr_pos = upper[pos]                               # [B, M_U]
        valid = nbr_pos >= 0
        nbr_ids = jnp.where(valid, upper_ids[jnp.maximum(nbr_pos, 0)], -1)
        nd = batch_gather_dist(Q, vectors,
                               jnp.where(act[:, None], nbr_ids, -1), metric)
        jj = jnp.argmin(nd, axis=1)
        best = jnp.take_along_axis(nd, jj[:, None], axis=1)[:, 0]
        upd = act & (best < d)
        return (jnp.where(upd, nbr_pos[b_idx, jj], pos),
                jnp.where(upd, best, d),
                dc + jnp.where(act, valid.sum(axis=1), 0).astype(jnp.int32),
                upd)

    pos0 = jnp.broadcast_to(graph.entry_pos, (bsz,))
    d0 = point_dist(Q, gather_rows(vectors, upper_ids[pos0]), metric)
    init = (pos0, d0, jnp.ones((bsz,), jnp.int32), jnp.ones((bsz,), bool))
    pos, _, dc, _ = lax.while_loop(cond, body, init)
    return upper_ids[pos], dc


# ---------------------------------------------------------------------------
# shared pieces of the lower-level loop (used by both the one-shot
# search_many path and the resumable engine_* stepping API, so the two
# stay in bitwise lockstep)
# ---------------------------------------------------------------------------


def _resolve_branching(sel2: jax.Array, params: SearchParams, sigma_g,
                       n: int, m_l: int, bsz: int):
    """Normalize (semimask, heuristic) to the loop's static/per-lane form.

    Returns ``(sel2, mode, global_branch[B])``: ONEHOP_A becomes ONEHOP_S
    over the full mask; ADAPTIVE_GLOBAL evaluates the paper's rule with a
    scalar or per-lane sigma_g (defaulting to each lane's own |S|/|V|).
    """
    mode = int(params.heuristic)
    if mode == int(Heuristic.ONEHOP_A):
        sel2 = jnp.broadcast_to(bitset.full_mask(n), sel2.shape)
        mode = int(Heuristic.ONEHOP_S)
    if mode == int(Heuristic.ADAPTIVE_GLOBAL):
        if sigma_g is None:
            sigma_g = bitset.count_batch(sel2) / n
        global_branch = adaptive_rule(sigma_g, m_l, params.ub, params.lf)
    else:
        global_branch = jnp.int32(mode if mode <= 2 else 0)
    return sel2, mode, jnp.broadcast_to(global_branch, (bsz,))


def _init_state(graph: HnswGraph, Q: jax.Array, sel2: jax.Array,
                seeds: jax.Array, params: SearchParams) -> _BatchState:
    """Fresh per-lane beams holding only each lane's seed entry point."""
    bsz, efs = Q.shape[0], params.efs
    seed_d = point_dist(Q, gather_rows(graph.vectors, seeds), params.metric)
    pad_d = jnp.full((bsz, efs - 1), jnp.inf, seed_d.dtype)
    return _BatchState(
        d=jnp.concatenate([seed_d[:, None], pad_d], axis=1),
        ids=jnp.concatenate(
            [seeds[:, None], jnp.full((bsz, efs - 1), -1, jnp.int32)], axis=1),
        exp=jnp.zeros((bsz, efs), bool),
        sel=jnp.concatenate(
            [bitset.test_batch(sel2, seeds[:, None])[:, 0:1],
             jnp.zeros((bsz, efs - 1), bool)], axis=1),
        visited=bitset.set_bits_batch(
            jnp.zeros((bsz, bitset.n_words(graph.n)), jnp.uint32),
            seeds[:, None]),
        it=jnp.zeros((bsz,), jnp.int32),
        t_dc=jnp.zeros((bsz,), jnp.int32),
        s_dc=jnp.zeros((bsz,), jnp.int32),
        picks=jnp.zeros((bsz, 3), jnp.int32),
    )


def _loop_fns(graph: HnswGraph, Q: jax.Array, sel2: jax.Array,
              params: SearchParams, mode: int, global_branch: jax.Array,
              efs_lanes=None):
    """Build the (lane_cond, body) closures of the batched lower-level
    loop. ``sel2`` is per-lane ``[B, W]``; ``mode`` is the static resolved
    heuristic; ``global_branch`` the per-lane fallback branch vector.

    ``efs_lanes`` (optional ``int32[B]``) makes the beam RAGGED: after
    every merge, slots at/past each lane's own efs are cleared (d=+inf,
    id=-1, sel=False, exp=True), so a lane admitted at a small efs is
    bit-identical to a lane whose beam was only ever that wide -- the
    convergence radius closes at the lane's own efs and the sorted-merge
    prefix property keeps its first ``efs_lanes[b]`` slots equal to the
    narrow beam's. Lanes at the full ``params.efs`` are untouched (the
    tail mask is empty for them), so a uniform-efs batch is bitwise
    unchanged."""
    efs = params.efs
    metric = params.metric
    m_l = graph.m_l
    k2 = params.two_hop_cap or m_l
    max_iters = params.max_iters or graph.n
    bsz = Q.shape[0]
    b_idx = jnp.arange(bsz)
    vectors, lower = graph.vectors, graph.lower

    dedupe = jax.vmap(_dedupe_keep_first)

    efs_eff = efs if efs_lanes is None else efs_lanes

    def lane_cond(st: _BatchState):
        _, d_min = _frontier_min(st)
        keep = (d_min < jnp.inf) & (d_min <= _r_max(st, efs_eff))
        return keep & (st.it < max_iters)

    def body(st: _BatchState) -> _BatchState:
        live = lane_cond(st)                               # [B]
        j, _ = _frontier_min(st)
        c_min = jnp.take_along_axis(st.ids, j[:, None], axis=1)[:, 0]
        # retired lanes contribute no candidates to the shared gathers
        nbrs = jnp.where(live[:, None],
                         lower[jnp.maximum(c_min, 0)], -1)  # [B, M_L]

        if mode == int(Heuristic.ADAPTIVE_LOCAL):
            deg = (nbrs >= 0).sum(axis=1)
            # each lane estimates sigma_l against its OWN selected set
            sigma_l = bitset.count_members_batch(sel2, nbrs) / \
                jnp.maximum(deg, 1)
            branch = adaptive_rule(sigma_l, m_l, params.ub, params.lf)
        else:
            branch = global_branch
        is_dir = branch == int(Heuristic.DIRECTED)

        # shared first-degree pass: one gather serves every branch
        visited_t = bitset.test_batch(st.visited, nbrs)            # [B, M]
        new1 = (nbrs >= 0) & ~visited_t
        sel1 = bitset.test_batch(sel2, nbrs) & ~visited_t  # == cand1 mask
        cand1 = jnp.where(sel1, nbrs, -1)
        d_all = batch_gather_dist(Q, vectors, nbrs, metric)
        d1 = jnp.where(sel1, d_all, jnp.inf)
        n1 = sel1.sum(axis=1)
        # directed marks every neighbor it ordered; the others only the
        # selected candidates they actually inserted
        mark1 = jnp.where(is_dir[:, None], new1, sel1)
        visited1 = bitset.set_bits_batch(st.visited, jnp.where(mark1, nbrs, -1))

        # second-degree parents: distance-ordered for directed, scan order
        # for blind, none for onehop-s / retired lanes
        order1 = jnp.argsort(jnp.where(nbrs >= 0, d_all, jnp.inf), axis=1)
        parents = jnp.where(is_dir[:, None],
                            jnp.take_along_axis(nbrs, order1, axis=1), nbrs)
        two_hop = live & (branch != int(Heuristic.ONEHOP_S))
        parents = jnp.where(two_hop[:, None], parents, -1)
        budget = jnp.where(two_hop, jnp.maximum(k2 - n1, 0), 0)

        def do_second(args):
            visited1, parents, budget = args
            nb2 = lower[jnp.maximum(parents, 0)]           # [B, M, M]
            flat = jnp.where((parents >= 0)[:, :, None], nb2,
                             -1).reshape(bsz, -1)
            elig = ((flat >= 0) & bitset.test_batch(sel2, flat)
                    & ~bitset.test_batch(visited1, flat))
            cand = _take_first_batch(elig, flat, 2 * k2)   # over-take ...
            cand = dedupe(cand)                            # ... dedupe ...
            cand2 = _take_first_batch(cand >= 0, cand, k2,
                                      budget=budget)       # ... then cap
            d2 = batch_gather_dist(Q, vectors, cand2, metric)
            return (cand2, d2, bitset.set_bits_batch(visited1, cand2),
                    (cand2 >= 0).sum(axis=1))

        def skip_second(args):
            visited1, _, _ = args
            return (jnp.full((bsz, k2), -1, jnp.int32),
                    jnp.full((bsz, k2), jnp.inf, jnp.float32),
                    visited1,
                    jnp.zeros((bsz,), jnp.int32))

        cand2, d2, visited2, n2 = lax.cond(
            jnp.any(two_hop), do_second, skip_second,
            (visited1, parents, budget))

        t_add = jnp.where(is_dir, new1.sum(axis=1) + n2, n1 + n2)
        s_add = n1 + n2

        # retire the expanded slot and merge candidates (per lane);
        # one-hot mask arithmetic instead of batched .at[] scatters --
        # XLA CPU serializes per-lane scatters, these are the hot path
        slot = jnp.arange(efs)[None, :] == j[:, None]      # [B, efs]
        exp = st.exp | slot
        sel_j = jnp.take_along_axis(st.sel, j[:, None], axis=1)
        d = jnp.where(slot & ~sel_j, jnp.inf, st.d)

        cand_ids = jnp.concatenate([cand1, cand2], axis=1)
        cand_d = jnp.concatenate([d1, d2], axis=1)
        all_d = jnp.concatenate(
            [d, jnp.where(cand_ids >= 0, cand_d, jnp.inf)], axis=1)
        all_id = jnp.concatenate([st.ids, cand_ids], axis=1)
        all_exp = jnp.concatenate(
            [exp, jnp.zeros_like(cand_ids, dtype=bool)], axis=1)
        all_sel = jnp.concatenate([st.sel, cand_ids >= 0], axis=1)

        # navilint: op-ok the single fused beam-merge top_k PR 3 kept
        neg, order2 = lax.top_k(-all_d, efs)
        new_d = -neg
        new_id = jnp.take_along_axis(all_id, order2, axis=1)
        new_exp = jnp.take_along_axis(all_exp, order2, axis=1)
        new_sel = jnp.take_along_axis(all_sel, order2, axis=1)
        if efs_lanes is not None:
            # ragged beam tail: the merge is sorted ascending, so its
            # first efs_lanes[b] slots equal the top-efs_lanes[b] merge
            # of an efs_lanes[b]-wide beam; clearing the tail keeps the
            # induction exact and stops small-efs lanes paying the
            # full-cap radius (their r_max closes at their own efs)
            tail = jnp.arange(efs)[None, :] >= efs_lanes[:, None]
            new_d = jnp.where(tail, jnp.inf, new_d)
            new_id = jnp.where(tail, -1, new_id)
            new_exp = new_exp | tail
            new_sel = new_sel & ~tail
        keep = live[:, None]
        return _BatchState(
            d=jnp.where(keep, new_d, st.d),
            ids=jnp.where(keep, new_id, st.ids),
            exp=jnp.where(keep, new_exp, st.exp),
            sel=jnp.where(keep, new_sel, st.sel),
            visited=jnp.where(keep, visited2, st.visited),
            it=st.it + live.astype(jnp.int32),
            t_dc=st.t_dc + jnp.where(live, t_add, 0).astype(jnp.int32),
            s_dc=st.s_dc + jnp.where(live, s_add, 0).astype(jnp.int32),
            picks=st.picks + ((jnp.arange(3)[None, :] == branch[:, None])
                              & live[:, None]).astype(jnp.int32),
        )

    return lane_cond, body


def _extract_results(st: _BatchState, efs: int):
    """Selected-slot top-k of the final beams: (dists[B, efs], ids[B, efs],
    per-lane stats with upper_dc left zero for the caller to fill)."""
    bsz = st.it.shape[0]
    res_d = jnp.where(st.sel & (st.ids >= 0), st.d, jnp.inf)
    # navilint: op-ok one top_k per search at extraction, not per step
    neg, order = lax.top_k(-res_d, efs)
    out_d = -neg
    out_id = jnp.where(jnp.isfinite(out_d),
                       jnp.take_along_axis(st.ids, order, axis=1), -1)
    stats = SearchStats(iters=st.it, t_dc=st.t_dc, s_dc=st.s_dc,
                        upper_dc=jnp.zeros((bsz,), jnp.int32),
                        picks=st.picks)
    return out_d, out_id, stats


def beam_search_lower_batch(
    graph: HnswGraph,
    Q: jax.Array,
    sel_bits: jax.Array,
    seeds: jax.Array,
    params: SearchParams,
    sigma_g=None,
    efs_lanes=None,
) -> tuple[jax.Array, jax.Array, SearchStats]:
    """Search G_L for B queries at once. Returns the full beams
    (dists[B, efs], ids[B, efs]) ascending, plus per-lane stats.

    ``seeds``: int32[B] entry node ids (one per lane).
    ``sel_bits``: one shared semimask ``[W]`` (the group's selection
    subquery) or a per-lane stack ``[B, W]`` (each lane its own S).
    ``sigma_g``: scalar or per-lane ``[B]`` (ADAPTIVE_GLOBAL only).
    ``efs_lanes``: optional per-lane ``int32[B]`` efs (ragged beams; each
    lane is bit-identical to a search at its own efs <= params.efs).
    """
    bsz = Q.shape[0]
    sel2 = bitset.broadcast_lanes(sel_bits, bsz)
    sel2, mode, global_branch = _resolve_branching(
        sel2, params, sigma_g, graph.n, graph.m_l, bsz)
    lane_cond, body = _loop_fns(graph, Q, sel2, params, mode, global_branch,
                                efs_lanes=efs_lanes)

    st = _init_state(graph, Q, sel2, seeds, params)
    st = lax.while_loop(lambda s: jnp.any(lane_cond(s)), body, st)
    return _extract_results(st, params.efs)


def search_lanes(graph: HnswGraph, Q: jax.Array, sel_bits: jax.Array,
                 params: SearchParams, sigma_g=None,
                 efs_lanes=None) -> SearchResult:
    """Unjitted body of :func:`search_many` -- the full 2-level filtered
    search for a [B, d] query batch. Exposed so callers embedding the
    engine in a larger traced program (``repro.core.distributed`` runs it
    per shard inside ``shard_map``) share one source of truth with the
    jitted entry point."""
    entry, upper_dc = greedy_upper_batch(graph, Q, params.metric)
    beam_d, beam_id, stats = beam_search_lower_batch(
        graph, Q, sel_bits, entry, params, sigma_g=sigma_g,
        efs_lanes=efs_lanes)
    k = params.k
    return SearchResult(
        dists=beam_d[:, :k],
        ids=beam_id[:, :k],
        # +1: the entry vector's own distance at the lower level
        stats=stats._replace(upper_dc=upper_dc.astype(jnp.int32) + 1),
    )


@functools.partial(jax.jit, static_argnames=("params",))
def search_many(graph: HnswGraph, Q: jax.Array, sel_bits: jax.Array,
                params: SearchParams, sigma_g=None,
                efs_lanes=None) -> SearchResult:
    """Full 2-level filtered search for a [B, d] query batch.

    Lane-for-lane equivalent to ``search.search`` per query with that
    lane's own semimask (same ids, dists, and stats), at a fraction of
    the vmap path's per-iteration cost. ``sel_bits`` is ``[W]`` (shared)
    or ``[B, W]`` (per-lane, the mixed-plan serving path); ``efs_lanes``
    (optional ``int32[B]``) runs each lane at its own efs.
    """
    return search_lanes(graph, Q, sel_bits, params, sigma_g=sigma_g,
                        efs_lanes=efs_lanes)


# ---------------------------------------------------------------------------
# resumable stepping API -- the continuous-batching scheduler's device side
# ---------------------------------------------------------------------------
# The serving tier holds a fixed [B, efs] beam state across device calls:
#   parked_state   -> all lanes empty (converged-by-construction)
#   engine_refill  -> reset a subset of lanes to fresh beams for new
#                     requests (their own query + their own semimask)
#   engine_steps   -> run at most n_steps loop iterations; returns the
#                     per-lane live mask so the host can spot convergence
#   engine_finalize-> extract per-lane (dists, ids, stats) at any point
# A lane stepped to convergence through any chunking of engine_steps calls
# passes through exactly the `search_many` state sequence (converged and
# parked lanes are frozen by the body's live mask), so per-lane results
# stay bitwise-identical to the single-query path.


def parked_state(n: int, bsz: int, params: SearchParams) -> _BatchState:
    """An all-parked batch state: every lane is empty and converged."""
    efs = params.efs
    return _BatchState(
        d=jnp.full((bsz, efs), jnp.inf, jnp.float32),
        ids=jnp.full((bsz, efs), -1, jnp.int32),
        exp=jnp.ones((bsz, efs), bool),
        sel=jnp.zeros((bsz, efs), bool),
        visited=jnp.zeros((bsz, bitset.n_words(n)), jnp.uint32),
        it=jnp.zeros((bsz,), jnp.int32),
        t_dc=jnp.zeros((bsz,), jnp.int32),
        s_dc=jnp.zeros((bsz,), jnp.int32),
        picks=jnp.zeros((bsz, 3), jnp.int32),
    )


def refill_lanes(graph: HnswGraph, Q: jax.Array, sel_bits: jax.Array,
                 st: _BatchState, upper_dc: jax.Array, refill: jax.Array,
                 params: SearchParams) -> tuple[_BatchState, jax.Array]:
    """Unjitted body of :func:`engine_refill` (shard_map-embeddable)."""
    bsz = Q.shape[0]
    sel2 = bitset.broadcast_lanes(sel_bits, bsz)
    sel2, _, _ = _resolve_branching(sel2, params, None, graph.n,
                                    graph.m_l, bsz)
    entry, dc = greedy_upper_batch(graph, Q, params.metric)
    fresh = _init_state(graph, Q, sel2, entry, params)

    def merge(new, old):
        sel_b = refill.reshape((bsz,) + (1,) * (new.ndim - 1))
        return jnp.where(sel_b, new, old)

    merged = jax.tree_util.tree_map(merge, fresh, st)
    return merged, jnp.where(refill, dc.astype(jnp.int32) + 1, upper_dc)


@functools.partial(jax.jit, static_argnames=("params",))
def engine_refill(graph: HnswGraph, Q: jax.Array, sel_bits: jax.Array,
                  st: _BatchState, upper_dc: jax.Array, refill: jax.Array,
                  params: SearchParams) -> tuple[_BatchState, jax.Array]:
    """Reset the lanes flagged in ``refill`` (bool[B]) to fresh beams.

    Refilled lanes run the greedy upper descent for their (new) query and
    start a fresh lower-level beam over their (new) per-lane semimask;
    all other lanes pass through bit-identically. Returns the merged
    state and the updated per-lane ``upper_dc`` accounting.
    """
    return refill_lanes(graph, Q, sel_bits, st, upper_dc, refill, params)


@functools.partial(jax.jit, static_argnames=("params",),
                   donate_argnums=(3, 4))
def engine_refill_overlap(graph: HnswGraph, Q: jax.Array,
                          sel_bits: jax.Array, st: _BatchState,
                          upper_dc: jax.Array, refill: jax.Array,
                          params: SearchParams
                          ) -> tuple[_BatchState, jax.Array]:
    """:func:`engine_refill` with ``st`` and ``upper_dc`` DONATED (the
    serving tier's overlapped path: refill dispatches in place and the
    next step chunk chains onto it without a host sync). The caller must
    replace its state references with the returned ones."""
    return refill_lanes(graph, Q, sel_bits, st, upper_dc, refill, params)


def step_lanes(graph: HnswGraph, Q: jax.Array, sel_bits: jax.Array,
               st: _BatchState, params: SearchParams, n_steps: int,
               sigma_g=None, efs_lanes=None) -> tuple[_BatchState, jax.Array]:
    """Unjitted body of :func:`engine_steps` (shard_map-embeddable)."""
    bsz = Q.shape[0]
    sel2 = bitset.broadcast_lanes(sel_bits, bsz)
    sel2, mode, global_branch = _resolve_branching(
        sel2, params, sigma_g, graph.n, graph.m_l, bsz)
    lane_cond, body = _loop_fns(graph, Q, sel2, params, mode, global_branch,
                                efs_lanes=efs_lanes)

    def cond(c):
        s, i = c
        keep = jnp.any(lane_cond(s))
        return keep & (i < n_steps) if n_steps else keep

    def chunk_body(c):
        s, i = c
        return body(s), i + 1

    st, _ = lax.while_loop(cond, chunk_body, (st, jnp.int32(0)))
    return st, lane_cond(st)


@functools.partial(jax.jit, static_argnames=("params", "n_steps"))
def engine_steps(graph: HnswGraph, Q: jax.Array, sel_bits: jax.Array,
                 st: _BatchState, params: SearchParams, n_steps: int,
                 sigma_g=None, efs_lanes=None) -> tuple[_BatchState, jax.Array]:
    """Advance the batch by at most ``n_steps`` loop iterations
    (``n_steps=0``: unbounded -- run to whole-batch convergence, the
    right call when the request queue is empty and there is nothing to
    refill between chunks).

    Returns ``(state, live[B])``; a lane with ``live == False`` has
    converged (or is parked) and is safe to finalize and refill.
    ``efs_lanes`` (optional ``int32[B]``) steps each lane at its own efs
    (see :func:`_loop_fns`); it must stay constant for a lane between
    refills.
    """
    return step_lanes(graph, Q, sel_bits, st, params, n_steps,
                      sigma_g=sigma_g, efs_lanes=efs_lanes)


@functools.partial(jax.jit, static_argnames=("params", "n_steps"),
                   donate_argnums=(3,))
def engine_steps_overlap(graph: HnswGraph, Q: jax.Array, sel_bits: jax.Array,
                         st: _BatchState, params: SearchParams, n_steps: int,
                         sigma_g=None, efs_lanes=None
                         ) -> tuple[_BatchState, jax.Array]:
    """:func:`engine_steps` with the state buffers DONATED: the input
    ``st`` is consumed (its buffers are reused for the output state), so
    the chunk dispatches without a copy and the host can keep working
    while it runs -- the serving tier's overlapped stepping path
    (:meth:`repro.serving.lanes.LaneBatch.step_async`). The caller must
    drop its reference to the input state: reading it after this call
    raises on a donated buffer."""
    return step_lanes(graph, Q, sel_bits, st, params, n_steps,
                      sigma_g=sigma_g, efs_lanes=efs_lanes)


def evict_lanes(st: _BatchState, upper_dc: jax.Array, evict: jax.Array
                ) -> tuple[_BatchState, jax.Array]:
    """Park the lanes flagged in ``evict`` (bool[B]): their beams become
    empty and converged (ids -1, sel False, d +inf), so they stop
    contributing work in ``engine_steps`` (their ``live`` predicate is
    False -- an un-evicted overdue lane would keep an ``n_steps=0`` call
    spinning forever), finalize to all ``-1`` ids, and are immediately
    refillable. The serving tier uses this for deadline eviction: it
    finalizes first (to salvage a partial beam), then parks the lane.

    Works on both state layouts: flat ``[B, ...]`` leaves and the
    shard-stacked ``[S, B, ...]`` leaves of :class:`ShardedNavix`
    (detected from ``st.it``'s rank -- the lane axis is the last leading
    axis), so one op serves ``engine_evict`` and the sharded
    ``evict_program`` without a ``shard_map`` round-trip: the merge is
    elementwise over lanes and preserves the state's sharding.
    """
    lead = st.it.ndim          # 1 = flat [B], 2 = shard-stacked [S, B]
    bsz = st.it.shape[-1]

    def merge(new, old):
        sel_b = evict.reshape((1,) * (lead - 1) + (bsz,)
                              + (1,) * (old.ndim - lead))
        return jnp.where(sel_b, new, old)

    parked = _BatchState(
        d=jnp.full_like(st.d, jnp.inf),
        ids=jnp.full_like(st.ids, -1),
        exp=jnp.ones_like(st.exp),
        sel=jnp.zeros_like(st.sel),
        visited=jnp.zeros_like(st.visited),
        it=jnp.zeros_like(st.it),
        t_dc=jnp.zeros_like(st.t_dc),
        s_dc=jnp.zeros_like(st.s_dc),
        picks=jnp.zeros_like(st.picks),
    )
    udc = merge(jnp.zeros_like(upper_dc), upper_dc)
    return jax.tree_util.tree_map(merge, parked, st), udc


@jax.jit
def engine_evict(st: _BatchState, upper_dc: jax.Array, evict: jax.Array
                 ) -> tuple[_BatchState, jax.Array]:
    """Jitted :func:`evict_lanes`: park the flagged lanes in place.

    No static arguments -- one compiled program per state shape serves
    every params/heuristic combination.
    """
    return evict_lanes(st, upper_dc, evict)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def engine_evict_overlap(st: _BatchState, upper_dc: jax.Array,
                         evict: jax.Array) -> tuple[_BatchState, jax.Array]:
    """:func:`engine_evict` with the state DONATED -- the serving tier's
    in-place eviction (parks lanes without copying the batch state; safe
    to dispatch while a donated step chunk is still in flight, the evict
    simply chains onto it). Shape-generic over flat ``[B, ...]`` and
    shard-stacked ``[S, B, ...]`` states like :func:`engine_evict`."""
    return evict_lanes(st, upper_dc, evict)


def finalize_lanes(st: _BatchState, upper_dc: jax.Array,
                   params: SearchParams) -> SearchResult:
    """Unjitted body of :func:`engine_finalize` (shard_map-embeddable)."""
    out_d, out_id, stats = _extract_results(st, params.efs)
    return SearchResult(
        dists=out_d, ids=out_id,
        stats=stats._replace(upper_dc=upper_dc.astype(jnp.int32)))


@functools.partial(jax.jit, static_argnames=("params",))
def engine_finalize(st: _BatchState, upper_dc: jax.Array,
                    params: SearchParams) -> SearchResult:
    """Extract per-lane results from a (possibly partially converged)
    batch state: full-efs beams, the host slices each lane to its own k."""
    return finalize_lanes(st, upper_dc, params)


#: the multi-row execution engines (name -> raw jitted entry point);
#: the single registry behind NavixIndex.search_many, NavixDB.execute,
#: and ProgramCache.batch
BATCH_ENGINES = {"batched": search_many, "vmap": search_batch}


def resolve_engine(engine: str):
    """Validate an engine name and return its raw entry point."""
    try:
        return BATCH_ENGINES[engine]
    except KeyError:
        raise ValueError(f"unknown engine {engine!r}; valid: "
                         f"{tuple(BATCH_ENGINES)}") from None
