"""HNSW construction (paper Algorithm 1 / Section 4.1).

NaviX builds a 2-level index: ``G_U`` over a ``sample_rate`` (5%) sample
with max degree ``M_U``, and ``G_L`` over all vectors with max degree
``M_L = 2 * M_U``. Kuzu builds with morsel-driven parallelism and tolerates
benign races between worker threads; the JAX adaptation is *batch-parallel
insertion*: each batch (morsel) of vectors searches a frozen snapshot of the
graph (vmapped), then all edge updates are merged functionally. Intra-batch
inserts do not see each other -- the same staleness the paper's data race
produces, with the same justification (HNSW is approximate; quality is
validated by recall tests).

Neighbor selection uses the relative-neighborhood (RNG) pruning rule of
Toussaint [43] exactly as in Algorithm 1: candidate ``c_j`` (in ascending
distance from ``v``) is kept iff it is closer to ``v`` than to every
previously kept candidate. The same rule shrinks overflowing adjacency
lists when backward edges are added.

Insertion order: upper-sample nodes are inserted into the lower level
first (phase A), so that upper-layer entry points always exist in the
lower level -- the batched equivalent of the paper inserting a node into
every level it belongs to at once.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import bitset
from repro.core.distances import dist_matrix, normalize, point_dist, validate_metric
from repro.core.graph import HnswGraph
from repro.core.heuristics import Heuristic
from repro.core.search import SearchParams, _take_first, beam_search_lower


class BuildParams(NamedTuple):
    m_u: int = 16                  # upper max degree; M_L = 2 * m_u
    ef_construction: int = 100
    sample_rate: float = 0.05
    metric: str = "l2"
    batch_size: int = 256          # morsel size (paper: 2048 rows / thread)
    new_edge_cap: int = 8          # max backward edges per target per batch
    seed: int = 0


@dataclasses.dataclass
class BuildStats:
    n: int = 0
    n_upper: int = 0
    seconds: float = 0.0
    search_dc: int = 0             # distance computations in insert searches
    batches: int = 0


# ---------------------------------------------------------------------------
# RNG (relative neighborhood) pruning -- Toussaint's rule, vectorized
# ---------------------------------------------------------------------------


def rng_prune_mask(cand_d: jax.Array, pd: jax.Array, valid: jax.Array,
                   m: int) -> jax.Array:
    """keep[j] per Algorithm 1's SelectNeighbors/RNGShrink.

    ``cand_d``: f32[C] distances candidate->v, ascending. ``pd``: f32[C, C]
    pairwise candidate distances. Keeps at most ``m``.
    """
    c = cand_d.shape[0]

    def body(i, keep):
        # min distance from candidate i to any already-kept candidate
        mind = jnp.where(keep, pd[i], jnp.inf).min()
        ok = valid[i] & (keep.sum() < m) & (cand_d[i] < mind)
        return keep.at[i].set(ok)

    return lax.fori_loop(0, c, body, jnp.zeros((c,), bool))


def _prune_forward(v: jax.Array, cand_ids: jax.Array, cand_d: jax.Array,
                   vectors: jax.Array, m: int, metric: str) -> jax.Array:
    """Select <=m forward neighbors from an ascending beam via RNG rule."""
    X = vectors[jnp.maximum(cand_ids, 0)]
    pd = dist_matrix(X, X, metric)
    keep = rng_prune_mask(cand_d, pd, cand_ids >= 0, m)
    return _take_first(keep, cand_ids, m)


# ---------------------------------------------------------------------------
# one level of construction
# ---------------------------------------------------------------------------


def _graph_view(adj, deg, vectors) -> HnswGraph:
    """Wrap one level's adjacency as an HnswGraph for beam_search_lower."""
    return HnswGraph(
        lower=adj, lower_deg=deg,
        upper=jnp.full((1, 1), -1, jnp.int32),
        upper_deg=jnp.zeros((1,), jnp.int32),
        upper_ids=jnp.zeros((1,), jnp.int32),
        entry_pos=jnp.int32(0),
        vectors=vectors,
    )


@functools.partial(jax.jit, static_argnames=("efc", "m_fwd", "m_cap", "p_cap",
                                             "metric"), donate_argnums=(0, 1))
def _insert_batch(adj, deg, vectors, batch_ids, seeds, efc, m_fwd, m_cap,
                  p_cap, metric):
    """Insert a batch of nodes into one level. Returns (adj, deg, dc).

    ``batch_ids`` may contain -1 padding lanes (batches are padded to a
    small set of fixed sizes so jit compiles only a couple of variants);
    padded lanes run a throwaway search and all their writes are dropped.
    """
    n = vectors.shape[0]
    bsz = batch_ids.shape[0]
    lane_ok = batch_ids >= 0
    safe_ids = jnp.maximum(batch_ids, 0)
    view = _graph_view(adj, deg, vectors)
    params = SearchParams(k=efc, efs=efc, heuristic=int(Heuristic.ONEHOP_A),
                          metric=metric)
    full = bitset.full_mask(n)

    def one(vid, seed):
        q = vectors[vid]
        beam_d, beam_id, stats = beam_search_lower(view, q, full, seed[None],
                                                   params)
        # the node being inserted may already appear (re-insert safety)
        beam_id = jnp.where(beam_id == vid, -1, beam_id)
        beam_d = jnp.where(beam_id >= 0, beam_d, jnp.inf)
        fwd = _prune_forward(q, beam_id, beam_d, vectors, m_fwd, metric)
        return fwd, stats.t_dc

    fwd, dcs = jax.vmap(one)(safe_ids, seeds)             # [B, m_fwd]
    fwd = jnp.where(lane_ok[:, None], fwd, -1)
    dcs = jnp.where(lane_ok, dcs, 0)

    # ---- forward edges --------------------------------------------------
    rows = jnp.full((bsz, adj.shape[1]), -1, jnp.int32).at[:, :m_fwd].set(fwd)
    adj = adj.at[jnp.where(lane_ok, batch_ids, n)].set(rows, mode="drop")
    deg = deg.at[jnp.where(lane_ok, batch_ids, n)].set(
        (rows >= 0).sum(axis=1), mode="drop")

    # ---- backward edges (append; RNG-shrink on overflow) ----------------
    tgt = fwd.reshape(-1)                                  # [B*m_fwd]
    src = jnp.repeat(safe_ids, m_fwd)
    valid = tgt >= 0
    big = jnp.int32(n + 1)
    order = jnp.argsort(jnp.where(valid, tgt, big))
    st, ss, sv = tgt[order], src[order], valid[order]
    prev = jnp.concatenate([big[None], st[:-1]])
    newseg = sv & (st != prev)
    seg_first = lax.cummax(jnp.where(newseg, jnp.arange(st.shape[0]), 0))
    rank = jnp.arange(st.shape[0]) - seg_first
    keep = sv & (rank < p_cap)

    u_max = tgt.shape[0]
    uniq = _take_first(newseg, st, u_max)                  # [U] target ids
    slot = jnp.cumsum(newseg) - 1
    news = jnp.full((u_max + 1, p_cap), -1, jnp.int32)
    news = news.at[jnp.where(keep, slot, u_max),
                   jnp.where(keep, rank, 0)].set(jnp.where(keep, ss, -1),
                                                 mode="drop")
    news = news[:u_max]

    def merge_one(t, new_srcs, row):
        cand = jnp.concatenate([row, new_srcs])            # [m_cap + P]
        d_t = jnp.where(cand >= 0,
                        point_dist(vectors[jnp.maximum(t, 0)],
                                   vectors[jnp.maximum(cand, 0)], metric),
                        jnp.inf)
        o = jnp.argsort(d_t)
        cand, d_t = cand[o], d_t[o]
        total = (cand >= 0).sum()
        X = vectors[jnp.maximum(cand, 0)]
        pd = dist_matrix(X, X, metric)
        keep_rng = rng_prune_mask(d_t, pd, cand >= 0, m_cap)
        keep_all = (cand >= 0) & (jnp.arange(cand.shape[0]) < m_cap)
        sel = jnp.where(total > m_cap, keep_rng, keep_all)
        return _take_first(sel, cand, m_cap)

    def chunked(carry, xs):
        t, new_srcs = xs
        row = carry[jnp.maximum(t, 0)]
        new_rows = jax.vmap(merge_one)(t, new_srcs, row)
        carry = carry.at[jnp.where(t >= 0, t, n)].set(new_rows, mode="drop")
        return carry, None

    n_chunks = max(1, u_max // 2048)
    usable = n_chunks * (u_max // n_chunks)
    adj, _ = lax.scan(chunked, adj,
                      (uniq[:usable].reshape(n_chunks, -1),
                       news[:usable].reshape(n_chunks, -1, p_cap)))
    if usable < u_max:
        adj, _ = chunked(adj, (uniq[usable:], news[usable:]))
    deg = (adj >= 0).sum(axis=1)
    return adj, deg, dcs.sum()


_BOOT = 32  # bootstrap pad size; steady-state batches pad to batch_size


def _batch_schedule(n_total: int, start: int, batch_size: int):
    """Doubling warm-up then fixed morsels, all padded to one of two sizes
    {_BOOT, batch_size} so ``_insert_batch`` compiles at most twice.
    Yields (lo, hi, padded_size)."""
    out, i, b = [], start, 1
    while i < n_total:
        step = min(b, batch_size, n_total - i)
        pad = _BOOT if step <= _BOOT else batch_size
        out.append((i, i + step, pad))
        i += step
        b *= 2
    return out


def _pad_batch(ids, pad: int):
    ids = np.asarray(ids, dtype=np.int32)
    if len(ids) < pad:
        ids = np.concatenate([ids, np.full(pad - len(ids), -1, np.int32)])
    return jnp.asarray(ids)


def _build_level(vectors, ids_in_order, m_fwd, m_cap, efc, p_cap, metric,
                 batch_size=256, entry_fn=None):
    """Build one proximity-graph level over ``vectors`` restricted to
    ``ids_in_order`` (insertion order). Returns (adj, deg, dc)."""
    n = vectors.shape[0]
    adj = jnp.full((n, m_cap), -1, jnp.int32)
    deg = jnp.zeros((n,), jnp.int32)
    total_dc = 0
    first = int(ids_in_order[0])
    for lo, hi, pad in _batch_schedule(len(ids_in_order), 1, batch_size):
        batch = _pad_batch(ids_in_order[lo:hi], pad)
        if entry_fn is None:
            seeds = jnp.full((pad,), first, jnp.int32)
        else:
            seeds = entry_fn(batch)
        adj, deg, dc = _insert_batch(adj, deg, vectors, batch, seeds,
                                     efc=efc, m_fwd=m_fwd, m_cap=m_cap,
                                     p_cap=p_cap, metric=metric)
        total_dc += int(dc)
    return adj, deg, total_dc


# ---------------------------------------------------------------------------
# the full 2-level build
# ---------------------------------------------------------------------------


def build(vectors: jax.Array, params: BuildParams) -> tuple[HnswGraph, BuildStats]:
    validate_metric(params.metric)
    t0 = time.perf_counter()
    vectors = jnp.asarray(vectors, dtype=jnp.float32)
    if params.metric == "cos":
        vectors = normalize(vectors)
    n, d = vectors.shape
    m_u = params.m_u
    m_l = 2 * m_u
    rng = np.random.default_rng(params.seed)

    n_upper = max(1, int(round(n * params.sample_rate)))
    upper_ids_np = np.sort(rng.choice(n, size=n_upper, replace=False))
    upper_ids = jnp.asarray(upper_ids_np, dtype=jnp.int32)

    stats = BuildStats(n=n, n_upper=n_upper)

    # ---- upper level over the sampled subset (positions 0..n_u-1) -------
    up_vectors = vectors[upper_ids]
    up_adj, up_deg, dc_u = _build_level(
        up_vectors, list(range(n_upper)), m_fwd=max(m_u // 2, 4), m_cap=m_u,
        efc=max(params.ef_construction // 2, 32), p_cap=params.new_edge_cap,
        metric=params.metric)
    stats.search_dc += dc_u

    # ---- lower level: phase A (upper nodes first), then the rest --------
    rest = np.setdiff1d(np.arange(n, dtype=np.int64), upper_ids_np)
    order = np.concatenate([upper_ids_np, rest])

    graph_upper = HnswGraph(
        lower=jnp.full((n, m_l), -1, jnp.int32),
        lower_deg=jnp.zeros((n,), jnp.int32),
        upper=up_adj, upper_deg=up_deg, upper_ids=upper_ids,
        entry_pos=jnp.int32(0), vectors=vectors)

    from repro.core.search import greedy_upper  # local import (cycle-free)

    @jax.jit
    def entries(batch_ids):
        def one(vid):
            e, _ = greedy_upper(graph_upper, vectors[jnp.maximum(vid, 0)],
                                params.metric)
            return e
        return jax.vmap(one)(batch_ids)

    lo_adj = jnp.full((n, m_l), -1, jnp.int32)
    lo_deg = jnp.zeros((n,), jnp.int32)
    total_dc = 0
    first = int(order[0])
    n_batches = 0
    for lo, hi, pad in _batch_schedule(len(order), 1, params.batch_size):
        batch = _pad_batch(order[lo:hi], pad)
        # phase A batches are seeded at the first node; phase B batches use
        # greedy upper-layer entries (all upper nodes are in G_L by then)
        if lo < n_upper:
            seeds = jnp.full((pad,), first, jnp.int32)
        else:
            seeds = entries(batch)
        lo_adj, lo_deg, dc = _insert_batch(
            lo_adj, lo_deg, vectors, batch, seeds,
            efc=params.ef_construction, m_fwd=m_u, m_cap=m_l,
            p_cap=params.new_edge_cap, metric=params.metric)
        total_dc += int(dc)
        n_batches += 1
    stats.search_dc += total_dc
    stats.batches = n_batches

    graph = HnswGraph(lower=lo_adj, lower_deg=lo_deg, upper=up_adj,
                      upper_deg=up_deg, upper_ids=upper_ids,
                      entry_pos=jnp.int32(0), vectors=vectors)
    stats.seconds = time.perf_counter() - t0
    return graph, stats
