"""Filtered-search heuristic space (paper Section 3, Table 1 / Figure 3).

Per candidate ``c_min`` popped from the beam, the search must decide

  1. explore all or only selected vectors        (onehop-a vs the rest)
  2. how much of the neighborhood to explore     (1 hop vs 2 hops)
  3. in which order to explore 2nd-degree hoods  (blind vs directed)

Fixed heuristics:
  ONEHOP_S  -- selected 1st-degree only              (best at high sigma)
  DIRECTED  -- 2 hops, parents ordered by dist(v_Q)  (best at medium sigma)
  BLIND     -- 2 hops, parents in scan order         (best at very low sigma)
  ONEHOP_A  -- unfiltered original HNSW (all 1st-degree); used for
               construction / unfiltered search / postfilter streaming.

Adaptive rule (both adaptive-global and adaptive-local, paper Section 3.2):

  sigma >= ub_onehop (0.5)                 -> ONEHOP_S
  esv = sigma*(M+1)*M >= M*lf  (lf = 3)    -> DIRECTED
  otherwise                                -> BLIND

adaptive-global evaluates the rule once with sigma_g = |S|/|V|;
adaptive-local evaluates it *per iteration* with the local selectivity
sigma_l = |S intersect nbrs(c_min)| / |nbrs(c_min)| (semimask bit tests only).
"""

from __future__ import annotations

import enum

import jax.numpy as jnp


class Heuristic(enum.IntEnum):
    # order matters: these index the lax.switch branch table
    ONEHOP_S = 0
    DIRECTED = 1
    BLIND = 2
    # meta-strategies (resolved to one of the above before/during search)
    ADAPTIVE_GLOBAL = 3
    ADAPTIVE_LOCAL = 4
    ONEHOP_A = 5

    @staticmethod
    def from_name(name: str) -> "Heuristic":
        return _BY_NAME[name.replace("-", "_").lower()]


_BY_NAME = {
    "onehop_s": Heuristic.ONEHOP_S,
    "onehop_a": Heuristic.ONEHOP_A,
    "directed": Heuristic.DIRECTED,
    "blind": Heuristic.BLIND,
    "adaptive_g": Heuristic.ADAPTIVE_GLOBAL,
    "adaptive_global": Heuristic.ADAPTIVE_GLOBAL,
    "adaptive_l": Heuristic.ADAPTIVE_LOCAL,
    "adaptive_local": Heuristic.ADAPTIVE_LOCAL,
    "navix": Heuristic.ADAPTIVE_LOCAL,
}

FIXED = (Heuristic.ONEHOP_S, Heuristic.DIRECTED, Heuristic.BLIND)

#: selectivity above which onehop-s is safe (paper: "50% is a safe choice")
UB_ONEHOP_S = 0.5
#: leniency factor for the directed-vs-blind boundary (paper default: 3)
LENIENCY_FACTOR = 3.0


def adaptive_rule(sigma, m: int, ub: float = UB_ONEHOP_S,
                  lf: float = LENIENCY_FACTOR):
    """The paper's decision rule -> int32 branch index (traceable).

    esv = sigma * (M+1) * M is the estimated number of selected vectors in
    the 1st+2nd degree neighborhood; directed only pays off when esv >= M*lf.
    """
    sigma = jnp.asarray(sigma, dtype=jnp.float32)
    esv = sigma * (m + 1) * m
    pick = jnp.where(
        sigma >= ub,
        jnp.int32(Heuristic.ONEHOP_S),
        jnp.where(esv >= m * lf, jnp.int32(Heuristic.DIRECTED),
                  jnp.int32(Heuristic.BLIND)),
    )
    return pick
