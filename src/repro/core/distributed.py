"""Distributed (sharded) NaviX search -- the paper's technique at scale.

Production layout (DESIGN.md Section 4): the vector set V is split into
S shards over the mesh's "model" axis; each shard builds its OWN HNSW
subgraph over its slice (shard-and-merge ANN). A filtered query runs
adaptive-local search on every shard in parallel (queries sharded over
"data", replicated over "model"), then per-shard top-k lists are merged
into the global top-k (one small all-gather over "model").

Straggler mitigation = quorum merge: searches carry an ``alive`` shard
mask; dead/slow shards contribute empty results and the merge proceeds
when >= quorum shards responded -- recall degrades gracefully instead of
latency collapsing (tested in tests/test_distributed_search.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import bitset
from repro.core.build import BuildParams, build
from repro.core.graph import HnswGraph
from repro.core.heuristics import Heuristic
from repro.core.navix import NavixConfig
from repro.core.search import SearchParams, beam_search_lower, greedy_upper

# jax >= 0.6 exposes top-level jax.shard_map (check_vma=); older releases
# ship it under jax.experimental.shard_map with the check_rep= spelling
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_REPL_KW = "check_vma"
else:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_REPL_KW = "check_rep"


def _stack_graphs(graphs: list[HnswGraph]) -> HnswGraph:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *graphs)


@dataclasses.dataclass
class ShardedNavix:
    mesh: Mesh
    graphs: HnswGraph          # every leaf has leading [S] shard dim
    n_local: int               # vectors per shard (padded)
    n_total: int
    config: NavixConfig
    model_axis: str = "model"
    data_axis: str = "data"

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape[self.model_axis])

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, vectors: np.ndarray, config: NavixConfig, mesh: Mesh,
              model_axis: str = "model", data_axis: str = "data"
              ) -> "ShardedNavix":
        n, d = vectors.shape
        s = int(mesh.shape[model_axis])
        n_local = -(-n // s)
        pad = s * n_local - n
        if pad:
            # pad with copies of the last row; padded ids are masked out of
            # every semimask so they can never be returned
            vectors = np.concatenate([vectors, np.repeat(vectors[-1:], pad, 0)])
        graphs = []
        for i in range(s):
            sl = vectors[i * n_local:(i + 1) * n_local]
            g, _ = build(jnp.asarray(sl), config.build_params())
            graphs.append(g)
        stacked = _stack_graphs(graphs)
        spec = jax.tree.map(lambda x: NamedSharding(
            mesh, P(model_axis, *([None] * (x.ndim - 1)))), stacked)
        stacked = jax.tree.map(jax.device_put, stacked, spec)
        return cls(mesh=mesh, graphs=stacked, n_local=n_local, n_total=n,
                   config=config, model_axis=model_axis, data_axis=data_axis)

    # ------------------------------------------------------------------
    def shard_semimask(self, mask: np.ndarray) -> jax.Array:
        """bool[n_total] -> packed u32[S, W_local] (padded rows excluded)."""
        s, nl = self.n_shards, self.n_local
        m = np.zeros(s * nl, dtype=bool)
        m[: self.n_total] = np.asarray(mask, dtype=bool)
        packed = bitset.pack(jnp.asarray(m.reshape(s, nl)))
        return jax.device_put(packed, NamedSharding(
            self.mesh, P(self.model_axis, None)))

    # ------------------------------------------------------------------
    def search_fn(self, k: int, efs: int, heuristic: str = "adaptive_local"):
        """Returns a jitted (Q, sel_bits, alive) -> (dists, ids) function.

        Q: f32[B, d] (B divisible by the data axis); sel_bits: u32[S, W];
        alive: bool[S] shard liveness (all True = no stragglers).
        Output ids are GLOBAL vector ids; quorum merges survivors only.
        """
        mesh = self.mesh
        params = SearchParams(k=k, efs=max(efs, k), metric=self.config.metric,
                              heuristic=int(Heuristic.from_name(heuristic)))
        n_local = self.n_local
        model_axis, data_axis = self.model_axis, self.data_axis
        graphs = self.graphs

        def local_search(graph_leaves, q_local, sel, alive):
            graph = jax.tree.unflatten(
                jax.tree.structure(graphs), graph_leaves)
            graph = jax.tree.map(lambda x: x[0], graph)      # drop shard dim
            sel = sel[0]
            sidx = jax.lax.axis_index(model_axis)
            my_alive = alive[sidx]

            def one(q):
                entry, _ = greedy_upper(graph, q, params.metric)
                d, ids, _ = beam_search_lower(graph, q, sel, entry[None],
                                              params)
                return d[:k], ids[:k]

            d, ids = jax.vmap(one)(q_local)                  # [b, k]
            gids = jnp.where(ids >= 0, ids + sidx * n_local, -1)
            d = jnp.where(my_alive, d, jnp.inf)
            gids = jnp.where(my_alive, gids, -1)
            return d[None], gids[None]                       # [1, b, k]

        graph_specs = jax.tree.map(
            lambda x: P(model_axis, *([None] * (x.ndim - 1))), graphs)

        @jax.jit
        def run(Q, sel_bits, alive):
            leaves = jax.tree.leaves(graphs)
            leaf_specs = jax.tree.leaves(graph_specs,
                                         is_leaf=lambda x: isinstance(x, P))
            d, ids = _shard_map(
                functools.partial(local_search),
                mesh=mesh,
                in_specs=(tuple(leaf_specs), P(data_axis, None),
                          P(model_axis, None), P()),
                out_specs=(P(model_axis, data_axis, None),
                           P(model_axis, data_axis, None)),
                # while-loop beam search inside
                **{_CHECK_REPL_KW: False},
            )(tuple(leaves), Q, sel_bits, alive)
            # merge: [S, B, k] -> global top-k per query
            s, b, _ = d.shape
            d = d.transpose(1, 0, 2).reshape(b, s * k)
            ids = ids.transpose(1, 0, 2).reshape(b, s * k)
            neg, order = jax.lax.top_k(-d, k)
            out_d = -neg
            out_i = jnp.take_along_axis(ids, order, axis=1)
            return out_d, jnp.where(jnp.isfinite(out_d), out_i, -1)

        return run

    def search(self, Q, semimask: np.ndarray, k: int = 100, efs: int = 0,
               heuristic: str = "adaptive_local",
               alive: Optional[np.ndarray] = None, quorum: int = 0):
        """Convenience wrapper; raises if fewer than ``quorum`` shards are
        alive (the serving tier's retry/deadline policy decides quorum)."""
        alive = (np.ones(self.n_shards, bool) if alive is None
                 else np.asarray(alive, bool))
        if quorum and alive.sum() < quorum:
            raise RuntimeError(
                f"quorum not met: {int(alive.sum())}/{self.n_shards} alive, "
                f"need {quorum}")
        fn = self.search_fn(k=k, efs=efs or 2 * k, heuristic=heuristic)
        sel = self.shard_semimask(semimask)
        return fn(jnp.asarray(Q, jnp.float32), sel, jnp.asarray(alive))
